"""L2 structural tests: every registered export traces, its output shapes
are consistent with the family contract, and gradient executables return
cotangents of the right sizes.  These run at build time (no PJRT
execution needed — `jax.eval_shape` only)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import families as F
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def registry():
    exports, models = M.build()
    return {e.name: e for e in exports}, models


def test_every_export_traces(registry):
    exports, _ = registry
    assert len(exports) >= 60
    for name, e in exports.items():
        out = jax.eval_shape(e.fn, *e.args)
        if not isinstance(out, (tuple, list)):
            out = (out,)
        assert all(a.dtype == jnp.float32 for a in out), name


@pytest.mark.parametrize("fam", ["toy", "img16", "img32", "latent", "cde",
                                 "cnf_mnist8", "cnf_cifar8", "cnf_density2d"])
def test_family_contract(registry, fam):
    """{f, f_vjp, step, inv, step_vjp} exist with consistent shapes."""
    exports, _ = registry
    f = exports[f"{fam}.f"]
    state = f.args[1].shape
    # f: (t, z, *ctx, θ) → dz with dz.shape == z.shape
    out = jax.eval_shape(f.fn, *f.args)
    assert out[0].shape == state

    step = exports[f"{fam}.step"]
    zo, vo, err = jax.eval_shape(step.fn, *step.args)
    assert zo.shape == state and vo.shape == state and err.shape == state

    inv = exports[f"{fam}.inv"]
    zi, vi = jax.eval_shape(inv.fn, *inv.args)
    assert zi.shape == state and vi.shape == state

    vjp = exports[f"{fam}.step_vjp"]
    az, av, ath = jax.eval_shape(vjp.fn, *vjp.args)
    theta_len = f.args[-1].shape
    assert az.shape == state and av.shape == state
    assert ath.shape == theta_len

    fv = exports[f"{fam}.f_vjp"]
    az2, ath2 = jax.eval_shape(fv.fn, *fv.args)
    assert az2.shape == state and ath2.shape == theta_len


def test_component_lengths_match_entries(registry):
    """The manifest models' component lengths line up with the θ inputs of
    the corresponding executables — the contract the Rust side trusts."""
    exports, models = registry
    for fam in ["toy", "img16", "img32", "latent", "cde"]:
        f = exports[f"{fam}.f"]
        theta_len = int(np.prod(f.args[-1].shape))
        assert models[fam]["components"]["f"]["len"] == theta_len, fam


def test_step_vjp_matches_autodiff_of_step():
    """For one family, the exported ψ-vjp equals jax.vjp of the exported ψ
    on concrete values (the two are built from the same f_ref, but this
    guards the hand-assembled plumbing in family_exports)."""
    exports, _ = M.build()
    by_name = {e.name: e for e in exports}
    step = by_name["toy.step"].fn
    vjp = by_name["toy.step_vjp"].fn

    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.standard_normal((1, 4)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 4)), jnp.float32)
    th = jnp.asarray([0.7], jnp.float32)
    t, h, eta = jnp.float32(0.1), jnp.float32(0.3), jnp.float32(0.9)
    azo = jnp.asarray(rng.standard_normal((1, 4)), jnp.float32)
    avo = jnp.asarray(rng.standard_normal((1, 4)), jnp.float32)

    az, av, ath = vjp(z, v, t, h, eta, th, azo, avo)

    def fwd(zz, vv, tt):
        zo, vo, _ = step(zz, vv, t, h, eta, tt)
        return zo, vo

    _, pull = jax.vjp(fwd, z, v, th)
    az_r, av_r, ath_r = pull((azo, avo))
    np.testing.assert_allclose(az, az_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(av, av_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(ath, ath_r, rtol=1e-5, atol=1e-6)


def test_cnf_state_layout():
    """CNF families augment the state with [Δlogp, ke, je]."""
    exports, models = M.build()
    by_name = {e.name: e for e in exports}
    for key in ["cnf_mnist8", "cnf_cifar8", "cnf_density2d"]:
        dim = models[key]["dim"]
        f = by_name[f"{key}.f"]
        assert f.args[1].shape[1] == dim + 3, key
        # ctx (the Hutchinson probe) is batch × dim
        assert f.args[2].shape == (models[key]["batch"], dim), key
