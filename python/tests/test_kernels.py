"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, swept over
shapes and seeds with hypothesis.  This is the core correctness signal for
the kernel layer — the exported vjp graphs differentiate the oracle, so
kernel == oracle makes gradient and forward paths consistent.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import alf_step as K
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


def _weights(rng, d, h):
    s = 1.0 / np.sqrt(max(d, h))
    return (
        _rand(rng, d, h) * s,
        _rand(rng, h) * 0.1,
        _rand(rng, h, d) * s,
        _rand(rng, d) * 0.1,
    )


shapes = st.tuples(
    st.integers(min_value=1, max_value=96),  # batch (crosses the BM=64 tile)
    st.integers(min_value=1, max_value=48),  # state dim
    st.integers(min_value=1, max_value=64),  # hidden dim
)


@settings(max_examples=25, deadline=None)
@given(shapes=shapes, seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_mlp_f_matches_ref(shapes, seed):
    b, d, h = shapes
    rng = np.random.default_rng(seed)
    z = _rand(rng, b, d)
    w = _weights(rng, d, h)
    out = K.mlp_f(z, *w)
    expect = R.mlp_f(z, *w)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    shapes=shapes,
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    h_step=st.floats(min_value=0.01, max_value=0.5),
    eta=st.floats(min_value=0.55, max_value=1.0),
)
def test_alf_step_matches_ref(shapes, seed, h_step, eta):
    b, d, hid = shapes
    rng = np.random.default_rng(seed)
    z = _rand(rng, b, d)
    v = _rand(rng, b, d)
    w = _weights(rng, d, hid)
    hs = jnp.asarray([h_step], dtype=jnp.float32)
    es = jnp.asarray([eta], dtype=jnp.float32)
    zo, vo, err = K.alf_step(z, v, hs, es, *w)
    zo_r, vo_r, err_r = R.alf_step(z, v, h_step, eta, *w)
    np.testing.assert_allclose(zo, zo_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(vo, vo_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(err, err_r, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    shapes=shapes,
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    h_step=st.floats(min_value=0.01, max_value=0.5),
    eta=st.floats(min_value=0.55, max_value=1.0),
)
def test_alf_inv_matches_ref_and_roundtrips(shapes, seed, h_step, eta):
    b, d, hid = shapes
    rng = np.random.default_rng(seed)
    z = _rand(rng, b, d)
    v = _rand(rng, b, d)
    w = _weights(rng, d, hid)
    hs = jnp.asarray([h_step], dtype=jnp.float32)
    es = jnp.asarray([eta], dtype=jnp.float32)
    zi, vi = K.alf_inv(z, v, hs, es, *w)
    zi_r, vi_r = R.alf_inv(z, v, h_step, eta, *w)
    np.testing.assert_allclose(zi, zi_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(vi, vi_r, rtol=1e-4, atol=1e-4)
    # kernel-level roundtrip: psi(psi^-1(x)) == x
    zo, vo, _ = K.alf_step(zi, vi, hs, es, *w)
    np.testing.assert_allclose(zo, z, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(vo, v, rtol=1e-3, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(shapes=shapes, seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_hutch_div_matches_ref(shapes, seed):
    b, d, h = shapes
    rng = np.random.default_rng(seed)
    z = _rand(rng, b, d)
    eps = jnp.asarray(
        rng.choice([-1.0, 1.0], size=(b, d)).astype(np.float32)
    )
    w = _weights(rng, d, h)
    out, div = K.hutch_div(z, eps, *w)
    out_r, div_r = R.hutch_div(z, eps, *w)
    np.testing.assert_allclose(out, out_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(div, div_r, rtol=1e-4, atol=1e-4)


def test_hutch_div_is_unbiased_trace_estimate():
    """E_eps[epsᵀJeps] = tr(J): average many probes against the dense
    Jacobian trace."""
    rng = np.random.default_rng(0)
    b, d, h = 4, 6, 10
    z = _rand(rng, b, d)
    w = _weights(rng, d, h)

    def f_single(zi):
        return R.mlp_f(zi[None, :], *w)[0]

    jac = jax.vmap(jax.jacobian(f_single))(z)  # (B, D, D)
    trace = jnp.trace(jac, axis1=1, axis2=2)

    n_probe = 4000
    acc = np.zeros(b, dtype=np.float64)
    for i in range(n_probe):
        eps = jnp.asarray(
            rng.choice([-1.0, 1.0], size=(b, d)).astype(np.float32)
        )
        _, div = R.hutch_div(z, eps, *w)
        acc += np.asarray(div, dtype=np.float64)
    est = acc / n_probe
    np.testing.assert_allclose(est, trace, rtol=0.15, atol=0.05)


def test_alf_step_order_vs_midpoint():
    """One ALF step from a consistent (z, v=f(z)) equals one midpoint step
    (they coincide when v is exact — §3.1 'Difference from midpoint')."""
    rng = np.random.default_rng(1)
    b, d, h = 2, 5, 7
    z = _rand(rng, b, d)
    w = _weights(rng, d, h)
    v = R.mlp_f(z, *w)
    hstep = 0.1
    zo, _, _ = R.alf_step(z, v, hstep, 1.0, *w)
    mid = z + hstep * R.mlp_f(z + 0.5 * hstep * v, *w)
    np.testing.assert_allclose(zo, mid, rtol=1e-5, atol=1e-6)


def test_vmem_footprint_estimate_reasonable():
    bytes_ = K.vmem_footprint_bytes(64, 128, 256)
    assert 0 < bytes_ < 16 * 1024 * 1024
