"""L2 model registry: assembles every experiment's executable set and the
manifest the Rust runtime is driven by.

Models (DESIGN.md §5):
  toy     — dz/dt = α z, runtime smoke tests (Fig. 4 cross-check)
  img16   — "Cifar10" stand-in classifier  (Fig. 5, Table 1)
  img32   — "ImageNet" stand-in classifier (Fig. 6, Tables 2/3)
  latent  — latent-ODE on hopper trajectories (Table 4) + RNN/GRU baselines
  cde     — Neural CDE on synthetic speech commands (Table 5)
  cnf*    — FFJORD on synth-MNIST / synth-CIFAR / 2-D densities (Table 6)
  realnvp — discrete-flow baseline (Table 6)
"""

from . import families as F

# ---------------------------------------------------------------------------
# Model dimensions (kept CPU-feasible; every experiment config in Rust reads
# these from the manifest, so there is a single source of truth).
# ---------------------------------------------------------------------------

DIMS = {
    "toy": dict(batch=1, dim=4),
    "img16": dict(batch=32, d_in=16 * 16 * 3, d=64, hidden=128, classes=10),
    "img32": dict(batch=16, d_in=32 * 32 * 3, d=128, hidden=256, classes=100),
    "latent": dict(batch=32, obs=8, t_len=32, gru_h=64, latent=16, f_hidden=64,
                   t_out=16),
    "cde": dict(batch=32, channels=6, pieces=39, t_total=1.0, d=32, hidden=64,
                classes=10),
    "cnf_mnist8": dict(batch=32, dim=64, hidden=128),
    "cnf_cifar8": dict(batch=16, dim=192, hidden=192),
    "cnf_density2d": dict(batch=64, dim=2, hidden=64),
    "realnvp_mnist8": dict(batch=32, dim=64, hidden=128, n_layers=4),
    "realnvp_cifar8": dict(batch=16, dim=192, hidden=192, n_layers=4),
}


def build():
    """Returns (exports, manifest_models)."""
    exports = []
    models = {}

    # ---- toy --------------------------------------------------------------
    d = DIMS["toy"]
    exports += F.toy_family("toy", d["batch"], d["dim"])
    models["toy"] = {
        **d,
        "state_dim": d["dim"],
        "components": {
            "f": {"params": [F.param_spec("alpha", (1,), "ones")]},
        },
    }

    # ---- image classifiers --------------------------------------------------
    for key in ("img16", "img32"):
        d = DIMS[key]
        exports += F.mlpdyn(key, d["batch"], d["d"], d["hidden"])
        exports += F.stem_exports(key, d["batch"], d["d_in"], d["d"])
        exports += F.head_exports(key, d["batch"], d["d"], d["classes"])
        exports += F.resnet_exports(
            key, d["batch"], d["d_in"], d["d"], d["hidden"], d["classes"]
        )
        models[key] = {
            **d,
            "state_dim": d["d"],
            "components": {
                "stem": {"params": F.stem_param_specs(d["d_in"], d["d"])},
                "f": {"params": F.mlp_param_specs(d["d"], d["hidden"], d["d"])},
                "head": {"params": F.head_param_specs(d["d"], d["classes"])},
            },
        }

    # ---- latent ODE ----------------------------------------------------------
    d = DIMS["latent"]
    exports += F.mlpdyn("latent", d["batch"], d["latent"], d["f_hidden"])
    exports += F.encoder_exports(
        "latent", d["batch"], d["obs"], d["t_len"], d["gru_h"], d["latent"]
    )
    exports += F.decoder_exports("latent", d["batch"], d["latent"], d["obs"])
    exports += F.seq_baseline_exports(
        "rnn", d["batch"], d["obs"], d["t_len"], d["t_out"], d["gru_h"], "rnn"
    )
    exports += F.seq_baseline_exports(
        "gru", d["batch"], d["obs"], d["t_len"], d["t_out"], d["gru_h"], "gru"
    )
    models["latent"] = {
        **d,
        "state_dim": d["latent"],
        "components": {
            "enc": {"params": F.encoder_param_specs(d["obs"], d["gru_h"], d["latent"])},
            "f": {"params": F.mlp_param_specs(d["latent"], d["f_hidden"], d["latent"])},
            "dec": {"params": F.decoder_param_specs(d["latent"], d["obs"])},
        },
    }
    models["rnn"] = {
        "batch": d["batch"],
        "components": {
            "all": {"params": F.seq_baseline_param_specs(d["obs"], d["gru_h"], "rnn")}
        },
    }
    models["gru"] = {
        "batch": d["batch"],
        "components": {
            "all": {"params": F.seq_baseline_param_specs(d["obs"], d["gru_h"], "gru")}
        },
    }

    # ---- Neural CDE -----------------------------------------------------------
    d = DIMS["cde"]
    exports += F.cde_family(
        "cde", d["batch"], d["d"], d["hidden"], d["channels"], d["pieces"], d["t_total"]
    )
    exports += F.stem_exports("cde", d["batch"], d["channels"], d["d"])
    exports += F.head_exports("cde", d["batch"], d["d"], d["classes"])
    models["cde"] = {
        **d,
        "state_dim": d["d"],
        "components": {
            "stem": {"params": F.stem_param_specs(d["channels"], d["d"])},
            "f": {"params": F.mlp_param_specs(d["d"], d["hidden"], d["d"] * d["channels"])},
            "head": {"params": F.head_param_specs(d["d"], d["classes"])},
        },
    }

    # ---- CNF / FFJORD -----------------------------------------------------------
    for key in ("cnf_mnist8", "cnf_cifar8", "cnf_density2d"):
        d = DIMS[key]
        exports += F.cnf_family(key, d["batch"], d["dim"], d["hidden"])
        models[key] = {
            **d,
            "state_dim": d["dim"] + 3,
            "components": {
                "f": {"params": F.cnf_param_specs(d["dim"], d["hidden"])},
            },
        }

    # ---- RealNVP baselines ---------------------------------------------------
    for key in ("realnvp_mnist8", "realnvp_cifar8"):
        d = DIMS[key]
        exports += F.realnvp_exports(key, d["batch"], d["dim"], d["hidden"], d["n_layers"])
        models[key] = {
            **d,
            "components": {
                "all": {
                    "params": F.realnvp_param_specs(d["dim"], d["hidden"], d["n_layers"])
                }
            },
        }

    # annotate component lengths
    for m in models.values():
        for comp in m.get("components", {}).values():
            comp["len"] = F.spec_len(comp["params"])

    return exports, models
