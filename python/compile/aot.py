"""AOT export: lower every L2 graph to HLO *text* and write the manifest.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the image's
xla_extension 0.5.1 (the version the published `xla` rust crate binds)
rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --out-dir ../artifacts``  (via `make artifacts`).
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default printer elides literals over a
    # size threshold as `constant({...})`, which xla_extension 0.5.1's
    # text parser silently zero-fills — gradients through any masked op
    # (e.g. RealNVP coupling masks) would be zeroed.
    text = comp.as_hlo_text(print_large_constants=True)
    if "{...}" in text:
        raise RuntimeError("HLO text contains an elided literal ('{...}')")
    return text


def export_one(exp, out_dir):
    # keep_unused: the manifest promises the full input list even when a
    # graph ignores an arg (e.g. t for autonomous dynamics) — the Rust
    # engine always supplies every declared buffer.
    lowered = jax.jit(exp.fn, keep_unused=True).lower(*exp.args)
    text = to_hlo_text(lowered)
    fname = f"{exp.name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    # output arity from the traced avals
    out_avals = jax.eval_shape(exp.fn, *exp.args)
    if not isinstance(out_avals, (tuple, list)):
        out_avals = (out_avals,)
    entry = {
        "file": fname,
        "doc": exp.doc,
        "inputs": [
            {"shape": list(a.shape), "dtype": str(a.dtype)} for a in exp.args
        ],
        "outputs": [
            {"shape": list(a.shape), "dtype": str(a.dtype)} for a in out_avals
        ],
        "hlo_sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
    }
    return entry


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only",
        default=None,
        help="prefix filter, e.g. 'img16' — NOTE: rewrites the manifest with "
        "existing entries preserved for non-matching names",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    jax.config.update("jax_platform_name", "cpu")
    exports, models = model.build()
    if args.only:
        exports = [e for e in exports if e.name.startswith(args.only)]

    manifest = {"version": 1, "entries": {}, "models": models}
    if args.only:
        # partial regeneration must not clobber the other entries
        prev = os.path.join(args.out_dir, "manifest.json")
        if os.path.exists(prev):
            with open(prev) as f:
                manifest["entries"] = json.load(f).get("entries", {})
    t0 = time.time()
    for i, exp in enumerate(exports):
        t1 = time.time()
        manifest["entries"][exp.name] = export_one(exp, args.out_dir)
        print(
            f"[{i + 1:3}/{len(exports)}] {exp.name:32s} "
            f"({time.time() - t1:5.1f}s)",
            flush=True,
        )

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(exports)} artifacts + manifest in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
