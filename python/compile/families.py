"""L2 compute-graph families: for each dynamics family the standard
executable set {f, f_vjp, alf_step, alf_inv, alf_vjp} plus model-specific
stems/heads and the discrete baselines, all as pure functions of flat
per-component parameter vectors.

The Rust coordinator composes everything dynamic (solver loops, the four
gradient protocols, optimizers) from these fixed-shape graphs; Python never
runs after `make artifacts`.

Forward-only graphs route through the L1 Pallas kernels
(``kernels.alf_step``); vjp graphs differentiate the pure-jnp oracle
(``kernels.ref``) — sound because kernel == oracle is enforced by the L1
test suite.
"""

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .kernels import alf_step as K
from .kernels import ref as R

# ---------------------------------------------------------------------------
# Registry plumbing
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Export:
    """One AOT artifact: a jax function plus its example input specs."""

    name: str
    fn: Callable
    args: Sequence[jax.ShapeDtypeStruct]
    doc: str = ""


F32 = jnp.float32


def spec(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), F32)


def param_spec(name, shape, init, **kw):
    d = {"name": name, "shape": list(shape), "init": init}
    d.update(kw)
    return d


def spec_len(specs):
    n = 0
    for s in specs:
        k = 1
        for d in s["shape"]:
            k *= d
        n += k
    return n


# ---------------------------------------------------------------------------
# Flat-θ (un)packing helpers
# ---------------------------------------------------------------------------


def unpack(theta, shapes):
    """Split a flat θ into tensors of the given shapes (in order)."""
    out = []
    ofs = 0
    for shp in shapes:
        k = 1
        for d in shp:
            k *= d
        out.append(theta[ofs : ofs + k].reshape(shp))
        ofs += k
    return out


def mlp_shapes(d_in, h, d_out):
    return [(d_in, h), (h,), (h, d_out), (d_out,)]


def mlp_param_specs(d_in, h, d_out):
    return [
        param_spec("w1", (d_in, h), "glorot_uniform", fan_in=d_in, fan_out=h),
        param_spec("b1", (h,), "zeros"),
        param_spec("w2", (h, d_out), "glorot_uniform", fan_in=h, fan_out=d_out),
        param_spec("b2", (d_out,), "zeros"),
    ]


# ---------------------------------------------------------------------------
# Generic dynamics-family builder
#
# A family provides f_ref(t, z, theta, *ctx) in pure jnp and optionally a
# kernel-backed forward.  From that we derive the five standard executables.
# ctx tensors (spline coefficients, Hutchinson probes) ride along unchanged.
# ---------------------------------------------------------------------------


def family_exports(
    name,
    batch,
    dim,
    theta_len,
    f_ref,
    f_fwd=None,
    ctx_specs=(),
    state_dim=None,
):
    """Build {f, f_vjp, step, inv, step_vjp} exports for one family.

    state_dim: the solver-state width (equals dim unless the family augments
    the state, e.g. CNF's [z, logp, ke, je]).
    """
    sd = state_dim or dim
    f_fwd = f_fwd or f_ref
    zspec = spec(batch, sd)
    tspec = spec()
    thspec = spec(theta_len)
    ctx = list(ctx_specs)

    def f_exec(t, z, *rest):
        (*c, th) = rest
        return (f_fwd(t, z, th, *c),)

    def f_vjp_exec(t, z, *rest):
        (*c, th, a) = rest

        def g(zz, tt):
            return f_ref(t, zz, tt, *c)

        _, vjp = jax.vjp(g, z, th)
        az, ath = vjp(a)
        return az, ath

    def step_exec(z, v, t, h, eta, *rest):
        (*c, th) = rest
        # Damped-ALF ψ with the step time t (families may be t-dependent:
        # f is evaluated at s1 = t + h/2).
        k1 = z + v * (h / 2.0)
        u1 = f_fwd(t + h / 2.0, k1, th, *c)
        v_out = (1.0 - 2.0 * eta) * v + 2.0 * eta * u1
        z_out = k1 + v_out * (h / 2.0)
        err = eta * h * (u1 - v)
        return z_out, v_out, err

    def inv_exec(z_out, v_out, t_out, h, eta, *rest):
        (*c, th) = rest
        k1 = z_out - v_out * (h / 2.0)
        u1 = f_fwd(t_out - h / 2.0, k1, th, *c)
        v_in = (v_out - 2.0 * eta * u1) / (1.0 - 2.0 * eta)
        z_in = k1 - v_in * (h / 2.0)
        return z_in, v_in

    def step_vjp_exec(z, v, t, h, eta, *rest):
        (*c, th, azo, avo) = rest

        def g(zz, vv, tt):
            k1 = zz + vv * (h / 2.0)
            u1 = f_ref(t + h / 2.0, k1, tt, *c)
            v_out = (1.0 - 2.0 * eta) * vv + 2.0 * eta * u1
            z_out = k1 + v_out * (h / 2.0)
            return z_out, v_out

        _, vjp = jax.vjp(g, z, v, th)
        az, av, ath = vjp((azo, avo))
        return az, av, ath

    def bwd_exec(z_out, v_out, t_out, h, eta, *rest):
        """Fused MALI backward micro-step: ψ⁻¹ reconstruction followed by
        the vjp through ψ at the reconstructed point — one executable
        instead of two, halving the per-step PJRT round-trips of the
        backward pass (EXPERIMENTS.md §Perf)."""
        (*c, th, azo, avo) = rest
        # ψ⁻¹ — written with f_ref (not the Pallas kernel) so XLA can CSE
        # the shared k1/u1 computation with the vjp recomputation below;
        # kernel == oracle is enforced by the L1 test suite.
        k1 = z_out - v_out * (h / 2.0)
        u1 = f_ref(t_out - h / 2.0, k1, th, *c)
        v_in = (v_out - 2.0 * eta * u1) / (1.0 - 2.0 * eta)
        z_in = k1 - v_in * (h / 2.0)

        # vjp of ψ at (z_in, v_in); t = t_out − h
        def g(zz, vv, tt):
            kk1 = zz + vv * (h / 2.0)
            uu1 = f_ref(t_out - h / 2.0, kk1, tt, *c)
            vv_out = (1.0 - 2.0 * eta) * vv + 2.0 * eta * uu1
            zz_out = kk1 + vv_out * (h / 2.0)
            return zz_out, vv_out

        _, vjp = jax.vjp(g, z_in, v_in, th)
        az, av, ath = vjp((azo, avo))
        return z_in, v_in, az, av, ath

    # NOTE: mlpdyn() replaces entries by index (step = 2, inv = 3), so new
    # exports must be appended at the END of this list.
    return [
        Export(f"{name}.f", f_exec, [tspec, zspec, *ctx, thspec], "dynamics eval"),
        Export(
            f"{name}.f_vjp",
            f_vjp_exec,
            [tspec, zspec, *ctx, thspec, zspec],
            "dynamics vjp",
        ),
        Export(
            f"{name}.step",
            step_exec,
            [zspec, zspec, tspec, tspec, tspec, *ctx, thspec],
            "fused damped-ALF ψ",
        ),
        Export(
            f"{name}.inv",
            inv_exec,
            [zspec, zspec, tspec, tspec, tspec, *ctx, thspec],
            "fused ψ⁻¹",
        ),
        Export(
            f"{name}.step_vjp",
            step_vjp_exec,
            [zspec, zspec, tspec, tspec, tspec, *ctx, thspec, zspec, zspec],
            "vjp through ψ",
        ),
        Export(
            f"{name}.bwd",
            bwd_exec,
            [zspec, zspec, tspec, tspec, tspec, *ctx, thspec, zspec, zspec],
            "fused ψ⁻¹ + ψ-vjp (MALI backward micro-step)",
        ),
    ]


# ---------------------------------------------------------------------------
# MLP-dynamics family (image classifiers, latent ODE): Pallas-kernel forward
# ---------------------------------------------------------------------------


def mlpdyn(name, batch, dim, hidden):
    shapes = mlp_shapes(dim, hidden, dim)

    def f_ref(t, z, theta):
        w1, b1, w2, b2 = unpack(theta, shapes)
        return R.mlp_f(z, w1, b1, w2, b2)

    def f_fwd(t, z, theta):
        w1, b1, w2, b2 = unpack(theta, shapes)
        return K.mlp_f(z, w1, b1, w2, b2)

    exports = family_exports(
        name, batch, dim, spec_len(mlp_param_specs(dim, hidden, dim)), f_ref, f_fwd
    )

    # Replace the generic ψ/ψ⁻¹ with the fused Pallas kernels (exact same
    # math; one kernel launch instead of composed HLO ops).
    zspec, tspec = spec(batch, dim), spec()
    thspec = spec(spec_len(mlp_param_specs(dim, hidden, dim)))

    def step_kernel(z, v, t, h, eta, theta):
        w1, b1, w2, b2 = unpack(theta, shapes)
        hs = jnp.reshape(h, (1,))
        es = jnp.reshape(eta, (1,))
        return K.alf_step(z, v, hs, es, w1, b1, w2, b2)

    def inv_kernel(z_out, v_out, t_out, h, eta, theta):
        w1, b1, w2, b2 = unpack(theta, shapes)
        hs = jnp.reshape(h, (1,))
        es = jnp.reshape(eta, (1,))
        return K.alf_inv(z_out, v_out, hs, es, w1, b1, w2, b2)

    exports[2] = Export(
        f"{name}.step",
        step_kernel,
        [zspec, zspec, tspec, tspec, tspec, thspec],
        "fused damped-ALF ψ (Pallas)",
    )
    exports[3] = Export(
        f"{name}.inv",
        inv_kernel,
        [zspec, zspec, tspec, tspec, tspec, thspec],
        "fused ψ⁻¹ (Pallas)",
    )
    return exports


def toy_family(name="toy", batch=1, dim=4):
    """dz/dt = α·z with θ = [α] — runtime smoke tests against analytics."""

    def f_ref(t, z, theta):
        return theta[0] * z

    return family_exports(name, batch, dim, 1, f_ref)


# ---------------------------------------------------------------------------
# Classification stems / heads (images + CDE)
# ---------------------------------------------------------------------------


def stem_exports(name, batch, d_in, d_out):
    shapes = [(d_in, d_out), (d_out,)]
    th = spec(d_in * d_out + d_out)

    def fwd(x, theta):
        w, b = unpack(theta, shapes)
        return (jnp.tanh(x @ w + b),)

    def vjp(x, theta, a):
        def g(xx, tt):
            w, b = unpack(tt, shapes)
            return jnp.tanh(xx @ w + b)

        _, pull = jax.vjp(g, x, theta)
        ax, ath = pull(a)
        return ax, ath

    return [
        Export(f"{name}.stem", fwd, [spec(batch, d_in), th], "stem x→z₀"),
        Export(
            f"{name}.stem_vjp",
            vjp,
            [spec(batch, d_in), th, spec(batch, d_out)],
            "stem vjp (a_x for FGSM, a_θ)",
        ),
    ]


def stem_param_specs(d_in, d_out):
    return [
        param_spec("w", (d_in, d_out), "glorot_uniform", fan_in=d_in, fan_out=d_out),
        param_spec("b", (d_out,), "zeros"),
    ]


def head_exports(name, batch, d, classes):
    shapes = [(d, classes), (classes,)]
    th = spec(d * classes + classes)

    def loss_fn(z, y1h, theta):
        w, b = unpack(theta, shapes)
        logits = z @ w + b
        logp = jax.nn.log_softmax(logits, axis=1)
        loss = -jnp.mean(jnp.sum(y1h * logp, axis=1))
        return loss, logits

    def loss_grad(z, y1h, theta):
        (loss, logits), pull = jax.vjp(
            lambda zz, tt: loss_fn(zz, y1h, tt), z, theta, has_aux=False
        )
        az, ath = pull((jnp.ones(()), jnp.zeros_like(logits)))
        return loss, logits, az, ath

    return [
        Export(
            f"{name}.head_loss_grad",
            loss_grad,
            [spec(batch, d), spec(batch, classes), th],
            "fused softmax-CE loss + logits + (a_z, a_θ)",
        )
    ]


def head_param_specs(d, classes):
    return [
        param_spec("w", (d, classes), "glorot_uniform", fan_in=d, fan_out=classes),
        param_spec("b", (classes,), "zeros"),
    ]


# ---------------------------------------------------------------------------
# Discrete ResNet baseline sharing the ODE's f (paper §4.2: y = x + f(x))
# ---------------------------------------------------------------------------


def resnet_exports(name, batch, d_in, d, hidden, classes):
    stem_shapes = [(d_in, d), (d,)]
    f_shapes = mlp_shapes(d, hidden, d)
    head_shapes = [(d, classes), (classes,)]
    th_stem = spec(spec_len(stem_param_specs(d_in, d)))
    th_f = spec(spec_len(mlp_param_specs(d, hidden, d)))
    th_head = spec(spec_len(head_param_specs(d, classes)))

    def forward(x, ts, tf, thd):
        w, b = unpack(ts, stem_shapes)
        z = jnp.tanh(x @ w + b)
        w1, b1, w2, b2 = unpack(tf, f_shapes)
        z = z + R.mlp_f(z, w1, b1, w2, b2)  # one-step-Euler residual block
        wh, bh = unpack(thd, head_shapes)
        return z @ wh + bh

    def loss_of(x, y1h, ts, tf, thd):
        logits = forward(x, ts, tf, thd)
        logp = jax.nn.log_softmax(logits, axis=1)
        return -jnp.mean(jnp.sum(y1h * logp, axis=1)), logits

    def loss_grad(x, y1h, ts, tf, thd):
        (loss, logits), pull = jax.vjp(
            lambda a, bb, c: loss_of(x, y1h, a, bb, c), ts, tf, thd
        )
        gs, gf, gh = pull((jnp.ones(()), jnp.zeros_like(logits)))
        return loss, logits, gs, gf, gh

    def fwd_grad_x(x, y1h, ts, tf, thd):
        """Loss + dL/dx — FGSM attack gradients for the ResNet."""
        (loss, logits), pull = jax.vjp(lambda xx: loss_of(xx, y1h, ts, tf, thd), x)
        (gx,) = pull((jnp.ones(()), jnp.zeros_like(logits)))
        return loss, logits, gx

    args = [spec(batch, d_in), spec(batch, classes), th_stem, th_f, th_head]
    return [
        Export(f"{name}.resnet_loss_grad", loss_grad, args, "discrete baseline loss+grads"),
        Export(f"{name}.resnet_grad_x", fwd_grad_x, args, "FGSM input gradient"),
    ]


# ---------------------------------------------------------------------------
# Latent-ODE components (Table 4): GRU encoder + decoder + seq baselines
# ---------------------------------------------------------------------------


def gru_shapes(d_in, h):
    # fused-gate GRU: Wz, Wr, Wh each (d_in + h, h); biases (h,)
    return [((d_in + h), 3 * h), (3 * h,)]


def gru_cell(x, hprev, w, b):
    zru = jnp.concatenate([x, hprev], axis=1) @ w + b
    h3 = zru.shape[1] // 3
    zg = jax.nn.sigmoid(zru[:, :h3])
    rg = jax.nn.sigmoid(zru[:, h3 : 2 * h3])
    cand_in = jnp.concatenate([x, rg * hprev], axis=1)
    # candidate re-uses the last third's weights applied to the gated state:
    # a standard "fused" variant — the candidate weights live in w's last
    # third of output columns.
    hc = jnp.tanh(cand_in @ w[:, 2 * h3 :] + b[2 * h3 :])
    return (1.0 - zg) * hprev + zg * hc


def encoder_exports(name, batch, obs, t_len, h, latent):
    wshapes = gru_shapes(obs, h)
    out_shapes = [(h, 2 * latent), (2 * latent,)]
    th_len = spec_len(encoder_param_specs(obs, h, latent))
    th = spec(th_len)

    def encode(seq, theta):
        w, b, wo, bo = unpack(theta, wshapes + out_shapes)

        def scan_fn(hprev, xt):
            hnew = gru_cell(xt, hprev, w, b)
            return hnew, None

        h0 = jnp.zeros((batch, h), dtype=F32)
        # run the GRU backwards in time (latent-ODE convention)
        seq_t = jnp.flip(jnp.transpose(seq, (1, 0, 2)), axis=0)
        h_last, _ = jax.lax.scan(scan_fn, h0, seq_t)
        out = h_last @ wo + bo
        return out[:, :latent], out[:, latent:]

    def encode_vjp(seq, theta, a_mu, a_lv):
        _, pull = jax.vjp(lambda tt: encode(seq, tt), theta)
        (ath,) = pull((a_mu, a_lv))
        return (ath,)

    return [
        Export(
            f"{name}.enc",
            encode,
            [spec(batch, t_len, obs), th],
            "GRU encoder → (μ, logσ²)",
        ),
        Export(
            f"{name}.enc_vjp",
            encode_vjp,
            [spec(batch, t_len, obs), th, spec(batch, latent), spec(batch, latent)],
            "encoder vjp",
        ),
    ]


def encoder_param_specs(obs, h, latent):
    return [
        param_spec("gru_w", ((obs + h), 3 * h), "glorot_uniform", fan_in=obs + h, fan_out=3 * h),
        param_spec("gru_b", (3 * h,), "zeros"),
        param_spec("out_w", (h, 2 * latent), "glorot_uniform", fan_in=h, fan_out=2 * latent),
        param_spec("out_b", (2 * latent,), "zeros"),
    ]


def decoder_exports(name, batch, latent, obs):
    shapes = [(latent, obs), (obs,)]
    th = spec(spec_len(decoder_param_specs(latent, obs)))

    def dec(z, theta):
        w, b = unpack(theta, shapes)
        return (z @ w + b,)

    def dec_vjp(z, theta, a):
        def g(zz, tt):
            w, b = unpack(tt, shapes)
            return zz @ w + b

        _, pull = jax.vjp(g, z, theta)
        az, ath = pull(a)
        return az, ath

    return [
        Export(f"{name}.dec", dec, [spec(batch, latent), th], "latent decoder"),
        Export(
            f"{name}.dec_vjp",
            dec_vjp,
            [spec(batch, latent), th, spec(batch, obs)],
            "decoder vjp",
        ),
    ]


def decoder_param_specs(latent, obs):
    return [
        param_spec("w", (latent, obs), "glorot_uniform", fan_in=latent, fan_out=obs),
        param_spec("b", (obs,), "zeros"),
    ]


def seq_baseline_exports(name, batch, obs, t_in, t_out, h, cell):
    """RNN / GRU sequence baselines (Table 4): encode the observed prefix,
    roll out `t_out` predictions, fused MSE loss + grads."""
    if cell == "gru":
        wshapes = gru_shapes(obs, h)
    else:
        wshapes = [((obs + h), h), (h,)]
    out_shapes = [(h, obs), (obs,)]
    th_len = spec_len(seq_baseline_param_specs(obs, h, cell))
    th = spec(th_len)

    def run(seq, theta):
        ws = unpack(theta, wshapes + out_shapes)
        if cell == "gru":
            w, b, wo, bo = ws

            def step(hprev, xt):
                return gru_cell(xt, hprev, w, b), None

        else:
            w, b, wo, bo = ws

            def step(hprev, xt):
                return jnp.tanh(jnp.concatenate([xt, hprev], axis=1) @ w + b), None

        h0 = jnp.zeros((batch, h), dtype=F32)
        seq_t = jnp.transpose(seq, (1, 0, 2))
        hT, _ = jax.lax.scan(step, h0, seq_t)

        # autoregressive rollout
        def roll(carry, _):
            hprev, xprev = carry
            hnew = (
                gru_cell(xprev, hprev, w, b)
                if cell == "gru"
                else jnp.tanh(jnp.concatenate([xprev, hprev], axis=1) @ w + b)
            )
            xnew = hnew @ wo + bo
            return (hnew, xnew), xnew

        x0 = seq[:, -1, :]
        _, preds = jax.lax.scan(roll, (hT, x0), None, length=t_out)
        return jnp.transpose(preds, (1, 0, 2))  # (B, t_out, obs)

    def loss_grad(seq, target, theta):
        def l(tt):
            p = run(seq, tt)
            return jnp.mean((p - target) ** 2)

        loss, g = jax.value_and_grad(l)(theta)
        return loss, g

    return [
        Export(
            f"{name}.loss_grad",
            loss_grad,
            [spec(batch, t_in, obs), spec(batch, t_out, obs), th],
            f"{cell} seq baseline fused loss+grad",
        ),
        Export(
            f"{name}.predict",
            lambda seq, theta: (run(seq, theta),),
            [spec(batch, t_in, obs), th],
            f"{cell} rollout predictions",
        ),
    ]


def seq_baseline_param_specs(obs, h, cell):
    mult = 3 if cell == "gru" else 1
    return [
        param_spec("w", ((obs + h), mult * h), "glorot_uniform", fan_in=obs + h, fan_out=mult * h),
        param_spec("b", (mult * h,), "zeros"),
        param_spec("out_w", (h, obs), "glorot_uniform", fan_in=h, fan_out=obs),
        param_spec("out_b", (obs,), "zeros"),
    ]


# ---------------------------------------------------------------------------
# Neural-CDE dynamics (Table 5): dz = f_θ(z) · Ẋ(t), spline evaluated inside
# ---------------------------------------------------------------------------


def cde_family(name, batch, dim, hidden, channels, pieces, t_total):
    """ctx = spline coefficients (B, channels, pieces, 4) over a uniform
    grid on [0, t_total]; the graph evaluates Ẋ(t) by piece lookup."""
    field_specs = mlp_param_specs(dim, hidden, dim * channels)
    shapes = mlp_shapes(dim, hidden, dim * channels)
    dt_piece = t_total / pieces

    def xdot(t, coeffs):
        # piece index and local offset
        idx = jnp.clip(jnp.floor(t / dt_piece).astype(jnp.int32), 0, pieces - 1)
        u = t - idx.astype(F32) * dt_piece
        cf = coeffs[:, :, idx, :]  # (B, C, 4)
        return cf[..., 1] + 2.0 * cf[..., 2] * u + 3.0 * cf[..., 3] * u * u  # (B, C)

    def f_ref(t, z, theta, coeffs):
        w1, b1, w2, b2 = unpack(theta, shapes)
        field = R.mlp_f(z, w1, b1, w2, b2)  # (B, dim*channels)
        field = jnp.tanh(field).reshape(z.shape[0], dim, channels)
        dx = xdot(t, coeffs)  # (B, C)
        return jnp.einsum("bdc,bc->bd", field, dx)

    ctx = [spec(batch, channels, pieces, 4)]
    return family_exports(
        name, batch, dim, spec_len(field_specs), f_ref, ctx_specs=ctx
    )


# ---------------------------------------------------------------------------
# FFJORD / CNF dynamics (Table 6): state = [z, Δlogp, E_kin, E_jac]
# ---------------------------------------------------------------------------


def cnf_family(name, batch, dim, hidden):
    """Time-conditioned MLP dynamics with Hutchinson divergence and the
    RNODE regularizer integrands (kinetic energy, Jacobian-Frobenius
    estimate).  ctx = the Rademacher probe (fixed per solve).
    State layout: [z (dim) | Δlogp | ke | je] → state_dim = dim + 3."""
    shapes = mlp_shapes(dim + 1, hidden, dim)
    th_len = spec_len(cnf_param_specs(dim, hidden))

    def f_ref(t, state, theta, eps):
        z = state[:, :dim]
        w1, b1, w2, b2 = unpack(theta, shapes)
        tcol = jnp.full((z.shape[0], 1), t, dtype=F32)
        zt = jnp.concatenate([z, tcol], axis=1)
        pre = zt @ w1 + b1
        hid = jnp.tanh(pre)
        out = hid @ w2 + b2  # f(z, t): (B, dim)
        gate = 1.0 - hid * hid
        w1z = w1[:dim, :]  # z-rows of w1
        left = eps @ w1z  # (B, H)
        right = eps @ w2.T  # (B, H)
        div = jnp.sum(left * gate * right, axis=1)  # εᵀJε
        eta_row = left * gate  # εᵀ·(dhid/dpre-part)
        jac_vec = eta_row @ w2  # εᵀ J (B, dim)
        ke = jnp.sum(out * out, axis=1)
        je = jnp.sum(jac_vec * jac_vec, axis=1)
        return jnp.concatenate(
            [out, -div[:, None], ke[:, None], je[:, None]], axis=1
        )

    ctx = [spec(batch, dim)]
    return family_exports(
        name,
        batch,
        dim,
        th_len,
        f_ref,
        ctx_specs=ctx,
        state_dim=dim + 3,
    )


def cnf_param_specs(dim, hidden):
    return mlp_param_specs(dim + 1, hidden, dim)


# ---------------------------------------------------------------------------
# RealNVP discrete-flow baseline (Table 6)
# ---------------------------------------------------------------------------


def realnvp_exports(name, batch, dim, hidden, n_layers=4):
    per = mlp_shapes(dim, hidden, 2 * dim)
    layer_len = spec_len(mlp_param_specs(dim, hidden, 2 * dim))
    th = spec(n_layers * layer_len)

    def masks():
        return [
            jnp.asarray(
                [(i + l) % 2 for i in range(dim)], dtype=F32
            )
            for l in range(n_layers)
        ]

    def flow(x, theta):
        logdet = jnp.zeros((x.shape[0],), dtype=F32)
        z = x
        for l, m in enumerate(masks()):
            tl = theta[l * layer_len : (l + 1) * layer_len]
            w1, b1, w2, b2 = unpack(tl, per)
            hcore = jnp.tanh((z * m) @ w1 + b1) @ w2 + b2
            s = jnp.tanh(hcore[:, :dim]) * (1.0 - m)
            t_shift = hcore[:, dim:] * (1.0 - m)
            z = z * jnp.exp(s) + t_shift
            logdet = logdet + jnp.sum(s, axis=1)
        return z, logdet

    def loss_grad(x, theta):
        def l(tt):
            z, logdet = flow(x, tt)
            logp = -0.5 * jnp.sum(z * z, axis=1) - 0.5 * dim * jnp.log(2 * jnp.pi)
            nll = -jnp.mean(logp + logdet)
            # bits/dim
            return nll / (dim * jnp.log(2.0))

        loss, g = jax.value_and_grad(l)(theta)
        return loss, g

    def nll_eval(x, theta):
        z, logdet = flow(x, theta)
        logp = -0.5 * jnp.sum(z * z, axis=1) - 0.5 * dim * jnp.log(2 * jnp.pi)
        bpd = -(logp + logdet) / (dim * jnp.log(2.0))
        return (bpd,)

    return [
        Export(
            f"{name}.loss_grad",
            loss_grad,
            [spec(batch, dim), th],
            "RealNVP fused BPD loss + grad",
        ),
        Export(f"{name}.bpd", nll_eval, [spec(batch, dim), th], "per-sample BPD"),
    ]


def realnvp_param_specs(dim, hidden, n_layers=4):
    out = []
    for l in range(n_layers):
        for s in mlp_param_specs(dim, hidden, 2 * dim):
            out.append({**s, "name": f"l{l}_{s['name']}"})
    return out
