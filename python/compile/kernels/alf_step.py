"""L1 Pallas kernels: fused (damped) ALF step, its exact inverse, the plain
MLP dynamics, and the CNF Hutchinson-divergence kernel.

TPU mapping (DESIGN.md §Hardware-Adaptation): each kernel keeps the
``(z, v)`` batch tile resident in VMEM across the k1 → f → update phases —
one launch instead of the three HBM round-trips an eager CUDA port would
make — and the MLP matmuls are expressed so Mosaic can tile them for the
128×128 MXU with f32 accumulation.  ``BlockSpec`` partitions the batch
across the grid, which is the threadblock-grid analogue.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
run Mosaic custom-calls, so interpret mode is the correctness (and the
only runnable) path on this image; real-TPU efficiency is estimated in
DESIGN.md §Perf from the BlockSpec footprint.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile: grid dimension 0 walks the batch in BM-row blocks.  64 rows of
# f32 keeps the working set (z, v, k1, u1 tiles + both weight panels for the
# sizes used here) well under 16 MiB of VMEM.
BM = 64


def _grid(b):
    return (max(1, (b + BM - 1) // BM),)


def _batch_tile(d):
    """BlockSpec for a (B, D) operand tiled over the batch grid."""
    return pl.BlockSpec((BM, d), lambda i: (i, 0))


def _replicated(shape):
    """BlockSpec for an operand every grid step sees in full (weights)."""
    ndim = len(shape)
    return pl.BlockSpec(shape, lambda i: (0,) * ndim)


def _mlp(zblk, w1, b1, w2, b2):
    # Two MXU matmuls with fp32 accumulation; tanh on the VPU.
    hid = jnp.tanh(
        jax.lax.dot_general(
            zblk, w1, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        + b1
    )
    return (
        jax.lax.dot_general(
            hid, w2, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        + b2
    )


def _alf_step_kernel(h_ref, eta_ref, z_ref, v_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                     zo_ref, vo_ref, err_ref):
    h = h_ref[0]
    eta = eta_ref[0]
    z = z_ref[...]
    v = v_ref[...]
    k1 = z + v * (h * 0.5)
    u1 = _mlp(k1, w1_ref[...], b1_ref[...], w2_ref[...], b2_ref[...])
    v_out = (1.0 - 2.0 * eta) * v + 2.0 * eta * u1
    zo_ref[...] = k1 + v_out * (h * 0.5)
    vo_ref[...] = v_out
    err_ref[...] = eta * h * (u1 - v)


def alf_step(z, v, h, eta, w1, b1, w2, b2):
    """Fused damped-ALF step; drop-in for ``ref.alf_step``.

    h, eta are shape-(1,) f32 arrays (scalar operands reach every grid step).
    """
    b, d = z.shape
    out_shape = [
        jax.ShapeDtypeStruct((b, d), z.dtype),
        jax.ShapeDtypeStruct((b, d), z.dtype),
        jax.ShapeDtypeStruct((b, d), z.dtype),
    ]
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _alf_step_kernel,
        grid=_grid(b),
        in_specs=[
            scalar,
            scalar,
            _batch_tile(d),
            _batch_tile(d),
            _replicated(w1.shape),
            _replicated(b1.shape),
            _replicated(w2.shape),
            _replicated(b2.shape),
        ],
        out_specs=[_batch_tile(d)] * 3,
        out_shape=out_shape,
        interpret=True,
    )(h, eta, z, v, w1, b1, w2, b2)


def _alf_inv_kernel(h_ref, eta_ref, z_ref, v_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                    zi_ref, vi_ref):
    h = h_ref[0]
    eta = eta_ref[0]
    z_out = z_ref[...]
    v_out = v_ref[...]
    k1 = z_out - v_out * (h * 0.5)
    u1 = _mlp(k1, w1_ref[...], b1_ref[...], w2_ref[...], b2_ref[...])
    v_in = (v_out - 2.0 * eta * u1) / (1.0 - 2.0 * eta)
    zi_ref[...] = k1 - v_in * (h * 0.5)
    vi_ref[...] = v_in


def alf_inv(z_out, v_out, h, eta, w1, b1, w2, b2):
    """Fused exact inverse psi^-1; drop-in for ``ref.alf_inv``."""
    b, d = z_out.shape
    out_shape = [
        jax.ShapeDtypeStruct((b, d), z_out.dtype),
        jax.ShapeDtypeStruct((b, d), z_out.dtype),
    ]
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _alf_inv_kernel,
        grid=_grid(b),
        in_specs=[
            scalar,
            scalar,
            _batch_tile(d),
            _batch_tile(d),
            _replicated(w1.shape),
            _replicated(b1.shape),
            _replicated(w2.shape),
            _replicated(b2.shape),
        ],
        out_specs=[_batch_tile(d)] * 2,
        out_shape=out_shape,
        interpret=True,
    )(h, eta, z_out, v_out, w1, b1, w2, b2)


def _mlp_f_kernel(z_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    o_ref[...] = _mlp(z_ref[...], w1_ref[...], b1_ref[...], w2_ref[...], b2_ref[...])


def mlp_f(z, w1, b1, w2, b2):
    """Plain MLP dynamics eval (used by the RK baselines); matches
    ``ref.mlp_f``."""
    b, d = z.shape
    return pl.pallas_call(
        _mlp_f_kernel,
        grid=_grid(b),
        in_specs=[
            _batch_tile(d),
            _replicated(w1.shape),
            _replicated(b1.shape),
            _replicated(w2.shape),
            _replicated(b2.shape),
        ],
        out_specs=_batch_tile(d),
        out_shape=jax.ShapeDtypeStruct((b, d), z.dtype),
        interpret=True,
    )(z, w1, b1, w2, b2)


def _hutch_kernel(z_ref, eps_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref, div_ref):
    z = z_ref[...]
    eps = eps_ref[...]
    w1 = w1_ref[...]
    w2 = w2_ref[...]
    pre = (
        jax.lax.dot_general(
            z, w1, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        + b1_ref[...]
    )
    hid = jnp.tanh(pre)
    o_ref[...] = (
        jax.lax.dot_general(
            hid, w2, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        + b2_ref[...]
    )
    gate = 1.0 - hid * hid
    left = jax.lax.dot_general(
        eps, w1, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    right = jax.lax.dot_general(
        eps, w2, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    div_ref[...] = jnp.sum(left * gate * right, axis=1, keepdims=True)


def hutch_div(z, eps, w1, b1, w2, b2):
    """Fused dynamics + Hutchinson divergence; matches ``ref.hutch_div``
    (div returned as (B, 1) here, squeezed by the caller)."""
    b, d = z.shape
    out_shape = [
        jax.ShapeDtypeStruct((b, d), z.dtype),
        jax.ShapeDtypeStruct((b, 1), z.dtype),
    ]
    out, div = pl.pallas_call(
        _hutch_kernel,
        grid=_grid(b),
        in_specs=[
            _batch_tile(d),
            _batch_tile(d),
            _replicated(w1.shape),
            _replicated(b1.shape),
            _replicated(w2.shape),
            _replicated(b2.shape),
        ],
        out_specs=[_batch_tile(d), _batch_tile(1)],
        out_shape=out_shape,
        interpret=True,
    )(z, eps, w1, b1, w2, b2)
    return out, div[:, 0]


@functools.lru_cache(maxsize=None)
def vmem_footprint_bytes(b, d, h):
    """Estimated VMEM working set of one alf_step grid step (DESIGN §Perf):
    four (BM, D) batch tiles + weight panels + hidden tile, f32."""
    bm = min(BM, b)
    tiles = 4 * bm * d  # z, v, k1/z_out, err
    hidden = bm * h  # u1 / hid
    weights = d * h * 2 + h + d
    return 4 * (tiles + hidden + weights)
