"""Pure-jnp oracles for every Pallas kernel (L1 correctness anchors).

Each function here is the mathematical definition; the Pallas kernels in
this package must match it to float tolerance (asserted by
``python/tests/test_kernels.py`` with hypothesis sweeps over shapes/seeds).

The vjp-producing graphs exported by ``aot.py`` differentiate *these*
implementations (pallas_call under ``interpret=True`` is a black box to
reverse-mode AD), which is sound because kernel == ref is separately
enforced.

Conventions (row-major batch):
  z, v : (B, D)    w1 : (D, H)   b1 : (H,)   w2 : (H, D)   b2 : (D,)
  MLP dynamics:  f(z) = tanh(z @ w1 + b1) @ w2 + b2
"""

import jax.numpy as jnp


def mlp_f(z, w1, b1, w2, b2):
    """The shared MLP ODE dynamics (autonomous)."""
    return jnp.tanh(z @ w1 + b1) @ w2 + b2


def mlp_f_t(t, z, w1, b1, w2, b2):
    """Time-conditioned MLP dynamics: t is appended as an input feature.

    w1 has shape (D + 1, H) in this variant.
    """
    b = z.shape[0]
    tcol = jnp.full((b, 1), t, dtype=z.dtype)
    zt = jnp.concatenate([z, tcol], axis=1)
    return jnp.tanh(zt @ w1 + b1) @ w2 + b2


def alf_step(z, v, h, eta, w1, b1, w2, b2):
    """One damped-ALF step psi over the MLP dynamics (paper Algo. 2 / A.5).

    Returns (z_out, v_out, err) with err = eta * h * (u1 - v), the embedded
    (2,1) error estimate.
    """
    k1 = z + v * (h / 2.0)
    u1 = mlp_f(k1, w1, b1, w2, b2)
    v_out = (1.0 - 2.0 * eta) * v + 2.0 * eta * u1
    z_out = k1 + v_out * (h / 2.0)
    err = eta * h * (u1 - v)
    return z_out, v_out, err


def alf_inv(z_out, v_out, h, eta, w1, b1, w2, b2):
    """Exact inverse psi^-1 (paper Algo. 3 / Eq. 49)."""
    k1 = z_out - v_out * (h / 2.0)
    u1 = mlp_f(k1, w1, b1, w2, b2)
    v_in = (v_out - 2.0 * eta * u1) / (1.0 - 2.0 * eta)
    z_in = k1 - v_in * (h / 2.0)
    return z_in, v_in


def hutch_div(z, eps, w1, b1, w2, b2):
    """MLP dynamics + Hutchinson divergence estimate in one pass.

    For f(z) = tanh(z@w1 + b1) @ w2 + b2 the Jacobian is
    J = w1 · diag(1 - tanh²(pre)) · w2 (row convention), so
    epsᵀ J eps = Σ_k (eps@w1)_k (1 − tanh²(pre)_k) (w2 epsᵀ)_k,
    computable without materializing J.

    Returns (f(z), div_est) with shapes ((B, D), (B,)).
    """
    pre = z @ w1 + b1
    hid = jnp.tanh(pre)
    out = hid @ w2 + b2
    gate = 1.0 - hid * hid  # (B, H)
    left = eps @ w1  # (B, H)
    right = eps @ w2.T  # (B, H)
    div = jnp.sum(left * gate * right, axis=1)  # (B,)
    return out, div
