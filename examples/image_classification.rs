//! End-to-end driver (DESIGN.md): train the Cifar-like Neural-ODE
//! classifier with MALI for several hundred optimizer steps on the
//! synthetic corpus, logging the loss curve — proof that all three layers
//! (Pallas kernels → AOT HLO graphs → Rust coordinator) compose into a
//! working training system.  The run is recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example image_classification            # ~400 steps
//! cargo run --release --example image_classification -- --long  # full recipe
//! ```

use mali_ode::data::images::{generate, ImageSpec};
use mali_ode::models::image::OdeImageClassifier;
use mali_ode::runtime::Engine;
use mali_ode::solvers::dynamics::Dynamics;
use mali_ode::train::trainer::{ImageTrainer, TrainCfg};
use mali_ode::util::json::Json;
use mali_ode::util::mem::{fmt_bytes, process_rss_bytes};
use mali_ode::util::rng::Rng;
use std::rc::Rc;

fn main() -> anyhow::Result<()> {
    let long = std::env::args().any(|a| a == "--long");
    let engine = Rc::new(Engine::from_env()?);
    let mut rng = Rng::new(0);
    let mut model = OdeImageClassifier::new(engine, "img16", &mut rng)?;
    println!(
        "model img16: {} parameters (stem {} + f {} + head {})",
        model.param_count(),
        model.stem.len(),
        model.dynamics.param_dim(),
        model.head.len(),
    );

    let n = if long { 3200 + 640 } else { 1600 + 320 };
    let n_test = if long { 640 } else { 320 };
    let (train, test) = generate(&ImageSpec::cifar_like(), n, 42).split(n_test);
    let epochs = if long { 9 } else { 8 };
    let batches_per_epoch = train.len() / model.batch;
    println!(
        "corpus: {} train / {} test, {} batches/epoch × {epochs} epochs = {} steps",
        train.len(),
        test.len(),
        batches_per_epoch,
        batches_per_epoch * epochs,
    );

    let cfg = TrainCfg {
        epochs,
        lr: 0.05,
        lr_drops: vec![epochs / 3, 2 * epochs / 3],
        method: "mali".into(),
        solver: "alf".into(),
        h: 0.0, // adaptive, paper's training tolerance
        rtol: 1e-1,
        atol: 1e-2,
        seed: 0,
        ..TrainCfg::default()
    };
    let report = ImageTrainer::new(cfg).train_ode(&mut model, &train, &test)?;

    println!("\nepoch  loss     acc     secs   f-evals");
    for e in &report.epochs {
        println!(
            "{:5}  {:.4}  {:.3}  {:5.1}  {}",
            e.epoch, e.train_loss, e.test_acc, e.wall_secs, e.f_evals
        );
    }
    println!(
        "\nfinal accuracy {:.3} in {:.1}s | solver-state peak {} | process RSS {}",
        report.final_acc,
        report.total_secs,
        fmt_bytes(report.peak_mem_bytes),
        fmt_bytes(process_rss_bytes()),
    );

    // persist the loss curve for EXPERIMENTS.md
    let rows: Vec<Json> = report
        .epochs
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("epoch", Json::Num(e.epoch as f64)),
                ("train_loss", Json::Num(e.train_loss)),
                ("test_acc", Json::Num(e.test_acc)),
                ("wall_secs", Json::Num(e.wall_secs)),
            ])
        })
        .collect();
    let summary = mali_ode::coordinator::report::summary(
        rows,
        vec![
            ("final_acc", Json::Num(report.final_acc)),
            ("total_secs", Json::Num(report.total_secs)),
            ("peak_mem_bytes", Json::Num(report.peak_mem_bytes as f64)),
        ],
    );
    mali_ode::coordinator::report::write_summary("runs", "e2e_image", &summary)?;
    println!("loss curve written to runs/e2e_image.json");

    anyhow::ensure!(
        report.final_acc > 0.3,
        "end-to-end training failed to learn (acc {})",
        report.final_acc
    );
    Ok(())
}
