//! Time-series modeling with a latent ODE (paper §4.3): train on
//! irregularly-observable SLIP-hopper trajectories and compare MALI
//! against the GRU sequence baseline.
//!
//! ```bash
//! cargo run --release --example time_series
//! ```

use mali_ode::grad::IvpSpec;
use mali_ode::models::latent::{LatentOde, SeqBaseline};
use mali_ode::models::SolveCfg;
use mali_ode::opt::by_name as opt_by_name;
use mali_ode::runtime::Engine;
use mali_ode::sim::hopper;
use mali_ode::solvers::dynamics::Dynamics;
use mali_ode::util::rng::Rng;
use std::rc::Rc;

fn main() -> anyhow::Result<()> {
    let engine = Rc::new(Engine::from_env()?);
    let mut rng = Rng::new(1);
    let mut model = LatentOde::new(engine.clone(), &mut rng)?;
    println!(
        "latent ODE: {} params | encoder sees {} frames, predicts {} future frames",
        model.param_count(),
        model.t_len,
        model.t_out,
    );

    let n_train = 8 * model.batch;
    let n_test = 2 * model.batch;
    let ds = hopper::generate(n_train + n_test, model.t_len, model.t_out, 3.0, 7);
    println!("simulated {} SLIP-hopper trajectories (Raibert-controlled)", ds.n);

    let solver = mali_ode::solvers::by_name("alf")?;
    let method = mali_ode::grad::by_name("mali")?;
    let spec = IvpSpec::fixed(0.0, 1.0, 0.25);

    let mut opt_enc = opt_by_name("adamax", 0.01, model.enc.len())?;
    let mut opt_dec = opt_by_name("adamax", 0.01, model.dec.len())?;
    let mut opt_dyn = opt_by_name("adamax", 0.01, model.dynamics.param_dim())?;

    let epochs = 10;
    for epoch in 0..epochs {
        let mut loss_sum = 0.0;
        let mut n_batches = 0;
        let mut order: Vec<usize> = (0..n_train).collect();
        rng.shuffle(&mut order);
        for chunk in order.chunks(model.batch) {
            if chunk.len() < model.batch {
                continue;
            }
            let mut seq = Vec::new();
            let mut tgt = Vec::new();
            for &i in chunk {
                seq.extend_from_slice(ds.observed(i, model.t_len));
                tgt.extend_from_slice(ds.target(i, model.t_len, model.t_out));
            }
            let cfg = SolveCfg {
                solver: &*solver,
                spec: spec.clone(),
                method: &*method,
            };
            let out = model.step(&seq, &tgt, &cfg, &mut rng)?;
            loss_sum += out.loss;
            n_batches += 1;
            opt_enc.step(&mut model.enc.value, &model.enc.grad);
            opt_dec.step(&mut model.dec.value, &model.dec.grad);
            let mut theta = model.dynamics.params().to_vec();
            opt_dyn.step(&mut theta, &model.dyn_grad);
            model.dynamics.set_params(&theta);
        }
        println!("epoch {epoch:2}: train ELBO loss {:.5}", loss_sum / n_batches as f64);
    }

    // held-out MSE, latent-ODE vs GRU baseline trained on the same data
    let cfg = SolveCfg {
        solver: &*solver,
        spec,
        method: &*method,
    };
    let mut seq = Vec::new();
    let mut tgt = Vec::new();
    for i in n_train..n_train + model.batch {
        seq.extend_from_slice(ds.observed(i, model.t_len));
        tgt.extend_from_slice(ds.target(i, model.t_len, model.t_out));
    }
    let preds = model.predict(&seq, &cfg)?;
    let ode_mse = LatentOde::mse(&preds, &tgt);

    let mut gru = SeqBaseline::new(engine, "gru", &mut rng)?;
    let mut opt = opt_by_name("adamax", 0.01, gru.params.len())?;
    for _ in 0..epochs {
        for start in (0..n_train).step_by(model.batch) {
            let mut s = Vec::new();
            let mut t = Vec::new();
            for i in start..start + model.batch {
                s.extend_from_slice(ds.observed(i, model.t_len));
                t.extend_from_slice(ds.target(i, model.t_len, model.t_out));
            }
            gru.step(&s, &t)?;
            opt.step(&mut gru.params.value, &gru.params.grad);
        }
    }
    let gp = gru.predict(&seq)?;
    let gru_mse = gp
        .iter()
        .zip(&tgt)
        .map(|(p, t)| ((p - t) as f64).powi(2))
        .sum::<f64>()
        / gp.len() as f64;

    println!("\nheld-out MSE: latent-ODE (MALI) {ode_mse:.5} | GRU baseline {gru_mse:.5}");
    Ok(())
}
