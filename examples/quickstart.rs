//! Quickstart: solve a Neural ODE and differentiate through it with MALI.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Walks the public API end to end on the paper's toy problem
//! (`dz/dt = αz`, `L = z(T)²`, paper Eq. 6) where every quantity has a
//! closed form — so you can see MALI's constant-memory gradient match the
//! analytic one, first with native Rust dynamics and then through a real
//! AOT-compiled HLO graph.

use mali_ode::grad::{by_name as grad_by_name, IvpSpec, SquareLoss};
use mali_ode::runtime::{Engine, HloDynamics};
use mali_ode::solvers::by_name as solver_by_name;
use mali_ode::solvers::dynamics::{Dynamics, LinearToy};
use mali_ode::util::mem::{fmt_bytes, MemTracker};
use std::rc::Rc;

fn main() -> anyhow::Result<()> {
    let (alpha, t_end) = (0.4, 2.0);
    let z0 = vec![1.0f32, -0.5, 0.8, 2.0];

    // ---- 1. native dynamics: MALI vs the analytic gradient ---------------
    let toy = LinearToy::new(alpha, z0.len());
    let (gz_ref, ga_ref) = toy.analytic_grads(&z0, t_end);

    let solver = solver_by_name("alf")?; // ALF: the invertible solver MALI needs
    let mali = grad_by_name("mali")?;
    let spec = IvpSpec::adaptive(0.0, t_end, 1e-5, 1e-6);
    let tracker = MemTracker::new();
    let res = mali.grad(&toy, &*solver, &spec, &z0, &SquareLoss, tracker)?;

    println!("toy problem  dz/dt = {alpha}·z,  L = z(T)²,  T = {t_end}");
    println!("  loss                = {:.6}", res.loss);
    println!("  dL/dz0 (MALI)       = {:?}", &res.grad_z0);
    println!("  dL/dz0 (analytic)   = {:?}", &gz_ref);
    println!("  dL/dα  (MALI)       = {:.5}  (analytic {:.5})", res.grad_theta[0], ga_ref);
    println!(
        "  forward steps N_t = {}, retained memory = {} (constant in N_t)",
        res.stats.fwd.n_accepted,
        fmt_bytes(res.stats.peak_mem_bytes),
    );
    let max_rel = res
        .grad_z0
        .iter()
        .zip(&gz_ref)
        .map(|(a, b)| ((a - b) / b).abs())
        .fold(0.0f32, f32::max);
    println!("  max relative gradient error = {max_rel:.2e}");

    // ---- 2. the same protocol through an AOT-compiled HLO graph ----------
    // Optional: needs the AOT artifacts and a PJRT runtime (the offline
    // build stubs PJRT — see DESIGN.md §2); the native path above is the
    // complete MALI demonstration either way.
    match Engine::from_env() {
        Ok(engine) => {
            let mut hlo = HloDynamics::new(Rc::new(engine), "toy")?;
            hlo.set_params(&[alpha as f32]);
            let tracker = MemTracker::new();
            let res_hlo = mali.grad(&hlo, &*solver, &spec, &z0, &SquareLoss, tracker)?;
            println!("\nsame solve via the PJRT runtime (artifacts/toy.*.hlo.txt):");
            println!("  dL/dz0 (MALI, HLO)  = {:?}", &res_hlo.grad_z0);
            println!("  dL/dα  (MALI, HLO)  = {:.5}", res_hlo.grad_theta[0]);
        }
        Err(e) => {
            println!("\n[skipping the HLO/PJRT section: {e:#}]");
        }
    }

    // ---- 3. compare against the adjoint method's reverse error -----------
    let dopri5 = solver_by_name("dopri5")?;
    let adjoint = grad_by_name("adjoint")?;
    let res_adj = adjoint.grad(&toy, &*dopri5, &spec, &z0, &SquareLoss, MemTracker::new())?;
    let adj_rel = res_adj
        .grad_z0
        .iter()
        .zip(&gz_ref)
        .map(|(a, b)| ((a - b) / b).abs())
        .fold(0.0f32, f32::max);
    println!("\nadjoint method on the same problem: max rel grad error = {adj_rel:.2e}");
    println!("(MALI reconstructs the exact forward trajectory via ψ⁻¹; the adjoint\n re-solves it as a separate IVP and inherits that reverse-time error.)");
    Ok(())
}
