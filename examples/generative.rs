//! Continuous generative modeling with FFJORD + MALI (paper §4.4): learn
//! a 2-D pinwheel density, report BPD before/after, and render samples
//! from the trained flow as ASCII art.
//!
//! ```bash
//! cargo run --release --example generative
//! ```

use mali_ode::data::density::Density2D;
use mali_ode::grad::IvpSpec;
use mali_ode::models::cnf::Ffjord;
use mali_ode::models::SolveCfg;
use mali_ode::opt::{by_name as opt_by_name, clip_grad_norm};
use mali_ode::runtime::Engine;
use mali_ode::util::rng::Rng;
use std::rc::Rc;

fn ascii_scatter(points: &[f32], n: usize, extent: f64) -> String {
    let mut grid = vec![0u32; n * n];
    for p in points.chunks(2) {
        let x = ((p[0] as f64 + extent) / (2.0 * extent) * n as f64) as isize;
        let y = ((p[1] as f64 + extent) / (2.0 * extent) * n as f64) as isize;
        if (0..n as isize).contains(&x) && (0..n as isize).contains(&y) {
            grid[y as usize * n + x as usize] += 1;
        }
    }
    let glyphs = [' ', '.', ':', 'o', 'O', '@'];
    let mut out = String::new();
    for row in (0..n).rev() {
        for col in 0..n {
            let c = grid[row * n + col] as usize;
            out.push(glyphs[c.min(glyphs.len() - 1)]);
        }
        out.push('\n');
    }
    out
}

fn main() -> anyhow::Result<()> {
    let engine = Rc::new(Engine::from_env()?);
    let mut rng = Rng::new(3);
    let mut model = Ffjord::new(engine, "cnf_density2d", &mut rng)?;
    model.lambda_k = 0.05; // RNODE regularization keeps the flow well-conditioned
    model.lambda_j = 0.05;
    println!("FFJORD (2-D): {} params, Hutchinson-divergence CNF", model.param_count());

    let solver = mali_ode::solvers::by_name("alf")?;
    let method = mali_ode::grad::by_name("mali")?;
    let cfg = SolveCfg {
        solver: &*solver,
        spec: IvpSpec::fixed(0.0, 1.0, 0.25),
        method: &*method,
    };

    let target = Density2D::Pinwheel;
    let x_test = target.sample_n(model.batch, &mut Rng::new(99));
    let before = model.bpd(&x_test, &cfg, &mut Rng::new(7))?;

    let mut opt = opt_by_name("adam", 1e-3, model.param_count())?;
    let steps = 200;
    for step in 0..steps {
        let x = target.sample_n(model.batch, &mut rng);
        let out = model.step(&x, &cfg, &mut rng)?;
        clip_grad_norm(&mut model.params.grad, 10.0);
        let g = model.params.grad.clone();
        opt.step(&mut model.params.value, &g);
        if step % 50 == 0 {
            println!("step {step:4}: loss {:.4}", out.loss);
        }
    }

    let after = model.bpd(&x_test, &cfg, &mut Rng::new(7))?;
    println!("\ntest BPD: {before:.4} → {after:.4} (lower is better)");

    // draw samples from the trained flow (reverse-time integration; the
    // trained dynamics are stiffer than at init, so sample adaptively)
    let sample_cfg = SolveCfg {
        solver: &*solver,
        spec: IvpSpec::adaptive(0.0, 1.0, 1e-3, 1e-4),
        method: &*method,
    };
    let mut samples = Vec::new();
    for k in 0..8 {
        let mut r = Rng::new(1000 + k);
        samples.extend(model.sample(&sample_cfg, &mut r)?);
    }
    let (mn, mx) = samples.iter().fold((f32::MAX, f32::MIN), |(a, b), &v| (a.min(v), b.max(v)));
    println!("\nsamples from the trained flow (range [{mn:.2}, {mx:.2}]):");
    println!("{}", ascii_scatter(&samples, 44, 2.0));
    println!("target density (pinwheel), same sample count:");
    let mut r = Rng::new(5);
    let reference = target.sample_n(samples.len() / 2, &mut r);
    println!("{}", ascii_scatter(&reference, 44, 2.0));
    Ok(())
}
