//! Multi-observation gradient tests — the contract of the observation-grid
//! refactor, enumerated through the shared `tests/common/methods.rs`
//! registry so new protocols auto-enroll:
//!
//! * `grad_obs` matches central finite differences of `forward_loss_obs`
//!   for **every** registered method;
//! * MALI's continuous ψ⁻¹ injection sweep agrees with the ACA, naive,
//!   and symplectic replays to roundoff on the same ALF solve, and its
//!   retained memory (via `MemTracker`) is constant in both the solver
//!   step count and the number of observations K;
//! * the centralized path reproduces the legacy segment-wise latent-ODE
//!   loop (loss, `dL/dθ`, `dL/dz₀`) within tolerance in fixed and
//!   adaptive modes while spending strictly fewer `f` evaluations;
//! * the batched path equals B solo runs row for row.
//!
//! Tolerances were calibrated against a numpy float32 port of this stack
//! (legacy-parity observed ≲ 1e-6 relative on the standard mild
//! `MlpDynamics`; FD errors ≲ 1e-5).

use mali_ode::grad::batch_driver::grad_obs_batched;
use mali_ode::grad::{
    by_name, forward_loss_obs, FnObsLoss, GradMethod, IvpSpec, ObsGrid, ObsGradResult,
    ObsSquareLoss,
};
use mali_ode::solvers::batch::BatchSpec;
use mali_ode::solvers::by_name as solver_by_name;
use mali_ode::solvers::dynamics::{Dynamics, LinearToy, MlpDynamics};
use mali_ode::solvers::integrate::integrate;
use mali_ode::solvers::Solver;
use mali_ode::util::mem::MemTracker;
use mali_ode::util::rng::Rng;
use std::cell::RefCell;

#[path = "common/methods.rs"]
mod methods;

use methods::{l2, solver_for, EXACT_METHODS, METHODS};

/// max |a - b| / max(1, max |b|)
fn rel(a: &[f32], b: &[f32]) -> f64 {
    let den = b.iter().fold(1.0f64, |m, &x| m.max(x.abs() as f64));
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64).abs())
        .fold(0.0f64, f64::max)
        / den
}

/// Every method's multi-observation gradients match central finite
/// differences of the end-to-end observation loss (fixed grid, so the
/// perturbed runs share the discretization).
#[test]
fn grad_obs_matches_finite_differences_all_methods() {
    let mut rng = Rng::new(7);
    let mut dynamics = MlpDynamics::new(3, 4, &mut rng);
    let z0 = vec![0.4f32, -0.3, 0.2];
    let spec = IvpSpec::fixed(0.0, 0.8, 0.1);
    let grid = ObsGrid::new(vec![0.3, 0.55, 0.8]).unwrap();
    let head = ObsSquareLoss {
        weights: vec![1.0, 0.5, 2.0],
    };

    for method in METHODS {
        let solver = solver_by_name(solver_for(method)).unwrap();
        let m = by_name(method).unwrap();
        let r = m
            .grad_obs(&dynamics, &*solver, &spec, &grid, &z0, &head, MemTracker::new())
            .unwrap();
        assert_eq!(r.obs_losses.len(), 3, "{method}");
        assert!(
            (r.loss - r.obs_losses.iter().sum::<f64>()).abs() < 1e-12,
            "{method}: total is the sum of per-observation losses"
        );

        let theta0 = dynamics.params().to_vec();
        let eps = 1e-2f32;
        for &k in &[0usize, theta0.len() / 3, theta0.len() - 1] {
            let mut tp = theta0.clone();
            tp[k] += eps;
            dynamics.set_params(&tp);
            let (lp, _, _, _) =
                forward_loss_obs(&dynamics, &*solver, &spec, &grid, &z0, &head).unwrap();
            let mut tm = theta0.clone();
            tm[k] -= eps;
            dynamics.set_params(&tm);
            let (lm, _, _, _) =
                forward_loss_obs(&dynamics, &*solver, &spec, &grid, &z0, &head).unwrap();
            dynamics.set_params(&theta0);
            let fd = (lp - lm) / (2.0 * eps as f64);
            let got = r.grad_theta[k] as f64;
            assert!(
                (fd - got).abs() < 2e-2 * (1.0 + fd.abs()),
                "{method} θ[{k}]: fd {fd} vs {got}"
            );
        }
        for j in 0..z0.len() {
            let mut zp = z0.clone();
            zp[j] += eps;
            let (lp, _, _, _) =
                forward_loss_obs(&dynamics, &*solver, &spec, &grid, &zp, &head).unwrap();
            let mut zm = z0.clone();
            zm[j] -= eps;
            let (lm, _, _, _) =
                forward_loss_obs(&dynamics, &*solver, &spec, &grid, &zm, &head).unwrap();
            let fd = (lp - lm) / (2.0 * eps as f64);
            let got = r.grad_z0[j] as f64;
            assert!(
                (fd - got).abs() < 2e-2 * (1.0 + fd.abs()),
                "{method} z0[{j}]: fd {fd} vs {got}"
            );
        }
    }
}

/// MALI's continuous injection sweep == ACA == naive == symplectic to
/// roundoff on the same solve (the exact set backprops through the same
/// accepted steps with exact states), in fixed and adaptive modes — on
/// ALF and on the reversible-4 composition.
#[test]
fn mali_aca_naive_obs_agree() {
    let mut rng = Rng::new(42);
    let dynamics = MlpDynamics::new(5, 7, &mut rng);
    let z0: Vec<f32> = (0..5).map(|i| 0.25 * i as f32 - 0.5).collect();
    let grid = ObsGrid::new(vec![0.3, 0.55, 0.8]).unwrap();
    let head = ObsSquareLoss {
        weights: vec![1.0, 0.5, 2.0],
    };
    for sname in ["alf", "reversible4"] {
        let solver = solver_by_name(sname).unwrap();
        for spec in [
            IvpSpec::fixed(0.0, 0.8, 0.1),
            IvpSpec::adaptive(0.0, 0.8, 1e-3, 1e-5),
        ] {
            let results: Vec<ObsGradResult> = EXACT_METHODS
                .iter()
                .map(|m| {
                    by_name(m)
                        .unwrap()
                        .grad_obs(&dynamics, &*solver, &spec, &grid, &z0, &head, MemTracker::new())
                        .unwrap()
                })
                .collect();
            for r in &results[1..] {
                assert!((r.loss - results[0].loss).abs() < 1e-6, "{sname}");
                for k in 0..grid.len() {
                    assert!((r.obs_losses[k] - results[0].obs_losses[k]).abs() < 1e-6);
                }
                assert!(
                    l2(&r.grad_theta, &results[0].grad_theta) < 1e-4,
                    "{sname} θ mismatch {}",
                    l2(&r.grad_theta, &results[0].grad_theta)
                );
                assert!(l2(&r.grad_z0, &results[0].grad_z0) < 1e-4, "{sname}");
            }
            // MALI reconstructs z₀ through the whole multi-observation span
            let rec = results[0].reconstructed_z0.as_ref().unwrap();
            for (r, z) in rec.iter().zip(&z0) {
                assert!((r - z).abs() < 1e-3 * (1.0 + z.abs()), "{sname} ψ⁻¹ recon");
            }
        }
    }
}

/// The legacy segment-wise loop (what `models/latent.rs` hand-rolled
/// before the refactor): forward advance with per-segment `solver.init`
/// re-initialisation + checkpoints, then per-segment `method.grad` calls
/// chaining the running cotangent through `FnLoss` heads.
#[allow(clippy::too_many_arguments)]
fn legacy_segmentwise(
    method: &dyn GradMethod,
    solver: &dyn Solver,
    dynamics: &dyn Dynamics,
    spec: &IvpSpec,
    times: &[f64],
    z0: &[f32],
    weights: &[f64],
) -> (f64, Vec<f32>, Vec<f32>, u64) {
    use mali_ode::grad::FnLoss;
    use mali_ode::solvers::integrate::ErrorNorm;

    // forward: checkpoint the state at each observation
    let mut checkpoints: Vec<Vec<f32>> = vec![z0.to_vec()];
    let mut f_evals = 0u64;
    let mut t_prev = spec.t0;
    for &t in times {
        let s0 = solver.init(dynamics, t_prev, checkpoints.last().unwrap());
        let (s_end, st) = integrate(
            solver,
            dynamics,
            t_prev,
            t,
            s0,
            &spec.mode,
            &ErrorNorm::Full,
            &mut (),
        )
        .unwrap();
        f_evals += st.f_evals;
        checkpoints.push(s_end.z);
        t_prev = t;
    }
    // backward: per-segment grad with the running cotangent injected
    let head = ObsSquareLoss {
        weights: weights.to_vec(),
    };
    use mali_ode::grad::ObsLossHead;
    let mut loss_total = 0.0f64;
    let mut grad_theta = vec![0.0f32; dynamics.param_dim()];
    let mut a_z = vec![0.0f32; z0.len()];
    for k in (0..times.len()).rev() {
        let (l, g) = head.loss_grad_at(k, times[k], &checkpoints[k + 1]);
        loss_total += l;
        for (a, d) in a_z.iter_mut().zip(&g) {
            *a += d;
        }
        let seg = IvpSpec {
            t0: if k == 0 { spec.t0 } else { times[k - 1] },
            t1: times[k],
            mode: spec.mode.clone(),
            norm: ErrorNorm::Full,
        };
        let snapshot = RefCell::new(a_z.clone());
        let seg_head = FnLoss(|_z: &[f32]| (0.0, snapshot.borrow().clone()));
        let res = method
            .grad(
                dynamics,
                solver,
                &seg,
                &checkpoints[k],
                &seg_head,
                MemTracker::new(),
            )
            .unwrap();
        for (g, d) in grad_theta.iter_mut().zip(&res.grad_theta) {
            *g += d;
        }
        a_z = res.grad_z0;
        f_evals += res.stats.f_evals;
    }
    (loss_total, grad_theta, a_z, f_evals)
}

/// The centralized `grad_obs` reproduces the legacy segment-wise loop in
/// loss / dL/dθ / dL/dz₀ within tolerance — in fixed AND adaptive modes —
/// while spending strictly fewer `f` evaluations (the legacy loop pays a
/// duplicated forward pass).
#[test]
fn grad_obs_matches_legacy_segmentwise_loop() {
    let mut rng = Rng::new(7);
    let dynamics = MlpDynamics::new(3, 4, &mut rng);
    let z0 = vec![0.4f32, -0.3, 0.2];
    let times = [0.25, 0.5, 0.75, 1.0];
    let weights = [1.0f64; 4];
    let grid = ObsGrid::new(times.to_vec()).unwrap();
    let head = ObsSquareLoss {
        weights: weights.to_vec(),
    };

    for spec in [
        IvpSpec::fixed(0.0, 1.0, 0.25),
        IvpSpec::adaptive(0.0, 1.0, 1e-5, 1e-7),
    ] {
        for method in METHODS {
            let solver = solver_by_name(solver_for(method)).unwrap();
            let m = by_name(method).unwrap();
            let new = m
                .grad_obs(&dynamics, &*solver, &spec, &grid, &z0, &head, MemTracker::new())
                .unwrap();
            let (leg_loss, leg_th, leg_z0, leg_f) =
                legacy_segmentwise(&*m, &*solver, &dynamics, &spec, &times, &z0, &weights);

            assert!(
                (new.loss - leg_loss).abs() < 1e-3 * (1.0 + leg_loss.abs()),
                "{method}: loss {} vs legacy {leg_loss}",
                new.loss
            );
            assert!(
                rel(&new.grad_theta, &leg_th) < 1e-3,
                "{method}: θ parity {}",
                rel(&new.grad_theta, &leg_th)
            );
            assert!(
                rel(&new.grad_z0, &leg_z0) < 1e-3,
                "{method}: z₀ parity {}",
                rel(&new.grad_z0, &leg_z0)
            );
            // one pass beats forward-twice: strictly fewer f evaluations
            // (leg_f undercounts the legacy loop — per-segment init evals
            // are not included — so this bound is conservative)
            assert!(
                new.stats.f_evals < leg_f,
                "{method}: f_evals {} vs legacy {leg_f}",
                new.stats.f_evals
            );
        }
    }
}

/// MALI's multi-observation memory law: the tracked peak equals the
/// augmented end state — `N_z(N_f + 1)` with N_f = 1 — **constant** in
/// both the solver step count and the number of observations K, while
/// ACA's checkpoint store grows with the step count.
#[test]
fn mali_obs_memory_constant_in_steps_and_k() {
    let toy = LinearToy::new(0.8, 8);
    let z0 = vec![1.0f32; 8];
    let solver = solver_by_name("alf").unwrap();
    let peak = |method: &str, h: f64, k: usize| -> usize {
        let grid = ObsGrid::uniform(0.0, 2.0, k);
        let head = ObsSquareLoss {
            weights: vec![1.0; k],
        };
        let spec = IvpSpec::fixed(0.0, 2.0, h);
        let tracker = MemTracker::new();
        by_name(method)
            .unwrap()
            .grad_obs(&toy, &*solver, &spec, &grid, &z0, &head, tracker.clone())
            .unwrap();
        tracker.peak_bytes()
    };
    let base = peak("mali", 0.25, 4);
    // the augmented end state: z and v, 8 f32 each
    assert_eq!(base, 2 * 8 * 4, "N_z(N_f + 1) law");
    assert_eq!(base, peak("mali", 0.02, 4), "constant in step count");
    assert_eq!(base, peak("mali", 0.02, 32), "constant in K");
    assert_eq!(base, peak("mali", 0.25, 1), "K = 1 degenerates to grad()");
    // ACA at the same resolution pays the checkpoint store
    assert!(
        peak("aca", 0.02, 4) > 10 * base,
        "ACA checkpoint store should dwarf MALI's end state"
    );
}

/// Batched multi-observation gradients equal B solo runs row for row —
/// losses, gradients, per-sample controller decisions — for all four
/// methods, in fixed and adaptive modes.
#[test]
fn batched_obs_equals_solo_rows_all_methods() {
    let mut rng = Rng::new(77);
    let dynamics = MlpDynamics::new(3, 4, &mut rng);
    let bspec = BatchSpec::new(4, 3);
    let mut z0 = vec![0.0f32; bspec.flat_len()];
    rng.fill_uniform_sym(&mut z0, 0.6);
    for (b, scale) in [0.05f32, 0.6, 1.0, 1.6].iter().enumerate() {
        for x in &mut z0[b * 3..(b + 1) * 3] {
            *x *= scale;
        }
    }
    let grid = ObsGrid::new(vec![0.3, 0.55, 0.8]).unwrap();
    let head = ObsSquareLoss {
        weights: vec![1.0, 0.5, 2.0],
    };

    for spec in [
        IvpSpec::fixed(0.0, 0.8, 0.1),
        IvpSpec::adaptive(0.0, 0.8, 1e-3, 1e-5),
    ] {
        for method in METHODS {
            let solver = solver_by_name(solver_for(method)).unwrap();
            let m = by_name(method).unwrap();
            let solos: Vec<ObsGradResult> = (0..bspec.batch)
                .map(|b| {
                    m.grad_obs(
                        &dynamics,
                        &*solver,
                        &spec,
                        &grid,
                        bspec.row(&z0, b),
                        &head,
                        MemTracker::new(),
                    )
                    .unwrap()
                })
                .collect();
            let batched = grad_obs_batched(
                &*m,
                &dynamics,
                &*solver,
                &spec,
                &grid,
                &z0,
                &bspec,
                &head,
                MemTracker::new(),
            )
            .unwrap();
            assert_eq!(batched.batch, 4, "{method}");

            // per-observation losses: batch totals equal Σ solo
            for k in 0..grid.len() {
                let want: f64 = solos.iter().map(|s| s.obs_losses[k]).sum();
                assert!(
                    (batched.obs_losses[k] - want).abs() < 1e-9 * (1.0 + want.abs()),
                    "{method} obs loss {k}"
                );
            }
            let want_total: f64 = solos.iter().map(|s| s.loss).sum();
            assert!((batched.loss - want_total).abs() < 1e-9 * (1.0 + want_total.abs()));

            for (b, solo) in solos.iter().enumerate() {
                for (i, (&got, &want)) in bspec
                    .row(&batched.grad_z0, b)
                    .iter()
                    .zip(&solo.grad_z0)
                    .enumerate()
                {
                    assert!(
                        (got - want).abs() < 1e-6 * (1.0 + want.abs()),
                        "{method} grad_z0[{b}][{i}]: {got} vs {want}"
                    );
                }
                for (&got, &want) in bspec.row(&batched.z_final, b).iter().zip(&solo.z_final) {
                    assert!((got - want).abs() < 1e-6 * (1.0 + want.abs()), "{method} z_final {b}");
                }
                assert_eq!(
                    batched.per_sample_fwd[b].n_accepted, solo.stats.fwd.n_accepted,
                    "{method} accepted-step count row {b}"
                );
                assert_eq!(
                    batched.per_sample_fwd[b].n_trials, solo.stats.fwd.n_trials,
                    "{method} trial count row {b}"
                );
            }

            // θ: batched sum equals Σ solo (summation order differs)
            let mut theta_sum = vec![0.0f64; solos[0].grad_theta.len()];
            for solo in &solos {
                for (acc, &g) in theta_sum.iter_mut().zip(&solo.grad_theta) {
                    *acc += g as f64;
                }
            }
            let scale: f64 = theta_sum.iter().map(|g| g.abs()).fold(1.0, f64::max);
            for (k, (&got, &want)) in batched.grad_theta.iter().zip(&theta_sum).enumerate() {
                assert!(
                    ((got as f64) - want).abs() < 1e-4 * scale,
                    "{method} grad_theta[{k}]: {got} vs {want}"
                );
            }
        }
    }
}

/// Misuse is rejected loudly: empty grids on grad_obs, MALI without ψ⁻¹.
#[test]
fn grad_obs_rejects_misuse() {
    let toy = LinearToy::new(1.0, 2);
    let z0 = [1.0f32, 0.5];
    let spec = IvpSpec::fixed(0.0, 1.0, 0.25);
    let head = ObsSquareLoss { weights: vec![] };
    for method in METHODS {
        let solver = solver_by_name(solver_for(method)).unwrap();
        let err = by_name(method)
            .unwrap()
            .grad_obs(
                &toy,
                &*solver,
                &spec,
                &ObsGrid::none(),
                &z0,
                &head,
                MemTracker::new(),
            )
            .unwrap_err();
        assert!(err.to_string().contains("empty observation grid"), "{method}");
    }
    let err = by_name("mali")
        .unwrap()
        .grad_obs(
            &toy,
            &*solver_by_name("dopri5").unwrap(),
            &spec,
            &ObsGrid::new(vec![1.0]).unwrap(),
            &z0,
            &head,
            MemTracker::new(),
        )
        .unwrap_err();
    assert!(err.to_string().contains("invertible"));
}

/// A closure observation head (the model-side pattern: decode + cotangent
/// in one lambda) flows through `grad_obs` unchanged — and a K = 1 grid
/// at `t1` reproduces the terminal-loss `grad()` result exactly for every
/// method (the CDE rewiring contract).
#[test]
fn terminal_grid_reproduces_grad() {
    use mali_ode::grad::SquareLoss;
    let mut rng = Rng::new(11);
    let dynamics = MlpDynamics::new(3, 4, &mut rng);
    let z0 = vec![0.3f32, -0.2, 0.5];
    let grid = ObsGrid::new(vec![0.8]).unwrap();
    let head = FnObsLoss(|_k, _t, z: &[f32]| {
        let l: f64 = z.iter().map(|&x| (x as f64) * (x as f64)).sum();
        (l, z.iter().map(|&x| 2.0 * x).collect())
    });
    for spec in [
        IvpSpec::fixed(0.0, 0.8, 0.1),
        IvpSpec::adaptive(0.0, 0.8, 1e-3, 1e-5),
    ] {
        for method in METHODS {
            let solver = solver_by_name(solver_for(method)).unwrap();
            let m = by_name(method).unwrap();
            let obs = m
                .grad_obs(&dynamics, &*solver, &spec, &grid, &z0, &head, MemTracker::new())
                .unwrap();
            let term = m
                .grad(&dynamics, &*solver, &spec, &z0, &SquareLoss, MemTracker::new())
                .unwrap();
            assert!(
                (obs.loss - term.loss).abs() < 1e-9 * (1.0 + term.loss.abs()),
                "{method} loss"
            );
            assert!(l2(&obs.grad_theta, &term.grad_theta) < 1e-5, "{method} θ");
            assert!(l2(&obs.grad_z0, &term.grad_z0) < 1e-5, "{method} z₀");
            assert_eq!(
                obs.stats.fwd.n_accepted, term.stats.fwd.n_accepted,
                "{method}: identical forward grid"
            );
        }
    }
}
