//! Batch/single equivalence — the contract of the batch-first refactor:
//! a `[B, N_z]` batched gradient run must match B independent
//! single-sample runs to roundoff in loss, `dL/dθ`, `dL/dz₀` and (fixed
//! step) exactly in `f`-evaluation counts, for **all four** gradient
//! protocols; and the per-sample active-mask controller of the adaptive
//! loop must never change any sample's accepted-step count versus a solo
//! run.

use mali_ode::grad::batch_driver::grad_batched;
use mali_ode::grad::{by_name, GradResult, IvpSpec, SquareLoss};
use mali_ode::solvers::batch::BatchSpec;
use mali_ode::solvers::by_name as solver_by_name;
use mali_ode::solvers::dynamics::MlpDynamics;
use mali_ode::util::mem::MemTracker;
use mali_ode::util::rng::Rng;

const METHODS: [&str; 4] = ["mali", "aca", "naive", "adjoint"];

/// MALI needs ψ⁻¹ (ALF); the adjoint re-solve runs the usual RK pairing.
fn solver_for(method: &str) -> &'static str {
    match method {
        "adjoint" => "rk23",
        _ => "alf",
    }
}

/// B=4 rows of a 3-dim MLP Neural ODE at different scales.
fn problem() -> (MlpDynamics, Vec<f32>, BatchSpec) {
    let mut rng = Rng::new(77);
    let dynamics = MlpDynamics::new(3, 4, &mut rng);
    let bspec = BatchSpec::new(4, 3);
    let mut z0 = vec![0.0f32; bspec.flat_len()];
    rng.fill_uniform_sym(&mut z0, 0.6);
    // desynchronize the adaptive controllers: rows at different magnitudes
    for (b, scale) in [0.05f32, 0.6, 1.0, 1.6].iter().enumerate() {
        for x in &mut z0[b * 3..(b + 1) * 3] {
            *x *= scale;
        }
    }
    (dynamics, z0, bspec)
}

fn solo_runs(
    dynamics: &MlpDynamics,
    z0: &[f32],
    bspec: &BatchSpec,
    method: &str,
    spec: &IvpSpec,
) -> Vec<GradResult> {
    let m = by_name(method).unwrap();
    let solver = solver_by_name(solver_for(method)).unwrap();
    (0..bspec.batch)
        .map(|b| {
            m.grad(
                dynamics,
                &*solver,
                spec,
                bspec.row(z0, b),
                &SquareLoss,
                MemTracker::new(),
            )
            .unwrap()
        })
        .collect()
}

fn check_equivalence(spec: &IvpSpec, fixed_step: bool) {
    let (dynamics, z0, bspec) = problem();
    for method in METHODS {
        let solos = solo_runs(&dynamics, &z0, &bspec, method, spec);
        let m = by_name(method).unwrap();
        let solver = solver_by_name(solver_for(method)).unwrap();
        let batched = grad_batched(
            &*m,
            &dynamics,
            &*solver,
            spec,
            &z0,
            &bspec,
            &SquareLoss,
            MemTracker::new(),
        )
        .unwrap();
        assert_eq!(batched.batch, 4);
        assert_eq!(batched.losses.len(), 4, "{method}: separable losses");

        for (b, solo) in solos.iter().enumerate() {
            assert!(
                (batched.losses[b] - solo.loss).abs() < 1e-9 * (1.0 + solo.loss.abs()),
                "{method} loss row {b}: {} vs {}",
                batched.losses[b],
                solo.loss
            );
            for (i, (&got, &want)) in bspec
                .row(&batched.grad_z0, b)
                .iter()
                .zip(&solo.grad_z0)
                .enumerate()
            {
                assert!(
                    (got - want).abs() < 1e-6 * (1.0 + want.abs()),
                    "{method} grad_z0[{b}][{i}]: {got} vs {want}"
                );
            }
            for (i, (&got, &want)) in bspec
                .row(&batched.z_final, b)
                .iter()
                .zip(&solo.z_final)
                .enumerate()
            {
                assert!(
                    (got - want).abs() < 1e-6 * (1.0 + want.abs()),
                    "{method} z_final[{b}][{i}]: {got} vs {want}"
                );
            }
            // per-sample step control must match the solo controller
            assert_eq!(
                batched.per_sample_fwd[b].n_accepted, solo.stats.fwd.n_accepted,
                "{method} accepted-step count row {b}"
            );
            assert_eq!(
                batched.per_sample_fwd[b].n_trials, solo.stats.fwd.n_trials,
                "{method} trial count row {b}"
            );
        }

        // θ-gradient: batched sum equals the sum of solo runs (summation
        // order differs, so roundoff-level tolerance)
        let mut theta_sum = vec![0.0f64; dynamics_theta_len(&solos)];
        for solo in &solos {
            for (acc, &g) in theta_sum.iter_mut().zip(&solo.grad_theta) {
                *acc += g as f64;
            }
        }
        let theta_scale: f64 = theta_sum.iter().map(|g| g.abs()).fold(1.0, f64::max);
        for (k, (&got, &want)) in batched.grad_theta.iter().zip(&theta_sum).enumerate() {
            assert!(
                ((got as f64) - want).abs() < 1e-4 * theta_scale,
                "{method} grad_theta[{k}]: {got} vs {want}"
            );
        }

        if fixed_step {
            // exact evaluation-count parity on the shared fixed grid
            let solo_f: u64 = solos.iter().map(|s| s.stats.f_evals).sum();
            assert_eq!(
                batched.stats.f_evals, solo_f,
                "{method}: batched f_evals vs Σ solo"
            );
            let solo_vjp: u64 = solos.iter().map(|s| s.stats.vjp_evals).sum();
            assert_eq!(
                batched.stats.vjp_evals, solo_vjp,
                "{method}: batched vjp_evals vs Σ solo"
            );
        }
    }
}

fn dynamics_theta_len(solos: &[GradResult]) -> usize {
    solos[0].grad_theta.len()
}

/// Fixed-step: every row shares the grid; batched must equal 4 solos to
/// roundoff in loss / dL/dθ / dL/dz₀ and exactly in f-evals.
#[test]
fn fixed_step_batched_equals_solo_all_methods() {
    check_equivalence(&IvpSpec::fixed(0.0, 0.8, 0.1), true);
}

/// Adaptive: per-sample controllers desynchronize, and the active mask
/// must not change any controller decision — accepted/trial counts and
/// results match solo runs row for row.
#[test]
fn adaptive_batched_equals_solo_all_methods() {
    check_equivalence(&IvpSpec::adaptive(0.0, 0.8, 1e-3, 1e-5), false);
}

/// The seminorm adjoint variant also survives batching.
#[test]
fn seminorm_adjoint_batched_matches_solo() {
    let (dynamics, z0, bspec) = problem();
    let spec = IvpSpec::adaptive(0.0, 0.6, 1e-3, 1e-5);
    let m = by_name("adjoint-seminorm").unwrap();
    let solver = solver_by_name("rk23").unwrap();
    let batched = grad_batched(
        &*m,
        &dynamics,
        &*solver,
        &spec,
        &z0,
        &bspec,
        &SquareLoss,
        MemTracker::new(),
    )
    .unwrap();
    for b in 0..bspec.batch {
        let solo = m
            .grad(
                &dynamics,
                &*solver,
                &spec,
                bspec.row(&z0, b),
                &SquareLoss,
                MemTracker::new(),
            )
            .unwrap();
        assert!(
            (batched.losses[b] - solo.loss).abs() < 1e-9 * (1.0 + solo.loss.abs()),
            "loss row {b}"
        );
        for (&got, &want) in bspec.row(&batched.grad_z0, b).iter().zip(&solo.grad_z0) {
            assert!((got - want).abs() < 1e-6 * (1.0 + want.abs()), "row {b}");
        }
    }
}

/// MALI's batched ψ⁻¹ sweep reconstructs every row's z₀ to roundoff, and
/// the retained memory obeys the Table-1 law with `N_z → B·N_z`: exactly
/// the flat end state (z and v), flat in the number of solver steps.
#[test]
fn batched_mali_memory_law_scales_with_batch() {
    let (dynamics, z0, bspec) = problem();
    let m = by_name("mali").unwrap();
    let solver = solver_by_name("alf").unwrap();
    let peak = |h: f64| -> (usize, Vec<f32>) {
        let tracker = MemTracker::new();
        let res = grad_batched(
            &*m,
            &dynamics,
            &*solver,
            &IvpSpec::fixed(0.0, 2.0, h),
            &z0,
            &bspec,
            &SquareLoss,
            tracker.clone(),
        )
        .unwrap();
        (tracker.peak_bytes(), res.reconstructed_z0.unwrap())
    };
    let (few, rec) = peak(0.5);
    let (many, _) = peak(0.05);
    // constant in step count, equal to the augmented end state: 2·B·N_z·4B
    assert_eq!(few, many, "MALI peak grew with step count");
    assert_eq!(few, 2 * bspec.flat_len() * 4, "B·N_z(N_f+1) law");
    for (i, (&r, &z)) in rec.iter().zip(&z0).enumerate() {
        assert!((r - z).abs() < 1e-3 * (1.0 + z.abs()), "ψ⁻¹ row recon [{i}]");
    }
}
