//! The ONE counting global allocator shared by every allocation-
//! accounting binary (`tests/alloc_steady.rs`, `tests/alloc_serve.rs`,
//! `benches/perf_hotpath.rs` — each pulls this file in with `#[path]`).
//!
//! Counting rules (keep them here, in one place, so the zero-allocation
//! gates cannot silently diverge between binaries):
//!
//! * every allocation path counts one call — `alloc`, `alloc_zeroed`
//!   and `realloc` alike (a realloc is new allocator traffic even when
//!   it moves nothing);
//! * bytes are the requested size (`layout.size()`; for `realloc` the
//!   `new_size`), so bytes/step can be attributed per configuration;
//! * `dealloc` is deliberately uncounted — the gates pin *pressure on
//!   the allocator*, and frees of warm-up buffers would only blur that.
//!
//! Each binary still declares its own `#[global_allocator] static`
//! (rustc requires the registration per crate); only the type and the
//! counters live here.

#![allow(dead_code)] // each including binary uses a subset

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Counting wrapper over the system allocator (see module docs).
pub struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Allocation calls so far.
pub fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// `(calls, bytes)` snapshot.
pub fn alloc_snapshot() -> (u64, u64) {
    (
        ALLOCS.load(Ordering::Relaxed),
        ALLOC_BYTES.load(Ordering::Relaxed),
    )
}
