//! Shared method-registry fixture: the single place the integration
//! suites enumerate gradient protocols and solvers, so a new
//! `GradMethod` or `Solver` auto-enrolls in FD fuzz, exact-agreement
//! cross-checks, batch ≡ solo, and obs-grid injection coverage by being
//! added to these tables (and nowhere else).
//!
//! Included via `#[path = "common/methods.rs"] mod methods;` — the inner
//! `allow(dead_code)` keeps suites that use only a slice of the fixture
//! warning-free.
#![allow(dead_code)]

use mali_ode::grad::{by_name, GradMethod, ObsGrid};
use mali_ode::solvers::{by_name as solver_by_name, Solver};
use mali_ode::util::rng::Rng;

/// Every registered gradient protocol (Table 1 order + the symplectic
/// adjoint extension).
pub const METHODS: [&str; 5] = ["mali", "aca", "naive", "adjoint", "symplectic"];

/// Protocols whose gradients are exact to roundoff on the *same* solve —
/// index 0 (MALI) is the agreement anchor the suites compare against.
/// The adjoint method is excluded: it re-solves the trajectory backwards,
/// so it only agrees up to the reverse-solve tolerance.
pub const EXACT_METHODS: [&str; 4] = ["mali", "aca", "naive", "symplectic"];

/// The solver axis of the method grid: an adaptive RK pair, the paper's
/// ALF, and the 4th-order reversible composition.
pub const SOLVERS: [&str; 3] = ["heun-euler", "alf", "reversible4"];

/// Default solver per method (the pairing fig4/table1 report): the
/// reconstruction- and checkpoint-based protocols ride ALF; the adjoint
/// method uses a plain RK pair, as in the paper's baselines.
pub fn solver_for(method: &str) -> &'static str {
    match method {
        "adjoint" => "heun-euler",
        _ => "alf",
    }
}

/// Whether a `GradMethod` × `Solver` pair is runnable: MALI reconstructs
/// the trajectory through ψ⁻¹, so it needs an invertible solver.
pub fn supports(method: &str, solver: &str) -> bool {
    method != "mali" || matches!(solver, "alf" | "reversible4")
}

/// All supported `(method, solver)` pairs of the grid —
/// `METHODS × SOLVERS` minus the pairs [`supports`] rejects.
pub fn pairs() -> Vec<(&'static str, &'static str)> {
    let mut out = Vec::new();
    for m in METHODS {
        for s in SOLVERS {
            if supports(m, s) {
                out.push((m, s));
            }
        }
    }
    out
}

pub fn method(name: &str) -> Box<dyn GradMethod + Send + Sync> {
    by_name(name).unwrap()
}

pub fn solver(name: &str) -> Box<dyn Solver + Send + Sync> {
    solver_by_name(name).unwrap()
}

pub fn l2(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        .sqrt()
}

/// Random observation grid: 1–3 strictly increasing times inside
/// `(0, t1]`, sometimes ending exactly at `t1`.
pub fn random_grid(rng: &mut Rng, t1: f64) -> ObsGrid {
    let k = 1 + rng.below(3);
    let mut times: Vec<f64> = Vec::with_capacity(k);
    let mut lo = 0.15 * t1;
    for i in 0..k {
        let hi = t1 * (i as f64 + 1.0) / k as f64;
        let t = if i + 1 == k && rng.below(2) == 0 {
            t1
        } else {
            rng.range(lo, hi.max(lo + 1e-3))
        };
        times.push(t.min(t1));
        lo = times[i] + 1e-3;
    }
    ObsGrid::new(times).unwrap()
}
