//! Integration tests over the AOT runtime: every exported executable is
//! loaded through the real PJRT client and cross-checked against native
//! Rust implementations or mathematical identities.
//!
//! Requires `make artifacts` (the repo ships them built).

use mali_ode::grad::{by_name as grad_by_name, IvpSpec, SquareLoss};
use mali_ode::runtime::{Engine, HloDynamics};
use mali_ode::solvers::alf::AlfSolver;
use mali_ode::solvers::by_name as solver_by_name;
use mali_ode::solvers::dynamics::{Dynamics, LinearToy};
use mali_ode::util::mem::MemTracker;
use mali_ode::util::rng::Rng;
use std::rc::Rc;

/// `None` (test skipped) when the AOT artifacts or the PJRT runtime are
/// absent — the offline build stubs PJRT (`runtime::xla_stub`), so this
/// whole suite only runs where device execution is actually possible.
fn engine() -> Option<Rc<Engine>> {
    Engine::from_env_or_skip("runtime integration test")
}

/// Every artifact in the manifest loads, compiles and executes with
/// finite outputs.
#[test]
fn all_artifacts_execute() {
    let Some(e) = engine() else { return };
    let names: Vec<String> = e.manifest.entries.keys().cloned().collect();
    assert!(names.len() >= 60, "expected the full artifact set, got {}", names.len());
    for name in &names {
        let spec = e.manifest.entry(name).unwrap().clone();
        let inputs: Vec<Vec<f32>> = spec
            .inputs
            .iter()
            .map(|t| vec![0.05f32; t.len().max(1)])
            .collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let out = e.call(name, &refs).unwrap_or_else(|err| panic!("{name}: {err:#}"));
        assert_eq!(out.len(), spec.outputs.len(), "{name}");
        for (i, o) in out.iter().enumerate() {
            assert!(
                o.iter().all(|v| v.is_finite()),
                "{name} output {i} not finite"
            );
        }
    }
}

/// The full gradient protocol through HLO toy dynamics matches the
/// closed-form solution (paper Eq. 7) — the end-to-end numerical anchor
/// of the runtime.
#[test]
fn mali_through_hlo_matches_analytic() {
    let Some(e) = engine() else { return };
    let alpha = 0.35f64;
    let mut d = HloDynamics::new(e, "toy").unwrap();
    d.set_params(&[alpha as f32]);
    let native = LinearToy::new(alpha, 4);
    let z0 = vec![1.0f32, -0.4, 0.7, 2.0];
    let t_end = 1.5;
    let (gz_ref, ga_ref) = native.analytic_grads(&z0, t_end);

    let solver = solver_by_name("alf").unwrap();
    let mali = grad_by_name("mali").unwrap();
    let spec = IvpSpec::adaptive(0.0, t_end, 1e-5, 1e-6);
    let res = mali
        .grad(&d, &*solver, &spec, &z0, &SquareLoss, MemTracker::new())
        .unwrap();
    for (g, r) in res.grad_z0.iter().zip(&gz_ref) {
        assert!(((g - r) / r).abs() < 1e-3, "dL/dz0: {g} vs {r}");
    }
    assert!(
        ((res.grad_theta[0] as f64 - ga_ref) / ga_ref).abs() < 1e-3,
        "dL/dα: {} vs {ga_ref}",
        res.grad_theta[0]
    );
}

/// All gradient methods agree on a real HLO model: MALI ≡ ACA exactly
/// (same solver, reverse-exact trajectory), adjoint approximately.
#[test]
fn methods_agree_on_img16_hlo() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(5);
    let mut d = HloDynamics::new(e, "img16").unwrap();
    d.init_params(&mut rng).unwrap();
    let n = d.dim();
    let mut z0 = vec![0.0f32; n];
    rng.fill_uniform_sym(&mut z0, 0.5);

    let alf = solver_by_name("alf").unwrap();
    let heun = solver_by_name("heun-euler").unwrap();
    let spec = IvpSpec::fixed(0.0, 1.0, 0.25);

    let mali = grad_by_name("mali")
        .unwrap()
        .grad(&d, &*alf, &spec, &z0, &SquareLoss, MemTracker::new())
        .unwrap();
    let aca_alf = grad_by_name("aca")
        .unwrap()
        .grad(&d, &*alf, &spec, &z0, &SquareLoss, MemTracker::new())
        .unwrap();
    let max_diff = mali
        .grad_theta
        .iter()
        .zip(&aca_alf.grad_theta)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "MALI vs ACA(ALF): {max_diff}");

    // adjoint on a same-order solver: same direction, small deviation
    let adj = grad_by_name("adjoint")
        .unwrap()
        .grad(&d, &*heun, &spec, &z0, &SquareLoss, MemTracker::new())
        .unwrap();
    let dot: f64 = mali
        .grad_theta
        .iter()
        .zip(&adj.grad_theta)
        .map(|(a, b)| *a as f64 * *b as f64)
        .sum();
    let na: f64 = mali.grad_theta.iter().map(|a| (*a as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = adj.grad_theta.iter().map(|a| (*a as f64).powi(2)).sum::<f64>().sqrt();
    let cos = dot / (na * nb);
    assert!(cos > 0.99, "adjoint gradient direction off: cos {cos}");
}

/// ψ⁻¹∘ψ = id through the fused HLO kernels for every ALF-exporting
/// family, undamped and damped (paper Algo. 3 / Eq. 49).
#[test]
fn fused_roundtrip_all_families() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(9);
    for family in ["toy", "img16", "img32", "latent", "cnf_density2d"] {
        let mut d = HloDynamics::new(e.clone(), family).unwrap();
        if d.param_dim() > 1 {
            d.init_params(&mut rng).unwrap();
        } else {
            d.set_params(&[0.5]);
        }
        if d.n_ctx() > 0 {
            // CNF probe (batch × dim Rademacher); other families have no ctx
            let len = e
                .manifest
                .entry(&format!("{family}.f"))
                .unwrap()
                .inputs[2]
                .len();
            let mut probe = vec![0.0f32; len];
            for p in probe.iter_mut() {
                *p = rng.rademacher();
            }
            d.set_ctx(0, probe).unwrap();
        }
        let n = d.dim();
        let mut z = vec![0.0f32; n];
        rng.fill_uniform_sym(&mut z, 0.4);
        for &eta in &[1.0, 0.9] {
            let solver = AlfSolver::new(eta);
            let v = d.f(0.0, &z);
            let (z1, v1, _) = solver.psi(&d, 0.0, 0.2, &z, &v);
            let (z0b, v0b) = solver.psi_inv(&d, 0.2, 0.2, &z1, &v1);
            let max_z = z.iter().zip(&z0b).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
            let max_v = v.iter().zip(&v0b).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
            assert!(max_z < 1e-4, "{family} η={eta}: z roundtrip {max_z}");
            assert!(max_v < 1e-4, "{family} η={eta}: v roundtrip {max_v}");
        }
    }
}

/// The fused ψ (one PJRT call) and the composed path (`f` + host algebra)
/// agree numerically on every family — the L1 kernel is a pure
/// optimization, not a semantic change.
#[test]
fn fused_equals_composed() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(11);
    for family in ["img16", "latent"] {
        let mut d = HloDynamics::new(e.clone(), family).unwrap();
        d.init_params(&mut rng).unwrap();
        let n = d.dim();
        let mut z = vec![0.0f32; n];
        rng.fill_uniform_sym(&mut z, 0.5);
        let v = d.f(0.0, &z);
        let solver = AlfSolver::new(1.0);
        let fused = solver.psi(&d, 0.1, 0.3, &z, &v);
        d.use_fused = false;
        let composed = solver.psi(&d, 0.1, 0.3, &z, &v);
        d.use_fused = true;
        for i in 0..n {
            assert!((fused.0[i] - composed.0[i]).abs() < 1e-4, "{family} z[{i}]");
            assert!((fused.1[i] - composed.1[i]).abs() < 1e-4, "{family} v[{i}]");
        }
    }
}

/// The fused MALI backward micro-step (`<fam>.bwd`, one PJRT call) agrees
/// with the composed ψ⁻¹ + ψ-vjp path it replaces.
#[test]
fn fused_bwd_equals_composed() {
    use mali_ode::solvers::{Solver, State};
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(13);
    for family in ["img16", "latent"] {
        let mut d = HloDynamics::new(e.clone(), family).unwrap();
        d.init_params(&mut rng).unwrap();
        let n = d.dim();
        let mut z = vec![0.0f32; n];
        rng.fill_uniform_sym(&mut z, 0.5);
        let solver = AlfSolver::new(1.0);
        let v = d.f(0.0, &z);
        let (z1, v1, _) = solver.psi(&d, 0.0, 0.25, &z, &v);
        let s_out = State {
            z: z1,
            v: Some(v1),
        };
        let mut az = vec![0.0f32; n];
        let mut av = vec![0.0f32; n];
        rng.fill_uniform_sym(&mut az, 1.0);
        rng.fill_uniform_sym(&mut av, 1.0);
        let a_out = State {
            z: az,
            v: Some(av),
        };
        let fused = solver
            .invert_and_vjp(&d, 0.25, 0.25, &s_out, &a_out)
            .unwrap();
        d.use_fused = false;
        let composed = solver
            .invert_and_vjp(&d, 0.25, 0.25, &s_out, &a_out)
            .unwrap();
        d.use_fused = true;
        for i in 0..n {
            assert!((fused.0.z[i] - composed.0.z[i]).abs() < 1e-4, "{family} z_in[{i}]");
            assert!((fused.1.z[i] - composed.1.z[i]).abs() < 1e-4, "{family} a_z[{i}]");
        }
        let max_th = fused
            .2
            .iter()
            .zip(&composed.2)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_th < 1e-3, "{family} a_θ diff {max_th}");
    }
}

/// Engine determinism across instances (fresh compile, same artifacts).
#[test]
fn engine_is_deterministic_across_instances() {
    let (Some(a), Some(b)) = (
        Engine::from_env_or_skip("runtime integration test"),
        Engine::from_env_or_skip("runtime integration test"),
    ) else {
        return;
    };
    let z = [0.3f32, -0.2, 0.9, 0.0];
    let out_a = a.call1("toy.f", &[&[0.1], &z, &[0.7]]).unwrap();
    let out_b = b.call1("toy.f", &[&[0.1], &z, &[0.7]]).unwrap();
    assert_eq!(out_a, out_b);
}

/// Manifest hygiene: every referenced file exists; every component length
/// matches its parameter specs.
#[test]
fn manifest_is_self_consistent() {
    let Some(e) = engine() else { return };
    for (name, entry) in &e.manifest.entries {
        assert!(
            e.manifest.hlo_path(entry).exists(),
            "missing HLO file for {name}"
        );
        assert!(!entry.outputs.is_empty(), "{name} has no outputs");
    }
    for (mname, model) in &e.manifest.models {
        for (cname, comp) in &model.components {
            let total: usize = comp.params.iter().map(|p| p.len()).sum();
            assert_eq!(comp.len, total, "{mname}.{cname} length mismatch");
        }
    }
    // no elided literals may ever reach the parser (it zero-fills them)
    for entry in e.manifest.entries.values() {
        let text = std::fs::read_to_string(e.manifest.hlo_path(entry)).unwrap();
        assert!(
            !text.contains("{...}"),
            "{}: elided literal in HLO text",
            entry.name
        );
    }
}
