//! Kernel-equivalence property suite: every chunked/SIMD dispatch kernel
//! in `mali_ode::tensor` must match the frozen [`scalar`] oracle
//! **bitwise** — not approximately — for all shapes, so swapping the
//! dispatch body (autovectorized arrays on stable, `std::simd` under
//! `--features simd`) can never move a single ULP anywhere in the solver
//! stack.  CI runs this file under both feature settings (the stable
//! matrix legs and the `simd-nightly` job); the assertions are identical
//! because the contract is identical.
//!
//! Coverage is a seeded-random sweep over the shapes that exercise every
//! dispatch path: widths 1..=67 (head-only, single-chunk, multi-chunk,
//! chunk+tail — spanning several `LANES` boundaries), destination slices
//! taken at offsets 0..4 from the backing allocation (so the alignment
//! head peel sees every f32 phase of a `LANES`-aligned boundary), and
//! batch sizes B ∈ {1, 3, 32} for the row kernels.  The matmul
//! accumulation-order identity — blocked dispatch = blocked scalar
//! oracle = naive i/p/j triple loop, per output element — is asserted
//! explicitly across shapes below, at and beyond the column-block width.

use mali_ode::tensor::{self, scalar, LANES};
use mali_ode::util::rng::Rng;

/// Bit-exact view: `assert_eq!` on f32 slices would treat `-0.0 == 0.0`
/// and miss sign-of-zero divergence; comparing the raw bits does not.
fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

fn filled(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, 1.0);
    v
}

/// Offsets into the backing buffers: 0..4 covers every 4-byte phase the
/// destination pointer can take relative to a `LANES * 4`-byte boundary
/// (further offsets repeat phases modulo `LANES`).
const OFFSETS: [usize; 4] = [0, 1, 2, 3];
const MAX_W: usize = 67;

#[test]
fn axpy_matches_scalar_bitwise_across_widths_and_offsets() {
    let mut rng = Rng::new(0xA11);
    assert!(MAX_W > 8 * LANES, "sweep must span several chunk widths");
    for w in 1..=MAX_W {
        for off in OFFSETS {
            let x_back = filled(&mut rng, MAX_W + 4);
            let y_back = filled(&mut rng, MAX_W + 4);
            let a = rng.range(-2.0, 2.0) as f32;
            let x = &x_back[off..off + w];
            let mut y_k = y_back.clone();
            let mut y_s = y_back.clone();
            tensor::axpy(a, x, &mut y_k[off..off + w]);
            scalar::axpy(a, x, &mut y_s[off..off + w]);
            assert_eq!(bits(&y_k), bits(&y_s), "axpy w={w} off={off}");
        }
    }
}

#[test]
fn add_scaled_into_matches_scalar_bitwise_across_widths_and_offsets() {
    let mut rng = Rng::new(0xADD);
    for w in 1..=MAX_W {
        for off in OFFSETS {
            let x_back = filled(&mut rng, MAX_W + 4);
            let y_back = filled(&mut rng, MAX_W + 4);
            let a = rng.range(-2.0, 2.0) as f32;
            let x = &x_back[off..off + w];
            let y = &y_back[off..off + w];
            let mut o_k = vec![9.0f32; MAX_W + 4];
            let mut o_s = vec![9.0f32; MAX_W + 4];
            tensor::add_scaled_into(x, a, y, &mut o_k[off..off + w]);
            scalar::add_scaled_into(x, a, y, &mut o_s[off..off + w]);
            assert_eq!(bits(&o_k), bits(&o_s), "add_scaled_into w={w} off={off}");
        }
    }
}

#[test]
fn row_kernels_match_scalar_bitwise_across_batch_sizes() {
    let mut rng = Rng::new(0xB0B);
    // n_z sweep straddles the lane width and several chunk boundaries;
    // with B up to 32 the flat buffers also cross MATMUL-scale lengths
    let widths = [1usize, 2, 3, 5, 7, 8, 9, 15, 16, 17, 31, 33, 64, 67];
    for &b in &[1usize, 3, 32] {
        for &n_z in &widths {
            for off in OFFSETS {
                let flat = b * n_z;
                let x_back = filled(&mut rng, flat + 4);
                let y_back = filled(&mut rng, flat + 4);
                let coeffs = filled(&mut rng, b);
                let x = &x_back[off..off + flat];

                let mut y_k = y_back.clone();
                let mut y_s = y_back.clone();
                tensor::axpy_rows(&coeffs, x, &mut y_k[off..off + flat], n_z);
                scalar::axpy_rows(&coeffs, x, &mut y_s[off..off + flat], n_z);
                assert_eq!(
                    bits(&y_k),
                    bits(&y_s),
                    "axpy_rows B={b} n_z={n_z} off={off}"
                );

                let y = &y_back[off..off + flat];
                let mut o_k = vec![9.0f32; flat + 4];
                let mut o_s = vec![9.0f32; flat + 4];
                tensor::add_scaled_rows_into(x, &coeffs, y, n_z, &mut o_k[off..off + flat]);
                scalar::add_scaled_rows_into(x, &coeffs, y, n_z, &mut o_s[off..off + flat]);
                assert_eq!(
                    bits(&o_k),
                    bits(&o_s),
                    "add_scaled_rows_into B={b} n_z={n_z} off={off}"
                );
            }
        }
    }
}

#[test]
fn lincomb_into_matches_scalar_bitwise_including_zero_terms() {
    let mut rng = Rng::new(0x11C);
    for w in 1..=MAX_W {
        for &n_terms in &[1usize, 2, 5] {
            let xs: Vec<Vec<f32>> = (0..n_terms).map(|_| filled(&mut rng, w)).collect();
            let mut cs: Vec<f32> = (0..n_terms)
                .map(|_| rng.range(-2.0, 2.0) as f32)
                .collect();
            // zero coefficients are part of the contract (the oracle
            // accumulates them too — RK tableaus hit this constantly)
            if n_terms > 1 {
                cs[1] = 0.0;
            }
            let terms: Vec<(f32, &[f32])> =
                cs.iter().zip(&xs).map(|(&c, x)| (c, x.as_slice())).collect();
            let mut o_k = vec![9.0f32; w];
            let mut o_s = vec![9.0f32; w];
            tensor::lincomb_into(&terms, &mut o_k);
            scalar::lincomb_into(&terms, &mut o_s);
            assert_eq!(bits(&o_k), bits(&o_s), "lincomb_into w={w} terms={n_terms}");
        }
    }
}

/// The accumulation-order identity, asserted explicitly: for every output
/// element, the blocked dispatch matmul, the blocked scalar oracle and
/// the naive i/p/j triple loop all add the `k` products in the same
/// ascending-`p` order, so all three agree **bitwise** — blocking and
/// vectorization only regroup work *across* output elements, never the
/// additions *within* one.
#[test]
fn matmul_accumulation_order_identity() {
    let mut rng = Rng::new(0x3A7);
    // shapes below, at and across the column-block width (64), plus the
    // B=32 row-kernel scale used by the batched solvers
    let shapes = [
        (1usize, 1usize, 1usize),
        (3, 4, 5),
        (2, 7, 64),
        (3, 5, 65),
        (5, 8, 130),
        (32, 4, 4),
        (32, 64, 64),
    ];
    for &(m, k, n) in &shapes {
        let mut a = filled(&mut rng, m * k);
        let b = filled(&mut rng, k * n);
        // sprinkle zeros so the zero-skip path must also preserve order
        for av in a.iter_mut().step_by(7) {
            *av = 0.0;
        }
        let mut naive = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p];
                if av == 0.0 {
                    continue;
                }
                for j in 0..n {
                    naive[i * n + j] += av * b[p * n + j];
                }
            }
        }
        let mut o_k = vec![1.0f32; m * n];
        let mut o_s = vec![1.0f32; m * n];
        tensor::matmul_into(&a, &b, m, k, n, &mut o_k);
        scalar::matmul_into(&a, &b, m, k, n, &mut o_s);
        assert_eq!(bits(&o_k), bits(&naive), "dispatch vs naive ({m},{k},{n})");
        assert_eq!(bits(&o_s), bits(&naive), "oracle vs naive ({m},{k},{n})");
    }
}

/// `simd_enabled()` faithfully reports the compiled dispatch path, so the
/// bench JSON's `simd_feature` field can be trusted.
#[test]
fn simd_flag_reports_compiled_feature() {
    assert_eq!(tensor::simd_enabled(), cfg!(feature = "simd"));
}
