//! End-to-end system tests: short but complete training runs through all
//! three layers for each experiment family, asserting learning actually
//! happens and the paper's structural claims hold.

use mali_ode::data::images::{generate, ImageSpec};
use mali_ode::data::speech::{self, SpeechSpec};
use mali_ode::grad::IvpSpec;
use mali_ode::models::cde::NeuralCde;
use mali_ode::models::image::OdeImageClassifier;
use mali_ode::models::latent::LatentOde;
use mali_ode::models::SolveCfg;
use mali_ode::opt::by_name as opt_by_name;
use mali_ode::runtime::Engine;
use mali_ode::sim::hopper;
use mali_ode::solvers::dynamics::Dynamics;
use mali_ode::train::trainer::{ImageTrainer, TrainCfg};
use mali_ode::util::rng::Rng;
use std::rc::Rc;

/// `None` (test skipped) when the AOT artifacts or the PJRT runtime are
/// absent — the offline build stubs PJRT (`runtime::xla_stub`); the
/// CLI test below runs regardless (native dynamics only).
fn engine() -> Option<Rc<Engine>> {
    Engine::from_env_or_skip("end-to-end test")
}

/// Image classifier: a short MALI run learns the synthetic corpus well
/// above chance, with constant solver-state memory.
#[test]
fn image_classifier_end_to_end() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(1);
    let mut model = OdeImageClassifier::new(e, "img16", &mut rng).unwrap();
    let (train, test) = generate(&ImageSpec::cifar_like(), 320 + 96, 3).split(96);
    let cfg = TrainCfg {
        epochs: 4,
        lr: 0.05,
        lr_drops: vec![],
        method: "mali".into(),
        solver: "alf".into(),
        h: 0.25,
        seed: 1,
        ..TrainCfg::default()
    };
    let report = ImageTrainer::new(cfg).train_ode(&mut model, &train, &test).unwrap();
    assert!(report.final_acc > 0.5, "acc {}", report.final_acc);
    // constant memory: one augmented state (z + v), batch 32 × d 64 × 4 B × 2
    assert_eq!(report.peak_mem_bytes, 32 * 64 * 4 * 2);
}

/// Trained-once, evaluated-everywhere (Table 2 in miniature): the ODE
/// keeps its accuracy under solvers it never saw in training.
#[test]
fn discretization_invariance_in_miniature() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(2);
    let mut model = OdeImageClassifier::new(e, "img16", &mut rng).unwrap();
    let (train, test) = generate(&ImageSpec::cifar_like(), 480 + 96, 4).split(96);
    let cfg = TrainCfg {
        epochs: 6,
        lr: 0.05,
        lr_drops: vec![],
        method: "mali".into(),
        solver: "alf".into(),
        h: 0.25,
        seed: 2,
        ..TrainCfg::default()
    };
    ImageTrainer::new(cfg).train_ode(&mut model, &train, &test).unwrap();
    let method = mali_ode::grad::by_name("mali").unwrap();
    let mut accs = Vec::new();
    for solver_name in ["alf", "rk2", "rk4", "dopri5"] {
        let solver = mali_ode::solvers::by_name(solver_name).unwrap();
        let spec = if solver_name == "dopri5" {
            IvpSpec::adaptive(0.0, 1.0, 1e-3, 1e-4)
        } else {
            IvpSpec::fixed(0.0, 1.0, 0.25)
        };
        let acc = ImageTrainer::evaluate(&model, &test, &*solver, &spec, &*method).unwrap();
        accs.push(acc);
    }
    let base = accs[0];
    assert!(base > 0.5, "model failed to train: {base}");
    for (i, acc) in accs.iter().enumerate() {
        assert!(
            (acc - base).abs() < 0.15,
            "solver {i}: accuracy {acc} far from training-solver accuracy {base}"
        );
    }
}

/// Latent ODE on hopper: a short MALI run beats the untrained model.
#[test]
fn latent_ode_end_to_end() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(3);
    let mut model = LatentOde::new(e, &mut rng).unwrap();
    let ds = hopper::generate(3 * model.batch, model.t_len, model.t_out, 3.0, 5);
    let solver = mali_ode::solvers::by_name("alf").unwrap();
    let method = mali_ode::grad::by_name("mali").unwrap();
    let spec = IvpSpec::fixed(0.0, 1.0, 0.25);

    let (batch, t_len, t_out) = (model.batch, model.t_len, model.t_out);
    let batch_of = move |start: usize| {
        let mut seq = Vec::new();
        let mut tgt = Vec::new();
        for i in start..start + batch {
            seq.extend_from_slice(ds.observed(i, t_len));
            tgt.extend_from_slice(ds.target(i, t_len, t_out));
        }
        (seq, tgt)
    };
    let (test_seq, test_tgt) = batch_of(2 * model.batch);
    let cfg = SolveCfg {
        solver: &*solver,
        spec: spec.clone(),
        method: &*method,
    };
    let before = LatentOde::mse(&model.predict(&test_seq, &cfg).unwrap(), &test_tgt);

    let mut opt_enc = opt_by_name("adamax", 0.01, model.enc.len()).unwrap();
    let mut opt_dec = opt_by_name("adamax", 0.01, model.dec.len()).unwrap();
    let mut opt_dyn = opt_by_name("adamax", 0.01, model.dynamics.param_dim()).unwrap();
    for _ in 0..12 {
        for start in [0, model.batch] {
            let (seq, tgt) = batch_of(start);
            let cfg = SolveCfg {
                solver: &*solver,
                spec: spec.clone(),
                method: &*method,
            };
            model.step(&seq, &tgt, &cfg, &mut rng).unwrap();
            opt_enc.step(&mut model.enc.value, &model.enc.grad);
            opt_dec.step(&mut model.dec.value, &model.dec.grad);
            let mut theta = model.dynamics.params().to_vec();
            opt_dyn.step(&mut theta, &model.dyn_grad);
            model.dynamics.set_params(&theta);
        }
    }
    let cfg = SolveCfg {
        solver: &*solver,
        spec,
        method: &*method,
    };
    let after = LatentOde::mse(&model.predict(&test_seq, &cfg).unwrap(), &test_tgt);
    assert!(
        after < before,
        "latent ODE did not improve: {before} → {after}"
    );
}

/// Neural CDE on synthetic speech: accuracy after a short run beats chance.
#[test]
fn neural_cde_end_to_end() {
    let Some(e) = engine() else { return };
    let mut rng = Rng::new(4);
    let mut model = NeuralCde::new(e, &mut rng).unwrap();
    let ds = speech::generate(&SpeechSpec::commands10(), 5 * model.batch, 6);
    let (train, test) = ds.split(model.batch);
    let solver = mali_ode::solvers::by_name("alf").unwrap();
    let method = mali_ode::grad::by_name("mali").unwrap();
    let spec = IvpSpec::fixed(0.0, 1.0, 0.25);

    let mut opt_stem = opt_by_name("adam", 0.01, model.stem.len()).unwrap();
    let mut opt_head = opt_by_name("adam", 0.01, model.head.len()).unwrap();
    let mut opt_dyn = opt_by_name("adam", 0.01, model.dynamics.param_dim()).unwrap();
    for _ in 0..16 {
        let mut order: Vec<usize> = (0..train.len()).collect();
        rng.shuffle(&mut order);
        for chunk in order.chunks(model.batch) {
            if chunk.len() < model.batch {
                continue;
            }
            let (ctx, x0, y1h, _) = model.prepare_batch(&train, chunk);
            let cfg = SolveCfg {
                solver: &*solver,
                spec: spec.clone(),
                method: &*method,
            };
            model.step(ctx, &x0, &y1h, &cfg).unwrap();
            opt_stem.step(&mut model.stem.value, &model.stem.grad);
            opt_head.step(&mut model.head.value, &model.head.grad);
            let mut theta = model.dynamics.params().to_vec();
            opt_dyn.step(&mut theta, &model.dyn_grad);
            model.dynamics.set_params(&theta);
        }
    }
    let idx: Vec<usize> = (0..model.batch).collect();
    let (ctx, x0, _, y) = model.prepare_batch(&test, &idx);
    let cfg = SolveCfg {
        solver: &*solver,
        spec,
        method: &*method,
    };
    let logits = model.predict(ctx, &x0, &cfg).unwrap();
    let acc = model.accuracy(&logits, &y);
    assert!(acc > 0.2, "CDE stuck at chance: {acc}");
}

/// The CLI surface works end to end: `run fig4` writes its summary.
#[test]
fn cli_run_fig4_writes_summary() {
    let dir = std::env::temp_dir().join("mali_cli_test_runs");
    std::fs::remove_dir_all(&dir).ok();
    mali_ode::coordinator::run_cli(&[
        "run".into(),
        "fig4".into(),
        "--runs".into(),
        dir.to_str().unwrap().into(),
    ])
    .unwrap();
    let summary =
        mali_ode::util::json::Json::parse_file(&dir.join("fig4.json")).unwrap();
    assert!(!summary.get("rows").as_arr().unwrap().is_empty());
    std::fs::remove_dir_all(&dir).ok();
}
