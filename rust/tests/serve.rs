//! Serving-layer integration tests — the two acceptance properties of
//! the online inference layer plus end-to-end behaviour of the threaded
//! server:
//!
//! * **serve-vs-direct equivalence** — a coalesced micro-batch of K
//!   requests returns **bitwise** the same trajectories (final states,
//!   observation snapshots, step/trial counts) as K solo
//!   `integrate_obs` calls, fixed and adaptive, because the batched
//!   loop is decision-identical per row and micro-batching is therefore
//!   a pure scheduling change;
//! * **queue saturation** — under overload the server's memory stays
//!   bounded at the queue capacity and every rejected submission gets
//!   an explicit shed error (no silent buffering, no blocking).

use mali_ode::serve::{
    ModelRegistry, Pending, RequestClass, Server, ServerConfig, ServeWorker, SubmitError,
};
use mali_ode::solvers::by_name as solver_by_name;
use mali_ode::solvers::dynamics::LinearToy;
use mali_ode::solvers::integrate::{integrate_obs, ErrorNorm, ObsGrid, StepMode, StepObserver};
use mali_ode::solvers::State;
use std::sync::Arc;
use std::time::Duration;

const N_Z: usize = 4;
const ALPHA: f64 = -0.35;

/// Captures the solo trajectory's observation states into a flat
/// `[K, n_z]` buffer — the same layout the serve response uses.
struct SoloObs {
    n_z: usize,
    obs: Vec<f32>,
}

impl StepObserver for SoloObs {
    fn on_observation(&mut self, k: usize, _t: f64, state: &State) {
        self.obs[k * self.n_z..(k + 1) * self.n_z].copy_from_slice(&state.z);
    }
}

fn registry() -> Arc<ModelRegistry> {
    let mut reg = ModelRegistry::new();
    reg.register("toy", Box::new(LinearToy::new(ALPHA, N_Z)));
    Arc::new(reg)
}

fn request_rows(k: usize) -> Vec<Vec<f32>> {
    // heterogeneous row scales (the tiny rows are atol-dominated) so
    // per-sample adaptive controllers genuinely take different grids
    const SCALES: [f32; 5] = [0.001, 0.4, 1.0, 5.0, 20.0];
    (0..k)
        .map(|i| {
            let s = SCALES[i % SCALES.len()];
            (0..N_Z).map(|j| s * (1.0 + 0.17 * j as f32)).collect()
        })
        .collect()
}

/// Solo reference: one allocating `integrate_obs` call, plus the
/// observation snapshots and step stats — what each request would have
/// gotten with a private integration.
fn solo_reference(
    class: &RequestClass,
    z0: &[f32],
) -> (Vec<f32>, Vec<f32>, usize, usize) {
    let toy = LinearToy::new(ALPHA, N_Z);
    let solver = solver_by_name(&class.solver).unwrap();
    let s0 = solver.init(&toy, class.t0, z0);
    let mut obs = SoloObs {
        n_z: N_Z,
        obs: vec![0.0; class.grid.len() * N_Z],
    };
    let (sf, stats) = integrate_obs(
        &*solver,
        &toy,
        class.t0,
        class.t1,
        s0,
        &class.mode,
        &ErrorNorm::Full,
        &class.grid,
        &mut obs,
    )
    .unwrap();
    (sf.z, obs.obs, stats.n_accepted, stats.n_trials)
}

fn class_for(mode: StepMode) -> Arc<RequestClass> {
    let grid = ObsGrid::new(vec![0.31, 0.5, 1.0]).unwrap();
    Arc::new(RequestClass::new("toy", "alf", N_Z, 0.0, 1.0, mode, grid).unwrap())
}

/// A coalesced batch of K requests is bitwise identical to K solo
/// integrations — final states, observation states, steps and trials —
/// in both stepping modes.
#[test]
fn coalesced_batch_bitwise_equals_solo() {
    for mode in [StepMode::Fixed { h: 0.07 }, StepMode::adaptive(1e-4, 1e-6)] {
        let class = class_for(mode.clone());
        let rows = request_rows(5);
        let mut worker = ServeWorker::new(registry());
        let mut batch: Vec<Pending> = rows
            .iter()
            .map(|z0| Pending::new(class.clone(), z0.clone()))
            .collect();
        worker.process(&mut batch).unwrap();
        for (p, z0) in batch.iter().zip(&rows) {
            let (z_solo, obs_solo, acc, trials) = solo_reference(&class, z0);
            assert_eq!(p.z_final, z_solo, "final state bitwise ({mode:?})");
            assert_eq!(p.obs, obs_solo, "observation states bitwise ({mode:?})");
            assert_eq!(p.n_accepted, acc, "accepted steps ({mode:?})");
            assert_eq!(p.n_trials, trials, "controller trials ({mode:?})");
        }
        // heterogeneous rows under adaptive control genuinely took
        // different grids — the equivalence above is not vacuous
        if matches!(mode, StepMode::Adaptive { .. }) {
            assert!(
                batch.iter().any(|p| p.n_accepted != batch[0].n_accepted),
                "expected per-sample adaptive grids to diverge"
            );
        }
    }
}

/// The full threaded pipeline (queue → batcher → workers → response
/// slots) returns the same bitwise trajectories, with every request
/// accounted for in the metrics.
#[test]
fn threaded_server_matches_solo_bitwise() {
    let class = class_for(StepMode::adaptive(1e-4, 1e-6));
    let rows = request_rows(12);
    let server = Server::start(
        registry(),
        ServerConfig {
            queue_capacity: 64,
            max_batch: 8,
            max_wait: Duration::from_millis(20),
            workers: 2,
            shards: 0,
        },
    );
    // submit everything first so the batcher has real coalescing to do
    let handles: Vec<_> = rows
        .iter()
        .map(|z0| server.submit(&class, z0).expect("admitted"))
        .collect();
    for (handle, z0) in handles.into_iter().zip(&rows) {
        let resp = handle.wait().unwrap();
        let (z_solo, obs_solo, acc, trials) = solo_reference(&class, z0);
        assert_eq!(resp.z_final, z_solo, "final state bitwise through the server");
        assert_eq!(resp.obs, obs_solo, "observation states bitwise");
        assert_eq!(resp.n_accepted, acc);
        assert_eq!(resp.n_trials, trials);
        assert!(resp.queue_wait_s >= 0.0 && resp.service_s > 0.0);
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.requests, 12);
    assert_eq!(metrics.failed, 0);
    assert!(metrics.batches <= 12, "some coalescing bookkeeping");
    assert!(metrics.batch_occupancy() >= 1.0);
    assert_eq!(metrics.total.count(), 12);
}

/// Interleaved incompatible classes never share a batch and each
/// request still gets its own class's exact trajectory.
#[test]
fn mixed_classes_are_served_separately_and_correctly() {
    let fixed = class_for(StepMode::Fixed { h: 0.05 });
    let adaptive = class_for(StepMode::adaptive(1e-4, 1e-6));
    let rows = request_rows(6);
    let server = Server::start(
        registry(),
        ServerConfig {
            queue_capacity: 64,
            max_batch: 8,
            max_wait: Duration::from_millis(10),
            workers: 1,
            shards: 0,
        },
    );
    let handles: Vec<_> = rows
        .iter()
        .enumerate()
        .map(|(i, z0)| {
            let class = if i % 2 == 0 { &fixed } else { &adaptive };
            (i, server.submit(class, z0).expect("admitted"))
        })
        .collect();
    for (i, handle) in handles {
        let class = if i % 2 == 0 { &fixed } else { &adaptive };
        let resp = handle.wait().unwrap();
        let (z_solo, obs_solo, acc, _) = solo_reference(class, &rows[i]);
        assert_eq!(resp.z_final, z_solo, "request {i} final state");
        assert_eq!(resp.obs, obs_solo, "request {i} observations");
        assert_eq!(resp.n_accepted, acc, "request {i} steps");
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.requests, 6);
    assert_eq!(metrics.failed, 0);
}

/// Overload policy: the queue never holds more than `capacity` requests
/// (bounded memory), every rejected submission is an explicit
/// `Overloaded` error, the shed count is exact, and draining resumes
/// normal service.
#[test]
fn queue_saturation_bounds_memory_and_sheds_explicitly() {
    let class = class_for(StepMode::Fixed { h: 0.05 });
    // paused server: nothing drains, so saturation is deterministic
    let server = Server::start(
        registry(),
        ServerConfig {
            queue_capacity: 4,
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            workers: 0,
            shards: 0,
        },
    );
    let z0 = vec![1.0f32; N_Z];
    let mut admitted = Vec::new();
    let mut shed = 0u64;
    for _ in 0..10 {
        match server.submit(&class, &z0) {
            Ok(h) => admitted.push(h),
            Err(SubmitError::Overloaded { capacity }) => {
                assert_eq!(capacity, 4);
                shed += 1;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
        assert!(server.queue_depth() <= 4, "queue depth bounded at capacity");
    }
    assert_eq!(admitted.len(), 4, "exactly capacity requests admitted");
    assert_eq!(shed, 6, "every overflow submission shed explicitly");
    assert_eq!(server.shed_count(), 6);
    let metrics = server.shutdown();
    assert_eq!(metrics.shed, 6, "shed count folded into the shutdown metrics");
    assert_eq!(metrics.failed, 4, "pending requests failed loudly at shutdown");
    for h in admitted {
        let err = h.wait().unwrap_err();
        assert!(
            err.to_string().contains("shut down"),
            "waiter got the shutdown error, not a hang: {err}"
        );
    }
}
