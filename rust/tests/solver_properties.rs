//! Property-based tests over the solver layer: randomized dynamics, states,
//! step sizes and damping coefficients (proptest is not vendored offline —
//! `util::rng` drives seeded random sweeps with explicit case counts, which
//! shrink-free but reproducible by seed).

use mali_ode::grad::{by_name, IvpSpec, SquareLoss};
use mali_ode::solvers::alf::AlfSolver;
use mali_ode::solvers::dynamics::{Dynamics, LinearToy, MlpDynamics};
use mali_ode::solvers::integrate::{integrate, ErrorNorm, GridRecorder, StepMode};
use mali_ode::solvers::{by_name as solver_by_name, Solver, State};
use mali_ode::util::mem::MemTracker;
use mali_ode::util::rng::Rng;

const CASES: usize = 40;

/// ∀ (z, v, t, h, η): ψ⁻¹(ψ(z, v)) = (z, v) to roundoff — the invertibility
/// property MALI is built on (paper §3.1 "Invertibility of ALF").
#[test]
fn prop_alf_roundtrip() {
    let mut rng = Rng::new(101);
    for case in 0..CASES {
        let d = 1 + rng.below(8);
        let hidden = 2 + rng.below(8);
        let dynamics = MlpDynamics::new(d, hidden, &mut rng);
        let eta = rng.range(0.55, 1.0);
        let solver = AlfSolver::new(eta);
        let mut z = vec![0.0f32; d];
        rng.fill_normal(&mut z, 1.0);
        let mut v = vec![0.0f32; d];
        rng.fill_normal(&mut v, 1.0);
        let t = rng.range(-1.0, 1.0);
        let h = rng.range(0.01, 0.5);
        let (z1, v1, _) = solver.psi(&dynamics, t, h, &z, &v);
        let (z0, v0) = solver.psi_inv(&dynamics, t + h, h, &z1, &v1);
        for i in 0..d {
            assert!(
                (z0[i] - z[i]).abs() < 1e-3,
                "case {case} (d={d}, η={eta:.3}, h={h:.3}): z[{i}] {} vs {}",
                z0[i],
                z[i]
            );
            assert!((v0[i] - v[i]).abs() < 1e-3, "case {case}: v[{i}]");
        }
    }
}

/// ∀ trajectories: reconstructing the whole trajectory backward from the end
/// state (Eq. 5) recovers every forward state (paper Fig. 3).
#[test]
fn prop_full_trajectory_reconstruction() {
    let mut rng = Rng::new(202);
    for case in 0..12 {
        let d = 2 + rng.below(5);
        let dynamics = MlpDynamics::new(d, 6, &mut rng);
        let solver = AlfSolver::new(1.0);
        let mut z0 = vec![0.0f32; d];
        rng.fill_normal(&mut z0, 0.8);
        let s0 = solver.init(&dynamics, 0.0, &z0);

        // forward adaptive run, recording the grid and all states
        let mut rec = GridRecorder::new(0.0);
        let mut states: Vec<State> = vec![s0.clone()];
        struct Collect<'a> {
            states: &'a mut Vec<State>,
        }
        impl mali_ode::solvers::integrate::StepObserver for Collect<'_> {
            fn on_accept(&mut self, s: &mali_ode::solvers::integrate::AcceptedStep) {
                self.states.push(s.after.clone());
            }
        }
        let (s_end, _) = integrate(
            &solver,
            &dynamics,
            0.0,
            1.0,
            s0,
            &StepMode::adaptive(1e-2, 1e-4),
            &ErrorNorm::Full,
            &mut Collect {
                states: &mut states,
            },
        )
        .unwrap();
        // record grid with a second pass (deterministic)
        let s0b = State {
            z: states[0].z.clone(),
            v: states[0].v.clone(),
        };
        let (_, _) = integrate(
            &solver,
            &dynamics,
            0.0,
            1.0,
            s0b,
            &StepMode::adaptive(1e-2, 1e-4),
            &ErrorNorm::Full,
            &mut rec,
        )
        .unwrap();

        // walk backward from the end state
        let mut cur = s_end;
        let n = rec.times().len() - 1;
        assert_eq!(states.len(), n + 1, "case {case}");
        for i in (1..=n).rev() {
            let h = rec.times()[i] - rec.times()[i - 1];
            cur = solver.invert(&dynamics, rec.times()[i], h, &cur).unwrap();
            let expect = &states[i - 1];
            for j in 0..d {
                assert!(
                    (cur.z[j] - expect.z[j]).abs() < 5e-3,
                    "case {case} step {i} z[{j}]: {} vs {}",
                    cur.z[j],
                    expect.z[j]
                );
            }
        }
    }
}

/// ∀ random small MLPs: MALI's θ-gradient equals ACA's (exact agreement is
/// the paper's central accuracy claim).
#[test]
fn prop_mali_equals_aca() {
    let mut rng = Rng::new(303);
    for case in 0..12 {
        let d = 2 + rng.below(4);
        let dynamics = MlpDynamics::new(d, 5, &mut rng);
        let mut z0 = vec![0.0f32; d];
        rng.fill_normal(&mut z0, 0.5);
        let solver = solver_by_name("alf").unwrap();
        let spec = if case % 2 == 0 {
            IvpSpec::fixed(0.0, 0.7, 0.07)
        } else {
            IvpSpec::adaptive(0.0, 0.7, 1e-3, 1e-5)
        };
        let g_mali = by_name("mali")
            .unwrap()
            .grad(&dynamics, &*solver, &spec, &z0, &SquareLoss, MemTracker::new())
            .unwrap();
        let g_aca = by_name("aca")
            .unwrap()
            .grad(&dynamics, &*solver, &spec, &z0, &SquareLoss, MemTracker::new())
            .unwrap();
        let diff: f64 = g_mali
            .grad_theta
            .iter()
            .zip(&g_aca.grad_theta)
            .map(|(&a, &b)| ((a - b) as f64).abs())
            .fold(0.0, f64::max);
        let scale: f64 = g_aca
            .grad_theta
            .iter()
            .map(|&x| (x as f64).abs())
            .fold(1e-9, f64::max);
        assert!(
            diff / scale < 1e-2,
            "case {case}: rel max diff {}",
            diff / scale
        );
    }
}

/// The reversible-4 triple-jump composition converges at 4th order on the
/// toy problem (observed order from successive halvings ≥ 3.5), and beats
/// plain ALF by a wide margin at every step size. Expected f32 errors at
/// h = 0.5 / 0.25 / 0.125 are ≈ 3.46e-3 / 2.31e-4 / 1.45e-5 (orders
/// 3.91, 4.00); the 3.5 gate leaves room for roundoff drift.
#[test]
fn prop_reversible4_convergence_order() {
    let toy = LinearToy::new(1.0, 1);
    let rev4 = solver_by_name("reversible4").unwrap();
    let alf = solver_by_name("alf").unwrap();
    let exact = 1f64.exp();
    let solve = |solver: &dyn Solver, h: f64| -> f64 {
        let s0 = solver.init(&toy, 0.0, &[1.0]);
        let (sf, _) = integrate(
            solver,
            &toy,
            0.0,
            1.0,
            s0,
            &StepMode::Fixed { h },
            &ErrorNorm::Full,
            &mut (),
        )
        .unwrap();
        ((sf.z[0] as f64) - exact).abs()
    };
    let hs = [0.5, 0.25, 0.125];
    let errs: Vec<f64> = hs.iter().map(|&h| solve(&*rev4, h)).collect();
    for w in errs.windows(2) {
        let order = (w[0] / w[1]).ln() / 2f64.ln();
        assert!(
            order >= 3.5,
            "observed order {order:.3} below 4th-order gate (errs {errs:?})"
        );
    }
    for (&h, &e4) in hs.iter().zip(&errs) {
        let e2 = solve(&*alf, h);
        assert!(
            e4 * 20.0 < e2,
            "h={h}: reversible4 err {e4:.3e} not ≪ ALF err {e2:.3e}"
        );
    }
}

/// ∀ tolerances: adaptive integration error decreases monotonically-ish with
/// tighter tolerance, and the number of accepted steps grows.
#[test]
fn prop_tolerance_monotonicity() {
    let toy = LinearToy::new(1.0, 1);
    for solver_name in ["alf", "reversible4", "rk23", "dopri5", "heun-euler"] {
        let solver = solver_by_name(solver_name).unwrap();
        let mut last_steps = 0usize;
        for (i, rtol) in [1e-2, 1e-4, 1e-6].iter().enumerate() {
            let s0 = solver.init(&toy, 0.0, &[1.0]);
            let (sf, st) = integrate(
                &*solver,
                &toy,
                0.0,
                3.0,
                s0,
                &StepMode::adaptive(*rtol, rtol * 1e-2),
                &ErrorNorm::Full,
                &mut (),
            )
            .unwrap();
            let err = ((sf.z[0] as f64) - 3f64.exp()).abs() / 3f64.exp();
            // loose absolute gate: relative error under ~100·rtol
            assert!(
                err < 100.0 * rtol,
                "{solver_name} rtol {rtol}: rel err {err}"
            );
            if i > 0 {
                assert!(
                    st.n_accepted >= last_steps,
                    "{solver_name}: steps should grow with tighter tol"
                );
            }
            last_steps = st.n_accepted;
        }
    }
}

/// ∀ h: the fixed-step loop always lands exactly on T and the grid is
/// uniform — required for MALI's reconstruction to be well-posed.
#[test]
fn prop_fixed_grid_exact() {
    let toy = LinearToy::new(0.3, 2);
    let solver = solver_by_name("alf").unwrap();
    let mut rng = Rng::new(404);
    for _ in 0..CASES {
        let t1 = rng.range(0.3, 4.0);
        let h = rng.range(0.01, 0.7);
        let s0 = solver.init(&toy, 0.0, &[1.0, -1.0]);
        let mut rec = GridRecorder::new(0.0);
        integrate(
            &*solver,
            &toy,
            0.0,
            t1,
            s0,
            &StepMode::Fixed { h },
            &ErrorNorm::Full,
            &mut rec,
        )
        .unwrap();
        assert!((rec.times().last().unwrap() - t1).abs() < 1e-9);
        let n = rec.times().len() - 1;
        let hs = t1 / n as f64;
        for (i, w) in rec.times().windows(2).enumerate() {
            assert!(
                ((w[1] - w[0]) - hs).abs() < 1e-9,
                "step {i}: {} vs {hs}",
                w[1] - w[0]
            );
        }
    }
}

/// Damping sweep: for every η ∈ (0.5, 1] the one-step error of damped ALF
/// on the toy problem stays bounded and the roundtrip property holds; at
/// η = 1 the error is smallest in the asymptotic regime (2nd vs 1st order).
#[test]
fn prop_damped_alf_error_ordering() {
    let toy = LinearToy::new(1.0, 1);
    let h = 0.02;
    let mut errs = Vec::new();
    for &eta in &[1.0, 0.9, 0.8, 0.7, 0.6] {
        let solver = AlfSolver::new(eta);
        let s0 = solver.init(&toy, 0.0, &[1.0]);
        let (sf, _) = integrate(
            &solver,
            &toy,
            0.0,
            1.0,
            s0,
            &StepMode::Fixed { h },
            &ErrorNorm::Full,
            &mut (),
        )
        .unwrap();
        errs.push(((sf.z[0] as f64) - 1f64.exp()).abs());
    }
    // η = 1 (second order) should beat the damped (first order) variants at
    // this small h
    for (i, &e) in errs.iter().enumerate().skip(1) {
        assert!(
            errs[0] <= e,
            "η=1 err {} should be ≤ damped err {} (idx {i})",
            errs[0],
            e
        );
    }
}
