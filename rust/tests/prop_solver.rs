//! Property-based differential tests for the workspace refactor — the
//! proof that threading preallocated buffers through the solver stack
//! changed **nothing** numerically:
//!
//! * workspace path ≡ allocating path, **bitwise**, for every solver
//!   entry point (ψ/ψ⁻¹/ψ-vjp, step/step_vjp/invert/invert_and_vjp,
//!   solo + batch) over seeded-random dims, times, steps and damping;
//! * `integrate_ws` (with a dirty, reused workspace) ≡ `integrate`
//!   bitwise, in fixed and adaptive mode, with and without observation
//!   grids;
//! * ALF's ψ∘ψ⁻¹ round trip stays exact to float roundoff across random
//!   configurations, and the reversible-4 composition Ψ = ψ∘ψ∘ψ inherits
//!   it (Ψ⁻¹∘Ψ = id within a roundoff envelope, every `_into` entry
//!   point bitwise equal to its allocating wrapper under dirty reuse);
//! * batched adaptive integration stays decision-identical to solo runs
//!   row for row on random batches.

use mali_ode::dynamics_native::{ConvStemDynamics, MlpDynamics as NativeMlp, TimeMode};
use mali_ode::solvers::alf::AlfSolver;
use mali_ode::solvers::batch::{BatchSpec, BatchState};
use mali_ode::solvers::by_name as solver_by_name;
use mali_ode::solvers::dynamics::{Dynamics, LinearToy, MlpDynamics};
use mali_ode::solvers::integrate::{
    integrate, integrate_batch, integrate_batch_ws, integrate_obs, integrate_obs_ws,
    BatchGridRecorder, ErrorNorm, GridRecorder, ObsGrid, StepMode,
};
use mali_ode::solvers::reversible::Reversible4;
use mali_ode::solvers::rk::{RkSolver, Tableau};
use mali_ode::solvers::workspace::{BatchWorkspace, SolverWorkspace};
use mali_ode::solvers::{Solver, State};
use mali_ode::util::rng::Rng;

fn rand_state(rng: &mut Rng, n: usize, with_v: bool) -> State {
    let mut z = vec![0.0f32; n];
    rng.fill_uniform_sym(&mut z, 1.0);
    let v = with_v.then(|| {
        let mut v = vec![0.0f32; n];
        rng.fill_uniform_sym(&mut v, 1.0);
        v
    });
    State { z, v }
}

/// Every ALF entry point: `_into` output bitwise equal to the allocating
/// wrapper, across random dims / times / steps / damping, on both toy
/// and MLP dynamics.
#[test]
fn alf_workspace_bitwise_equals_allocating() {
    let mut rng = Rng::new(101);
    let mut ws = SolverWorkspace::new(); // deliberately reused (dirty) across trials
    for trial in 0..24 {
        let n = 1 + rng.below(6);
        let eta = [1.0, 0.95, 0.9, 0.8][rng.below(4)];
        let solver = AlfSolver::new(eta);
        let dynamics: Box<dyn Dynamics> = if trial % 2 == 0 {
            Box::new(LinearToy::new(rng.range(-1.0, 1.0), n))
        } else {
            Box::new(MlpDynamics::new(n, 2 + rng.below(5), &mut rng))
        };
        let d = &*dynamics;
        let t = rng.range(-1.0, 1.0);
        let h = rng.range(0.01, 0.4);
        let s = {
            let mut z = vec![0.0f32; n];
            rng.fill_uniform_sym(&mut z, 1.0);
            let v = d.f(t, &z);
            State { z, v: Some(v) }
        };
        let a_out = rand_state(&mut rng, n, trial % 3 != 0);

        // step
        let (want, want_err) = solver.step(d, t, h, &s);
        let mut out = rand_state(&mut rng, n, false); // dirty output buffer
        let mut err = vec![7.0f32; 1];
        let has_err = solver.step_into(d, t, h, &s, &mut out, &mut err, &mut ws);
        assert!(has_err, "trial {trial}");
        assert_eq!(out, want, "step trial {trial}");
        assert_eq!(Some(err.clone()), want_err, "step err trial {trial}");

        // step_vjp (θ-accumulation starts from zero on both paths)
        let (want_a, want_th) = solver.step_vjp(d, t, h, &s, &a_out);
        let mut a_in = rand_state(&mut rng, n, false);
        let mut th = vec![0.0f32; d.param_dim()];
        solver.step_vjp_into(d, t, h, &s, &a_out, &mut a_in, &mut th, &mut ws);
        assert_eq!(a_in, want_a, "step_vjp trial {trial}");
        assert_eq!(th, want_th, "step_vjp θ trial {trial}");

        // invert
        let want_inv = solver.invert(d, t + h, h, &s).unwrap();
        let mut inv = rand_state(&mut rng, n, false);
        assert!(solver.invert_into(d, t + h, h, &s, &mut inv, &mut ws));
        assert_eq!(inv, want_inv, "invert trial {trial}");

        // invert_and_vjp
        let (want_s, want_a, want_th) = solver.invert_and_vjp(d, t + h, h, &s, &a_out).unwrap();
        let mut s_in = rand_state(&mut rng, n, false);
        let mut a_in = rand_state(&mut rng, n, false);
        let mut th = vec![0.0f32; d.param_dim()];
        let ok = solver.invert_and_vjp_into(
            d, t + h, h, &s, &a_out, &mut s_in, &mut a_in, &mut th, &mut ws,
        );
        assert!(ok);
        assert_eq!(s_in, want_s, "invert_and_vjp s trial {trial}");
        assert_eq!(a_in, want_a, "invert_and_vjp a trial {trial}");
        assert_eq!(th, want_th, "invert_and_vjp θ trial {trial}");
    }
}

/// Every RK entry point across the tableau family: `_into` bitwise equal
/// to the allocating wrapper.
#[test]
fn rk_workspace_bitwise_equals_allocating() {
    let mut rng = Rng::new(202);
    let mut ws = SolverWorkspace::new();
    let tableaus = [
        Tableau::euler(),
        Tableau::midpoint(),
        Tableau::rk4(),
        Tableau::heun_euler(),
        Tableau::rk23(),
        Tableau::dopri5(),
    ];
    for (trial, tab) in tableaus.iter().enumerate() {
        let n = 1 + rng.below(5);
        let solver = RkSolver::new(tab.clone());
        let dynamics = MlpDynamics::new(n, 3 + rng.below(4), &mut rng);
        let t = rng.range(-0.5, 0.5);
        let h = rng.range(0.05, 0.35);
        let s = rand_state(&mut rng, n, false);
        let a_out = rand_state(&mut rng, n, false);

        let (want, want_err) = solver.step(&dynamics, t, h, &s);
        let mut out = rand_state(&mut rng, n, false);
        let mut err = Vec::new();
        let has_err = solver.step_into(&dynamics, t, h, &s, &mut out, &mut err, &mut ws);
        assert_eq!(out, want, "{} step", tab.name);
        assert_eq!(has_err, want_err.is_some(), "{} err presence", tab.name);
        if let Some(we) = want_err {
            assert_eq!(err, we, "{} err", tab.name);
        }
        assert!(
            solver.invert(&dynamics, t + h, h, &s).is_none(),
            "RK must stay non-invertible"
        );

        let (want_a, want_th) = solver.step_vjp(&dynamics, t, h, &s, &a_out);
        let mut a_in = rand_state(&mut rng, n, false);
        let mut th = vec![0.0f32; dynamics.param_dim()];
        solver.step_vjp_into(&dynamics, t, h, &s, &a_out, &mut a_in, &mut th, &mut ws);
        assert_eq!(a_in, want_a, "{} step_vjp trial {trial}", tab.name);
        assert_eq!(th, want_th, "{} step_vjp θ", tab.name);
    }
}

/// Batched entry points: `_into` bitwise equal to the allocating batch
/// wrappers under desynchronized per-row `(t, h)`.
#[test]
fn batch_workspace_bitwise_equals_allocating() {
    let mut rng = Rng::new(303);
    let mut ws = BatchWorkspace::new();
    for trial in 0..12 {
        let b = 1 + rng.below(4);
        let n_z = 1 + rng.below(4);
        let spec = BatchSpec::new(b, n_z);
        let dynamics: Box<dyn Dynamics> = if trial % 2 == 0 {
            Box::new(LinearToy::new(rng.range(-1.0, 1.0), n_z))
        } else {
            Box::new(MlpDynamics::new(n_z, 2 + rng.below(4), &mut rng))
        };
        let d = &*dynamics;
        let ts: Vec<f64> = (0..b).map(|_| rng.range(-1.0, 1.0)).collect();
        let hs: Vec<f64> = (0..b).map(|_| rng.range(0.02, 0.3)).collect();
        let mut z = vec![0.0f32; spec.flat_len()];
        rng.fill_uniform_sym(&mut z, 1.0);
        let mut az = vec![0.0f32; spec.flat_len()];
        rng.fill_uniform_sym(&mut az, 1.0);
        let mut av = vec![0.0f32; spec.flat_len()];
        rng.fill_uniform_sym(&mut av, 1.0);

        // ALF
        let alf = AlfSolver::new([1.0, 0.9][trial % 2]);
        let v = d.f_batch(&ts, &z, &spec);
        let s = BatchState::from_flat_zv(z.clone(), v.clone(), spec);
        let a_out = BatchState::from_flat_zv(az.clone(), av.clone(), spec);

        let (want, want_err) = alf.step_batch(d, &ts, &hs, &s);
        let mut out = BatchState::from_flat(vec![0.0f32; spec.flat_len()], spec);
        let mut err = Vec::new();
        assert!(alf.step_batch_into(d, &ts, &hs, &s, &mut out, &mut err, &mut ws));
        assert_eq!(out, want, "alf step_batch trial {trial}");
        assert_eq!(Some(err.clone()), want_err, "alf step_batch err {trial}");

        let (want_a, want_th) = alf.step_vjp_batch(d, &ts, &hs, &s, &a_out);
        let mut a_in = BatchState::from_flat(vec![0.0f32; spec.flat_len()], spec);
        let mut th = vec![0.0f32; d.param_dim()];
        alf.step_vjp_batch_into(d, &ts, &hs, &s, &a_out, &mut a_in, &mut th, &mut ws);
        assert_eq!(a_in, want_a, "alf step_vjp_batch {trial}");
        assert_eq!(th, want_th, "alf step_vjp_batch θ {trial}");

        let ts_out: Vec<f64> = ts.iter().zip(&hs).map(|(&t, &h)| t + h).collect();
        let want_inv = alf.invert_batch(d, &ts_out, &hs, &s).unwrap();
        let mut inv = BatchState::from_flat(vec![0.0f32; spec.flat_len()], spec);
        assert!(alf.invert_batch_into(d, &ts_out, &hs, &s, &mut inv, &mut ws));
        assert_eq!(inv, want_inv, "alf invert_batch {trial}");

        let (want_s, want_a, want_th) =
            alf.invert_and_vjp_batch(d, &ts_out, &hs, &s, &a_out).unwrap();
        let mut s_in = BatchState::from_flat(vec![0.0f32; spec.flat_len()], spec);
        let mut a_in = BatchState::from_flat(vec![0.0f32; spec.flat_len()], spec);
        let mut th = vec![0.0f32; d.param_dim()];
        assert!(alf.invert_and_vjp_batch_into(
            d, &ts_out, &hs, &s, &a_out, &mut s_in, &mut a_in, &mut th, &mut ws
        ));
        assert_eq!(s_in, want_s, "alf invert_and_vjp_batch s {trial}");
        assert_eq!(a_in, want_a, "alf invert_and_vjp_batch a {trial}");
        assert_eq!(th, want_th, "alf invert_and_vjp_batch θ {trial}");

        // RK (dopri5 as the stiffest tableau: 7 stages, sparse rows)
        let rk = RkSolver::new(Tableau::dopri5());
        let s = BatchState::from_flat(z.clone(), spec);
        let a_out = BatchState::from_flat(az.clone(), spec);
        let (want, want_err) = rk.step_batch(d, &ts, &hs, &s);
        let mut out = BatchState::from_flat(vec![0.0f32; spec.flat_len()], spec);
        let mut err = Vec::new();
        assert!(rk.step_batch_into(d, &ts, &hs, &s, &mut out, &mut err, &mut ws));
        assert_eq!(out, want, "rk step_batch {trial}");
        assert_eq!(Some(err.clone()), want_err, "rk step_batch err {trial}");

        let (want_a, want_th) = rk.step_vjp_batch(d, &ts, &hs, &s, &a_out);
        let mut a_in = BatchState::from_flat(vec![0.0f32; spec.flat_len()], spec);
        let mut th = vec![0.0f32; d.param_dim()];
        rk.step_vjp_batch_into(d, &ts, &hs, &s, &a_out, &mut a_in, &mut th, &mut ws);
        assert_eq!(a_in, want_a, "rk step_vjp_batch {trial}");
        assert_eq!(th, want_th, "rk step_vjp_batch θ {trial}");
    }
}

/// `integrate_ws` with a reused (dirty) workspace is bitwise identical to
/// the allocating `integrate`, in both modes, with and without grids —
/// final state, accepted grid and structural stats all equal.
#[test]
fn integrate_ws_bitwise_equals_integrate() {
    let mut rng = Rng::new(404);
    let mut ws = SolverWorkspace::new();
    for trial in 0..8 {
        let n = 1 + rng.below(4);
        let toy = LinearToy::new(rng.range(0.2, 1.0), n);
        let solver = solver_by_name(["alf", "dopri5"][trial % 2]).unwrap();
        let mode = if trial % 4 < 2 {
            StepMode::Fixed {
                h: rng.range(0.05, 0.2),
            }
        } else {
            StepMode::adaptive(1e-4, 1e-6)
        };
        let t1 = rng.range(0.5, 2.0);
        let mut z0 = vec![0.0f32; n];
        rng.fill_uniform_sym(&mut z0, 2.0);
        let grid = if trial % 2 == 0 {
            ObsGrid::none()
        } else {
            ObsGrid::new(vec![t1 * 0.37, t1 * 0.81]).unwrap()
        };

        let s0 = solver.init(&toy, 0.0, &z0);
        let mut rec_a = GridRecorder::new(0.0);
        let (want_state, want_stats) = integrate_obs(
            &*solver,
            &toy,
            0.0,
            t1,
            s0,
            &mode,
            &ErrorNorm::Full,
            &grid,
            &mut rec_a,
        )
        .unwrap();

        let s0 = solver.init(&toy, 0.0, &z0);
        let mut rec_b = GridRecorder::new(0.0);
        let stats = integrate_obs_ws(
            &*solver,
            &toy,
            0.0,
            t1,
            &s0,
            &mode,
            &ErrorNorm::Full,
            &grid,
            &mut rec_b,
            &mut ws,
        )
        .unwrap();
        assert_eq!(ws.output().z, want_state.z, "trial {trial} final z");
        assert_eq!(ws.output().v, want_state.v, "trial {trial} final v");
        assert_eq!(stats.n_accepted, want_stats.n_accepted, "trial {trial}");
        assert_eq!(stats.n_trials, want_stats.n_trials, "trial {trial}");
        assert_eq!(stats.f_evals, want_stats.f_evals, "trial {trial}");
        assert_eq!(rec_a.times(), rec_b.times(), "trial {trial} grids");
        assert_eq!(rec_a.obs_marks(), rec_b.obs_marks(), "trial {trial} marks");
    }
}

/// ALF's ψ∘ψ⁻¹ round trip stays exact to float roundoff across random
/// configurations (the invariant MALI's constant-memory reconstruction
/// rests on), and the workspace ψ⁻¹ equals the allocating ψ⁻¹ bitwise.
#[test]
fn alf_psi_roundtrip_random_configs() {
    let mut rng = Rng::new(505);
    let mut ws = SolverWorkspace::new();
    for trial in 0..20 {
        let n = 1 + rng.below(6);
        let eta = [1.0, 0.9, 0.8, 0.7][rng.below(4)];
        let solver = AlfSolver::new(eta);
        let dynamics = MlpDynamics::new(n, 2 + rng.below(6), &mut rng);
        let t = rng.range(-1.0, 1.0);
        let h = rng.range(0.01, 0.3);
        let mut z = vec![0.0f32; n];
        rng.fill_uniform_sym(&mut z, 1.0);
        let v = dynamics.f(t, &z);

        let (z1, v1, _) = solver.psi(&dynamics, t, h, &z, &v);
        let (z0, v0) = solver.psi_inv(&dynamics, t + h, h, &z1, &v1);
        for i in 0..n {
            assert!(
                (z0[i] - z[i]).abs() < 1e-4 * (1.0 + z[i].abs()),
                "trial {trial} z[{i}]: {} vs {}",
                z0[i],
                z[i]
            );
            assert!(
                (v0[i] - v[i]).abs() < 1e-4 * (1.0 + v[i].abs()),
                "trial {trial} v[{i}]"
            );
        }

        // workspace ψ⁻¹ ≡ allocating ψ⁻¹ bitwise
        let mut z0_ws = vec![0.0f32; n];
        let mut v0_ws = vec![0.0f32; n];
        solver.psi_inv_into(&dynamics, t + h, h, &z1, &v1, &mut z0_ws, &mut v0_ws, &mut ws);
        assert_eq!(z0_ws, z0, "trial {trial}");
        assert_eq!(v0_ws, v0, "trial {trial}");
    }
}

/// Every reversible-4 entry point: `_into` output bitwise equal to the
/// allocating wrapper, across random dims / times / steps / damping, with
/// a deliberately dirty reused workspace and dirty output buffers — the
/// triple-jump composition must honor the same take/restore workspace
/// contract as the ALF kernels it chains.
#[test]
fn reversible4_workspace_bitwise_equals_allocating() {
    let mut rng = Rng::new(909);
    let mut ws = SolverWorkspace::new(); // deliberately reused (dirty) across trials
    for trial in 0..24 {
        let n = 1 + rng.below(6);
        let eta = [1.0, 1.0, 0.95, 0.9][rng.below(4)];
        let solver = Reversible4::new(eta);
        let dynamics: Box<dyn Dynamics> = if trial % 2 == 0 {
            Box::new(LinearToy::new(rng.range(-1.0, 1.0), n))
        } else {
            Box::new(MlpDynamics::new(n, 2 + rng.below(5), &mut rng))
        };
        let d = &*dynamics;
        let t = rng.range(-1.0, 1.0);
        let h = rng.range(0.01, 0.4);
        let s = {
            let mut z = vec![0.0f32; n];
            rng.fill_uniform_sym(&mut z, 1.0);
            let v = d.f(t, &z);
            State { z, v: Some(v) }
        };
        let a_out = rand_state(&mut rng, n, trial % 3 != 0);

        // step (Ψ = ψ∘ψ∘ψ)
        let (want, want_err) = solver.step(d, t, h, &s);
        let mut out = rand_state(&mut rng, n, false); // dirty output buffer
        let mut err = vec![7.0f32; 1];
        let has_err = solver.step_into(d, t, h, &s, &mut out, &mut err, &mut ws);
        assert!(has_err, "trial {trial}");
        assert_eq!(out, want, "step trial {trial}");
        assert_eq!(Some(err.clone()), want_err, "step err trial {trial}");

        // step_vjp (θ-accumulation starts from zero on both paths)
        let (want_a, want_th) = solver.step_vjp(d, t, h, &s, &a_out);
        let mut a_in = rand_state(&mut rng, n, false);
        let mut th = vec![0.0f32; d.param_dim()];
        solver.step_vjp_into(d, t, h, &s, &a_out, &mut a_in, &mut th, &mut ws);
        assert_eq!(a_in, want_a, "step_vjp trial {trial}");
        assert_eq!(th, want_th, "step_vjp θ trial {trial}");

        // invert (Ψ⁻¹ = ψ⁻¹∘ψ⁻¹∘ψ⁻¹, reversed sub-step order)
        let want_inv = solver.invert(d, t + h, h, &s).unwrap();
        let mut inv = rand_state(&mut rng, n, false);
        assert!(solver.invert_into(d, t + h, h, &s, &mut inv, &mut ws));
        assert_eq!(inv, want_inv, "invert trial {trial}");

        // invert_and_vjp (MALI backward micro-step on the composition)
        let (want_s, want_a, want_th) = solver.invert_and_vjp(d, t + h, h, &s, &a_out).unwrap();
        let mut s_in = rand_state(&mut rng, n, false);
        let mut a_in = rand_state(&mut rng, n, false);
        let mut th = vec![0.0f32; d.param_dim()];
        let ok = solver.invert_and_vjp_into(
            d, t + h, h, &s, &a_out, &mut s_in, &mut a_in, &mut th, &mut ws,
        );
        assert!(ok);
        assert_eq!(s_in, want_s, "invert_and_vjp s trial {trial}");
        assert_eq!(a_in, want_a, "invert_and_vjp a trial {trial}");
        assert_eq!(th, want_th, "invert_and_vjp θ trial {trial}");
    }
}

/// Batched reversible-4 entry points: `_into` bitwise equal to the
/// allocating batch wrappers under desynchronized per-row `(t, h)` with a
/// dirty reused workspace, including the composed
/// `invert_and_vjp_batch` (which routes both paths through the same
/// batched sub-step kernels).
#[test]
fn reversible4_batch_workspace_bitwise_equals_allocating() {
    let mut rng = Rng::new(1001);
    let mut ws = BatchWorkspace::new();
    for trial in 0..12 {
        let b = 1 + rng.below(4);
        let n_z = 1 + rng.below(4);
        let spec = BatchSpec::new(b, n_z);
        let dynamics: Box<dyn Dynamics> = if trial % 2 == 0 {
            Box::new(LinearToy::new(rng.range(-1.0, 1.0), n_z))
        } else {
            Box::new(MlpDynamics::new(n_z, 2 + rng.below(4), &mut rng))
        };
        let d = &*dynamics;
        let solver = Reversible4::new([1.0, 0.9][trial % 2]);
        let ts: Vec<f64> = (0..b).map(|_| rng.range(-1.0, 1.0)).collect();
        let hs: Vec<f64> = (0..b).map(|_| rng.range(0.02, 0.3)).collect();
        let mut z = vec![0.0f32; spec.flat_len()];
        rng.fill_uniform_sym(&mut z, 1.0);
        let v = d.f_batch(&ts, &z, &spec);
        let s = BatchState::from_flat_zv(z.clone(), v, spec);
        let mut az = vec![0.0f32; spec.flat_len()];
        rng.fill_uniform_sym(&mut az, 1.0);
        let mut av = vec![0.0f32; spec.flat_len()];
        rng.fill_uniform_sym(&mut av, 1.0);
        let a_out = BatchState::from_flat_zv(az, av, spec);

        let (want, want_err) = solver.step_batch(d, &ts, &hs, &s);
        let mut out = BatchState::from_flat(vec![0.0f32; spec.flat_len()], spec);
        let mut err = vec![7.0f32; 2]; // dirty, wrong-sized error buffer
        assert!(solver.step_batch_into(d, &ts, &hs, &s, &mut out, &mut err, &mut ws));
        assert_eq!(out, want, "step_batch trial {trial}");
        assert_eq!(Some(err.clone()), want_err, "step_batch err {trial}");

        let (want_a, want_th) = solver.step_vjp_batch(d, &ts, &hs, &s, &a_out);
        let mut a_in = BatchState::from_flat(vec![0.0f32; spec.flat_len()], spec);
        let mut th = vec![0.0f32; d.param_dim()];
        solver.step_vjp_batch_into(d, &ts, &hs, &s, &a_out, &mut a_in, &mut th, &mut ws);
        assert_eq!(a_in, want_a, "step_vjp_batch {trial}");
        assert_eq!(th, want_th, "step_vjp_batch θ {trial}");

        let ts_out: Vec<f64> = ts.iter().zip(&hs).map(|(&t, &h)| t + h).collect();
        let want_inv = solver.invert_batch(d, &ts_out, &hs, &s).unwrap();
        let mut inv = BatchState::from_flat(vec![0.0f32; spec.flat_len()], spec);
        assert!(solver.invert_batch_into(d, &ts_out, &hs, &s, &mut inv, &mut ws));
        assert_eq!(inv, want_inv, "invert_batch {trial}");

        let (want_s, want_a, want_th) = solver
            .invert_and_vjp_batch(d, &ts_out, &hs, &s, &a_out)
            .unwrap();
        let mut s_in = BatchState::from_flat(vec![0.0f32; spec.flat_len()], spec);
        let mut a_in = BatchState::from_flat(vec![0.0f32; spec.flat_len()], spec);
        let mut th = vec![0.0f32; d.param_dim()];
        assert!(solver.invert_and_vjp_batch_into(
            d, &ts_out, &hs, &s, &a_out, &mut s_in, &mut a_in, &mut th, &mut ws
        ));
        assert_eq!(s_in, want_s, "invert_and_vjp_batch s {trial}");
        assert_eq!(a_in, want_a, "invert_and_vjp_batch a {trial}");
        assert_eq!(th, want_th, "invert_and_vjp_batch θ {trial}");
    }
}

/// The composed inverse undoes the composed step across random
/// configurations: Ψ⁻¹(Ψ(z, v)) = (z, v) within the same roundoff
/// envelope ALF's single-step roundtrip satisfies — the invariant that
/// lets MALI run its constant-memory reconstruction on the 4th-order
/// solver unchanged.
#[test]
fn reversible4_roundtrip_random_configs() {
    let mut rng = Rng::new(1102);
    let mut ws = SolverWorkspace::new();
    for trial in 0..20 {
        let n = 1 + rng.below(6);
        let eta = [1.0, 1.0, 0.9, 0.8][rng.below(4)];
        let solver = Reversible4::new(eta);
        let dynamics = MlpDynamics::new(n, 2 + rng.below(6), &mut rng);
        let t = rng.range(-1.0, 1.0);
        let h = rng.range(0.01, 0.3);
        let s = {
            let mut z = vec![0.0f32; n];
            rng.fill_uniform_sym(&mut z, 1.0);
            let v = dynamics.f(t, &z);
            State { z, v: Some(v) }
        };

        let mut out = rand_state(&mut rng, n, false);
        let mut err = vec![0.0f32; 1];
        solver.step_into(&dynamics, t, h, &s, &mut out, &mut err, &mut ws);
        let mut back = rand_state(&mut rng, n, false);
        assert!(solver.invert_into(&dynamics, t + h, h, &out, &mut back, &mut ws));
        let (sv, bv) = (s.v.as_ref().unwrap(), back.v.as_ref().unwrap());
        for i in 0..n {
            assert!(
                (back.z[i] - s.z[i]).abs() < 1e-4 * (1.0 + s.z[i].abs()),
                "trial {trial} z[{i}]: {} vs {}",
                back.z[i],
                s.z[i]
            );
            assert!(
                (bv[i] - sv[i]).abs() < 1e-4 * (1.0 + sv[i].abs()),
                "trial {trial} v[{i}]: {} vs {}",
                bv[i],
                sv[i]
            );
        }
    }
}

/// Seeded-random native dynamics (MLP depths/widths/time-modes and the
/// conv stem) for the fused-path differential tests.
fn rand_native(trial: usize, rng: &mut Rng) -> Box<dyn Dynamics> {
    if trial % 3 == 2 {
        Box::new(ConvStemDynamics::new(
            3,
            2,
            &[1 + rng.below(3)],
            [TimeMode::None, TimeMode::Affine][rng.below(2)],
            rng,
        ))
    } else {
        let n = 2 + rng.below(5);
        let hidden: Vec<usize> = (0..rng.below(3)).map(|_| 3 + rng.below(5)).collect();
        let tm = [TimeMode::None, TimeMode::Concat, TimeMode::Affine][rng.below(3)];
        Box::new(NativeMlp::new(n, &hidden, tm, rng))
    }
}

/// The fused one-dispatch ψ / ψ⁻¹ / ψ-vjp / backward step of the native
/// dynamics is **bitwise** identical to the composed unfused path
/// (separate f / f_vjp calls through the solver's own kernel sequence),
/// across random dims, depths, time-modes, steps and damping.
#[test]
fn fused_psi_paths_bitwise_equal_unfused() {
    let mut rng = Rng::new(707);
    let mut ws_f = SolverWorkspace::new();
    let mut ws_u = SolverWorkspace::new();
    for trial in 0..18 {
        let eta = [1.0, 0.95, 0.9, 0.8][rng.below(4)];
        let dynamics = rand_native(trial, &mut rng);
        let d = &*dynamics;
        let n = d.dim();
        let fused = AlfSolver::new(eta);
        assert!(fused.prefer_fused, "fusion must be the default");
        let unfused = AlfSolver {
            eta,
            prefer_fused: false,
        };
        let t = rng.range(-0.5, 0.5);
        let h = rng.range(0.02, 0.3);
        let s = {
            let mut z = vec![0.0f32; n];
            rng.fill_uniform_sym(&mut z, 0.8);
            let v = d.f(t, &z);
            State { z, v: Some(v) }
        };
        let a_out = rand_state(&mut rng, n, true);

        // ψ
        let mut out_f = rand_state(&mut rng, n, false);
        let mut err_f = vec![3.0f32; 1];
        fused.step_into(d, t, h, &s, &mut out_f, &mut err_f, &mut ws_f);
        let mut out_u = rand_state(&mut rng, n, false);
        let mut err_u = vec![5.0f32; 1];
        unfused.step_into(d, t, h, &s, &mut out_u, &mut err_u, &mut ws_u);
        assert_eq!(out_f, out_u, "ψ trial {trial}");
        assert_eq!(err_f, err_u, "ψ err trial {trial}");

        // ψ⁻¹ (from the stepped state, so the round trip is the real one)
        let mut inv_f = rand_state(&mut rng, n, false);
        assert!(fused.invert_into(d, t + h, h, &out_f, &mut inv_f, &mut ws_f));
        let mut inv_u = rand_state(&mut rng, n, false);
        assert!(unfused.invert_into(d, t + h, h, &out_u, &mut inv_u, &mut ws_u));
        assert_eq!(inv_f, inv_u, "ψ⁻¹ trial {trial}");

        // ψ-vjp (θ accumulators start equal and must stay bitwise equal)
        let mut a_f = rand_state(&mut rng, n, false);
        let mut th_f = vec![0.0f32; d.param_dim()];
        fused.step_vjp_into(d, t, h, &s, &a_out, &mut a_f, &mut th_f, &mut ws_f);
        let mut a_u = rand_state(&mut rng, n, false);
        let mut th_u = vec![0.0f32; d.param_dim()];
        unfused.step_vjp_into(d, t, h, &s, &a_out, &mut a_u, &mut th_u, &mut ws_u);
        assert_eq!(a_f, a_u, "ψ-vjp trial {trial}");
        assert_eq!(th_f, th_u, "ψ-vjp θ trial {trial}");

        // fused backward (ψ⁻¹ + ψ-vjp in one dispatch)
        let mut s_f = rand_state(&mut rng, n, false);
        let mut ab_f = rand_state(&mut rng, n, false);
        let mut thb_f = vec![0.0f32; d.param_dim()];
        assert!(fused.invert_and_vjp_into(
            d, t + h, h, &out_f, &a_out, &mut s_f, &mut ab_f, &mut thb_f, &mut ws_f
        ));
        let mut s_u = rand_state(&mut rng, n, false);
        let mut ab_u = rand_state(&mut rng, n, false);
        let mut thb_u = vec![0.0f32; d.param_dim()];
        assert!(unfused.invert_and_vjp_into(
            d, t + h, h, &out_u, &a_out, &mut s_u, &mut ab_u, &mut thb_u, &mut ws_u
        ));
        assert_eq!(s_f, s_u, "bwd state trial {trial}");
        assert_eq!(ab_f, ab_u, "bwd cotangent trial {trial}");
        assert_eq!(thb_f, thb_u, "bwd θ trial {trial}");
    }
}

/// Batched fused dispatch ≡ batched unfused path, bitwise, under
/// desynchronized per-row `(t, h)` — and both ≡ the solo fused rows for
/// the state/cotangent outputs.
#[test]
fn fused_batch_paths_bitwise_equal_unfused() {
    let mut rng = Rng::new(808);
    let mut ws_f = BatchWorkspace::new();
    let mut ws_u = BatchWorkspace::new();
    for trial in 0..10 {
        let eta = [1.0, 0.9][trial % 2];
        let dynamics = rand_native(trial, &mut rng);
        let d = &*dynamics;
        let n_z = d.dim();
        let b = 1 + rng.below(4);
        let spec = BatchSpec::new(b, n_z);
        let fused = AlfSolver::new(eta);
        let unfused = AlfSolver {
            eta,
            prefer_fused: false,
        };
        let ts: Vec<f64> = (0..b).map(|_| rng.range(-0.5, 0.5)).collect();
        let hs: Vec<f64> = (0..b).map(|_| rng.range(0.02, 0.3)).collect();
        let mut z = vec![0.0f32; spec.flat_len()];
        rng.fill_uniform_sym(&mut z, 0.8);
        let v = d.f_batch(&ts, &z, &spec);
        let s = BatchState::from_flat_zv(z.clone(), v, spec);
        let mut az = vec![0.0f32; spec.flat_len()];
        rng.fill_uniform_sym(&mut az, 1.0);
        let mut av = vec![0.0f32; spec.flat_len()];
        rng.fill_uniform_sym(&mut av, 1.0);
        let a_out = BatchState::from_flat_zv(az, av, spec);

        // ψ batch
        let mut out_f = BatchState::from_flat(vec![0.0f32; spec.flat_len()], spec);
        let mut err_f = Vec::new();
        assert!(fused.step_batch_into(d, &ts, &hs, &s, &mut out_f, &mut err_f, &mut ws_f));
        let mut out_u = BatchState::from_flat(vec![0.0f32; spec.flat_len()], spec);
        let mut err_u = Vec::new();
        assert!(unfused.step_batch_into(d, &ts, &hs, &s, &mut out_u, &mut err_u, &mut ws_u));
        assert_eq!(out_f, out_u, "ψ batch trial {trial}");
        assert_eq!(err_f, err_u, "ψ batch err trial {trial}");

        // solo fused rows ≡ batched fused rows (state path)
        let mut ws_solo = SolverWorkspace::new();
        for row in 0..b {
            let srow = s.row_state(row);
            let mut orow = rand_state(&mut rng, n_z, false);
            let mut erow = vec![0.0f32; 1];
            fused.step_into(d, ts[row], hs[row], &srow, &mut orow, &mut erow, &mut ws_solo);
            assert_eq!(
                orow.z.as_slice(),
                spec.row(&out_f.z.data, row),
                "solo≡batch ψ z row {row} trial {trial}"
            );
        }

        // ψ⁻¹ batch
        let ts_out: Vec<f64> = ts.iter().zip(&hs).map(|(&t, &h)| t + h).collect();
        let mut inv_f = BatchState::from_flat(vec![0.0f32; spec.flat_len()], spec);
        assert!(fused.invert_batch_into(d, &ts_out, &hs, &out_f, &mut inv_f, &mut ws_f));
        let mut inv_u = BatchState::from_flat(vec![0.0f32; spec.flat_len()], spec);
        assert!(unfused.invert_batch_into(d, &ts_out, &hs, &out_u, &mut inv_u, &mut ws_u));
        assert_eq!(inv_f, inv_u, "ψ⁻¹ batch trial {trial}");

        // ψ-vjp batch
        let mut a_f = BatchState::from_flat(vec![0.0f32; spec.flat_len()], spec);
        let mut th_f = vec![0.0f32; d.param_dim()];
        fused.step_vjp_batch_into(d, &ts, &hs, &s, &a_out, &mut a_f, &mut th_f, &mut ws_f);
        let mut a_u = BatchState::from_flat(vec![0.0f32; spec.flat_len()], spec);
        let mut th_u = vec![0.0f32; d.param_dim()];
        unfused.step_vjp_batch_into(d, &ts, &hs, &s, &a_out, &mut a_u, &mut th_u, &mut ws_u);
        assert_eq!(a_f, a_u, "ψ-vjp batch trial {trial}");
        assert_eq!(th_f, th_u, "ψ-vjp batch θ trial {trial}");
    }
}

/// Random batches under per-sample adaptive control stay
/// decision-identical to solo runs row for row — grids, trial counts and
/// final states — through the workspace loop.
#[test]
fn batch_decision_identity_random() {
    let mut rng = Rng::new(606);
    let mut ws = BatchWorkspace::new();
    for trial in 0..4 {
        let b = 2 + rng.below(3);
        let n_z = 1 + rng.below(3);
        let toy = LinearToy::new(rng.range(0.4, 1.1), n_z);
        let solver = solver_by_name("alf").unwrap();
        let mode = StepMode::adaptive(1e-4, 1e-6);
        let t1 = 2.0;
        let spec = BatchSpec::new(b, n_z);
        let mut z0 = vec![0.0f32; spec.flat_len()];
        // very different row scales → desynchronized controllers
        for (i, zi) in z0.iter_mut().enumerate() {
            let row = i / n_z;
            *zi = (0.001 + row as f32).powi(2) * rng.range(0.5, 1.5) as f32;
        }

        let mut solo_grids = Vec::new();
        let mut solo_finals = Vec::new();
        let mut solo_trials = Vec::new();
        for row in 0..b {
            let s0 = solver.init(&toy, 0.0, spec.row(&z0, row));
            let mut rec = GridRecorder::new(0.0);
            let (sf, st) = integrate(
                &*solver,
                &toy,
                0.0,
                t1,
                s0,
                &mode,
                &ErrorNorm::Full,
                &mut rec,
            )
            .unwrap();
            solo_grids.push(rec.times().to_vec());
            solo_finals.push(sf.z);
            solo_trials.push(st.n_trials);
        }

        let b0 = solver.init_batch(&toy, 0.0, &z0, &spec);
        let mut rec = BatchGridRecorder::new(0.0, b);
        let stats = integrate_batch_ws(
            &*solver,
            &toy,
            0.0,
            t1,
            &b0,
            &mode,
            &ErrorNorm::Full,
            &mut rec,
            &mut ws,
        )
        .unwrap();
        let final_state = ws.take_output();
        for row in 0..b {
            assert_eq!(rec.times[row], solo_grids[row], "trial {trial} grid row {row}");
            assert_eq!(
                spec.row(&final_state.z.data, row),
                solo_finals[row].as_slice(),
                "trial {trial} final row {row}"
            );
            assert_eq!(
                stats.per_sample[row].n_trials, solo_trials[row],
                "trial {trial} trials row {row}"
            );
        }
        // ws-loop batch ≡ allocating-loop batch, bitwise
        let b0 = solver.init_batch(&toy, 0.0, &z0, &spec);
        let (want_state, want_stats) = integrate_batch(
            &*solver,
            &toy,
            0.0,
            t1,
            b0,
            &mode,
            &ErrorNorm::Full,
            &mut (),
        )
        .unwrap();
        assert_eq!(final_state, want_state, "trial {trial} ws ≡ alloc batch");
        assert_eq!(
            stats.n_accepted_total(),
            want_stats.n_accepted_total(),
            "trial {trial}"
        );
    }
}
