//! End-to-end tests of the TCP front-end (DESIGN.md §11) over loopback —
//! the transport's acceptance properties:
//!
//! * **transport transparency** — a request served over TCP is
//!   **bitwise** identical to the same request through the in-process
//!   [`Server::submit`] path: final state, per-observation snapshots,
//!   step/trial counts.  The wire protocol must be a pure encoding of
//!   the serve layer, never a reinterpretation — including pipelined
//!   out-of-order completion and two classes multiplexed on one
//!   connection;
//! * **resilience** — under overload every shed gets an explicit RETRY
//!   (exact accounting: client-observed == transport-sent == queue
//!   sheds), capped-backoff retry converges, the queue never exceeds
//!   its capacity, and graceful drain completes all accepted in-flight
//!   work while refusing new submits with RETRY(draining);
//! * **robustness** — oversized length prefixes, unknown frame types
//!   and submits against unopened classes are refused without wedging
//!   the connection or the server.

use mali_ode::serve::transport::{
    Backoff, Bridge, ClientEvent, ResponseFrame, TcpClient, TcpFront, TransportConfig,
};
use mali_ode::serve::{ModelRegistry, RequestClass, Server, ServerConfig};
use mali_ode::solvers::dynamics::LinearToy;
use mali_ode::solvers::integrate::{ObsGrid, StepMode};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

const N_Z: usize = 4;
const ALPHA: f64 = -0.35;

fn registry() -> Arc<ModelRegistry> {
    let mut reg = ModelRegistry::new();
    reg.register("toy", Box::new(LinearToy::new(ALPHA, N_Z)));
    Arc::new(reg)
}

fn start(queue_capacity: usize, workers: usize, max_batch: usize) -> Arc<Server> {
    Arc::new(Server::start(
        registry(),
        ServerConfig {
            queue_capacity,
            max_batch,
            max_wait: Duration::from_micros(200),
            workers,
            shards: 1,
        },
    ))
}

fn front_for(server: &Arc<Server>, cfg: TransportConfig) -> TcpFront {
    TcpFront::bind("127.0.0.1:0", server.clone() as Arc<dyn Bridge>, cfg).unwrap()
}

fn class_with(mode: StepMode, grid: ObsGrid) -> Arc<RequestClass> {
    Arc::new(RequestClass::new("toy", "alf", N_Z, 0.0, 1.0, mode, grid).unwrap())
}

fn request_rows(k: usize) -> Vec<Vec<f32>> {
    const SCALES: [f32; 5] = [0.001, 0.4, 1.0, 5.0, 20.0];
    (0..k)
        .map(|i| {
            let s = SCALES[i % SCALES.len()];
            (0..N_Z).map(|j| s * (1.0 + 0.17 * j as f32)).collect()
        })
        .collect()
}

/// Two classes multiplexed over one pipelined connection: every TCP
/// response is bitwise the direct-submit answer, and a fast request
/// submitted *after* a slow one completes *before* it (out-of-order
/// completion by req id).
#[test]
fn tcp_serving_is_bitwise_direct_submit() {
    let server = start(64, 2, 8);
    let front = front_for(&server, TransportConfig::default());
    let mut cl = TcpClient::connect(front.local_addr()).unwrap();

    // class 0: slow fixed grid (50k steps); class 1: fast adaptive
    let slow = class_with(
        StepMode::Fixed { h: 2e-5 },
        ObsGrid::new(vec![0.31, 0.5, 1.0]).unwrap(),
    );
    let fast = class_with(
        StepMode::adaptive(1e-4, 1e-6),
        ObsGrid::new(vec![0.31, 0.5, 1.0]).unwrap(),
    );
    cl.open_class(0, &slow).unwrap();
    cl.open_class(1, &fast).unwrap();

    // slow request first, fast second — with two workers the fast class
    // must complete first even though it was submitted later
    let rows = request_rows(6);
    cl.submit(1, 0, &rows[0]).unwrap();
    cl.submit(2, 1, &rows[1]).unwrap();
    let mut resp = ResponseFrame::default();
    let mut got: Vec<(u64, ResponseFrame)> = Vec::new();
    while got.len() < 2 {
        match cl.next_event(&mut resp).unwrap() {
            ClientEvent::Response => got.push((resp.req_id, resp.clone())),
            other => panic!("unexpected event {other:?}"),
        }
    }
    assert_eq!(
        got[0].0, 2,
        "fast request (req 2) must complete before the 50k-step slow one"
    );
    assert_eq!(got[1].0, 1);

    // bitwise equality against the direct in-process path, both classes
    for (req_id, tcp) in &got {
        let (class, z0) = if *req_id == 1 {
            (&slow, &rows[0])
        } else {
            (&fast, &rows[1])
        };
        let direct = server.submit(class, z0).unwrap().wait().unwrap();
        assert_eq!(tcp.z_final, direct.z_final, "final state bitwise (req {req_id})");
        assert_eq!(tcp.obs, direct.obs, "observation snapshots bitwise (req {req_id})");
        assert_eq!(tcp.n_accepted, direct.n_accepted, "steps (req {req_id})");
        assert_eq!(tcp.n_trials, direct.n_trials, "trials (req {req_id})");
    }

    // a pipelined burst across both classes, every answer bitwise
    let mut expect = Vec::new();
    for (i, z0) in rows.iter().enumerate() {
        let class_id = (i % 2) as u32;
        cl.submit(100 + i as u64, class_id, z0).unwrap();
        let class = if class_id == 0 { &slow } else { &fast };
        expect.push(server.submit(class, z0).unwrap().wait().unwrap());
    }
    let mut seen = 0;
    while seen < rows.len() {
        match cl.next_event(&mut resp).unwrap() {
            ClientEvent::Response => {
                let i = (resp.req_id - 100) as usize;
                assert_eq!(resp.z_final, expect[i].z_final, "burst req {i} final state");
                assert_eq!(resp.obs, expect[i].obs, "burst req {i} observations");
                assert_eq!(resp.n_accepted, expect[i].n_accepted);
                assert_eq!(resp.n_trials, expect[i].n_trials);
                seen += 1;
            }
            other => panic!("unexpected event {other:?}"),
        }
    }
    cl.goodbye().unwrap();
    assert!(front.shutdown(Duration::from_secs(10)).flushed);
}

/// Induced overload: a burst wider than the queue.  Every shed is
/// answered with RETRY, capped backoff converges (all requests
/// eventually served), the accounting is exact on both ends, and the
/// queue never grows past its capacity.
#[test]
fn overload_retries_are_exact_and_backoff_converges() {
    let server = start(4, 1, 4);
    // generous per-conn cap so the only refusals are queue sheds
    let front = front_for(
        &server,
        TransportConfig {
            max_inflight: 1024,
            ..TransportConfig::default()
        },
    );
    let mut cl = TcpClient::connect(front.local_addr()).unwrap();
    // ~10k steps per request: the reader outpaces the single worker
    let class = class_with(StepMode::Fixed { h: 1e-4 }, ObsGrid::none());
    cl.open_class(0, &class).unwrap();

    const BURST: usize = 48;
    let rows = request_rows(BURST);
    for (i, z0) in rows.iter().enumerate() {
        cl.submit(i as u64, 0, z0).unwrap();
    }
    let mut resp = ResponseFrame::default();
    let mut backoff = Backoff::new(
        Duration::from_micros(200),
        Duration::from_millis(20),
        7,
    );
    let mut served = vec![false; BURST];
    let mut done = 0usize;
    let mut retries = 0u64;
    while done < BURST {
        match cl.next_event(&mut resp).unwrap() {
            ClientEvent::Response => {
                let i = resp.req_id as usize;
                assert!(!served[i], "req {i} answered twice");
                served[i] = true;
                assert!(resp.n_accepted > 0);
                done += 1;
            }
            ClientEvent::Retry {
                req_id,
                backoff: hint,
                draining,
            } => {
                assert!(!draining);
                retries += 1;
                std::thread::sleep(backoff.next_delay(hint));
                cl.submit(req_id, 0, &rows[req_id as usize]).unwrap();
            }
            other => panic!("unexpected event {other:?}"),
        }
    }
    assert!(retries > 0, "burst of {BURST} into a 4-deep queue must shed");

    // exact accounting: client-observed == transport-sent == queue sheds
    let health = cl.health(9).unwrap();
    assert_eq!(health.queue_capacity, 4);
    assert!(health.queue_depth <= health.queue_capacity);
    assert_eq!(health.retries_sent, retries, "transport RETRY ledger");
    assert_eq!(health.shed_total, retries, "every RETRY was a queue shed");
    assert_eq!(front.retries_sent(), retries);
    assert_eq!(server.shed_count(), retries);

    cl.goodbye().unwrap();
    assert!(front.shutdown(Duration::from_secs(10)).flushed);
}

/// Graceful drain: accepted in-flight work completes and is flushed,
/// new submits are refused with RETRY(draining), the listener stops.
#[test]
fn graceful_drain_completes_accepted_work() {
    let server = start(16, 1, 4);
    let front = front_for(&server, TransportConfig::default());
    let addr = front.local_addr();
    let mut cl = TcpClient::connect(addr).unwrap();
    // ~50ms of work per request so both are genuinely in flight when
    // the drain begins
    let class = class_with(StepMode::Fixed { h: 2e-5 }, ObsGrid::none());
    cl.open_class(0, &class).unwrap();
    let rows = request_rows(2);
    cl.submit(1, 0, &rows[0]).unwrap();
    cl.submit(2, 0, &rows[1]).unwrap();

    front.begin_drain();
    // a submit after the drain flag flips is refused, tagged draining
    cl.submit(3, 0, &rows[0]).unwrap();
    let mut resp = ResponseFrame::default();
    let mut drain_retry = false;
    let mut served = 0;
    for _ in 0..3 {
        match cl.next_event(&mut resp).unwrap() {
            ClientEvent::Retry {
                req_id, draining, ..
            } => {
                assert_eq!(req_id, 3);
                assert!(draining, "drain refusals must carry the draining flag");
                drain_retry = true;
            }
            ClientEvent::Response => {
                assert!(resp.req_id == 1 || resp.req_id == 2);
                assert!(resp.n_accepted > 0);
                served += 1;
            }
            other => panic!("unexpected event {other:?}"),
        }
    }
    assert!(drain_retry);
    assert_eq!(served, 2, "accepted in-flight requests completed through the drain");

    let outcome = front.shutdown(Duration::from_secs(10));
    assert!(outcome.flushed, "drain must flush all accepted work");
    // the listener is gone: a fresh client cannot complete a handshake
    let refused = match TcpClient::connect(addr) {
        Err(_) => true,
        Ok(mut late) => late.health(1).is_err(),
    };
    assert!(refused, "post-drain connections must be refused");
}

/// Protocol robustness: oversized length prefixes and unknown frame
/// types close the connection; a submit naming an unopened class gets a
/// REQ_ERR while the connection (and server) keep working.
#[test]
fn malformed_input_is_contained() {
    let server = start(16, 1, 4);
    let front = front_for(
        &server,
        TransportConfig {
            max_frame: 1 << 12,
            ..TransportConfig::default()
        },
    );
    let addr = front.local_addr();

    // oversized length prefix: closed before any allocation matches it
    // (the length slot plus a type byte completes the 5-byte header the
    // reader validates against max_frame)
    let mut raw = TcpStream::connect(addr).unwrap();
    raw.write_all(b"MALI\x02\x00\x00\x00").unwrap();
    raw.write_all(&u32::MAX.to_le_bytes()).unwrap();
    raw.write_all(&[0x02]).unwrap();
    let mut buf = [0u8; 8];
    assert_eq!(raw.read(&mut buf).unwrap_or(0), 0, "oversized frame must close");

    // unknown frame type: same fate
    let mut raw = TcpStream::connect(addr).unwrap();
    raw.write_all(b"MALI\x02\x00\x00\x00").unwrap();
    raw.write_all(&2u32.to_le_bytes()).unwrap();
    raw.write_all(&[0x7f, 0x00]).unwrap();
    assert_eq!(raw.read(&mut buf).unwrap_or(0), 0, "unknown frame must close");

    // unopened class: in-band REQ_ERR, connection stays usable
    let mut cl = TcpClient::connect(addr).unwrap();
    let z0 = vec![1.0f32; N_Z];
    cl.submit(7, 5, &z0).unwrap();
    let mut resp = ResponseFrame::default();
    match cl.next_event(&mut resp).unwrap() {
        ClientEvent::ReqErr { req_id, msg } => {
            assert_eq!(req_id, 7);
            assert!(msg.contains("unopened class"), "{msg}");
        }
        other => panic!("expected REQ_ERR, got {other:?}"),
    }
    // ...and a real request on the same connection still round-trips
    let class = class_with(StepMode::Fixed { h: 0.01 }, ObsGrid::none());
    cl.open_class(0, &class).unwrap();
    let mut backoff = Backoff::new(Duration::from_micros(100), Duration::from_millis(5), 3);
    let attempts = cl
        .submit_with_retry(8, 0, &z0, &mut resp, &mut backoff)
        .unwrap();
    assert_eq!(attempts, 1);
    assert_eq!(resp.n_accepted, 100);
    let direct = server.submit(&class, &z0).unwrap().wait().unwrap();
    assert_eq!(resp.z_final, direct.z_final);

    assert!(front.shutdown(Duration::from_secs(10)).flushed);
}

/// Session transparency: a session streamed over TCP is bitwise an
/// in-process session fed the same events — every step's snapshots,
/// final state and step/trial counts — and HEALTH sees the live
/// session and the admitted steps.
#[test]
fn tcp_sessions_are_bitwise_in_process() {
    let server = start(64, 2, 8);
    let front = front_for(&server, TransportConfig::default());
    let mut cl = TcpClient::connect(front.local_addr()).unwrap();

    let mode = StepMode::adaptive(1e-4, 1e-6);
    let z0 = request_rows(1).remove(0);
    let chunks: [&[f64]; 3] = [&[0.15], &[0.3, 0.45, 0.5], &[0.8, 1.4]];

    let tcp_sid = cl.open_session(1, "toy", "alf", 0.0, &mode, &z0).unwrap();
    let ref_sid = server
        .open_session("toy", "alf", N_Z, 0.0, mode.clone(), &z0)
        .unwrap();

    let mut resp = ResponseFrame::default();
    for (j, chunk) in chunks.iter().enumerate() {
        cl.session_step(10 + j as u64, tcp_sid, chunk).unwrap();
        match cl.next_event(&mut resp).unwrap() {
            ClientEvent::Response => assert_eq!(resp.req_id, 10 + j as u64),
            other => panic!("step {j}: unexpected event {other:?}"),
        }
        let direct = server.session_step(ref_sid, chunk).unwrap().wait().unwrap();
        assert_eq!(resp.z_final, direct.z_final, "step {j} final state bitwise");
        assert_eq!(resp.obs, direct.obs, "step {j} snapshots bitwise");
        assert_eq!(resp.n_accepted, direct.n_accepted, "step {j} steps");
        assert_eq!(resp.n_trials, direct.n_trials, "step {j} trials");
    }

    let health = cl.health(5).unwrap();
    assert_eq!(health.sessions, 2, "both sessions are live");
    assert_eq!(health.admitted, chunks.len() as u64, "each TCP step was admitted");
    assert_eq!(health.shed_rate, 0.0);

    cl.close_session(tcp_sid).unwrap();
    assert!(server.close_session(ref_sid));
    assert_eq!(server.session_count(), 0);
    cl.goodbye().unwrap();
    assert!(front.shutdown(Duration::from_secs(10)).flushed);
}

/// A connection that dies mid-stream (no SESSION_CLOSE, no GOODBYE)
/// must release its warm sessions server-side — the slots, not just the
/// socket — and leave the front fully usable for new clients.
#[test]
fn dying_connection_releases_its_sessions() {
    let server = start(16, 1, 4);
    let front = front_for(&server, TransportConfig::default());
    let addr = front.local_addr();

    let mode = StepMode::Fixed { h: 0.05 };
    let z0 = request_rows(1).remove(0);
    {
        let mut cl = TcpClient::connect(addr).unwrap();
        let a = cl.open_session(1, "toy", "alf", 0.0, &mode, &z0).unwrap();
        let _b = cl.open_session(2, "toy", "alf", 0.5, &mode, &z0).unwrap();
        assert_eq!(server.session_count(), 2);
        // one warm step so session `a` holds genuinely live solver state
        cl.session_step(7, a, &[0.25]).unwrap();
        let mut resp = ResponseFrame::default();
        match cl.next_event(&mut resp).unwrap() {
            ClientEvent::Response => assert_eq!(resp.req_id, 7),
            other => panic!("unexpected event {other:?}"),
        }
        // drop without close/goodbye: the socket just vanishes
    }
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while server.session_count() > 0 {
        assert!(
            std::time::Instant::now() < deadline,
            "connection teardown leaked {} warm sessions",
            server.session_count()
        );
        std::thread::sleep(Duration::from_millis(2));
    }

    // the front is unharmed: a new client opens and streams normally
    let mut cl = TcpClient::connect(addr).unwrap();
    let sid = cl.open_session(3, "toy", "alf", 0.0, &mode, &z0).unwrap();
    cl.session_step(1, sid, &[0.5]).unwrap();
    let mut resp = ResponseFrame::default();
    assert!(matches!(cl.next_event(&mut resp).unwrap(), ClientEvent::Response));
    cl.close_session(sid).unwrap();
    cl.goodbye().unwrap();
    assert_eq!(server.session_count(), 0);
    assert!(front.shutdown(Duration::from_secs(10)).flushed);
}

/// Session refusals are in-band and connection-scoped: unknown models
/// fail the open, a sid from another connection is refused, and the
/// per-connection session cap holds.
#[test]
fn session_refusals_are_contained() {
    let server = start(16, 1, 4);
    let front = front_for(
        &server,
        TransportConfig {
            max_sessions: 1,
            ..TransportConfig::default()
        },
    );
    let addr = front.local_addr();
    let mode = StepMode::Fixed { h: 0.05 };
    let z0 = request_rows(1).remove(0);

    let mut cl = TcpClient::connect(addr).unwrap();
    assert!(
        cl.open_session(1, "nope", "alf", 0.0, &mode, &z0).is_err(),
        "unknown model must refuse the open"
    );
    let sid = cl.open_session(2, "toy", "alf", 0.0, &mode, &z0).unwrap();
    assert!(
        cl.open_session(3, "toy", "alf", 0.0, &mode, &z0).is_err(),
        "second open must trip the per-connection cap"
    );

    // a different connection cannot step this connection's session
    let mut intruder = TcpClient::connect(addr).unwrap();
    intruder.session_step(9, sid, &[0.5]).unwrap();
    let mut resp = ResponseFrame::default();
    match intruder.next_event(&mut resp).unwrap() {
        ClientEvent::ReqErr { req_id, msg } => {
            assert_eq!(req_id, 9);
            assert!(msg.contains("not opened on this connection"), "{msg}");
        }
        other => panic!("expected REQ_ERR, got {other:?}"),
    }
    // ...and the owner still streams on it untouched
    cl.session_step(4, sid, &[0.5]).unwrap();
    match cl.next_event(&mut resp).unwrap() {
        ClientEvent::Response => assert_eq!(resp.req_id, 4),
        other => panic!("unexpected event {other:?}"),
    }
    cl.close_session(sid).unwrap();
    cl.goodbye().unwrap();
    intruder.goodbye().unwrap();
    assert!(front.shutdown(Duration::from_secs(10)).flushed);
}

/// `wait_timeout` on the in-process handle: times out while a slow
/// request is in flight, then delivers the same response object.
#[test]
fn response_handle_wait_timeout() {
    let server = start(16, 1, 4);
    let class = class_with(StepMode::Fixed { h: 2e-5 }, ObsGrid::none());
    let z0 = vec![1.0f32; N_Z];
    let handle = server.submit(&class, &z0).unwrap();
    // 50k steps won't finish in 1ms
    assert!(handle.wait_timeout(Duration::from_millis(1)).is_none());
    let resp = handle
        .wait_timeout(Duration::from_secs(30))
        .expect("must deliver well before 30s")
        .unwrap();
    assert_eq!(resp.n_accepted, 50_000);
}
