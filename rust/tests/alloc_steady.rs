//! Steady-state allocation accounting for the hot paths — the refactor's
//! headline property, pinned with a counting global allocator:
//!
//! * a warmed-up `integrate_ws` run (fixed AND adaptive, ALF on the toy
//!   dynamics) performs **zero** heap allocations — not per step, zero
//!   for the whole solve;
//! * MALI's ψ⁻¹ reverse sweep (`invert_and_vjp_into` over the recorded
//!   accepted grid) performs **zero** heap allocations once its four
//!   ping-pong states are warm;
//! * the **sharded** batched integrate
//!   (`integrate_batch_obs_stats_sharded`) performs zero heap
//!   allocations once its per-shard workspaces are warm — on the
//!   sequential dispatch path AND with the shards running concurrently
//!   on a [`WorkerPool`] (the counting allocator is global, so the
//!   shard workers' allocations would be caught too);
//! * the native fused-dynamics backend (`dynamics_native::MlpDynamics`)
//!   is allocation-free once its pooled layer scratch is warm: solo and
//!   batched `f_into`/`f_vjp_into`, the whole fixed fused-ψ solve, the
//!   fused ψ-vjp step, the fused ψ⁻¹+vjp reverse sweep, and the sharded
//!   batched driver over the native MLP;
//! * the reversible-4 composition honors the same contracts: warmed
//!   fixed + adaptive `integrate_ws`, the composed Ψ⁻¹+vjp reverse
//!   sweep, and the sharded batched driver are all allocation-free;
//! * the symplectic-adjoint reverse replay (`step_vjp_into` over stored
//!   checkpoints) is allocation-free once the workspace is warm — the
//!   tape itself is the method's only O(N_t) cost;
//! * `MemTracker` peaks obey the Table-1 memory laws: MALI retains
//!   exactly the augmented end state (`N_z(N_f + 1)` — 2·N_z·4 bytes)
//!   on ALF **and** on reversible-4 (constant in step count), the
//!   adjoint exactly `z(T)` (N_z·4 bytes), and the symplectic adjoint
//!   peaks exactly at ACA's checkpoint bound (`N_z(N_f + N_t)`).
//!
//! The whole file is a single `#[test]` so no sibling test thread can
//! allocate concurrently inside a measured region (the shard pool's
//! threads are *part* of the sharded measurement, not a disturbance).

use mali_ode::dynamics_native::{MlpDynamics as NativeMlp, TimeMode};
use mali_ode::grad::{by_name as grad_by_name, IvpSpec, SquareLoss};
use mali_ode::solvers::batch::{BatchSpec, BatchState};
use mali_ode::solvers::by_name as solver_by_name;
use mali_ode::solvers::dynamics::{Dynamics, LinearToy};
use mali_ode::solvers::integrate::{
    integrate_batch_obs_stats_sharded, integrate_ws, BatchShards, ErrorNorm, GridRecorder,
    ObsGrid, StepMode,
};
use mali_ode::solvers::workspace::{BatchWorkspace, SolverWorkspace};
use mali_ode::solvers::{Solver, State};
use mali_ode::util::mem::MemTracker;
use mali_ode::util::pool::WorkerPool;
use mali_ode::util::rng::Rng;

#[path = "common/counting_alloc.rs"]
mod counting_alloc;
use counting_alloc::{alloc_count as allocs, CountingAlloc};

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Run the MALI reverse sweep over `times` starting from the (copied-in)
/// end state; returns the reconstructed initial z for verification.
#[allow(clippy::too_many_arguments)]
fn mali_sweep(
    solver: &dyn Solver,
    toy: &dyn Dynamics,
    times: &[f64],
    s_end: &State,
    dl_dz: &[f32],
    bufs: &mut [State; 4],
    grad_theta: &mut [f32],
    ws: &mut SolverWorkspace,
) {
    let [cur, a, prev, a_prev] = bufs;
    cur.z.copy_from_slice(&s_end.z);
    cur.v
        .as_mut()
        .expect("ALF state")
        .copy_from_slice(s_end.v.as_ref().expect("ALF state"));
    a.z.copy_from_slice(dl_dz);
    a.v.as_mut().expect("shaped").fill(0.0);
    let n = times.len() - 1;
    for i in (1..=n).rev() {
        let h = times[i] - times[i - 1];
        let ok = solver.invert_and_vjp_into(toy, times[i], h, cur, a, prev, a_prev, grad_theta, ws);
        assert!(ok, "ALF is invertible");
        std::mem::swap(cur, prev);
        std::mem::swap(a, a_prev);
    }
}

#[test]
fn zero_allocations_in_steady_state_hot_paths() {
    let n_z = 8usize;
    let toy = LinearToy::new(-0.4, n_z);
    let solver = solver_by_name("alf").unwrap();
    let z0: Vec<f32> = (0..n_z).map(|i| 1.0 + 0.1 * i as f32).collect();
    let norm = ErrorNorm::Full;
    let mut ws = SolverWorkspace::new();

    // ---- integrate: fixed grid ------------------------------------------
    let s0 = solver.init(&toy, 0.0, &z0);
    let fixed = StepMode::Fixed { h: 0.01 };
    // Two warm-up runs: the first sizes the loop buffers, the second
    // cycles the output slot through the recycling pool so every pooled
    // state is at its steady shape before measurement.
    integrate_ws(&*solver, &toy, 0.0, 1.0, &s0, &fixed, &norm, &mut (), &mut ws).unwrap();
    integrate_ws(&*solver, &toy, 0.0, 1.0, &s0, &fixed, &norm, &mut (), &mut ws).unwrap();
    let a0 = allocs();
    let stats = integrate_ws(&*solver, &toy, 0.0, 1.0, &s0, &fixed, &norm, &mut (), &mut ws)
        .unwrap();
    let delta = allocs() - a0;
    assert_eq!(stats.n_accepted, 100, "expected 100 fixed steps");
    assert_eq!(
        delta, 0,
        "steady-state fixed integrate allocated {delta} times over {} steps",
        stats.n_accepted
    );

    // ---- integrate: adaptive --------------------------------------------
    let adaptive = StepMode::adaptive(1e-4, 1e-6);
    integrate_ws(&*solver, &toy, 0.0, 1.0, &s0, &adaptive, &norm, &mut (), &mut ws).unwrap();
    let a0 = allocs();
    let stats = integrate_ws(&*solver, &toy, 0.0, 1.0, &s0, &adaptive, &norm, &mut (), &mut ws)
        .unwrap();
    let delta = allocs() - a0;
    assert!(stats.n_accepted > 0);
    assert_eq!(
        delta, 0,
        "steady-state adaptive integrate allocated {delta} times over {} trials",
        stats.n_trials
    );

    // ---- MALI reverse sweep ---------------------------------------------
    // forward once, keeping the accepted grid (recorder pushes allocate;
    // that is outside the measured region)
    let mut rec = GridRecorder::new(0.0);
    integrate_ws(&*solver, &toy, 0.0, 1.0, &s0, &fixed, &norm, &mut rec, &mut ws).unwrap();
    let s_end = ws.take_output();
    let dl_dz: Vec<f32> = s_end.z.iter().map(|&z| 2.0 * z).collect();
    let shaped = || State {
        z: vec![0.0f32; n_z],
        v: Some(vec![0.0f32; n_z]),
    };
    let mut bufs = [shaped(), shaped(), shaped(), shaped()];
    let mut grad_theta = vec![0.0f32; 1];
    // warm-up sweep
    mali_sweep(
        &*solver, &toy, rec.times(), &s_end, &dl_dz, &mut bufs, &mut grad_theta, &mut ws,
    );
    // measured sweep
    grad_theta[0] = 0.0;
    let a0 = allocs();
    mali_sweep(
        &*solver, &toy, rec.times(), &s_end, &dl_dz, &mut bufs, &mut grad_theta, &mut ws,
    );
    let delta = allocs() - a0;
    assert_eq!(
        delta,
        0,
        "steady-state MALI reverse sweep allocated {delta} times over {} steps",
        rec.times().len() - 1
    );
    // the sweep actually reconstructed the initial state
    for (r, z) in bufs[0].z.iter().zip(&z0) {
        assert!((r - z).abs() < 1e-3 * (1.0 + z.abs()), "ψ⁻¹ reconstruction");
    }

    // ---- reversible-4: same zero-allocation contracts -------------------
    // The triple-jump composition chains three ALF ψ kernels through
    // pooled temporaries; once the pools are sized the fixed AND adaptive
    // solves and the composed Ψ⁻¹+vjp reverse sweep never allocate.
    let rev4 = solver_by_name("reversible4").unwrap();
    let s0_r = rev4.init(&toy, 0.0, &z0);
    integrate_ws(&*rev4, &toy, 0.0, 1.0, &s0_r, &fixed, &norm, &mut (), &mut ws).unwrap();
    integrate_ws(&*rev4, &toy, 0.0, 1.0, &s0_r, &fixed, &norm, &mut (), &mut ws).unwrap();
    let a0 = allocs();
    let stats = integrate_ws(&*rev4, &toy, 0.0, 1.0, &s0_r, &fixed, &norm, &mut (), &mut ws)
        .unwrap();
    let delta = allocs() - a0;
    assert_eq!(stats.n_accepted, 100, "expected 100 fixed reversible-4 steps");
    assert_eq!(
        delta, 0,
        "steady-state fixed reversible-4 integrate allocated {delta} times"
    );

    integrate_ws(&*rev4, &toy, 0.0, 1.0, &s0_r, &adaptive, &norm, &mut (), &mut ws).unwrap();
    let a0 = allocs();
    let stats = integrate_ws(&*rev4, &toy, 0.0, 1.0, &s0_r, &adaptive, &norm, &mut (), &mut ws)
        .unwrap();
    let delta = allocs() - a0;
    assert!(stats.n_accepted > 0);
    assert_eq!(
        delta, 0,
        "steady-state adaptive reversible-4 integrate allocated {delta} times"
    );

    // `mali_sweep` is solver-generic, so the same four ping-pong buffers
    // drive the composed reverse chain
    let mut rec_r = GridRecorder::new(0.0);
    integrate_ws(&*rev4, &toy, 0.0, 1.0, &s0_r, &fixed, &norm, &mut rec_r, &mut ws).unwrap();
    let s_end_r = ws.take_output();
    let dl_dz_r: Vec<f32> = s_end_r.z.iter().map(|&z| 2.0 * z).collect();
    let mut bufs_r = [shaped(), shaped(), shaped(), shaped()];
    mali_sweep(
        &*rev4, &toy, rec_r.times(), &s_end_r, &dl_dz_r, &mut bufs_r, &mut grad_theta, &mut ws,
    );
    grad_theta[0] = 0.0;
    let a0 = allocs();
    mali_sweep(
        &*rev4, &toy, rec_r.times(), &s_end_r, &dl_dz_r, &mut bufs_r, &mut grad_theta, &mut ws,
    );
    let delta = allocs() - a0;
    assert_eq!(
        delta,
        0,
        "steady-state reversible-4 reverse sweep allocated {delta} times over {} steps",
        rec_r.times().len() - 1
    );
    for (r, z) in bufs_r[0].z.iter().zip(&z0) {
        assert!((r - z).abs() < 1e-3 * (1.0 + z.abs()), "composed Ψ⁻¹ reconstruction");
    }

    // ---- symplectic-adjoint reverse replay ------------------------------
    // The method's backward pass is `step_vjp_into` over the recorded
    // checkpoints (released as consumed); with a warm workspace the
    // replay itself never allocates — the tape is its only O(N_t) cost.
    struct TapeRec {
        steps: Vec<(f64, f64, State)>,
    }
    impl mali_ode::solvers::integrate::StepObserver for TapeRec {
        fn on_accept(&mut self, s: &mali_ode::solvers::integrate::AcceptedStep) {
            self.steps.push((s.t, s.h, s.before.clone()));
        }
    }
    let mut tape = TapeRec { steps: Vec::new() };
    integrate_ws(&*solver, &toy, 0.0, 1.0, &s0, &fixed, &norm, &mut tape, &mut ws).unwrap();
    let mut a_sym = shaped();
    let mut a_sym_prev = shaped();
    let mut replay = |a: &mut State, a_prev: &mut State, th: &mut [f32], ws: &mut SolverWorkspace| {
        a.z.copy_from_slice(&dl_dz);
        a.v.as_mut().expect("shaped").fill(0.0);
        for (t, h, before) in tape.steps.iter().rev() {
            solver.step_vjp_into(&toy, *t, *h, before, a, a_prev, th, ws);
            std::mem::swap(a, a_prev);
        }
    };
    replay(&mut a_sym, &mut a_sym_prev, &mut grad_theta, &mut ws);
    grad_theta[0] = 0.0;
    let a0 = allocs();
    replay(&mut a_sym, &mut a_sym_prev, &mut grad_theta, &mut ws);
    let delta = allocs() - a0;
    assert_eq!(
        delta,
        0,
        "warmed symplectic reverse replay allocated {delta} times over {} steps",
        tape.steps.len()
    );

    // ---- sharded batched integrate --------------------------------------
    // Zero-allocation contract on the intra-batch sharded driver: after
    // two warming calls (sizing pass + pool-cycling pass) a sharded
    // solve — per-shard staging, dispatch, merge — touches the allocator
    // not at all, whether the shards run inline or on pool workers.
    let nb = 6usize;
    let states: Vec<State> = (0..nb)
        .map(|b| {
            let row: Vec<f32> = (0..n_z).map(|j| 0.4 + 0.3 * b as f32 + 0.1 * j as f32).collect();
            solver.init(&toy, 0.0, &row)
        })
        .collect();
    let refs: Vec<&State> = states.iter().collect();
    let state0 = BatchState::from_states(&refs);
    let grid = ObsGrid::uniform(0.0, 1.0, 2);
    for (pool, label) in [(None, "sequential"), (Some(WorkerPool::new(1)), "pooled")] {
        let mut shards = BatchShards::new(2);
        let mut bws = BatchWorkspace::new();
        let mut per = Vec::new();
        let mut run = || {
            integrate_batch_obs_stats_sharded(
                &*solver,
                &toy,
                0.0,
                1.0,
                &state0,
                &fixed,
                &norm,
                &grid,
                |_, _| (),
                &mut per,
                &mut shards,
                &mut bws,
                pool.as_ref(),
            )
            .unwrap()
        };
        run();
        run();
        let a0 = allocs();
        let f_evals = run();
        let delta = allocs() - a0;
        assert!(f_evals > 0, "sharded {label}: nothing integrated");
        assert_eq!(
            delta, 0,
            "sharded {label}: warmed sharded integrate allocated {delta} times"
        );
    }

    // same driver on the composed solver: the per-sub-step stage times
    // and sizes live in the shard workspaces, so the identical two-warm-up
    // contract holds
    for (pool, label) in [(None, "sequential"), (Some(WorkerPool::new(1)), "pooled")] {
        let mut shards = BatchShards::new(2);
        let mut bws = BatchWorkspace::new();
        let mut per = Vec::new();
        let mut run = || {
            integrate_batch_obs_stats_sharded(
                &*rev4,
                &toy,
                0.0,
                1.0,
                &state0,
                &fixed,
                &norm,
                &grid,
                |_, _| (),
                &mut per,
                &mut shards,
                &mut bws,
                pool.as_ref(),
            )
            .unwrap()
        };
        run();
        run();
        let a0 = allocs();
        let f_evals = run();
        let delta = allocs() - a0;
        assert!(f_evals > 0, "sharded reversible-4 {label}: nothing integrated");
        assert_eq!(
            delta, 0,
            "sharded reversible-4 {label}: warmed sharded integrate allocated {delta} times"
        );
    }

    // ---- native MLP dynamics: warmed forward / VJP are allocation-free --
    // The fused-dynamics backend owns per-layer workspaces behind a
    // scratch pool; the first call sizes them, after which `f_into`,
    // `f_vjp_into` and their batched variants never touch the allocator.
    let mut mlp_rng = Rng::new(7);
    let mlp = NativeMlp::new(n_z, &[16, 12], TimeMode::Concat, &mut mlp_rng);
    let n_th = mlp.param_dim();
    let a_cot: Vec<f32> = (0..n_z).map(|i| 0.5 - 0.1 * i as f32).collect();
    let mut fz = vec![0.0f32; n_z];
    let mut az = vec![0.0f32; n_z];
    let mut ath = vec![0.0f32; n_th];
    for _ in 0..2 {
        mlp.f_into(0.3, &z0, &mut fz);
        mlp.f_vjp_into(0.3, &z0, &a_cot, &mut az, &mut ath);
    }
    let a0 = allocs();
    mlp.f_into(0.3, &z0, &mut fz);
    mlp.f_vjp_into(0.3, &z0, &a_cot, &mut az, &mut ath);
    let delta = allocs() - a0;
    assert_eq!(delta, 0, "warmed native-MLP f/f_vjp allocated {delta} times");

    let nbm = 4usize;
    let bspec = BatchSpec::new(nbm, n_z);
    let zb: Vec<f32> = (0..bspec.flat_len()).map(|i| 0.1 * (i % 13) as f32 - 0.5).collect();
    let ab: Vec<f32> = (0..bspec.flat_len()).map(|i| 0.3 - 0.05 * (i % 7) as f32).collect();
    let tsb = vec![0.25f64; nbm];
    let mut fzb = vec![0.0f32; bspec.flat_len()];
    let mut azb = vec![0.0f32; bspec.flat_len()];
    for _ in 0..2 {
        mlp.f_batch_into(&tsb, &zb, &bspec, &mut fzb);
        mlp.f_vjp_batch_into(&tsb, &zb, &ab, &bspec, &mut azb, &mut ath);
    }
    let a0 = allocs();
    mlp.f_batch_into(&tsb, &zb, &bspec, &mut fzb);
    mlp.f_vjp_batch_into(&tsb, &zb, &ab, &bspec, &mut azb, &mut ath);
    let delta = allocs() - a0;
    assert_eq!(delta, 0, "warmed native-MLP batched f/f_vjp allocated {delta} times");

    // ---- native MLP through the fused ALF ψ paths -----------------------
    // One fused dispatch per step: the whole fixed solve, the ψ-vjp step
    // and the ψ⁻¹+vjp reverse sweep stay allocation-free once warm.
    let s0_mlp = solver.init(&mlp, 0.0, &z0);
    integrate_ws(&*solver, &mlp, 0.0, 1.0, &s0_mlp, &fixed, &norm, &mut (), &mut ws).unwrap();
    integrate_ws(&*solver, &mlp, 0.0, 1.0, &s0_mlp, &fixed, &norm, &mut (), &mut ws).unwrap();
    let a0 = allocs();
    let stats = integrate_ws(&*solver, &mlp, 0.0, 1.0, &s0_mlp, &fixed, &norm, &mut (), &mut ws)
        .unwrap();
    let delta = allocs() - a0;
    assert_eq!(stats.n_accepted, 100);
    assert_eq!(
        delta, 0,
        "steady-state fused-MLP fixed integrate allocated {delta} times over {} steps",
        stats.n_accepted
    );

    let a_out_s = State {
        z: a_cot.clone(),
        v: Some(vec![0.0f32; n_z]),
    };
    let mut a_in_s = shaped();
    let mut ath_step = vec![0.0f32; n_th];
    for _ in 0..2 {
        solver.step_vjp_into(&mlp, 0.2, 0.01, &s0_mlp, &a_out_s, &mut a_in_s, &mut ath_step, &mut ws);
    }
    let a0 = allocs();
    solver.step_vjp_into(&mlp, 0.2, 0.01, &s0_mlp, &a_out_s, &mut a_in_s, &mut ath_step, &mut ws);
    let delta = allocs() - a0;
    assert_eq!(delta, 0, "warmed fused-MLP ψ-vjp step allocated {delta} times");

    let mut rec_mlp = GridRecorder::new(0.0);
    integrate_ws(&*solver, &mlp, 0.0, 1.0, &s0_mlp, &fixed, &norm, &mut rec_mlp, &mut ws).unwrap();
    let s_end_mlp = ws.take_output();
    let dl_dz_mlp: Vec<f32> = s_end_mlp.z.iter().map(|&z| 2.0 * z).collect();
    let mut bufs_mlp = [shaped(), shaped(), shaped(), shaped()];
    let mut grad_theta_mlp = vec![0.0f32; n_th];
    mali_sweep(
        &*solver, &mlp, rec_mlp.times(), &s_end_mlp, &dl_dz_mlp, &mut bufs_mlp,
        &mut grad_theta_mlp, &mut ws,
    );
    grad_theta_mlp.fill(0.0);
    let a0 = allocs();
    mali_sweep(
        &*solver, &mlp, rec_mlp.times(), &s_end_mlp, &dl_dz_mlp, &mut bufs_mlp,
        &mut grad_theta_mlp, &mut ws,
    );
    let delta = allocs() - a0;
    assert_eq!(
        delta,
        0,
        "steady-state fused-MLP reverse sweep allocated {delta} times over {} steps",
        rec_mlp.times().len() - 1
    );
    for (r, z) in bufs_mlp[0].z.iter().zip(&z0) {
        assert!((r - z).abs() < 1e-3 * (1.0 + z.abs()), "fused ψ⁻¹ reconstruction");
    }

    // ---- native MLP under the sharded batched driver --------------------
    let states_mlp: Vec<State> = (0..nb)
        .map(|b| {
            let row: Vec<f32> = (0..n_z).map(|j| 0.2 + 0.2 * b as f32 + 0.05 * j as f32).collect();
            solver.init(&mlp, 0.0, &row)
        })
        .collect();
    let refs_mlp: Vec<&State> = states_mlp.iter().collect();
    let state0_mlp = BatchState::from_states(&refs_mlp);
    for (pool, label) in [(None, "sequential"), (Some(WorkerPool::new(1)), "pooled")] {
        let mut shards = BatchShards::new(2);
        let mut bws = BatchWorkspace::new();
        let mut per = Vec::new();
        let mut run = || {
            integrate_batch_obs_stats_sharded(
                &*solver,
                &mlp,
                0.0,
                1.0,
                &state0_mlp,
                &fixed,
                &norm,
                &grid,
                |_, _| (),
                &mut per,
                &mut shards,
                &mut bws,
                pool.as_ref(),
            )
            .unwrap()
        };
        run();
        run();
        let a0 = allocs();
        let f_evals = run();
        let delta = allocs() - a0;
        assert!(f_evals > 0, "sharded native-MLP {label}: nothing integrated");
        assert_eq!(
            delta, 0,
            "sharded native-MLP {label}: warmed sharded integrate allocated {delta} times"
        );
    }

    // ---- MemTracker peaks unchanged by the refactor ---------------------
    let tracker = MemTracker::new();
    grad_by_name("mali")
        .unwrap()
        .grad(
            &toy,
            &*solver,
            &IvpSpec::fixed(0.0, 1.0, 0.01),
            &z0,
            &SquareLoss,
            tracker.clone(),
        )
        .unwrap();
    assert_eq!(
        tracker.peak_bytes(),
        2 * n_z * 4,
        "MALI retains exactly the augmented end state (N_z(N_f + 1) law)"
    );
    let tracker = MemTracker::new();
    let he = solver_by_name("heun-euler").unwrap();
    grad_by_name("adjoint")
        .unwrap()
        .grad(
            &toy,
            &*he,
            &IvpSpec::fixed(0.0, 1.0, 0.01),
            &z0,
            &SquareLoss,
            tracker.clone(),
        )
        .unwrap();
    assert_eq!(
        tracker.peak_bytes(),
        n_z * 4,
        "adjoint retains exactly z(T)"
    );

    // MALI's N_z(N_f + 1) law transfers unchanged to the reversible-4
    // solver: the composition inverts exactly, so the method still
    // retains only the augmented end state regardless of step count.
    let tracker = MemTracker::new();
    grad_by_name("mali")
        .unwrap()
        .grad(
            &toy,
            &*rev4,
            &IvpSpec::fixed(0.0, 1.0, 0.01),
            &z0,
            &SquareLoss,
            tracker.clone(),
        )
        .unwrap();
    assert_eq!(
        tracker.peak_bytes(),
        2 * n_z * 4,
        "MALI retains exactly the augmented end state on reversible-4"
    );

    // The symplectic adjoint checkpoints like ACA and only releases on
    // the way back, so its peak (end of forward, tape fully populated)
    // must coincide with ACA's N_z(N_f + N_t) bound exactly — and both
    // must dominate MALI's constant end-state footprint.
    let peak = |method: &str| {
        let tracker = MemTracker::new();
        grad_by_name(method)
            .unwrap()
            .grad(
                &toy,
                &*solver,
                &IvpSpec::fixed(0.0, 1.0, 0.01),
                &z0,
                &SquareLoss,
                tracker.clone(),
            )
            .unwrap();
        tracker.peak_bytes()
    };
    let (sym_peak, aca_peak) = (peak("symplectic"), peak("aca"));
    assert_eq!(
        sym_peak, aca_peak,
        "symplectic peak must equal ACA's checkpoint bound"
    );
    assert!(
        sym_peak > 2 * n_z * 4,
        "checkpointing must cost more than MALI's retained end state"
    );
}
