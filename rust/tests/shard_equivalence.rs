//! Sharding-equivalence suite: intra-batch sharding is a **pure
//! scheduling change**.  `integrate_batch_obs_stats_sharded` and the
//! sharded `ServeWorker::process` path must produce bitwise-identical
//! results to the 1-shard/direct run for every shard count — final
//! states, per-observation snapshots, per-sample accepted/trial counts
//! and the batch `f`-evaluation total (the toy dynamics count batched
//! `f` by rows, so the total is shard-invariant too).
//!
//! The suite also pins the cost-accounting side of fused dispatch: a
//! native-MLP solve through the fused ψ entries must report exactly the
//! per-sample `f`/`vjp` evaluation units the composed unfused path
//! reports (one fused dispatch is one f-eval per sample, not one per
//! batch and not one per kernel call).
//!
//! Coverage: shard counts {1, 2, 3, 8} × {sequential, pooled} dispatch,
//! a batch size (7) that divides into none of them evenly, a batch (3)
//! smaller than the shard count so trailing shards are entirely
//! inactive, fixed and adaptive stepping (adaptive with heterogeneous
//! rows, so the per-sample controllers genuinely diverge), and a K ≥ 2
//! observation grid streamed through per-shard observers.

use mali_ode::serve::{ModelRegistry, Pending, RequestClass, ServeWorker};
use mali_ode::solvers::batch::BatchState;
use mali_ode::solvers::by_name as solver_by_name;
use mali_ode::solvers::dynamics::{Dynamics, EvalCounters, LinearToy};
use mali_ode::solvers::integrate::{
    integrate_batch_obs_stats_sharded, integrate_batch_obs_stats_ws, BatchShards,
    BatchStepObserver, ErrorNorm, ObsGrid, StepMode,
};
use mali_ode::solvers::workspace::BatchWorkspace;
use mali_ode::solvers::{Solver, State};
use mali_ode::util::pool::WorkerPool;
use std::sync::{Arc, Mutex};

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

/// Everything a run produces, in bit-exact form.
#[derive(Debug, PartialEq)]
struct RunArtifacts {
    z: Vec<u32>,
    v: Vec<u32>,
    /// `(n_accepted, n_trials)` per sample, in global row order.
    per: Vec<(usize, usize)>,
    f_evals: u64,
    /// `(t bits, z bits)` per `(global row, grid index)`.
    obs: Vec<(u64, Vec<u32>)>,
}

/// Streams observations into a shared, globally-indexed sink; `base` is
/// the shard's first global row (the sharded driver hands observers
/// shard-local sample indices).
struct ObsSink<'a> {
    base: usize,
    k_total: usize,
    sink: &'a Mutex<Vec<(u64, Vec<u32>)>>,
}

impl BatchStepObserver for ObsSink<'_> {
    fn on_observation(&mut self, sample: usize, k: usize, t: f64, z: &[f32], _v: Option<&[f32]>) {
        let mut s = self.sink.lock().unwrap();
        s[(self.base + sample) * self.k_total + k] = (t.to_bits(), bits(z));
    }
}

/// One equivalence scenario: the initial batch plus everything needed to
/// run it direct or sharded.
struct Case<'a> {
    solver: &'a (dyn Solver + Sync),
    toy: &'a LinearToy,
    state0: &'a BatchState,
    mode: &'a StepMode,
    grid: &'a ObsGrid,
    nb: usize,
    n_z: usize,
    k: usize,
}

impl Case<'_> {
    fn harvest(
        &self,
        ws: &BatchWorkspace,
        per: &[mali_ode::solvers::integrate::IntStats],
        f_evals: u64,
        sink: Mutex<Vec<(u64, Vec<u32>)>>,
    ) -> RunArtifacts {
        let out = ws.output();
        RunArtifacts {
            z: bits(&out.z.data),
            v: out.v.as_ref().map(|t| bits(&t.data)).unwrap_or_default(),
            per: per.iter().map(|p| (p.n_accepted, p.n_trials)).collect(),
            f_evals,
            obs: sink.into_inner().unwrap(),
        }
    }

    fn run_direct(&self) -> RunArtifacts {
        let sink = Mutex::new(vec![(0u64, Vec::new()); self.nb * self.k]);
        let mut obs = ObsSink {
            base: 0,
            k_total: self.k,
            sink: &sink,
        };
        let mut per = Vec::new();
        let mut ws = BatchWorkspace::new();
        let f_evals = integrate_batch_obs_stats_ws(
            self.solver,
            self.toy,
            0.0,
            1.0,
            self.state0,
            self.mode,
            &ErrorNorm::Full,
            self.grid,
            &mut obs,
            &mut per,
            &mut ws,
        )
        .unwrap();
        self.harvest(&ws, &per, f_evals, sink)
    }

    fn run_sharded(&self, shard_count: usize, use_pool: bool) -> RunArtifacts {
        let sink = Mutex::new(vec![(0u64, Vec::new()); self.nb * self.k]);
        let mut shards = BatchShards::new(shard_count);
        let pool = if use_pool {
            Some(WorkerPool::new(shard_count.saturating_sub(1)))
        } else {
            None
        };
        let mut per = Vec::new();
        let mut ws = BatchWorkspace::new();
        let f_evals = integrate_batch_obs_stats_sharded(
            self.solver,
            self.toy,
            0.0,
            1.0,
            self.state0,
            self.mode,
            &ErrorNorm::Full,
            self.grid,
            |_shard, rows: std::ops::Range<usize>| ObsSink {
                base: rows.start,
                k_total: self.k,
                sink: &sink,
            },
            &mut per,
            &mut shards,
            &mut ws,
            pool.as_ref(),
        )
        .unwrap();
        self.harvest(&ws, &per, f_evals, sink)
    }

    /// Run direct once, then assert every `(shard count, dispatch)`
    /// combination reproduces it bit for bit.
    fn assert_all_equivalent(&self, label: &str, shard_counts: &[usize]) {
        let direct = self.run_direct();
        assert_eq!(direct.z.len(), self.nb * self.n_z, "{label}: output shape");
        assert_eq!(direct.per.len(), self.nb, "{label}: per-sample stats");
        assert!(
            direct.obs.iter().all(|(_, z)| z.len() == self.n_z),
            "{label}: every (row, grid point) observation fired"
        );
        assert!(direct.f_evals > 0, "{label}: f was evaluated");
        for &s in shard_counts {
            for use_pool in [false, true] {
                let got = self.run_sharded(s, use_pool);
                assert_eq!(
                    got, direct,
                    "{label}: shards={s} pooled={use_pool} diverged from direct run"
                );
            }
        }
    }
}

/// Heterogeneous rows (different magnitudes per row) so the adaptive
/// controllers take genuinely different step sequences per sample.
fn mk_state(solver: &dyn Solver, toy: &LinearToy, nb: usize, n_z: usize) -> BatchState {
    let states: Vec<State> = (0..nb)
        .map(|r| {
            let scale = 0.3 + 0.45 * r as f32;
            let z0: Vec<f32> = (0..n_z).map(|i| scale * (1.0 + 0.07 * i as f32)).collect();
            solver.init(toy, 0.0, &z0)
        })
        .collect();
    let refs: Vec<&State> = states.iter().collect();
    BatchState::from_states(&refs)
}

const SHARD_COUNTS: [usize; 4] = [1, 2, 3, 8];

#[test]
fn sharded_fixed_grid_is_bitwise_identical() {
    let (nb, n_z, k) = (7usize, 5usize, 3usize);
    let toy = LinearToy::new(-0.35, n_z);
    let solver = solver_by_name("alf").unwrap();
    let state0 = mk_state(&*solver, &toy, nb, n_z);
    let case = Case {
        solver: &*solver,
        toy: &toy,
        state0: &state0,
        mode: &StepMode::Fixed { h: 0.02 },
        grid: &ObsGrid::uniform(0.0, 1.0, k),
        nb,
        n_z,
        k,
    };
    // B = 7 divides into none of {2, 3, 8} evenly; 8 shards leave one
    // shard with no rows at all
    case.assert_all_equivalent("fixed B=7", &SHARD_COUNTS);
}

#[test]
fn sharded_adaptive_is_bitwise_identical() {
    let (nb, n_z, k) = (7usize, 5usize, 2usize);
    let toy = LinearToy::new(-0.35, n_z);
    let solver = solver_by_name("alf").unwrap();
    let state0 = mk_state(&*solver, &toy, nb, n_z);
    let case = Case {
        solver: &*solver,
        toy: &toy,
        state0: &state0,
        mode: &StepMode::adaptive(1e-4, 1e-6),
        grid: &ObsGrid::uniform(0.0, 1.0, k),
        nb,
        n_z,
        k,
    };
    let direct = case.run_direct();
    // heterogeneous rows must actually diverge, or this test proves less
    // than it claims
    assert!(
        direct.per.windows(2).any(|w| w[0] != w[1]),
        "adaptive rows took identical step sequences; raise the row spread"
    );
    case.assert_all_equivalent("adaptive B=7", &SHARD_COUNTS);
}

#[test]
fn more_shards_than_rows_leaves_inactive_shards_harmless() {
    let (nb, n_z, k) = (3usize, 5usize, 2usize);
    let toy = LinearToy::new(-0.35, n_z);
    let solver = solver_by_name("alf").unwrap();
    let state0 = mk_state(&*solver, &toy, nb, n_z);
    let case = Case {
        solver: &*solver,
        toy: &toy,
        state0: &state0,
        mode: &StepMode::Fixed { h: 0.02 },
        grid: &ObsGrid::uniform(0.0, 1.0, k),
        nb,
        n_z,
        k,
    };
    // 8 shards over 3 rows: five shards have empty ranges and must not
    // contribute anything (or crash) on either dispatch path
    case.assert_all_equivalent("B=3 with 8 shards", &[8]);
}

#[test]
fn device_batched_dynamics_are_rejected_when_sharded() {
    /// A dynamics that claims device batching (fixed [B, n_z] baked into
    /// one executable) — the one shape sharding cannot decompose.
    struct DeviceToy(LinearToy);
    impl Dynamics for DeviceToy {
        fn dim(&self) -> usize {
            self.0.dim()
        }
        fn param_dim(&self) -> usize {
            self.0.param_dim()
        }
        fn f(&self, t: f64, z: &[f32]) -> Vec<f32> {
            self.0.f(t, z)
        }
        fn f_vjp(&self, t: f64, z: &[f32], a: &[f32]) -> (Vec<f32>, Vec<f32>) {
            self.0.f_vjp(t, z, a)
        }
        fn params(&self) -> &[f32] {
            self.0.params()
        }
        fn set_params(&mut self, theta: &[f32]) {
            self.0.set_params(theta)
        }
        fn counters(&self) -> &EvalCounters {
            self.0.counters()
        }
        fn is_device_batched(&self) -> bool {
            true
        }
    }

    let (nb, n_z) = (4usize, 3usize);
    let toy = DeviceToy(LinearToy::new(-0.35, n_z));
    let solver = solver_by_name("alf").unwrap();
    let states: Vec<State> = (0..nb)
        .map(|r| {
            let z0 = vec![0.5 + r as f32; n_z];
            solver.init(&toy, 0.0, &z0)
        })
        .collect();
    let refs: Vec<&State> = states.iter().collect();
    let state0 = BatchState::from_states(&refs);
    let mut shards = BatchShards::new(2);
    let mut per = Vec::new();
    let mut ws = BatchWorkspace::new();
    let err = integrate_batch_obs_stats_sharded(
        &*solver,
        &toy,
        0.0,
        1.0,
        &state0,
        &StepMode::Fixed { h: 0.1 },
        &ErrorNorm::Full,
        &ObsGrid::none(),
        |_, _| (),
        &mut per,
        &mut shards,
        &mut ws,
        None,
    )
    .unwrap_err();
    assert!(
        err.to_string().contains("device-batched"),
        "wrong rejection: {err}"
    );
}

/// Fused dispatch is invisible to the Table-1 cost accounting: the same
/// native-MLP work — a sharded batched solve plus a solo ψ step, ψ-vjp
/// and ψ⁻¹+vjp — reports identical `f`/`vjp` evaluation-unit counts
/// whether the ALF solver takes the fused entries or the composed
/// unfused kernels.
#[test]
fn fused_dispatch_counts_same_eval_units_as_unfused() {
    use mali_ode::dynamics_native::{MlpDynamics, TimeMode};
    use mali_ode::solvers::alf::AlfSolver;
    use mali_ode::solvers::workspace::SolverWorkspace;
    use mali_ode::util::rng::Rng;

    const N_Z: usize = 4;
    const B: usize = 5;
    let mut rng = Rng::new(11);
    let mlp = MlpDynamics::new(N_Z, &[6], TimeMode::Concat, &mut rng);
    let fused = AlfSolver::new(1.0);
    assert!(fused.prefer_fused, "AlfSolver::new must default to fused dispatch");
    let unfused = AlfSolver {
        eta: 1.0,
        prefer_fused: false,
    };

    let count_run = |solver: &AlfSolver| -> (u64, u64) {
        mlp.counters().reset();

        // sharded batched forward (fixed grid, so both variants take the
        // same number of steps)
        let states: Vec<State> = (0..B)
            .map(|b| {
                let row: Vec<f32> =
                    (0..N_Z).map(|j| 0.3 + 0.2 * b as f32 + 0.05 * j as f32).collect();
                solver.init(&mlp, 0.0, &row)
            })
            .collect();
        let refs: Vec<&State> = states.iter().collect();
        let state0 = BatchState::from_states(&refs);
        let mut shards = BatchShards::new(2);
        let mut per = Vec::new();
        let mut bws = BatchWorkspace::new();
        integrate_batch_obs_stats_sharded(
            solver,
            &mlp,
            0.0,
            1.0,
            &state0,
            &StepMode::Fixed { h: 0.05 },
            &ErrorNorm::Full,
            &ObsGrid::none(),
            |_, _| (),
            &mut per,
            &mut shards,
            &mut bws,
            None,
        )
        .unwrap();

        // solo ψ, ψ-vjp and ψ⁻¹+vjp over one step
        let mut ws = SolverWorkspace::new();
        let z0: Vec<f32> = (0..N_Z).map(|j| 0.8 - 0.1 * j as f32).collect();
        let s0 = solver.init(&mlp, 0.0, &z0);
        let shaped = || State {
            z: vec![0.0f32; N_Z],
            v: Some(vec![0.0f32; N_Z]),
        };
        let mut stepped = shaped();
        let mut err = Vec::new();
        assert!(solver.step_into(&mlp, 0.0, 0.1, &s0, &mut stepped, &mut err, &mut ws));
        let a_out = State {
            z: vec![1.0f32; N_Z],
            v: Some(vec![0.0f32; N_Z]),
        };
        let mut a_in = shaped();
        let mut ath = vec![0.0f32; mlp.param_dim()];
        solver.step_vjp_into(&mlp, 0.0, 0.1, &s0, &a_out, &mut a_in, &mut ath, &mut ws);
        let mut s_prev = shaped();
        let mut a_prev = shaped();
        assert!(solver.invert_and_vjp_into(
            &mlp, 0.1, 0.1, &stepped, &a_out, &mut s_prev, &mut a_prev, &mut ath, &mut ws,
        ));

        (mlp.counters().f_evals.get(), mlp.counters().vjp_evals.get())
    };

    let (f_fused, vjp_fused) = count_run(&fused);
    let (f_unfused, vjp_unfused) = count_run(&unfused);
    assert!(f_fused > 0 && vjp_fused > 0, "nothing was counted");
    assert_eq!(
        (f_fused, vjp_fused),
        (f_unfused, vjp_unfused),
        "fused dispatch must count the same per-sample eval units as unfused"
    );
}

/// The serve layer's sharded `run_batch` branch: `ServeWorker::process`
/// must hand every request byte-for-byte the same response at every
/// shard count — final state, observation snapshots, step and trial
/// counts.
#[test]
fn serve_worker_process_is_bitwise_identical_across_shard_counts() {
    const N_Z: usize = 6;
    const B: usize = 7;
    let mut reg = ModelRegistry::new();
    reg.register("toy", Box::new(LinearToy::new(-0.4, N_Z)));
    let registry = Arc::new(reg);
    let rows: Vec<Vec<f32>> = (0..B)
        .map(|b| (0..N_Z).map(|j| 0.2 + 0.3 * b as f32 + 0.05 * j as f32).collect())
        .collect();
    for adaptive in [false, true] {
        let label = if adaptive { "adaptive" } else { "fixed" };
        let mut baseline: Option<Vec<(Vec<u32>, Vec<u32>, usize, usize)>> = None;
        for shards in SHARD_COUNTS {
            let mode = if adaptive {
                StepMode::adaptive(1e-4, 1e-6)
            } else {
                StepMode::Fixed { h: 0.01 }
            };
            let class = Arc::new(
                RequestClass::new(
                    "toy",
                    "alf",
                    N_Z,
                    0.0,
                    1.0,
                    mode,
                    ObsGrid::uniform(0.0, 1.0, 2),
                )
                .unwrap(),
            );
            let mut w = ServeWorker::with_shards(registry.clone(), shards);
            assert_eq!(w.shard_count(), shards);
            let mut batch: Vec<Pending> = rows
                .iter()
                .map(|z0| Pending::new(class.clone(), z0.clone()))
                .collect();
            w.process(&mut batch).unwrap();
            let got: Vec<(Vec<u32>, Vec<u32>, usize, usize)> = batch
                .iter()
                .map(|p| (bits(&p.z_final), bits(&p.obs), p.n_accepted, p.n_trials))
                .collect();
            assert!(
                got.iter().all(|(z, obs, acc, _)| {
                    z.len() == N_Z && obs.len() == 2 * N_Z && *acc > 0
                }),
                "{label} shards={shards}: malformed responses"
            );
            match &baseline {
                None => baseline = Some(got),
                Some(b) => assert_eq!(
                    &got, b,
                    "{label} shards={shards}: responses diverged from 1-shard run"
                ),
            }
        }
    }
}
