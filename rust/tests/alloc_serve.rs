//! Steady-state allocation accounting for the **serve loop** — the
//! serving layer's acceptance bar, pinned with the same counting global
//! allocator as `tests/alloc_steady.rs`:
//!
//! after warm-up, serving a micro-batch end to end
//! (`ServeWorker::process`: batch assembly → `init_batch_into` →
//! `integrate_batch_obs_stats_ws` → per-request scatter → metrics)
//! performs **zero** heap allocations —
//!
//! * fixed-grid stepping with heterogeneous rows and a 2-point
//!   observation grid (the lockstep path),
//! * adaptive stepping with identical rows (rows stay in lockstep, so
//!   the active mask never changes shape), and
//! * both of the above again through a **sharded** worker
//!   (`ServeWorker::with_shards(.., 2)`): the intra-batch sharded
//!   serve path — per-shard staging, concurrent dispatch on the
//!   worker's persistent shard pool, observation scatter through
//!   per-shard observers, merge — must hold the same zero-allocation
//!   bar once its per-shard workspaces are warm,
//! * a **warmed session step** (`SessionTable` + `session_id` envelope):
//!   each incremental advance runs in the session's warm solver
//!   workspace and the envelope's pooled buffers, and
//! * the full **TCP loopback** round trip (client encode → pooled
//!   envelope decode → solve → coalesced writer → client parse).
//!
//! The per-request envelope (`Pending` + its response buffers) is
//! allocated once at submit time and recycled here via
//! [`Pending::reset`] — the O(N_z) cost that stays on the submit path
//! by design (ADR-002).
//!
//! The whole file is a single `#[test]` so no sibling test thread can
//! allocate concurrently inside a measured region.

use mali_ode::serve::transport::{
    Bridge, ClientEvent, ResponseFrame, TcpClient, TcpFront, TransportConfig,
};
use mali_ode::serve::{
    ModelRegistry, Pending, RequestClass, Server, ServerConfig, ServeWorker, SessionTable,
};
use mali_ode::solvers::dynamics::LinearToy;
use mali_ode::solvers::integrate::{ObsGrid, StepMode};
use std::sync::Arc;
use std::time::Duration;

#[path = "common/counting_alloc.rs"]
mod counting_alloc;
use counting_alloc::{alloc_count as allocs, CountingAlloc};

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

const N_Z: usize = 8;
const B: usize = 4;

fn rearm(batch: &mut [Pending], rows: &[Vec<f32>]) {
    for (p, z0) in batch.iter_mut().zip(rows) {
        p.reset(z0);
    }
}

/// Warm twice (sizing pass + pool-cycling pass), then assert a third
/// serve of the same shapes allocates nothing.
fn assert_zero_alloc_steady(
    worker: &mut ServeWorker,
    batch: &mut Vec<Pending>,
    rows: &[Vec<f32>],
    label: &str,
) {
    worker.process(batch).unwrap();
    rearm(batch, rows);
    worker.process(batch).unwrap();
    rearm(batch, rows);
    let a0 = allocs();
    worker.process(batch).unwrap();
    let delta = allocs() - a0;
    let steps: usize = batch.iter().map(|p| p.n_accepted).sum();
    assert!(steps > 0, "{label}: warmed batch integrated nothing");
    assert_eq!(
        delta, 0,
        "{label}: warmed serve loop allocated {delta} times over {steps} accepted steps"
    );
}

#[test]
fn warmed_serve_loop_is_allocation_free() {
    let mut reg = ModelRegistry::new();
    reg.register("toy", Box::new(LinearToy::new(-0.4, N_Z)));
    let registry = Arc::new(reg);

    // ---- fixed grid, heterogeneous rows, 2 observation points -----------
    let grid = ObsGrid::new(vec![0.5, 1.0]).unwrap();
    let fixed_class = Arc::new(
        RequestClass::new("toy", "alf", N_Z, 0.0, 1.0, StepMode::Fixed { h: 0.01 }, grid)
            .unwrap(),
    );
    let fixed_rows: Vec<Vec<f32>> = (0..B)
        .map(|b| (0..N_Z).map(|j| 0.2 + b as f32 + 0.1 * j as f32).collect())
        .collect();
    let mut worker = ServeWorker::new(registry.clone());
    let mut batch: Vec<Pending> = fixed_rows
        .iter()
        .map(|z0| Pending::new(fixed_class.clone(), z0.clone()))
        .collect();
    assert_zero_alloc_steady(&mut worker, &mut batch, &fixed_rows, "fixed+obs");
    // the observation buffers were actually filled
    for p in &batch {
        assert!(p.obs.iter().any(|&x| x != 0.0), "obs snapshots written");
        assert_eq!(p.n_accepted, 100);
    }

    // ---- adaptive, identical rows (lockstep active mask) -----------------
    let adaptive_class = Arc::new(
        RequestClass::new(
            "toy",
            "alf",
            N_Z,
            0.0,
            1.0,
            StepMode::adaptive(1e-4, 1e-6),
            ObsGrid::none(),
        )
        .unwrap(),
    );
    let row: Vec<f32> = (0..N_Z).map(|j| 1.0 + 0.1 * j as f32).collect();
    let adaptive_rows: Vec<Vec<f32>> = (0..B).map(|_| row.clone()).collect();
    // same worker: solver cache, workspace and stats vectors are already
    // warm for this shape family; the class switch must not break the
    // steady state after one sizing pass
    let mut batch: Vec<Pending> = adaptive_rows
        .iter()
        .map(|z0| Pending::new(adaptive_class.clone(), z0.clone()))
        .collect();
    assert_zero_alloc_steady(&mut worker, &mut batch, &adaptive_rows, "adaptive");
    for p in &batch {
        assert!(p.n_trials >= p.n_accepted);
        assert!(p.obs.is_empty());
    }

    // metrics kept pace without touching the allocator mid-loop
    assert_eq!(worker.metrics().requests as usize, 6 * B);
    assert_eq!(worker.metrics().batches, 6);
    assert_eq!(worker.metrics().failed, 0);

    // ---- sharded worker: the same bar at shard_count = 2 -----------------
    // (shard pool threads spawn at construction, outside any measured
    // region; their steady-state work is measured — the counting
    // allocator is global)
    let mut sharded = ServeWorker::with_shards(registry.clone(), 2);
    assert_eq!(sharded.shard_count(), 2);
    let mut batch: Vec<Pending> = fixed_rows
        .iter()
        .map(|z0| Pending::new(fixed_class.clone(), z0.clone()))
        .collect();
    assert_zero_alloc_steady(&mut sharded, &mut batch, &fixed_rows, "sharded fixed+obs");
    for p in &batch {
        assert!(p.obs.iter().any(|&x| x != 0.0), "sharded obs snapshots written");
        assert_eq!(p.n_accepted, 100);
    }

    let mut batch: Vec<Pending> = adaptive_rows
        .iter()
        .map(|z0| Pending::new(adaptive_class.clone(), z0.clone()))
        .collect();
    assert_zero_alloc_steady(&mut sharded, &mut batch, &adaptive_rows, "sharded adaptive");
    assert_eq!(sharded.metrics().failed, 0);

    // ---- warmed session step: incremental advance allocates nothing ------
    // a session envelope is served solo (sequentially dependent on the
    // carried state); after one sizing pass each advance must run
    // entirely in the session's warm solver workspace plus the
    // envelope's pooled buffers
    let sessions = Arc::new(SessionTable::new());
    let sid = sessions
        .open(&registry, "toy", "alf", N_Z, 0.0, StepMode::Fixed { h: 0.01 }, &row)
        .unwrap();
    let mut session_worker = ServeWorker::with_shards(registry.clone(), 1);
    session_worker.attach_sessions(sessions.clone());
    let class = sessions.class_of(sid).unwrap();
    let mut env = vec![Pending::new(class, Vec::new())];
    env[0].session_id = sid;
    let mut t = 0.0f64;
    for pass in 0..3 {
        // two fresh events per advance, strictly past the barrier
        env[0].times.clear();
        t += 0.05;
        env[0].times.push(t);
        t += 0.05;
        env[0].times.push(t);
        if pass == 2 {
            let a0 = allocs();
            session_worker.process(&mut env).unwrap();
            let delta = allocs() - a0;
            assert_eq!(
                delta, 0,
                "warmed session step allocated {delta} times over {} accepted steps",
                env[0].n_accepted
            );
        } else {
            session_worker.process(&mut env).unwrap();
        }
        assert!(env[0].n_accepted > 0, "session advance integrated nothing");
        assert_eq!(env[0].obs.len(), 2 * N_Z, "one snapshot row per event");
    }
    assert_eq!(session_worker.metrics().session_steps, 3);
    assert_eq!(session_worker.metrics().failed, 0);
    assert!(sessions.close(sid));

    // ---- TCP transport: the warmed read → submit → respond loop ----------
    // the full loopback stack in one measured window — client frame
    // encode, server reader decode into a pooled envelope, queue hop,
    // worker solve, completion sink, writer coalesced encode, client
    // parse.  Client and server share this process (and so this counting
    // allocator), so the zero covers BOTH sides of the wire.
    let server = Arc::new(Server::start(
        registry.clone(),
        ServerConfig {
            queue_capacity: 64,
            max_batch: 4,
            max_wait: Duration::from_micros(200),
            workers: 1,
            shards: 1,
        },
    ));
    let front = TcpFront::bind(
        "127.0.0.1:0",
        server.clone() as Arc<dyn Bridge>,
        TransportConfig::default(),
    )
    .unwrap();
    let mut cl = TcpClient::connect(front.local_addr()).unwrap();
    cl.open_class(0, &fixed_class).unwrap();
    let z0: Vec<f32> = (0..N_Z).map(|j| 0.3 + 0.1 * j as f32).collect();
    let mut resp = ResponseFrame::default();
    // warm-up: envelope pool, frame buffers on both ends, outbound
    // queue capacity, registry-id memo, worker workspaces
    for req in 0..16u64 {
        cl.submit(req, 0, &z0).unwrap();
        match cl.next_event(&mut resp).unwrap() {
            ClientEvent::Response => assert_eq!(resp.n_accepted, 100),
            other => panic!("unexpected event {other:?}"),
        }
    }
    let a0 = allocs();
    for req in 16..24u64 {
        cl.submit(req, 0, &z0).unwrap();
        match cl.next_event(&mut resp).unwrap() {
            ClientEvent::Response => {}
            other => panic!("unexpected event {other:?}"),
        }
    }
    let delta = allocs() - a0;
    assert_eq!(
        delta, 0,
        "warmed TCP serve loop allocated {delta} times over 8 round-trips"
    );
    assert_eq!(resp.n_accepted, 100, "measured responses were real solves");
    cl.goodbye().unwrap();
    drop(cl);
    assert!(front.shutdown(Duration::from_secs(10)).flushed);
    // the front and its connection threads have released their server
    // handles; unwrap (tolerating the last thread's exit race) and check
    // the books
    let mut server = server;
    let server = loop {
        match Arc::try_unwrap(server) {
            Ok(s) => break s,
            Err(back) => {
                server = back;
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    };
    let metrics = server.shutdown();
    assert_eq!(metrics.requests, 24);
    assert_eq!(metrics.failed, 0);
}
