//! Acceptance suite for streaming sessions (DESIGN.md §12): the serve
//! layer's incremental-inference contract.
//!
//! * **incremental ≡ one-shot** — a session advanced through K event
//!   batches produces **bitwise** the same per-observation snapshots,
//!   final state and step/trial counts as one one-shot request whose
//!   grid is the concatenation of all the batches.  Warm state is an
//!   optimization, never a different computation — fixed and adaptive
//!   stepping alike (the adaptive controller's `h` is carried across
//!   steps exactly as it evolves inside the one-shot solve).
//! * **resume-boundary semantics** — a leading event time bitwise-equal
//!   to the session's barrier fires exactly once (the open-time barrier
//!   snapshot is the seed state); firing the same barrier twice is an
//!   error, never a silent duplicate, and the failed session is
//!   poisoned until closed.
//! * **hot-swap pinning** — `ModelRegistry::hot_swap` publishes new θ
//!   for *future* pins only: an open session (and any held version
//!   snapshot) keeps the exact parameters it pinned, while fresh
//!   requests see the new version.
//! * **lifecycle** — one step in flight per session (`BadRequest`, not
//!   a shed), idempotent close, unknown/closed ids refused, open-time
//!   validation.

use mali_ode::serve::{ModelRegistry, RequestClass, Server, ServerConfig, SubmitError};
use mali_ode::solvers::dynamics::{LinearToy, MlpDynamics};
use mali_ode::solvers::integrate::{ObsGrid, StepMode};
use mali_ode::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

const N_Z: usize = 4;

fn registry() -> Arc<ModelRegistry> {
    let mut reg = ModelRegistry::new();
    reg.register("lin", Box::new(LinearToy::new(-0.35, N_Z)));
    reg.register("mlp", Box::new(MlpDynamics::new(N_Z, 8, &mut Rng::new(23))));
    Arc::new(reg)
}

fn start(registry: Arc<ModelRegistry>, workers: usize) -> Server {
    Server::start(
        registry,
        ServerConfig {
            queue_capacity: 64,
            max_batch: 4,
            max_wait: Duration::from_micros(100),
            workers,
            shards: 1,
        },
    )
}

fn z0() -> Vec<f32> {
    (0..N_Z).map(|i| 0.3 + 0.1 * i as f32).collect()
}

/// The standard irregular event stream, chunked as a client would
/// deliver it: single events and multi-event bursts interleaved.
fn chunks() -> Vec<Vec<f64>> {
    vec![
        vec![0.15],
        vec![0.3, 0.45, 0.5],
        vec![0.8],
        vec![0.95, 1.4],
    ]
}

fn one_shot(server: &Server, model: &str, mode: &StepMode, times: &[f64], z0: &[f32]) -> mali_ode::serve::ServeResponse {
    let class = Arc::new(
        RequestClass::new(
            model,
            "alf",
            N_Z,
            0.0,
            *times.last().unwrap(),
            mode.clone(),
            ObsGrid::new(times.to_vec()).unwrap(),
        )
        .unwrap(),
    );
    server.submit(&class, z0).unwrap().wait().unwrap()
}

/// The tentpole: incremental session advance is bitwise the one-shot
/// solve over the concatenated grid — snapshots, final state and
/// step/trial counts — for both stepping modes and both a linear and a
/// nonlinear (MLP) model.
#[test]
fn incremental_session_is_bitwise_one_shot() {
    for mode in [StepMode::Fixed { h: 0.05 }, StepMode::adaptive(1e-5, 1e-7)] {
        for model in ["lin", "mlp"] {
            let server = start(registry(), 2);
            let z0 = z0();
            let all: Vec<f64> = chunks().concat();
            let reference = one_shot(&server, model, &mode, &all, &z0);

            let sid = server
                .open_session(model, "alf", N_Z, 0.0, mode.clone(), &z0)
                .unwrap();
            let mut obs = Vec::new();
            let mut n_accepted = 0usize;
            let mut n_trials = 0usize;
            let mut z_final = Vec::new();
            for chunk in chunks() {
                let r = server.session_step(sid, &chunk).unwrap().wait().unwrap();
                assert_eq!(r.obs.len(), chunk.len() * N_Z, "one row per event");
                assert_eq!(&r.obs[(chunk.len() - 1) * N_Z..], &r.z_final[..]);
                obs.extend_from_slice(&r.obs);
                n_accepted += r.n_accepted;
                n_trials += r.n_trials;
                z_final = r.z_final;
            }
            assert!(server.close_session(sid));

            assert_eq!(obs, reference.obs, "{model}/{mode:?}: snapshots");
            assert_eq!(z_final, reference.z_final, "{model}/{mode:?}: final state");
            assert_eq!(n_accepted, reference.n_accepted, "{model}/{mode:?}: steps");
            assert_eq!(n_trials, reference.n_trials, "{model}/{mode:?}: trials");

            let metrics = server.shutdown();
            assert_eq!(metrics.failed, 0);
            assert_eq!(metrics.session_steps, chunks().len() as u64);
        }
    }
}

/// Resume-boundary rule, positive half: a session opened at `t0` fires
/// the barrier snapshot (the seed state, bitwise) exactly once when the
/// first step leads with `t0`, and the remaining events match the
/// one-shot solve over the strictly-interior grid.
#[test]
fn barrier_event_fires_exactly_once() {
    let server = start(registry(), 1);
    let z0 = z0();
    let t0 = 0.2f64;
    let interior = [0.6, 0.9];

    let sid = server
        .open_session("mlp", "alf", N_Z, t0, StepMode::Fixed { h: 0.05 }, &z0)
        .unwrap();
    let r = server
        .session_step(sid, &[t0, interior[0], interior[1]])
        .unwrap()
        .wait()
        .unwrap();
    // row 0 is the seed state itself — observed, not re-integrated
    assert_eq!(&r.obs[..N_Z], &z0[..], "barrier snapshot is the seed state");

    // the interior rows are the plain resumed solve from (t0, z0)
    let class = Arc::new(
        RequestClass::new(
            "mlp",
            "alf",
            N_Z,
            t0,
            interior[1],
            StepMode::Fixed { h: 0.05 },
            ObsGrid::new(interior.to_vec()).unwrap(),
        )
        .unwrap(),
    );
    let reference = server.submit(&class, &z0).unwrap().wait().unwrap();
    assert_eq!(&r.obs[N_Z..], &reference.obs[..], "interior snapshots");
    assert_eq!(r.z_final, reference.z_final);
    assert!(server.close_session(sid));
    server.shutdown();
}

/// Resume-boundary rule, negative half: re-firing an already-fired
/// barrier is an explicit error (never a silent duplicate row), the
/// failed session is poisoned against further steps, and close still
/// releases it.
#[test]
fn duplicate_barrier_is_an_error_and_poisons() {
    let server = start(registry(), 1);
    let z0 = z0();
    let sid = server
        .open_session("lin", "alf", N_Z, 0.0, StepMode::Fixed { h: 0.05 }, &z0)
        .unwrap();
    let r = server.session_step(sid, &[0.3, 0.5]).unwrap().wait().unwrap();
    assert_eq!(r.obs.len(), 2 * N_Z);

    // 0.5 was observed by the previous step: leading with it again must
    // fail loudly instead of emitting the row twice
    let err = server
        .session_step(sid, &[0.5, 0.7])
        .unwrap()
        .wait()
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("already") || msg.contains("fired") || msg.contains("duplicate"),
        "unexpected duplicate-barrier error: {msg}"
    );

    // the session is poisoned: even a well-formed step is refused...
    let err = server
        .session_step(sid, &[0.9])
        .unwrap()
        .wait()
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("poisoned"),
        "expected poisoned-session refusal, got: {err:#}"
    );
    // ...but the slot is not leaked
    assert!(server.close_session(sid));
    assert_eq!(server.session_count(), 0);
    let metrics = server.shutdown();
    assert_eq!(metrics.failed, 2, "exactly the two refused steps failed");
}

/// Hot-swap pinning: an open session and a held version snapshot keep
/// the θ they pinned across `hot_swap`; only new pins see the new
/// parameters.
#[test]
fn hot_swap_never_changes_a_pinned_session() {
    let registry = registry();
    let server = start(registry.clone(), 1);
    let z0 = z0();
    let mode = StepMode::Fixed { h: 0.05 };
    let all: Vec<f64> = chunks().concat();

    // pre-swap ground truth + a held version snapshot
    let old_reference = one_shot(&server, "mlp", &mode, &all, &z0);
    let id = registry.resolve("mlp").unwrap();
    let pinned = registry.snapshot(id).unwrap();
    assert_eq!(pinned.version(), 1);
    let theta0 = pinned.dynamics().params().to_vec();

    // open before the swap: the session pins version 1
    let sid = server.open_session("mlp", "alf", N_Z, 0.0, mode.clone(), &z0).unwrap();

    // publish new parameters mid-stream — no drain, no rebuild
    let theta1: Vec<f32> = theta0.iter().map(|p| p * 1.25 + 0.01).collect();
    let v = registry.hot_swap("mlp", &theta1).unwrap();
    assert_eq!(v, 2);
    assert_eq!(registry.snapshot(id).unwrap().version(), 2);

    // the held snapshot still reads the exact old θ
    assert_eq!(pinned.dynamics().params(), &theta0[..]);

    // the open session still serves the exact old model...
    let mut obs = Vec::new();
    let mut z_final = Vec::new();
    for chunk in chunks() {
        let r = server.session_step(sid, &chunk).unwrap().wait().unwrap();
        obs.extend_from_slice(&r.obs);
        z_final = r.z_final;
    }
    assert_eq!(obs, old_reference.obs, "session θ changed under hot_swap");
    assert_eq!(z_final, old_reference.z_final);
    assert!(server.close_session(sid));

    // ...while fresh work (one-shot or a new session) pins version 2
    let new_reference = one_shot(&server, "mlp", &mode, &all, &z0);
    assert_ne!(new_reference.z_final, old_reference.z_final, "swap must be visible to new pins");
    let sid2 = server.open_session("mlp", "alf", N_Z, 0.0, mode.clone(), &z0).unwrap();
    let mut obs2 = Vec::new();
    for chunk in chunks() {
        obs2.extend_from_slice(&server.session_step(sid2, &chunk).unwrap().wait().unwrap().obs);
    }
    assert_eq!(obs2, new_reference.obs, "new session must pin the new version");
    assert!(server.close_session(sid2));

    let metrics = server.shutdown();
    assert_eq!(metrics.failed, 0);
}

/// One step in flight per session: the second concurrent step is a
/// `BadRequest` (a client protocol violation), not a shed — it must not
/// touch the overload accounting.
#[test]
fn concurrent_step_is_bad_request_not_shed() {
    // paused server (no workers): the first step stays queued for sure
    let server = start(registry(), 0);
    let sid = server
        .open_session("lin", "alf", N_Z, 0.0, StepMode::Fixed { h: 0.1 }, &z0())
        .unwrap();
    let first = server.session_step(sid, &[0.5]).unwrap();
    match server.session_step(sid, &[0.7]) {
        Err(SubmitError::BadRequest(msg)) => {
            assert!(msg.contains("in flight"), "unexpected refusal: {msg}")
        }
        other => panic!("expected BadRequest, got {other:?}"),
    }
    assert_eq!(server.shed_count(), 0, "busy refusal must not count as a shed");
    let metrics = server.shutdown();
    // the queued step was failed by shutdown, not lost
    assert!(first.wait().is_err());
    assert_eq!(metrics.shed, 0);
}

/// Lifecycle edges: open-time validation, idempotent close, and refusal
/// of unknown / closed session ids.
#[test]
fn lifecycle_validation_and_idempotent_close() {
    let server = start(registry(), 1);

    // open-time validation: unknown model / unknown solver / bad width
    assert!(server.open_session("nope", "alf", N_Z, 0.0, StepMode::Fixed { h: 0.1 }, &z0()).is_err());
    assert!(server.open_session("lin", "not-a-solver", N_Z, 0.0, StepMode::Fixed { h: 0.1 }, &z0()).is_err());
    assert!(server.open_session("lin", "alf", N_Z, 0.0, StepMode::Fixed { h: 0.1 }, &[1.0]).is_err());
    assert!(server
        .open_session("lin", "alf", N_Z, f64::NAN, StepMode::Fixed { h: 0.1 }, &z0())
        .is_err());
    assert_eq!(server.session_count(), 0, "failed opens must not leak slots");

    // unknown sid is refused before touching the queue
    match server.session_step(999, &[0.5]) {
        Err(SubmitError::BadRequest(msg)) => assert!(msg.contains("999")),
        other => panic!("expected BadRequest, got {other:?}"),
    }

    let sid = server
        .open_session("lin", "alf", N_Z, 0.0, StepMode::Fixed { h: 0.1 }, &z0())
        .unwrap();
    assert_eq!(server.session_count(), 1);
    assert!(server.close_session(sid));
    assert!(!server.close_session(sid), "close is idempotent");
    assert_eq!(server.session_count(), 0);
    assert!(server.session_step(sid, &[0.5]).is_err(), "stepping a closed session");
    let metrics = server.shutdown();
    assert_eq!(metrics.failed, 0);
}
