//! FD fuzz harness for the gradient protocols: seeded-random
//! `LinearToy`-family dynamics × every registered `GradMethod` ×
//! {fixed, adaptive} stepping × {empty, random} observation grids —
//! enumerated through the shared `tests/common/methods.rs` registry, so
//! a new protocol or solver auto-enrolls — cross-checked against
//!
//! * the toy problem's **analytic** gradients (paper Eq. 7) — the
//!   tightest anchor, valid in both stepping modes, checked over every
//!   supported method × solver pair of the grid;
//! * **central finite differences** of the end-to-end loss on fixed
//!   grids (perturbed runs share the discretization, so FD measures the
//!   discrete gradient the methods actually compute);
//! * cross-method agreement: the exact set (MALI ≡ ACA ≡ naive ≡
//!   symplectic) to roundoff (≲ 1e-4 relative) on the same ALF solve,
//!   in every fuzzed configuration.
//!
//! Tolerances follow the envelopes validated in `tests/grad_methods.rs`
//! and `tests/obs_grid.rs` (FD ≲ 2e-2·(1+|fd|) at ε = 1e-2 on f32
//! forward passes; exact-method agreement ≲ 1e-4).
//!
//! The native fused-dynamics backend (`dynamics_native::MlpDynamics`)
//! gets the same treatment: random depths/widths × all three time
//! conditioning modes × every method × {fixed, adaptive} × random
//! observation grids, FD-checked on the shared fixed discretization.

use mali_ode::grad::{by_name, forward_loss, forward_loss_obs, IvpSpec, ObsSquareLoss, SquareLoss};
use mali_ode::solvers::by_name as solver_by_name;
use mali_ode::solvers::dynamics::{Dynamics, LinearToy, MlpDynamics};
use mali_ode::util::mem::MemTracker;
use mali_ode::util::rng::Rng;

#[path = "common/methods.rs"]
mod methods;

use methods::{l2, random_grid, solver_for, EXACT_METHODS, METHODS};

/// Terminal-loss fuzz on the toy family: every supported method × solver
/// pair of the registry grid recovers the analytic gradients (Eq. 7) in
/// both stepping modes.
#[test]
fn fuzz_toy_terminal_gradients_match_analytic() {
    let mut rng = Rng::new(7001);
    for trial in 0..6 {
        let n = 1 + rng.below(4);
        let sign = if rng.below(2) == 0 { 1.0 } else { -1.0 };
        let alpha = sign * rng.range(0.3, 1.0);
        let t_end = rng.range(0.8, 1.6);
        let toy = LinearToy::new(alpha, n);
        let mut z0 = vec![0.0f32; n];
        for z in z0.iter_mut() {
            let s = if rng.below(2) == 0 { 1.0 } else { -1.0 };
            *z = (s * rng.range(0.5, 2.0)) as f32;
        }
        let (dz0_true, dalpha_true) = toy.analytic_grads(&z0, t_end);
        let z0_scale = 1.0 + dz0_true.iter().map(|&x| (x as f64).abs()).fold(0.0, f64::max);
        let a_scale = 1.0 + dalpha_true.abs();

        for (mi, &(method, sname)) in methods::pairs().iter().enumerate() {
            let solver = solver_by_name(sname).unwrap();
            let mode_fixed = (trial + mi) % 2 == 0;
            let spec = if mode_fixed {
                IvpSpec::fixed(0.0, t_end, 0.02)
            } else {
                IvpSpec::adaptive(0.0, t_end, 1e-6, 1e-8)
            };
            let m = by_name(method).unwrap();
            let r = m
                .grad(&toy, &*solver, &spec, &z0, &SquareLoss, MemTracker::new())
                .unwrap();
            assert!(
                (r.grad_theta[0] as f64 - dalpha_true).abs() < 0.05 * a_scale,
                "trial {trial} {method}×{sname}: dα {} vs analytic {dalpha_true}",
                r.grad_theta[0]
            );
            assert!(
                l2(&r.grad_z0, &dz0_true) < 0.05 * z0_scale,
                "trial {trial} {method}×{sname}: dz₀ err {}",
                l2(&r.grad_z0, &dz0_true)
            );
        }
    }
}

/// Multi-observation fuzz on the toy family: random grids, fixed-grid FD
/// cross-check (θ and z₀) plus exact-method agreement in both modes.
#[test]
fn fuzz_toy_obs_gradients() {
    let mut rng = Rng::new(7002);
    for trial in 0..4 {
        let n = 1 + rng.below(3);
        let alpha = rng.range(-0.9, 0.9);
        let t_end = rng.range(0.9, 1.5);
        let mut toy = LinearToy::new(alpha, n);
        let mut z0 = vec![0.0f32; n];
        rng.fill_uniform_sym(&mut z0, 1.5);
        let grid = random_grid(&mut rng, t_end);
        let weights: Vec<f64> = (0..grid.len()).map(|_| rng.range(0.5, 2.0)).collect();
        let head = ObsSquareLoss {
            weights: weights.clone(),
        };

        for &(label, fixed) in &[("fixed", true), ("adaptive", false)] {
            let spec = if fixed {
                IvpSpec::fixed(0.0, t_end, 0.05)
            } else {
                IvpSpec::adaptive(0.0, t_end, 1e-5, 1e-7)
            };
            let mut results = Vec::new();
            for method in METHODS {
                let solver = solver_by_name(solver_for(method)).unwrap();
                let m = by_name(method).unwrap();
                let head = ObsSquareLoss {
                    weights: weights.clone(),
                };
                let r = m
                    .grad_obs(&toy, &*solver, &spec, &grid, &z0, &head, MemTracker::new())
                    .unwrap();
                assert_eq!(r.obs_losses.len(), grid.len(), "{label} {method}");
                results.push((method, r));
            }
            // exact methods agree to roundoff on the same ALF solve
            let mali = &results[0].1;
            let max_abs = |xs: &[f32]| {
                1.0 + xs.iter().map(|&x| (x as f64).abs()).fold(0.0, f64::max)
            };
            for (method, r) in results
                .iter()
                .skip(1)
                .filter(|(m, _)| EXACT_METHODS.contains(m))
            {
                assert!(
                    l2(&r.grad_theta, &mali.grad_theta) < 1e-4 * max_abs(&mali.grad_theta),
                    "trial {trial} {label} {method} vs mali θ"
                );
                assert!(
                    l2(&r.grad_z0, &mali.grad_z0) < 1e-4 * max_abs(&mali.grad_z0),
                    "trial {trial} {label} {method} vs mali z₀"
                );
                assert!((r.loss - mali.loss).abs() < 1e-6 * (1.0 + mali.loss.abs()));
            }
            // FD cross-check on the shared fixed discretization
            if fixed {
                let eps = 1e-2f32;
                for (method, r) in &results {
                    let solver = solver_by_name(solver_for(method)).unwrap();
                    // θ (the toy has a single parameter α)
                    let theta0 = toy.params().to_vec();
                    let mut tp = theta0.clone();
                    tp[0] += eps;
                    toy.set_params(&tp);
                    let (lp, _, _, _) =
                        forward_loss_obs(&toy, &*solver, &spec, &grid, &z0, &head).unwrap();
                    let mut tm = theta0.clone();
                    tm[0] -= eps;
                    toy.set_params(&tm);
                    let (lm, _, _, _) =
                        forward_loss_obs(&toy, &*solver, &spec, &grid, &z0, &head).unwrap();
                    toy.set_params(&theta0);
                    let fd = (lp - lm) / (2.0 * eps as f64);
                    assert!(
                        (fd - r.grad_theta[0] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                        "trial {trial} {method} θ: fd {fd} vs {}",
                        r.grad_theta[0]
                    );
                    // z₀
                    for j in 0..z0.len() {
                        let mut zp = z0.clone();
                        zp[j] += eps;
                        let (lp, _, _, _) =
                            forward_loss_obs(&toy, &*solver, &spec, &grid, &zp, &head).unwrap();
                        let mut zm = z0.clone();
                        zm[j] -= eps;
                        let (lm, _, _, _) =
                            forward_loss_obs(&toy, &*solver, &spec, &grid, &zm, &head).unwrap();
                        let fd = (lp - lm) / (2.0 * eps as f64);
                        assert!(
                            (fd - r.grad_z0[j] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                            "trial {trial} {method} z0[{j}]: fd {fd} vs {}",
                            r.grad_z0[j]
                        );
                    }
                }
            }
        }
    }
}

/// Small-MLP FD fuzz: random dims, fixed grids (the perturbed runs share
/// the discretization), terminal loss — spot-checked θ coordinates and
/// every z₀ coordinate, all four methods.
#[test]
fn fuzz_mlp_terminal_fd() {
    let mut rng = Rng::new(7003);
    for trial in 0..3 {
        let d = 2 + rng.below(2);
        let hidden = 3 + rng.below(2);
        let mut dynamics = MlpDynamics::new(d, hidden, &mut rng);
        let mut z0 = vec![0.0f32; d];
        rng.fill_uniform_sym(&mut z0, 0.5);
        let t_end = rng.range(0.5, 0.9);
        let spec = IvpSpec::fixed(0.0, t_end, 0.1);

        for method in METHODS {
            let solver = solver_by_name(solver_for(method)).unwrap();
            let m = by_name(method).unwrap();
            let r = m
                .grad(&dynamics, &*solver, &spec, &z0, &SquareLoss, MemTracker::new())
                .unwrap();
            let theta0 = dynamics.params().to_vec();
            let eps = 1e-2f32;
            for &k in &[0usize, theta0.len() / 2, theta0.len() - 1] {
                let mut tp = theta0.clone();
                tp[k] += eps;
                dynamics.set_params(&tp);
                let (lp, _, _) =
                    forward_loss(&dynamics, &*solver, &spec, &z0, &SquareLoss).unwrap();
                let mut tm = theta0.clone();
                tm[k] -= eps;
                dynamics.set_params(&tm);
                let (lm, _, _) =
                    forward_loss(&dynamics, &*solver, &spec, &z0, &SquareLoss).unwrap();
                dynamics.set_params(&theta0);
                let fd = (lp - lm) / (2.0 * eps as f64);
                assert!(
                    (fd - r.grad_theta[k] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                    "trial {trial} {method} θ[{k}]: fd {fd} vs {}",
                    r.grad_theta[k]
                );
            }
            for j in 0..z0.len() {
                let mut zp = z0.clone();
                zp[j] += eps;
                let (lp, _, _) =
                    forward_loss(&dynamics, &*solver, &spec, &zp, &SquareLoss).unwrap();
                let mut zm = z0.clone();
                zm[j] -= eps;
                let (lm, _, _) =
                    forward_loss(&dynamics, &*solver, &spec, &zm, &SquareLoss).unwrap();
                let fd = (lp - lm) / (2.0 * eps as f64);
                assert!(
                    (fd - r.grad_z0[j] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                    "trial {trial} {method} z0[{j}]: fd {fd} vs {}",
                    r.grad_z0[j]
                );
            }
        }
    }
}

/// Native fused-MLP fuzz: random depths/widths and time-conditioning
/// modes, all four methods, fixed AND adaptive stepping, random
/// observation grids.  Exact methods agree on the same ALF solve (the
/// fused ψ/ψ⁻¹/ψ-vjp entries carry the whole computation here); on the
/// shared fixed discretization every method is FD-checked in θ (spot
/// coordinates across layers, including the time-affine tail) and in
/// every z₀ coordinate.
#[test]
fn fuzz_native_mlp_obs_gradients() {
    use mali_ode::dynamics_native::{MlpDynamics as NativeMlp, TimeMode};

    let mut rng = Rng::new(7004);
    for trial in 0..3usize {
        let n = 2 + rng.below(3);
        let depth = rng.below(3);
        let hidden: Vec<usize> = (0..depth).map(|_| 3 + rng.below(4)).collect();
        let time = match trial % 3 {
            0 => TimeMode::None,
            1 => TimeMode::Concat,
            _ => TimeMode::Affine,
        };
        let mut dynamics = NativeMlp::new(n, &hidden, time, &mut rng);
        let mut z0 = vec![0.0f32; n];
        rng.fill_uniform_sym(&mut z0, 0.8);
        let t_end = rng.range(0.6, 1.1);
        let grid = random_grid(&mut rng, t_end);
        let weights: Vec<f64> = (0..grid.len()).map(|_| rng.range(0.5, 2.0)).collect();

        for &(label, fixed) in &[("fixed", true), ("adaptive", false)] {
            let spec = if fixed {
                IvpSpec::fixed(0.0, t_end, 0.05)
            } else {
                IvpSpec::adaptive(0.0, t_end, 1e-5, 1e-7)
            };
            let mut results = Vec::new();
            for method in METHODS {
                let solver = solver_by_name(solver_for(method)).unwrap();
                let m = by_name(method).unwrap();
                let head = ObsSquareLoss {
                    weights: weights.clone(),
                };
                let r = m
                    .grad_obs(&dynamics, &*solver, &spec, &grid, &z0, &head, MemTracker::new())
                    .unwrap();
                assert_eq!(r.obs_losses.len(), grid.len(), "{label} {method}");
                results.push((method, r));
            }
            // exact methods agree on the same ALF solve; the envelope is a
            // touch looser than the toy's 1e-4 because deeper stacks
            // accumulate a little more ψ⁻¹-reconstruction roundoff
            let mali = &results[0].1;
            let max_abs = |xs: &[f32]| {
                1.0 + xs.iter().map(|&x| (x as f64).abs()).fold(0.0, f64::max)
            };
            for (method, r) in results
                .iter()
                .skip(1)
                .filter(|(m, _)| EXACT_METHODS.contains(m))
            {
                assert!(
                    l2(&r.grad_theta, &mali.grad_theta) < 1e-3 * max_abs(&mali.grad_theta),
                    "trial {trial} {label} {method} vs mali θ"
                );
                assert!(
                    l2(&r.grad_z0, &mali.grad_z0) < 1e-3 * max_abs(&mali.grad_z0),
                    "trial {trial} {label} {method} vs mali z₀"
                );
                assert!((r.loss - mali.loss).abs() < 1e-6 * (1.0 + mali.loss.abs()));
            }
            if !fixed {
                continue;
            }
            // FD on the shared fixed discretization
            let eps = 1e-2f32;
            let head = ObsSquareLoss {
                weights: weights.clone(),
            };
            let theta0 = dynamics.params().to_vec();
            let p = theta0.len();
            for (method, r) in &results {
                let solver = solver_by_name(solver_for(method)).unwrap();
                for &k in &[0usize, p / 4, p / 2, 3 * p / 4, p - 1] {
                    let mut tp = theta0.clone();
                    tp[k] += eps;
                    dynamics.set_params(&tp);
                    let (lp, _, _, _) =
                        forward_loss_obs(&dynamics, &*solver, &spec, &grid, &z0, &head).unwrap();
                    let mut tm = theta0.clone();
                    tm[k] -= eps;
                    dynamics.set_params(&tm);
                    let (lm, _, _, _) =
                        forward_loss_obs(&dynamics, &*solver, &spec, &grid, &z0, &head).unwrap();
                    dynamics.set_params(&theta0);
                    let fd = (lp - lm) / (2.0 * eps as f64);
                    assert!(
                        (fd - r.grad_theta[k] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                        "trial {trial} {method} θ[{k}]: fd {fd} vs {}",
                        r.grad_theta[k]
                    );
                }
                for j in 0..z0.len() {
                    let mut zp = z0.clone();
                    zp[j] += eps;
                    let (lp, _, _, _) =
                        forward_loss_obs(&dynamics, &*solver, &spec, &grid, &zp, &head).unwrap();
                    let mut zm = z0.clone();
                    zm[j] -= eps;
                    let (lm, _, _, _) =
                        forward_loss_obs(&dynamics, &*solver, &spec, &grid, &zm, &head).unwrap();
                    let fd = (lp - lm) / (2.0 * eps as f64);
                    assert!(
                        (fd - r.grad_z0[j] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
                        "trial {trial} {method} z0[{j}]: fd {fd} vs {}",
                        r.grad_z0[j]
                    );
                }
            }
        }
    }
}
