//! Integration tests over the gradient protocols — the empirical heart
//! of the reproduction: the exact set (MALI/ACA/naive/symplectic) must
//! agree to roundoff and with finite differences, while the adjoint
//! method carries reverse-trajectory error; MALI/adjoint memory must be
//! constant in N_t while ACA/naive grow and the symplectic adjoint stays
//! within the checkpoint bound.  The method and solver lists come from
//! the shared registry fixture in `tests/common/methods.rs`.

use mali_ode::grad::{by_name, forward_loss, FnLoss, IvpSpec, SquareLoss};
use mali_ode::solvers::dynamics::{Dynamics, LinearToy, MlpDynamics};
use mali_ode::solvers::{by_name as solver_by_name, by_name_eta};
use mali_ode::util::mem::MemTracker;
use mali_ode::util::rng::Rng;

#[path = "common/methods.rs"]
mod methods;

use methods::{l2, EXACT_METHODS, METHODS};

/// Paper Eq. 6/7: every method should recover the analytic gradients of the
/// toy problem.
#[test]
fn toy_analytic_gradients() {
    let t_end = 2.0;
    let toy = LinearToy::new(0.6, 2);
    let z0 = [1.0f32, -0.5];
    let (dz0_true, dalpha_true) = toy.analytic_grads(&z0, t_end);

    let mut errs = std::collections::BTreeMap::new();
    for method in METHODS {
        let solver = if method == "adjoint" {
            solver_by_name("dopri5").unwrap()
        } else {
            solver_by_name("alf").unwrap()
        };
        let spec = IvpSpec::adaptive(0.0, t_end, 1e-5, 1e-6);
        let m = by_name(method).unwrap();
        let r = m
            .grad(&toy, &*solver, &spec, &z0, &SquareLoss, MemTracker::new())
            .unwrap();
        let e_z0 = l2(&r.grad_z0, &dz0_true);
        let e_alpha = (r.grad_theta[0] as f64 - dalpha_true).abs();
        errs.insert(method, (e_z0, e_alpha));
        // absolute sanity: right ballpark for all methods
        let scale = dalpha_true.abs();
        assert!(
            e_alpha < 0.05 * scale,
            "{method}: dα err {e_alpha} vs scale {scale}"
        );
    }
}

/// MALI == ACA == naive == symplectic to float roundoff on the same ALF
/// solve: the whole exact set backprops through the same accepted steps
/// with exact states.
#[test]
fn mali_aca_naive_agree_exactly() {
    let mut rng = Rng::new(42);
    let dynamics = MlpDynamics::new(5, 7, &mut rng);
    let z0: Vec<f32> = (0..5).map(|i| 0.25 * i as f32 - 0.5).collect();
    let solver = solver_by_name("alf").unwrap();
    let spec = IvpSpec::adaptive(0.0, 1.0, 1e-3, 1e-5);

    let results: Vec<_> = EXACT_METHODS
        .iter()
        .map(|m| {
            by_name(m)
                .unwrap()
                .grad(&dynamics, &*solver, &spec, &z0, &SquareLoss, MemTracker::new())
                .unwrap()
        })
        .collect();
    for r in &results[1..] {
        assert!(
            l2(&r.grad_theta, &results[0].grad_theta) < 1e-4,
            "θ-grad mismatch vs mali: {}",
            l2(&r.grad_theta, &results[0].grad_theta)
        );
        assert!(l2(&r.grad_z0, &results[0].grad_z0) < 1e-4);
        assert!((r.loss - results[0].loss).abs() < 1e-6);
    }
}

/// Every method's θ-gradient on the MLP dynamics matches central finite
/// differences of the end-to-end loss.
#[test]
fn all_methods_match_finite_differences() {
    let mut rng = Rng::new(7);
    let mut dynamics = MlpDynamics::new(3, 4, &mut rng);
    let z0 = vec![0.4f32, -0.3, 0.2];
    let spec = IvpSpec::fixed(0.0, 0.8, 0.1);

    for method in METHODS {
        let solver = if method == "adjoint" {
            solver_by_name("rk4").unwrap()
        } else {
            solver_by_name("alf").unwrap()
        };
        let m = by_name(method).unwrap();
        let r = m
            .grad(&dynamics, &*solver, &spec, &z0, &SquareLoss, MemTracker::new())
            .unwrap();

        let theta0 = dynamics.params().to_vec();
        let eps = 1e-2f32;
        for &k in &[0usize, theta0.len() / 3, theta0.len() - 1] {
            let mut tp = theta0.clone();
            tp[k] += eps;
            dynamics.set_params(&tp);
            let (lp, _, _) =
                forward_loss(&dynamics, &*solver, &spec, &z0, &SquareLoss).unwrap();
            let mut tm = theta0.clone();
            tm[k] -= eps;
            dynamics.set_params(&tm);
            let (lm, _, _) =
                forward_loss(&dynamics, &*solver, &spec, &z0, &SquareLoss).unwrap();
            dynamics.set_params(&theta0);
            let fd = (lp - lm) / (2.0 * eps as f64);
            let got = r.grad_theta[k] as f64;
            assert!(
                (fd - got).abs() < 2e-2 * (1.0 + fd.abs()),
                "{method} θ[{k}]: fd {fd} vs {got}"
            );
        }
        // dL/dz0 finite difference
        for j in 0..z0.len() {
            let mut zp = z0.clone();
            zp[j] += eps;
            let (lp, _, _) =
                forward_loss(&dynamics, &*solver, &spec, &zp, &SquareLoss).unwrap();
            let mut zm = z0.clone();
            zm[j] -= eps;
            let (lm, _, _) =
                forward_loss(&dynamics, &*solver, &spec, &zm, &SquareLoss).unwrap();
            let fd = (lp - lm) / (2.0 * eps as f64);
            let got = r.grad_z0[j] as f64;
            assert!(
                (fd - got).abs() < 2e-2 * (1.0 + fd.abs()),
                "{method} z0[{j}]: fd {fd} vs {got}"
            );
        }
    }
}

/// Paper Fig. 4(c) / Table 1: MALI and adjoint memory is flat in the step
/// count; ACA grows ~N_t; naive grows at least as fast.
#[test]
fn memory_scaling_matches_table1() {
    let toy = LinearToy::new(1.0, 64);
    let z0 = vec![1.0f32; 64];
    let peak = |method: &str, h: f64| -> usize {
        let solver = solver_by_name("alf").unwrap();
        let spec = IvpSpec::fixed(0.0, 4.0, h);
        let tracker = MemTracker::new();
        by_name(method)
            .unwrap()
            .grad(&toy, &*solver, &spec, &z0, &SquareLoss, tracker.clone())
            .unwrap();
        tracker.peak_bytes()
    };
    for method in ["mali", "adjoint"] {
        let few = peak(method, 0.5); // 8 steps
        let many = peak(method, 0.05); // 80 steps
        assert!(
            many <= few + 2048,
            "{method}: memory grew {few} -> {many} with 10x steps"
        );
    }
    for method in ["aca", "naive"] {
        let few = peak(method, 0.5);
        let many = peak(method, 0.05);
        assert!(
            many as f64 > few as f64 * 5.0,
            "{method}: expected ~10x memory growth, got {few} -> {many}"
        );
    }
    // ordering at fixed resolution: naive ≥ aca > mali
    let (n, a, m) = (peak("naive", 0.1), peak("aca", 0.1), peak("mali", 0.1));
    assert!(n >= a, "naive {n} < aca {a}");
    assert!(a > m, "aca {a} <= mali {m}");

    // symplectic adjoint (Matsubara): the checkpoint tape grows with the
    // step count like ACA's...
    let s_few = peak("symplectic", 0.5);
    let s_many = peak("symplectic", 0.05);
    assert!(
        s_many as f64 > s_few as f64 * 5.0,
        "symplectic: expected ~10x tape growth, got {s_few} -> {s_many}"
    );
    // ...but its peak never exceeds the ACA checkpoint bound (it holds
    // only the tape, releasing each checkpoint as the sweep consumes it)
    let s = peak("symplectic", 0.1);
    assert!(s <= a, "symplectic peak {s} exceeds ACA bound {a}");
    assert!(s > m, "symplectic peak {s} should exceed MALI's constant {m}");
}

/// The memory laws transfer to the reversible-4 composition: MALI's
/// ψ⁻¹-reconstruction stays constant in the step count on it, while the
/// symplectic adjoint's tape grows — the laws are properties of the
/// *protocol*, not of ALF.
#[test]
fn reversible4_memory_laws() {
    let toy = LinearToy::new(1.0, 64);
    let z0 = vec![1.0f32; 64];
    let peak = |method: &str, h: f64| -> usize {
        let solver = solver_by_name("reversible4").unwrap();
        let spec = IvpSpec::fixed(0.0, 4.0, h);
        let tracker = MemTracker::new();
        by_name(method)
            .unwrap()
            .grad(&toy, &*solver, &spec, &z0, &SquareLoss, tracker.clone())
            .unwrap();
        tracker.peak_bytes()
    };
    let few = peak("mali", 0.5);
    let many = peak("mali", 0.05);
    assert!(
        many <= few + 2048,
        "mali×reversible4: memory grew {few} -> {many} with 10x steps"
    );
    let s_few = peak("symplectic", 0.5);
    let s_many = peak("symplectic", 0.05);
    assert!(
        s_many as f64 > s_few as f64 * 5.0,
        "symplectic×reversible4: expected tape growth, got {s_few} -> {s_many}"
    );
}

/// The adjoint's reverse-time trajectory drifts from the true initial state
/// while MALI's ψ⁻¹ reconstruction is exact (paper Thm. 2.1 + §3.2).
#[test]
fn reverse_trajectory_error_adjoint_vs_mali() {
    let toy = LinearToy::new(1.2, 4);
    let z0 = vec![1.0f32, 0.5, -0.5, 2.0];
    let t_end = 3.0;

    // adjoint with a loose tolerance: visible reconstruction error
    let solver = solver_by_name("heun-euler").unwrap();
    let spec = IvpSpec::adaptive(0.0, t_end, 1e-2, 1e-3);
    let adj = by_name("adjoint")
        .unwrap()
        .grad(&toy, &*solver, &spec, &z0, &SquareLoss, MemTracker::new())
        .unwrap();
    let adj_err = l2(adj.reconstructed_z0.as_ref().unwrap(), &z0);

    let alf = solver_by_name("alf").unwrap();
    let mali = by_name("mali")
        .unwrap()
        .grad(&toy, &*alf, &spec, &z0, &SquareLoss, MemTracker::new())
        .unwrap();
    let mali_err = l2(mali.reconstructed_z0.as_ref().unwrap(), &z0);

    assert!(
        mali_err < adj_err,
        "MALI reconstruction {mali_err} should beat adjoint {adj_err}"
    );
    assert!(mali_err < 1e-2, "MALI reconstruction should be ~roundoff: {mali_err}");
}

/// MALI refuses non-invertible solvers instead of silently degrading.
#[test]
fn mali_requires_invertible_solver() {
    let toy = LinearToy::new(1.0, 1);
    let solver = solver_by_name("dopri5").unwrap();
    let spec = IvpSpec::fixed(0.0, 1.0, 0.1);
    let err = by_name("mali")
        .unwrap()
        .grad(&toy, &*solver, &spec, &[1.0], &SquareLoss, MemTracker::new())
        .unwrap_err();
    assert!(err.to_string().contains("invertible"));
}

/// Damped MALI (η < 1) still matches finite differences — Table 7 support.
#[test]
fn damped_mali_gradients_correct() {
    let mut rng = Rng::new(13);
    let mut dynamics = MlpDynamics::new(3, 4, &mut rng);
    let z0 = vec![0.2f32, -0.1, 0.3];
    for &eta in &[0.95, 0.9, 0.85] {
        let solver = by_name_eta("alf", eta).unwrap();
        let spec = IvpSpec::fixed(0.0, 0.6, 0.1);
        let r = by_name("mali")
            .unwrap()
            .grad(&dynamics, &*solver, &spec, &z0, &SquareLoss, MemTracker::new())
            .unwrap();
        let theta0 = dynamics.params().to_vec();
        let eps = 1e-2f32;
        let k = theta0.len() / 2;
        let mut tp = theta0.clone();
        tp[k] += eps;
        dynamics.set_params(&tp);
        let (lp, _, _) = forward_loss(&dynamics, &*solver, &spec, &z0, &SquareLoss).unwrap();
        let mut tm = theta0.clone();
        tm[k] -= eps;
        dynamics.set_params(&tm);
        let (lm, _, _) = forward_loss(&dynamics, &*solver, &spec, &z0, &SquareLoss).unwrap();
        dynamics.set_params(&theta0);
        let fd = (lp - lm) / (2.0 * eps as f64);
        assert!(
            (fd - r.grad_theta[k] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
            "eta {eta}: fd {fd} vs {}",
            r.grad_theta[k]
        );
    }
}

/// Computation accounting sanity vs Table 1: naive trials ≥ accepted steps;
/// MALI backward adds ~2 f-evals per accepted step over forward.
#[test]
fn computation_accounting() {
    let toy = LinearToy::new(1.0, 8);
    let z0 = vec![1.0f32; 8];
    let solver = solver_by_name("alf").unwrap();
    let spec = IvpSpec::adaptive(0.0, 5.0, 1e-4, 1e-6);

    let mali = by_name("mali")
        .unwrap()
        .grad(&toy, &*solver, &spec, &z0, &SquareLoss, MemTracker::new())
        .unwrap();
    let nt = mali.stats.fwd.n_accepted as u64;
    let trials = mali.stats.fwd.n_trials as u64;
    assert!(trials >= nt);
    // forward ~ trials f-evals (+1 init); backward adds 1 ψ⁻¹ f-eval per
    // step, plus the vjp's internal eval: total f_evals ≈ trials + 1 + N_t
    assert!(
        mali.stats.f_evals >= trials + nt,
        "f_evals {} vs trials {trials} + steps {nt}",
        mali.stats.f_evals
    );
    assert!(mali.stats.vjp_evals >= nt);

    let naive = by_name("naive")
        .unwrap()
        .grad(&toy, &*solver, &spec, &z0, &SquareLoss, MemTracker::new())
        .unwrap();
    assert!(naive.stats.graph_depth >= mali.stats.graph_depth);
}

/// Loss heads are pluggable: a weighted-sum head propagates correctly.
#[test]
fn custom_loss_head() {
    let toy = LinearToy::new(0.5, 2);
    let z0 = [1.0f32, 2.0];
    let solver = solver_by_name("alf").unwrap();
    let spec = IvpSpec::fixed(0.0, 1.0, 0.05);
    let head = FnLoss(|z: &[f32]| {
        let l = z[0] as f64 * 3.0 - z[1] as f64;
        (l, vec![3.0, -1.0])
    });
    let r = by_name("mali")
        .unwrap()
        .grad(&toy, &*solver, &spec, &z0, &head, MemTracker::new())
        .unwrap();
    // analytic: z_i(T) = z0_i e^{0.5}; dL/dz0 = [3 e^{0.5}, −e^{0.5}]
    let e = 0.5f64.exp();
    assert!((r.grad_z0[0] as f64 - 3.0 * e).abs() < 1e-2);
    assert!((r.grad_z0[1] as f64 + e).abs() < 1e-2);
}
