//! Offline drop-in subset of the [`anyhow`](https://docs.rs/anyhow) crate.
//!
//! The build image has no registry access, so this in-tree vendored crate
//! provides exactly the surface `mali_ode` uses (see `docs/adr/001`):
//!
//! * [`Error`] — a boxed-free context-chain error (`{}` prints the top
//!   message, `{:#}` the whole chain joined with `": "`, like real anyhow);
//! * [`Result<T>`] — `Result<T, Error>` alias with a default type parameter;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` (both
//!   std-error and `anyhow::Error` variants) and on `Option`;
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! Semantics match the real crate for these uses; swapping the manifest
//! entry for the registry `anyhow = "1"` is a no-op for this codebase.

use std::fmt;

/// `Result<T, anyhow::Error>` with the same default-parameter shape as the
/// real crate (`anyhow::Result<T, E>` is still spellable).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A lightweight context-chain error.
///
/// Internally a flattened chain of messages: `chain[0]` is the outermost
/// (most recently attached) context, the tail is the original cause chain.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Attach an outer context message (what `.context(..)` does).
    fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, `outer: inner: root`, like anyhow.
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` coherent
// next to the reflexive `impl From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut cur: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = cur {
            chain.push(s.to_string());
            cur = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
///
/// The `E` parameter mirrors real anyhow's signature; it only disambiguates
/// the `Result` and `Option` impls.
pub trait Context<T, E> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Like [`Context::context`] but lazily evaluated.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

// One impl covers both `Result<T, impl std::error::Error>` (via the blanket
// `From` above) and `Result<T, Error>` (via the reflexive `From`) — this is
// what lets `.with_context(..)` chain on results that are already anyhow.
impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.wrap(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.wrap(f())
        })
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any printable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`anyhow!`]-constructed error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_top_and_alternate_chain() {
        let e: Error = Error::from(io_err());
        let e = e.wrap("outer layer");
        assert_eq!(format!("{e}"), "outer layer");
        assert_eq!(format!("{e:#}"), "outer layer: missing thing");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn context_on_std_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading config: missing thing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("no value for '{}'", "k")).unwrap_err();
        assert_eq!(format!("{e}"), "no value for 'k'");
    }

    #[test]
    fn context_chains_on_anyhow_result() {
        fn inner() -> Result<()> {
            bail!("inner failed with code {}", 7);
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner failed with code 7");
    }

    #[test]
    fn ensure_and_question_mark() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            let parsed: i32 = "42".parse()?; // std error converts via `?`
            Ok(parsed + x)
        }
        assert_eq!(f(1).unwrap(), 43);
        assert!(f(-1).unwrap_err().to_string().contains("must be positive"));
    }
}
