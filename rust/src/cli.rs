//! Hand-rolled CLI argument parsing (clap is not vendored offline).
//!
//! Grammar: `mali <command> [positional...] [--flag] [--key value]...`
//! with `--set a.b=c` collected separately for config overrides.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: Vec<String>,
    pub options: BTreeMap<String, String>,
    /// `--set key=value` config overrides, applied after the file loads.
    pub overrides: Vec<(String, String)>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if name == "set" {
                    let Some(kv) = it.next() else {
                        bail!("--set requires key=value");
                    };
                    let Some((k, v)) = kv.split_once('=') else {
                        bail!("--set expects key=value, got '{kv}'");
                    };
                    args.overrides.push((k.to_string(), v.to_string()));
                    continue;
                }
                // `--key=value` or `--key value` or boolean `--key`
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().is_some_and(|n| !n.starts_with("--")) {
                    args.options
                        .insert(name.to_string(), it.next().unwrap().clone());
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.command.is_empty() {
                args.command = a.clone();
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn usize_opt(&self, name: &str, default: usize) -> usize {
        self.opt(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_opt(&self, name: &str, default: f64) -> f64 {
        self.opt(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

pub const USAGE: &str = "\
mali — MALI (ICLR 2021) reproduction: memory-efficient reverse-accurate Neural-ODE integrator

USAGE:
    mali <COMMAND> [ARGS] [--set key=value]...

COMMANDS:
    list                       list registered experiments
    run <experiment>           run an experiment from configs/<experiment>.json
                               (fig5-native / table4-native run the fused
                               native-dynamics E2 / E8 — no artifacts needed;
                               fig4 / table1 also report the method grid:
                               five gradient protocols × three solvers)
    train <config.json>        train a model from an explicit config path
    toy                        quick toy-ODE gradient-accuracy demo (Fig. 4)
    stability                  print damped-ALF A-stability region areas (App. Fig. 1)
    serve-bench                online-inference micro-batching load generator (E12):
                               p50/p99 latency + steps/sec, coalesced vs solo vs naive
    serve-tcp                  serve the standard registry over TCP until a client
                               sends SHUTDOWN (--addr host:port, --port-file <path>,
                               --queue-cap N, --workers N, --max-inflight N)
    serve-client-bench         drive a running serve-tcp (E13): --addr/--port-file,
                               --clients/--requests/--window/--churn, --overload
                               [--assert-shed] for exact shed accounting, --shutdown
    finetune-serve             continual fine-tuning under live session traffic (E14):
                               hot_swap publishes new θ without draining while loopback
                               TCP sessions stream (--updates N, --sessions S, --events E);
                               asserts version pinning + exact admission accounting
    smoke                      load + execute every artifact once (runtime check)
    help                       show this message

COMMON OPTIONS:
    --artifacts <dir>          artifact directory (default: artifacts)
    --runs <dir>               metrics output directory (default: runs)
    --seed <u64>               RNG seed override
    --set a.b=c                dotted-path config override (repeatable)
    --verbose                  debug logging
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(&words.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_command_and_positional() {
        let a = parse(&["run", "fig5", "extra"]);
        assert_eq!(a.command, "run");
        assert_eq!(a.positional, vec!["fig5", "extra"]);
    }

    #[test]
    fn parses_options_flags_sets() {
        let a = parse(&[
            "run", "fig5", "--seed", "42", "--verbose", "--rtol=0.1", "--set", "train.lr=0.05",
            "--set", "solver.name=dopri5",
        ]);
        assert_eq!(a.opt("seed"), Some("42"));
        assert!(a.flag("verbose"));
        assert_eq!(a.f64_opt("rtol", 0.0), 0.1);
        assert_eq!(
            a.overrides,
            vec![
                ("train.lr".to_string(), "0.05".to_string()),
                ("solver.name".to_string(), "dopri5".to_string())
            ]
        );
    }

    #[test]
    fn boolean_flag_before_positional() {
        let a = parse(&["toy", "--verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.command, "toy");
    }

    #[test]
    fn rejects_malformed_set() {
        assert!(Args::parse(&["run".into(), "--set".into(), "noequals".into()]).is_err());
        assert!(Args::parse(&["run".into(), "--set".into()]).is_err());
    }
}
