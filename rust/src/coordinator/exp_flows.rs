//! E8 — Table 6: continuous generative models (FFJORD) vs the discrete
//! RealNVP baseline, BPD on the synthetic MNIST- / CIFAR-like corpora and
//! the 2-D density task.
//!
//! Columns follow the paper: FFJORD trained with the adjoint ("vanilla"),
//! with kinetic+Jacobian regularization ("rnode"), with the seminorm
//! adjoint ("seminorm"), and with MALI; plus RealNVP as the discrete flow.
//! Training uses each method's solver; evaluation always uses Dopri5 at
//! rtol = atol = 1e-5 (the paper's protocol).

use super::{report, Scale};
use crate::data::density::{self, Density2D};
use crate::grad::IvpSpec;
use crate::models::cnf::Ffjord;
use crate::models::realnvp::RealNvp;
use crate::models::SolveCfg;
use crate::opt::{by_name as opt_by_name, clip_grad_norm};
use crate::runtime::Engine;
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::util::logging::{log, Level};
use crate::util::rng::Rng;
use anyhow::Result;
use std::rc::Rc;

/// One FFJORD training variant.
struct Variant {
    name: &'static str,
    method: &'static str,
    solver: &'static str,
    /// RNODE regularizer weights (0 = off, the paper's "vanilla").
    lambda: f64,
}

const VARIANTS: [Variant; 4] = [
    Variant { name: "vanilla", method: "adjoint", solver: "heun-euler", lambda: 0.0 },
    Variant { name: "rnode", method: "adjoint", solver: "heun-euler", lambda: 0.05 },
    Variant { name: "seminorm", method: "adjoint-seminorm", solver: "heun-euler", lambda: 0.0 },
    Variant { name: "mali", method: "mali", solver: "alf", lambda: 0.05 },
];

/// Pixel batches for one corpus key.
fn corpus(key: &str, n: usize, seed: u64) -> Vec<f32> {
    match key {
        "cnf_mnist8" | "realnvp_mnist8" => density::mnist8(n, seed).x,
        "cnf_cifar8" | "realnvp_cifar8" => density::cifar8(n, seed).x,
        other => panic!("not a pixel corpus: {other}"),
    }
}

/// Train one FFJORD variant; returns held-out BPD evaluated with Dopri5.
fn train_ffjord(
    engine: &Rc<Engine>,
    key: &str,
    variant: &Variant,
    scale: Scale,
    seed: u64,
) -> Result<f64> {
    let mut rng = Rng::new(seed);
    let mut model = Ffjord::new(engine.clone(), key, &mut rng)?;
    model.lambda_k = variant.lambda;
    model.lambda_j = variant.lambda;

    let steps = scale.pick(10, 100);
    let solver = crate::solvers::by_name(variant.solver)?;
    let grad = crate::grad::by_name(variant.method)?;
    // train at the coarse tolerance (paper: adaptive, rtol 1e-2)
    let spec = IvpSpec::fixed(0.0, 1.0, 0.25);
    let mut opt = opt_by_name("adam", 1e-3, model.param_count())?;

    let is_2d = key == "cnf_density2d";
    for step in 0..steps {
        let x = if is_2d {
            Density2D::Pinwheel.sample_n(model.batch, &mut rng)
        } else {
            let all = corpus(key, model.batch * 8, seed + 31);
            let dim = model.dim;
            let k = rng.below(8);
            all[k * model.batch * dim..(k + 1) * model.batch * dim].to_vec()
        };
        let cfg = SolveCfg {
            solver: &*solver,
            spec: spec.clone(),
            method: &*grad,
        };
        let out = model.step(&x, &cfg, &mut rng)?;
        clip_grad_norm(&mut model.params.grad, 10.0);
        let grad_copy = model.params.grad.clone();
        opt.step(&mut model.params.value, &grad_copy);
        if step % 20 == 0 {
            log(
                Level::Debug,
                &format!("{key}/{}: step {step} loss {:.3}", variant.name, out.loss),
            );
        }
    }

    // evaluation: Dopri5, tight tolerance, regularizers off (BPD only)
    model.lambda_k = 0.0;
    model.lambda_j = 0.0;
    let eval_solver = crate::solvers::by_name("dopri5")?;
    let eval_method = crate::grad::by_name("mali")?; // unused in eval
    let eval_cfg = SolveCfg {
        solver: &*eval_solver,
        spec: IvpSpec::adaptive(0.0, 1.0, 1e-5, 1e-5),
        method: &*eval_method,
    };
    let mut eval_rng = Rng::new(seed + 99);
    let x_test = if is_2d {
        Density2D::Pinwheel.sample_n(model.batch, &mut eval_rng)
    } else {
        corpus(key, model.batch, seed + 77)
    };
    model.bpd(&x_test, &eval_cfg, &mut eval_rng)
}

/// Train the RealNVP baseline; returns held-out BPD.
fn train_realnvp(engine: &Rc<Engine>, key: &str, scale: Scale, seed: u64) -> Result<f64> {
    let mut rng = Rng::new(seed);
    let mut model = RealNvp::new(engine.clone(), key, &mut rng)?;
    let steps = scale.pick(30, 300);
    let mut opt = opt_by_name("adam", 1e-3, model.param_count())?;
    let all = corpus(key, model.batch * 8, seed + 31);
    let dim = model.dim;
    for _ in 0..steps {
        let k = rng.below(8);
        let x = &all[k * model.batch * dim..(k + 1) * model.batch * dim];
        model.step(x, &mut rng)?;
        clip_grad_norm(&mut model.params.grad, 10.0);
        let g = model.params.grad.clone();
        opt.step(&mut model.params.value, &g);
    }
    let x_test = corpus(key, model.batch, seed + 77);
    model.bpd(&x_test, &mut Rng::new(seed + 99))
}

/// Table 6 — BPD per dataset × model.
pub fn table6(scale: Scale, seed: u64) -> Result<Json> {
    let engine = Rc::new(Engine::from_env()?);
    let datasets = [
        ("synth-MNIST (8×8)", "cnf_mnist8", "realnvp_mnist8"),
        ("synth-CIFAR (8×8×3)", "cnf_cifar8", "realnvp_cifar8"),
    ];
    let mut table = Table::new(
        "Table 6: bits/dim, lower is better",
        &["dataset", "vanilla", "rnode", "seminorm", "mali", "realnvp"],
    );
    let mut rows = Vec::new();
    for (label, cnf_key, nvp_key) in datasets {
        let mut cells = vec![label.to_string()];
        for variant in &VARIANTS {
            let bpd = train_ffjord(&engine, cnf_key, variant, scale, seed)?;
            cells.push(format!("{bpd:.3}"));
            rows.push(Json::obj(vec![
                ("dataset", Json::Str(label.into())),
                ("model", Json::Str(variant.name.into())),
                ("bpd", Json::Num(bpd)),
            ]));
            log(Level::Info, &format!("table6 {label} {}: {bpd:.3}", variant.name));
        }
        let nvp = train_realnvp(&engine, nvp_key, scale, seed)?;
        cells.push(format!("{nvp:.3}"));
        rows.push(Json::obj(vec![
            ("dataset", Json::Str(label.into())),
            ("model", Json::Str("realnvp".into())),
            ("bpd", Json::Num(nvp)),
        ]));
        table.row(&cells);
    }

    // 2-D density sanity row (MALI vs vanilla only — no pixel bookkeeping)
    let mut cells = vec!["pinwheel (2-D)".to_string()];
    for variant in &VARIANTS {
        let bpd = train_ffjord(&engine, "cnf_density2d", variant, scale, seed)?;
        cells.push(format!("{bpd:.3}"));
        rows.push(Json::obj(vec![
            ("dataset", Json::Str("pinwheel".into())),
            ("model", Json::Str(variant.name.into())),
            ("bpd", Json::Num(bpd)),
        ]));
    }
    cells.push("-".into());
    table.row(&cells);
    table.print();

    Ok(report::summary(rows, vec![("seed", Json::Num(seed as f64))]))
}
