//! E6 / E7 / E9 — the time-series experiments:
//!
//! * **Table 4**: latent-ODE test MSE on hopper trajectories at 10/20/50 %
//!   of the training data, vs RNN and GRU sequence baselines, for each
//!   gradient method.
//! * **Table 5**: Neural-CDE test accuracy on the synthetic speech-command
//!   corpus for adjoint / SemiNorm / naive / ACA / MALI.
//! * **Table 7**: damped-MALI η ablation on both tasks.

use super::{report, Scale};
use crate::data::speech::{self, SpeechSpec};
use crate::data::SequenceDataset;
use crate::grad::IvpSpec;
use crate::models::cde::NeuralCde;
use crate::models::latent::{LatentOde, SeqBaseline};
use crate::models::SolveCfg;
use crate::opt::by_name as opt_by_name;
use crate::runtime::Engine;
use crate::solvers::dynamics::Dynamics;
use crate::sim::hopper;
use crate::train::metrics::AccuracyMeter;
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::util::logging::{log, Level};
use crate::util::rng::Rng;
use anyhow::Result;
use std::rc::Rc;

fn solver_for(method: &str) -> &'static str {
    match method {
        "mali" => "alf",
        _ => "heun-euler",
    }
}

/// Pad a (possibly short) trailing chunk of example indices up to `batch`
/// by cycling through `pool` (the epoch's index order), so the
/// fixed-batch device executables can run it; returns the padded indices
/// and the number of **real** examples.  Training counts every real
/// sample (the fill-ins just re-weight a few examples of the last
/// mini-batch); metrics count only the real rows — no sample is silently
/// dropped any more.
fn padded_chunk(chunk: &[usize], pool: &[usize], batch: usize) -> (Vec<usize>, usize) {
    let mut idx = chunk.to_vec();
    let mut c = 0usize;
    while idx.len() < batch {
        idx.push(pool[c % pool.len()]);
        c += 1;
    }
    (idx, chunk.len())
}

/// Test MSE over `test_idx` in fixed-size batches: the trailing chunk is
/// padded by cycling `test_idx`, `predict` maps an assembled `seq` batch
/// to a `batch × t_out × obs` prediction buffer, and the squared error is
/// averaged over the **real** rows only — shared by the latent-ODE and
/// RNN/GRU evaluation paths.
fn padded_test_mse(
    ds: &hopper::HopperDataset,
    test_idx: &[usize],
    batch: usize,
    t_len: usize,
    t_out: usize,
    obs: usize,
    mut predict: impl FnMut(&[f32]) -> Result<Vec<f32>>,
) -> Result<f64> {
    let per_example = t_out * obs;
    let mut sse = 0.0f64;
    let mut n_elems = 0usize;
    for chunk in test_idx.chunks(batch) {
        let (idx, real) = padded_chunk(chunk, test_idx, batch);
        let mut seq = Vec::new();
        let mut tgt = Vec::new();
        for &i in &idx {
            seq.extend_from_slice(ds.observed(i, t_len));
            tgt.extend_from_slice(ds.target(i, t_len, t_out));
        }
        let preds = predict(&seq)?;
        for j in 0..real * per_example {
            let d = (preds[j] - tgt[j]) as f64;
            sse += d * d;
        }
        n_elems += real * per_example;
    }
    Ok(sse / n_elems.max(1) as f64)
}

/// Train a latent ODE with one gradient method on a fraction of the data;
/// returns test MSE.
fn latent_ode_mse(
    engine: &Rc<Engine>,
    method: &str,
    eta: f64,
    train_frac: f64,
    scale: Scale,
    seed: u64,
) -> Result<f64> {
    let mut rng = Rng::new(seed);
    let mut model = LatentOde::new(engine.clone(), &mut rng)?;
    let n_total = scale.pick(4, 12) * model.batch;
    let n_test = scale.pick(1, 4) * model.batch;
    let ds = hopper::generate(n_total + n_test, model.t_len, model.t_out, 3.0, seed + 11);
    let n_train_max = n_total;
    // honest fraction: no rounding down to a batch multiple — the trailing
    // partial batch is padded, not dropped
    let n_train = (((n_train_max as f64) * train_frac).round() as usize).max(1);

    let epochs = scale.pick(3, 12);
    let solver = crate::solvers::by_name_eta(solver_for(method), eta)?;
    let grad = crate::grad::by_name(method)?;
    let spec = IvpSpec::fixed(0.0, 1.0, 0.25);

    let mut opt_enc = opt_by_name("adamax", 0.01, model.enc.len())?;
    let mut opt_dec = opt_by_name("adamax", 0.01, model.dec.len())?;
    let mut opt_dyn = opt_by_name("adamax", 0.01, model.dynamics.param_dim())?;

    for epoch in 0..epochs {
        // paper: lr ×0.999 per epoch
        let lr = 0.01 * 0.999f64.powi(epoch as i32);
        opt_enc.set_lr(lr);
        opt_dec.set_lr(lr);
        opt_dyn.set_lr(lr);
        let mut order: Vec<usize> = (0..n_train).collect();
        rng.shuffle(&mut order);
        let pool = order.clone();
        for chunk in order.chunks(model.batch) {
            let (idx, _real) = padded_chunk(chunk, &pool, model.batch);
            let mut seq = Vec::new();
            let mut tgt = Vec::new();
            for &i in &idx {
                seq.extend_from_slice(ds.observed(i, model.t_len));
                tgt.extend_from_slice(ds.target(i, model.t_len, model.t_out));
            }
            let cfg = SolveCfg {
                solver: &*solver,
                spec: spec.clone(),
                method: &*grad,
            };
            model.step(&seq, &tgt, &cfg, &mut rng)?;
            opt_enc.step(&mut model.enc.value, &model.enc.grad);
            opt_dec.step(&mut model.dec.value, &model.dec.grad);
            let mut theta = model.dynamics.params().to_vec();
            opt_dyn.step(&mut theta, &model.dyn_grad);
            model.dynamics.set_params(&theta);
        }
    }

    // test MSE over held-out trajectories (mean latent path); the trailing
    // partial batch is padded and only its real rows counted
    let cfg = SolveCfg {
        solver: &*solver,
        spec,
        method: &*grad,
    };
    let test_idx: Vec<usize> = (n_train_max..n_train_max + n_test).collect();
    padded_test_mse(
        &ds,
        &test_idx,
        model.batch,
        model.t_len,
        model.t_out,
        model.obs,
        |seq| model.predict(seq, &cfg),
    )
}

/// Train an RNN/GRU baseline on the same split; returns test MSE.
fn seq_baseline_mse(
    engine: &Rc<Engine>,
    key: &str,
    train_frac: f64,
    scale: Scale,
    seed: u64,
) -> Result<f64> {
    let mut rng = Rng::new(seed);
    let latent_model = LatentOde::new(engine.clone(), &mut rng)?;
    let (batch, t_len, t_out) = (latent_model.batch, latent_model.t_len, latent_model.t_out);
    let mut model = SeqBaseline::new(engine.clone(), key, &mut rng)?;
    let n_total = scale.pick(4, 12) * batch;
    let n_test = scale.pick(1, 4) * batch;
    let ds = hopper::generate(n_total + n_test, t_len, t_out, 3.0, seed + 11);
    // honest fraction + padded trailing batch, matching latent_ode_mse
    let n_train = (((n_total as f64) * train_frac).round() as usize).max(1);
    let epochs = scale.pick(3, 12);
    let mut opt = opt_by_name("adamax", 0.01, model.params.len())?;
    for _ in 0..epochs {
        let mut order: Vec<usize> = (0..n_train).collect();
        rng.shuffle(&mut order);
        let pool = order.clone();
        for chunk in order.chunks(batch) {
            let (idx, _real) = padded_chunk(chunk, &pool, batch);
            let mut seq = Vec::new();
            let mut tgt = Vec::new();
            for &i in &idx {
                seq.extend_from_slice(ds.observed(i, t_len));
                tgt.extend_from_slice(ds.target(i, t_len, t_out));
            }
            model.step(&seq, &tgt)?;
            opt.step(&mut model.params.value, &model.params.grad);
        }
    }
    let test_idx: Vec<usize> = (n_total..n_total + n_test).collect();
    padded_test_mse(&ds, &test_idx, batch, t_len, t_out, latent_model.obs, |seq| {
        model.predict(seq)
    })
}

/// Table 4 — latent-ODE MSE × training-data fraction × method.
pub fn table4(scale: Scale, seed: u64) -> Result<Json> {
    let engine = Rc::new(Engine::from_env()?);
    let fracs = [0.1, 0.2, 0.5];
    let mut table = Table::new(
        "Table 4: hopper test MSE ×0.01 (lower is better)",
        &["% data", "rnn", "gru", "adjoint", "naive", "aca", "mali"],
    );
    let mut rows = Vec::new();
    for &frac in &fracs {
        let mut cells = vec![format!("{:.0}%", frac * 100.0)];
        for key in ["rnn", "gru"] {
            let mse = seq_baseline_mse(&engine, key, frac, scale, seed)?;
            cells.push(format!("{:.2}", mse * 100.0));
            rows.push(Json::obj(vec![
                ("method", Json::Str(key.into())),
                ("frac", Json::Num(frac)),
                ("mse", Json::Num(mse)),
            ]));
        }
        for method in ["adjoint", "naive", "aca", "mali"] {
            let mse = latent_ode_mse(&engine, method, 1.0, frac, scale, seed)?;
            cells.push(format!("{:.2}", mse * 100.0));
            rows.push(Json::obj(vec![
                ("method", Json::Str(method.into())),
                ("frac", Json::Num(frac)),
                ("mse", Json::Num(mse)),
            ]));
            log(
                Level::Info,
                &format!("table4 {method} @ {frac}: mse {mse:.5}"),
            );
        }
        table.row(&cells);
    }
    table.print();
    Ok(report::summary(rows, vec![("seed", Json::Num(seed as f64))]))
}

/// Train a Neural CDE with one gradient method; returns test accuracy.
fn cde_accuracy(
    engine: &Rc<Engine>,
    method: &str,
    eta: f64,
    scale: Scale,
    seed: u64,
) -> Result<f64> {
    let mut rng = Rng::new(seed);
    let mut model = NeuralCde::new(engine.clone(), &mut rng)?;
    let n_train = scale.pick(4, 12) * model.batch;
    let n_test = scale.pick(1, 3) * model.batch;
    let ds = speech::generate(&SpeechSpec::commands10(), n_train + n_test, seed + 21);
    let (train, test) = ds.split(n_test);

    // paper App. B.2: fixed stepsize 0.25, 100 epochs, lr 0.004 — scaled
    let epochs = scale.pick(4, 20);
    let use_seminorm = method == "seminorm";
    let grad_name = if use_seminorm { "adjoint-seminorm" } else { method };
    let solver = crate::solvers::by_name_eta(solver_for(method), eta)?;
    let grad = crate::grad::by_name(grad_name)?;
    let spec = IvpSpec::fixed(0.0, 1.0, 0.25);

    let mut opt_stem = opt_by_name("adam", 0.01, model.stem.len())?;
    let mut opt_head = opt_by_name("adam", 0.01, model.head.len())?;
    let mut opt_dyn = opt_by_name("adam", 0.01, model.dynamics.param_dim())?;

    for _ in 0..epochs {
        let mut order: Vec<usize> = (0..train.len()).collect();
        rng.shuffle(&mut order);
        let pool = order.clone();
        for chunk in order.chunks(model.batch) {
            let (idx, _real) = padded_chunk(chunk, &pool, model.batch);
            let (ctx, x0, y1h, _) = model.prepare_batch(&train, &idx);
            let cfg = SolveCfg {
                solver: &*solver,
                spec: spec.clone(),
                method: &*grad,
            };
            model.step(ctx, &x0, &y1h, &cfg)?;
            opt_stem.step(&mut model.stem.value, &model.stem.grad);
            opt_head.step(&mut model.head.value, &model.head.grad);
            let mut theta = model.dynamics.params().to_vec();
            opt_dyn.step(&mut theta, &model.dyn_grad);
            model.dynamics.set_params(&theta);
        }
    }

    let mut meter = AccuracyMeter::default();
    let all: Vec<usize> = (0..test.len()).collect();
    for chunk in all.chunks(model.batch) {
        // pad the trailing batch; score only its real rows
        let (idx, real) = padded_chunk(chunk, &all, model.batch);
        let (ctx, x0, _, y) = model.prepare_batch(&test, &idx);
        let cfg = SolveCfg {
            solver: &*solver,
            spec: spec.clone(),
            method: &*grad,
        };
        let logits = model.predict(ctx, &x0, &cfg)?;
        let pred = crate::tensor::argmax_rows(&logits, model.batch, model.classes);
        meter.add(&pred[..real], &y[..real]);
    }
    Ok(meter.value())
}

/// Table 5 — Neural-CDE accuracy per gradient method.
pub fn table5(scale: Scale, seed: u64) -> Result<Json> {
    let engine = Rc::new(Engine::from_env()?);
    let methods = ["adjoint", "seminorm", "naive", "aca", "mali"];
    let mut table = Table::new(
        "Table 5: synthetic speech-command test accuracy",
        &["method", "accuracy"],
    );
    let mut rows = Vec::new();
    for method in methods {
        let acc = cde_accuracy(&engine, method, 1.0, scale, seed)?;
        table.row(&[method.into(), format!("{acc:.3}")]);
        rows.push(Json::obj(vec![
            ("method", Json::Str(method.into())),
            ("acc", Json::Num(acc)),
        ]));
        log(Level::Info, &format!("table5 {method}: acc {acc:.3}"));
    }
    table.print();
    Ok(report::summary(rows, vec![("seed", Json::Num(seed as f64))]))
}

/// Table 7 — damped-MALI η ablation on the CDE accuracy and latent-ODE MSE.
pub fn table7(scale: Scale, seed: u64) -> Result<Json> {
    let engine = Rc::new(Engine::from_env()?);
    let etas = [1.0, 0.95, 0.9, 0.85];
    let mut table = Table::new(
        "Table 7: damped MALI, η ablation",
        &["eta", "cde acc", "latent mse ×0.01 (10%)", "latent mse ×0.01 (20%)"],
    );
    let mut rows = Vec::new();
    for &eta in &etas {
        let acc = cde_accuracy(&engine, "mali", eta, scale, seed)?;
        let mse10 = latent_ode_mse(&engine, "mali", eta, 0.1, scale, seed)?;
        let mse20 = latent_ode_mse(&engine, "mali", eta, 0.2, scale, seed)?;
        table.row(&[
            format!("{eta}"),
            format!("{acc:.3}"),
            format!("{:.2}", mse10 * 100.0),
            format!("{:.2}", mse20 * 100.0),
        ]);
        rows.push(Json::obj(vec![
            ("eta", Json::Num(eta)),
            ("cde_acc", Json::Num(acc)),
            ("mse10", Json::Num(mse10)),
            ("mse20", Json::Num(mse20)),
        ]));
    }
    table.print();
    Ok(report::summary(rows, vec![("seed", Json::Num(seed as f64))]))
}

/// Expose the speech corpus type for the bench wrappers.
pub fn speech_corpus(n: usize, seed: u64) -> SequenceDataset {
    speech::generate(&SpeechSpec::commands10(), n, seed)
}

/// E8 **native** — the Table 4 protocol on the artifact-free latent ODE
/// ([`crate::models::native::NativeLatentOde`]): hopper sequences, linear
/// encoder/decoder on the host, fused time-concat MLP dynamics, all four
/// gradient methods.  Runs under plain `cargo test` / CI with no PJRT.
pub fn table4_native(scale: Scale, seed: u64) -> Result<Json> {
    use crate::models::native::NativeLatentOde;

    let (t_len, t_out, latent) = (6, 3, 8);
    let batch = 8;
    let n_train = scale.pick(24, 160);
    let n_test = scale.pick(8, 32);
    let ds = hopper::generate(n_train + n_test, t_len, t_out, 3.0, seed + 11);
    let epochs = scale.pick(3, 20);

    let mut table = Table::new(
        "E6 native: fused-MLP latent ODE, hopper test MSE ×0.01 (no artifacts)",
        &["method", "mse ×0.01", "f evals"],
    );
    let mut rows = Vec::new();
    for method in ["adjoint", "naive", "aca", "mali"] {
        let mut rng = Rng::new(seed);
        let mut model = NativeLatentOde::new(hopper::OBS_DIM, t_len, t_out, latent, &[16], &mut rng);
        let solver = crate::solvers::by_name(solver_for(method))?;
        let grad = crate::grad::by_name(method)?;
        let spec = IvpSpec::fixed(0.0, 1.0, 0.25);
        let mut opt_enc = opt_by_name("adamax", 0.01, model.enc.len())?;
        let mut opt_dec = opt_by_name("adamax", 0.01, model.dec.len())?;
        let mut opt_dyn = opt_by_name("adamax", 0.01, model.dynamics.param_dim())?;
        let mut f_evals = 0u64;
        for _ in 0..epochs {
            let mut order: Vec<usize> = (0..n_train).collect();
            rng.shuffle(&mut order);
            // the native model takes any batch size — no padding needed
            for chunk in order.chunks(batch) {
                let mut seq = Vec::new();
                let mut tgt = Vec::new();
                for &i in chunk {
                    seq.extend_from_slice(ds.observed(i, t_len));
                    tgt.extend_from_slice(ds.target(i, t_len, t_out));
                }
                let cfg = SolveCfg {
                    solver: &*solver,
                    spec: spec.clone(),
                    method: &*grad,
                };
                let out = model.step(&seq, &tgt, &cfg)?;
                f_evals += out.f_evals;
                opt_enc.step(&mut model.enc.value, &model.enc.grad);
                opt_dec.step(&mut model.dec.value, &model.dec.grad);
                let mut theta = model.dynamics.params().to_vec();
                opt_dyn.step(&mut theta, &model.dyn_grad);
                model.dynamics.set_params(&theta);
            }
        }
        let cfg = SolveCfg {
            solver: &*solver,
            spec,
            method: &*grad,
        };
        let mut sse = 0.0f64;
        let mut n_elems = 0usize;
        let test_idx: Vec<usize> = (n_train..n_train + n_test).collect();
        for chunk in test_idx.chunks(batch) {
            let mut seq = Vec::new();
            let mut tgt = Vec::new();
            for &i in chunk {
                seq.extend_from_slice(ds.observed(i, t_len));
                tgt.extend_from_slice(ds.target(i, t_len, t_out));
            }
            let preds = model.predict(&seq, chunk.len(), &cfg)?;
            for (p, t) in preds.iter().zip(&tgt) {
                let d = (p - t) as f64;
                sse += d * d;
            }
            n_elems += tgt.len();
        }
        let mse = sse / n_elems.max(1) as f64;
        table.row(&[
            method.into(),
            format!("{:.2}", mse * 100.0),
            f_evals.to_string(),
        ]);
        rows.push(Json::obj(vec![
            ("method", Json::Str(method.into())),
            ("mse", Json::Num(mse)),
            ("f_evals", Json::Num(f_evals as f64)),
        ]));
        log(Level::Info, &format!("table4-native {method}: mse {mse:.5}"));
    }
    table.print();
    Ok(report::summary(
        rows,
        vec![
            ("seed", Json::Num(seed as f64)),
            ("native", Json::Bool(true)),
        ],
    ))
}

#[cfg(test)]
mod native_tests {
    use super::*;

    /// E6 native runs end-to-end with no artifacts and no PJRT — the
    /// tier-1 guarantee the HLO-backed table4 cannot give.
    #[test]
    fn e8_native_smoke() {
        let summary = table4_native(Scale::Quick, 5).unwrap();
        let s = summary.dump();
        for method in ["mali", "aca", "naive", "adjoint"] {
            assert!(s.contains(method), "method {method} missing from summary");
        }
    }
}
