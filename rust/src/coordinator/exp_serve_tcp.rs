//! E13 — the TCP front-end under load: client-observed latency through
//! the binary transport vs the in-process E12 baseline, plus the two
//! multi-process halves (`mali serve-tcp` / `mali serve-client-bench`)
//! that CI runs against each other over loopback.
//!
//! The in-process grid (`mali run serve_tcp` → `runs/serve_tcp.json`):
//!
//! * **inproc** — closed-loop clients calling [`Server::submit`]
//!   directly: the E12-style baseline every transport number is
//!   compared against;
//! * **tcp-w1** — one request in flight per connection: isolates the
//!   per-request cost of framing + the socket hop;
//! * **tcp-w8** — eight pipelined requests per connection: out-of-order
//!   completions keep the coalescing batcher fed, so the socket hop
//!   amortizes away;
//! * **tcp-w8-churn** — same, but clients hang up and reconnect between
//!   bursts (connection churn: handshake + OPEN_CLASS re-interning on
//!   every reconnect).
//!
//! The `--overload` client mode drives a burst larger than the server
//! queue and checks **exact shed accounting**: every queue shed surfaces
//! as exactly one RETRY frame, client-observed RETRY count equals the
//! server's `retries_sent` delta equals the queue's `shed_total` delta,
//! and the queue depth never exceeds its capacity.

use super::exp_serve::{client_z0, standard_registry, N_Z, T_END};
use super::Scale;
use crate::cli::Args;
use crate::serve::transport::{
    Backoff, Bridge, ClientEvent, ResponseFrame, TcpClient, TcpFront, TransportConfig,
};
use crate::serve::{RequestClass, Server, ServerConfig};
use crate::solvers::integrate::{ObsGrid, StepMode};
use crate::util::bench::{quantile, Table};
use crate::util::json::Json;
use crate::util::logging::{log, Level};
use crate::util::pool;
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Context, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// The fixed-step request class both processes agree on (class id 0).
fn bench_class(h: f64) -> Result<RequestClass> {
    RequestClass::new(
        "lin8",
        "alf",
        N_Z,
        0.0,
        T_END,
        StepMode::Fixed { h },
        ObsGrid::none(),
    )
}

fn start_server(queue_capacity: usize, workers: usize) -> Arc<Server> {
    Arc::new(Server::start(
        Arc::new(standard_registry()),
        ServerConfig {
            queue_capacity,
            max_batch: 32,
            max_wait: Duration::from_micros(500),
            workers,
            shards: 1,
        },
    ))
}

/// Take the server back out of the `Arc` once the front (and its
/// connection threads) have released their clones.
fn unwrap_server(mut server: Arc<Server>) -> Server {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        match Arc::try_unwrap(server) {
            Ok(s) => return s,
            Err(back) => {
                assert!(
                    Instant::now() < deadline,
                    "server still shared after transport shutdown"
                );
                server = back;
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }
}

struct Cell {
    latencies_s: Vec<f64>,
    wall_s: f64,
    retries: u64,
    reconnects: u64,
}

/// In-process baseline: closed-loop clients on [`Server::submit`].
fn run_inproc(clients: usize, requests: usize, seed: u64, h: f64) -> Result<Cell> {
    let server = start_server(1024, pool::num_threads().clamp(1, 2));
    let class = Arc::new(bench_class(h)?);
    let mut root = Rng::new(seed);
    let rngs: Vec<Rng> = (0..clients).map(|i| root.fork(i as u64)).collect();
    let t0 = Instant::now();
    let per_client: Vec<Result<Vec<f64>>> = pool::par_map(&rngs, |rng| {
        let mut rng = rng.clone();
        let mut lats = Vec::with_capacity(requests);
        for _ in 0..requests {
            let z0 = client_z0(&mut rng);
            let t = Instant::now();
            let resp = loop {
                match server.submit(&class, &z0) {
                    Ok(handle) => break handle.wait()?,
                    Err(crate::serve::SubmitError::Overloaded { .. }) => {
                        std::thread::sleep(Duration::from_micros(200));
                    }
                    Err(e) => bail!("submit failed: {e}"),
                }
            };
            lats.push(t.elapsed().as_secs_f64());
            ensure!(resp.n_accepted > 0, "malformed response");
        }
        Ok(lats)
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let metrics = unwrap_server(server).shutdown();
    ensure!(metrics.failed == 0, "{} serve failures", metrics.failed);
    let mut latencies_s = Vec::new();
    for r in per_client {
        latencies_s.extend(r?);
    }
    Ok(Cell {
        latencies_s,
        wall_s,
        retries: 0,
        reconnects: 0,
    })
}

/// One client's windowed (pipelined) closed loop over a live
/// connection: up to `window` requests in flight, completions reaped
/// out of order, RETRY honored with backoff.  Returns latencies +
/// retries.
fn drive_connection(
    cl: &mut TcpClient,
    rng: &mut Rng,
    requests: usize,
    window: usize,
    next_req: &mut u64,
    backoff: &mut Backoff,
    lats: &mut Vec<f64>,
) -> Result<u64> {
    struct Slot {
        req_id: u64,
        t0: Instant,
        z0: Vec<f32>,
        busy: bool,
    }
    let mut slots: Vec<Slot> = (0..window.max(1))
        .map(|_| Slot {
            req_id: 0,
            t0: Instant::now(),
            z0: vec![0.0; N_Z],
            busy: false,
        })
        .collect();
    let mut sent = 0usize;
    let mut done = 0usize;
    let mut retries = 0u64;
    let mut resp = ResponseFrame::default();
    while done < requests {
        for s in slots.iter_mut() {
            if !s.busy && sent < requests {
                for v in s.z0.iter_mut() {
                    *v = rng.range(-1.0, 1.0) as f32;
                }
                s.req_id = *next_req;
                *next_req += 1;
                s.busy = true;
                s.t0 = Instant::now();
                cl.submit(s.req_id, 0, &s.z0)?;
                sent += 1;
            }
        }
        match cl.next_event(&mut resp)? {
            ClientEvent::Response => {
                let s = slots
                    .iter_mut()
                    .find(|s| s.busy && s.req_id == resp.req_id)
                    .with_context(|| format!("response for unknown req {}", resp.req_id))?;
                lats.push(s.t0.elapsed().as_secs_f64());
                ensure!(resp.n_accepted > 0, "malformed response");
                s.busy = false;
                done += 1;
                backoff.reset();
            }
            ClientEvent::Retry {
                req_id,
                backoff: hint,
                draining,
            } => {
                ensure!(!draining, "server started draining mid-bench");
                let s = slots
                    .iter_mut()
                    .find(|s| s.busy && s.req_id == req_id)
                    .with_context(|| format!("RETRY for unknown req {req_id}"))?;
                retries += 1;
                std::thread::sleep(backoff.next_delay(hint));
                cl.submit(s.req_id, 0, &s.z0)?;
            }
            ClientEvent::ReqErr { req_id, msg } => bail!("request {req_id} failed: {msg}"),
            other => bail!("unexpected frame mid-load: {other:?}"),
        }
    }
    Ok(retries)
}

/// TCP cell: C connections × R requests each against `addr`, window
/// `window`; `churn_every > 0` hangs up and reconnects between bursts.
fn run_tcp_clients(
    addr: &str,
    clients: usize,
    requests: usize,
    seed: u64,
    window: usize,
    churn_every: usize,
) -> Result<Cell> {
    let class = bench_class(0.01)?;
    let mut root = Rng::new(seed);
    let rngs: Vec<Rng> = (0..clients).map(|i| root.fork(i as u64)).collect();
    let addr = addr.to_string();
    let t0 = Instant::now();
    let per_client: Vec<Result<(Vec<f64>, u64, u64)>> = pool::par_map(&rngs, |rng| {
        let mut rng = rng.clone();
        let mut lats = Vec::with_capacity(requests);
        let mut retries = 0u64;
        let mut reconnects = 0u64;
        let mut next_req = 1u64;
        let mut backoff = Backoff::new(
            Duration::from_micros(100),
            Duration::from_millis(20),
            rng.next_u64(),
        );
        let chunk = if churn_every == 0 { requests } else { churn_every.max(1) };
        let mut left = requests;
        while left > 0 {
            let burst = left.min(chunk);
            let mut cl = TcpClient::connect(addr.as_str())?;
            cl.open_class(0, &class)?;
            retries += drive_connection(
                &mut cl,
                &mut rng,
                burst,
                window,
                &mut next_req,
                &mut backoff,
                &mut lats,
            )?;
            cl.goodbye()?;
            left -= burst;
            if left > 0 {
                reconnects += 1;
            }
        }
        Ok((lats, retries, reconnects))
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let mut latencies_s = Vec::new();
    let mut retries = 0u64;
    let mut reconnects = 0u64;
    for r in per_client {
        let (lats, rt, rc) = r?;
        latencies_s.extend(lats);
        retries += rt;
        reconnects += rc;
    }
    Ok(Cell {
        latencies_s,
        wall_s,
        retries,
        reconnects,
    })
}

fn cell_row(table: &mut Table, config: &str, cell: &Cell) -> Json {
    let n = cell.latencies_s.len();
    let p50 = quantile(&cell.latencies_s, 0.50) * 1e3;
    let p99 = quantile(&cell.latencies_s, 0.99) * 1e3;
    let mean = cell.latencies_s.iter().sum::<f64>() / n.max(1) as f64 * 1e3;
    let rps = n as f64 / cell.wall_s.max(1e-12);
    table.row(&[
        config.to_string(),
        format!("{rps:.0}"),
        format!("{p50:.3}"),
        format!("{p99:.3}"),
        format!("{mean:.3}"),
        cell.retries.to_string(),
        cell.reconnects.to_string(),
    ]);
    Json::obj(vec![
        ("config", Json::Str(config.into())),
        ("requests", Json::Num(n as f64)),
        ("wall_s", Json::Num(cell.wall_s)),
        ("p50_ms", Json::Num(p50)),
        ("p99_ms", Json::Num(p99)),
        ("mean_ms", Json::Num(mean)),
        ("requests_per_sec", Json::Num(rps)),
        ("retries", Json::Num(cell.retries as f64)),
        ("reconnects", Json::Num(cell.reconnects as f64)),
    ])
}

/// E13 runner (`mali run serve_tcp`): in-process baseline vs the TCP
/// path at window 1, window 8, and window 8 with connection churn.
pub fn serve_tcp_bench(scale: Scale, seed: u64) -> Result<Json> {
    let clients = scale.pick(4, 8);
    let requests = scale.pick(50, 400);
    let workers = pool::num_threads().clamp(1, 2);
    let mut table = Table::new(
        "E13: TCP front-end vs in-process serving (client-observed latency)",
        &["config", "req/s", "p50 ms", "p99 ms", "mean ms", "retries", "reconnects"],
    );
    let mut rows = Vec::new();

    let inproc = run_inproc(clients, requests, seed, 0.01)?;
    rows.push(cell_row(&mut table, "inproc", &inproc));

    let churn = (requests / 4).max(1);
    for (config, window, churn_every) in [
        ("tcp-w1", 1usize, 0usize),
        ("tcp-w8", 8, 0),
        ("tcp-w8-churn", 8, churn),
    ] {
        let server = start_server(1024, workers);
        let front = TcpFront::bind(
            "127.0.0.1:0",
            server.clone() as Arc<dyn Bridge>,
            TransportConfig::default(),
        )?;
        let addr = front.local_addr().to_string();
        let cell = run_tcp_clients(&addr, clients, requests, seed, window, churn_every)?;
        let outcome = front.shutdown(Duration::from_secs(10));
        ensure!(outcome.flushed, "drain left responses unflushed");
        let metrics = unwrap_server(server).shutdown();
        ensure!(metrics.failed == 0, "{} serve failures", metrics.failed);
        ensure!(
            metrics.requests as usize == clients * requests,
            "{config}: served {} of {}",
            metrics.requests,
            clients * requests
        );
        rows.push(cell_row(&mut table, config, &cell));
    }
    table.print();
    Ok(crate::coordinator::report::summary(
        rows,
        vec![
            ("bench", Json::Str("serve_tcp".into())),
            ("seed", Json::Num(seed as f64)),
            ("clients", Json::Num(clients as f64)),
            ("requests_per_client", Json::Num(requests as f64)),
            ("workers", Json::Num(workers as f64)),
            ("n_z", Json::Num(N_Z as f64)),
        ],
    ))
}

// ---------------------------------------------------------------------------
// Multi-process halves (CI's loopback E13 leg)
// ---------------------------------------------------------------------------

/// `mali serve-tcp`: stand up the standard registry behind the TCP
/// front and serve until a client sends SHUTDOWN, then drain and exit.
pub fn serve_tcp_cmd(args: &Args) -> Result<()> {
    let addr = args.opt_or("addr", "127.0.0.1:0");
    let server = start_server(
        args.usize_opt("queue-cap", 256),
        args.usize_opt("workers", pool::num_threads().clamp(1, 2)),
    );
    let cfg = TransportConfig {
        max_inflight: args.usize_opt("max-inflight", 1024),
        model_quota: args.usize_opt("model-quota", 0),
        ..TransportConfig::default()
    };
    let front = TcpFront::bind(addr.as_str(), server.clone() as Arc<dyn Bridge>, cfg)?;
    let local = front.local_addr();
    println!("serve-tcp listening on {local}");
    if let Some(path) = args.opt("port-file") {
        // written atomically-enough for a local runner: the readers in
        // ci poll for the file's existence
        let tmp = format!("{path}.tmp");
        std::fs::write(&tmp, format!("{local}\n")).context("write port file")?;
        std::fs::rename(&tmp, path).context("publish port file")?;
    }
    while !front.shutdown_requested() {
        std::thread::sleep(Duration::from_millis(20));
    }
    log(Level::Info, "SHUTDOWN received; draining");
    let outcome = front.shutdown(Duration::from_secs(10));
    let metrics = unwrap_server(server).shutdown();
    println!(
        "serve-tcp drained (flushed = {}, conns closed = {})\n{}",
        outcome.flushed,
        outcome.forced_conns,
        metrics.to_json().dump()
    );
    ensure!(outcome.flushed, "drain deadline hit with responses unflushed");
    Ok(())
}

fn resolve_addr(args: &Args) -> Result<String> {
    if let Some(a) = args.opt("addr") {
        return Ok(a.to_string());
    }
    if let Some(path) = args.opt("port-file") {
        let s = std::fs::read_to_string(path).context("read port file")?;
        return Ok(s.trim().to_string());
    }
    bail!("serve-client-bench needs --addr host:port or --port-file <path>")
}

/// `mali serve-client-bench`: drive a running `mali serve-tcp` from a
/// separate process.  Default mode records client-observed latency into
/// `runs/serve_tcp.json`; `--overload` floods the queue and checks
/// exact shed accounting; `--shutdown` tells the server to drain+exit
/// afterwards.
pub fn client_bench_cmd(args: &Args) -> Result<()> {
    let addr = resolve_addr(args)?;
    let seed = args
        .opt("seed")
        .and_then(|s| s.parse().ok())
        .unwrap_or(0u64);
    if args.flag("overload") {
        run_overload(args, &addr, seed)?;
    } else {
        let clients = args.usize_opt("clients", 4);
        let requests = args.usize_opt("requests", 50);
        let window = args.usize_opt("window", 8);
        let churn = args.usize_opt("churn", 0);
        let cell = run_tcp_clients(&addr, clients, requests, seed, window, churn)?;
        let mut table = Table::new(
            "serve-client-bench: client-observed latency over TCP",
            &["config", "req/s", "p50 ms", "p99 ms", "mean ms", "retries", "reconnects"],
        );
        let row = cell_row(&mut table, &format!("tcp-w{window}"), &cell);
        table.print();
        let summary = crate::coordinator::report::summary(
            vec![row],
            vec![
                ("bench", Json::Str("serve_tcp".into())),
                ("mode", Json::Str("external".into())),
                ("seed", Json::Num(seed as f64)),
                ("clients", Json::Num(clients as f64)),
                ("requests_per_client", Json::Num(requests as f64)),
            ],
        );
        crate::coordinator::report::write_summary(
            &args.opt_or("runs", "runs"),
            "serve_tcp",
            &summary,
        )?;
    }
    if args.flag("shutdown") {
        TcpClient::connect(addr.as_str())?.shutdown_server()?;
        println!("server acknowledged shutdown");
    }
    Ok(())
}

/// Induced overload with exact shed accounting: a burst wider than the
/// server queue, every refusal audited.  Asserts (under
/// `--assert-shed`) that client-observed RETRY count == the server's
/// `retries_sent` delta == the queue's `shed_total` delta, and that the
/// queue depth never exceeds capacity.
fn run_overload(args: &Args, addr: &str, seed: u64) -> Result<()> {
    let mut health_cl = TcpClient::connect(addr).context("health connection")?;
    let h0 = health_cl.health(1)?;
    ensure!(h0.ready, "server not ready");
    // the burst must stay under the server's per-connection in-flight
    // cap, otherwise conn-cap RETRYs mix into the queue-shed accounting
    let burst = args
        .usize_opt("burst", (h0.queue_capacity as usize).saturating_mul(8).min(512))
        .max(16);
    // slower requests than the E13 grid (10× the steps) so the reader
    // outpaces the workers and the queue genuinely sheds
    let class = bench_class(0.001)?;
    let mut cl = TcpClient::connect(addr).context("load connection")?;
    cl.open_class(0, &class)?;
    let mut rng = Rng::new(seed);
    let mut backoff = Backoff::new(
        Duration::from_micros(200),
        Duration::from_millis(50),
        seed ^ 0x5eed,
    );
    let mut lats = Vec::with_capacity(burst);
    let mut next_req = 1u64;
    let retries = drive_connection(
        &mut cl,
        &mut rng,
        burst,
        burst,
        &mut next_req,
        &mut backoff,
        &mut lats,
    )?;
    // depth audit while the tail is still draining, then the final books
    let mid = health_cl.health(2)?;
    ensure!(
        mid.queue_depth <= mid.queue_capacity,
        "queue depth {} exceeded capacity {}",
        mid.queue_depth,
        mid.queue_capacity
    );
    cl.goodbye()?;
    let h1 = health_cl.health(3)?;
    ensure!(
        h1.queue_depth <= h1.queue_capacity,
        "queue depth {} exceeded capacity {}",
        h1.queue_depth,
        h1.queue_capacity
    );
    let retry_delta = h1.retries_sent - h0.retries_sent;
    let shed_delta = h1.shed_total - h0.shed_total;
    println!(
        "overload: burst {burst}, served {}, client retries {retries}, \
         server retries_sent Δ {retry_delta}, queue sheds Δ {shed_delta}",
        lats.len()
    );
    if args.flag("assert-shed") {
        ensure!(retries > 0, "overload produced no sheds; raise --burst");
        ensure!(
            retries == retry_delta,
            "client saw {retries} RETRY frames but the server sent {retry_delta}"
        );
        ensure!(
            retry_delta == shed_delta,
            "retries_sent Δ {retry_delta} != shed Δ {shed_delta}: \
             a shed was dropped or double-answered"
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The in-process E13 cells run end to end at a tiny scale: the TCP
    /// path serves every request and the drain flushes clean.
    #[test]
    fn tcp_bench_smoke() {
        let server = start_server(256, 1);
        let front = TcpFront::bind(
            "127.0.0.1:0",
            server.clone() as Arc<dyn Bridge>,
            TransportConfig::default(),
        )
        .unwrap();
        let addr = front.local_addr().to_string();
        // window 4, churn every 3 requests: exercises pipelining and
        // reconnects in one pass
        let cell = run_tcp_clients(&addr, 2, 7, 11, 4, 3).unwrap();
        assert_eq!(cell.latencies_s.len(), 14);
        assert_eq!(cell.reconnects, 2 * 2, "7 requests / churn 3 → 2 reconnects each");
        let outcome = front.shutdown(Duration::from_secs(5));
        assert!(outcome.flushed);
        let metrics = unwrap_server(server).shutdown();
        assert_eq!(metrics.requests, 14);
        assert_eq!(metrics.failed, 0);
    }
}
