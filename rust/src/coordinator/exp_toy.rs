//! E1 / E10 / E11 — the toy-problem experiments:
//!
//! * **Fig. 4 (a,b)**: error of `dL/dz₀` and `dL/dα` vs integration time T
//!   for naive / adjoint / ACA / MALI on `dz/dt = αz`, `L = z(T)²`
//!   (analytic gradients from paper Eq. 7).
//! * **Fig. 4 (c)**: retained memory vs error tolerance — naive/ACA grow,
//!   adjoint/MALI constant.
//! * **Table 1**: measured computation / memory / graph-depth accounting
//!   against the paper's formulas.
//! * **App. Fig. 1**: damped-ALF A-stability regions.

use super::Scale;
use crate::grad::batch_driver::grad_batched;
use crate::grad::{by_name as grad_by_name, IvpSpec, SquareLoss};
use crate::solvers::batch::BatchSpec;
use crate::solvers::dynamics::{LinearToy, MlpDynamics};
use crate::solvers::stability::{ascii_region, stability_region};
use crate::solvers::{by_name as solver_by_name, by_name_eta};
use crate::util::bench::{print_series, Table};
use crate::util::json::Json;
use crate::util::mem::{fmt_bytes, MemTracker};
use anyhow::Result;

pub const METHODS: [&str; 5] = ["naive", "adjoint", "aca", "mali", "symplectic"];

/// The solver axis of the method-comparison grid: an adaptive RK pair, the
/// paper's ALF, and the 4th-order reversible composition.
pub const GRID_SOLVERS: [&str; 3] = ["heun-euler", "alf", "reversible4"];

/// Solver each gradient method uses on the toy problem: MALI needs ALF
/// (the symplectic adjoint also gets its symplectic reverse sweep there);
/// the others use the paper's default adaptive RK (Dopri5 via torchdiffeq).
fn solver_for(method: &str) -> &'static str {
    match method {
        "mali" | "symplectic" => "alf",
        _ => "dopri5",
    }
}

/// Whether a `GradMethod` × `Solver` pair is runnable: MALI reconstructs
/// the trajectory through ψ⁻¹, so it needs an invertible solver.
pub fn supports(method: &str, solver: &str) -> bool {
    method != "mali" || matches!(solver, "alf" | "reversible4")
}

/// Fig. 4 (a,b,c).  Returns the summary rows for `runs/fig4.json`.
pub fn fig4(scale: Scale, _seed: u64) -> Result<Json> {
    let alpha = -0.3f64; // contracting dynamics so long T stays bounded
    let z0 = vec![1.0f32, 0.5, -0.8, 1.5];
    let ts: Vec<f64> = scale
        .pick(vec![1.0, 5.0, 10.0, 20.0], vec![1.0, 2.0, 5.0, 10.0, 20.0, 40.0])
        .clone();
    let (rtol, atol) = (1e-5, 1e-6); // the paper's Fig. 4 tolerances

    // ---- panels (a), (b): gradient error vs T ---------------------------
    let mut err_z0: Vec<(&str, Vec<f64>)> = Vec::new();
    let mut err_alpha: Vec<(&str, Vec<f64>)> = Vec::new();
    let mut rows = Vec::new();
    for method in METHODS {
        let m = grad_by_name(method)?;
        let solver = solver_by_name(solver_for(method))?;
        let mut ez = Vec::new();
        let mut ea = Vec::new();
        for &t_end in &ts {
            // batch-first path: each component of z0 is one sample of the
            // scalar toy ODE (B = 4, n_z = 1) with its own step controller;
            // dL/dα sums over the batch, matching Eq. 7's summed analytic
            // gradient (analytic_grads reads only α and the passed z0).
            let toy = LinearToy::new(alpha, 1);
            let (gz_ref, ga_ref) = toy.analytic_grads(&z0, t_end);
            let spec = IvpSpec::adaptive(0.0, t_end, rtol, atol);
            let bspec = BatchSpec::new(z0.len(), 1);
            let tracker = MemTracker::new();
            let res =
                grad_batched(&*m, &toy, &*solver, &spec, &z0, &bspec, &SquareLoss, tracker)?;
            // relative error: the true gradients scale as e^{2αT}, so the
            // absolute error alone would just trace that envelope
            let ref_norm: f64 = gz_ref.iter().map(|&g| (g as f64).abs()).sum();
            let e_z: f64 = res
                .grad_z0
                .iter()
                .zip(&gz_ref)
                .map(|(a, b)| ((a - b) as f64).abs())
                .sum::<f64>()
                / ref_norm.max(1e-30);
            let e_a = (res.grad_theta[0] as f64 - ga_ref).abs() / ga_ref.abs().max(1e-30);
            ez.push(e_z.max(1e-16));
            ea.push(e_a.max(1e-16));
            rows.push(Json::obj(vec![
                ("method", Json::Str(method.into())),
                ("T", Json::Num(t_end)),
                ("err_dz0", Json::Num(e_z)),
                ("err_dalpha", Json::Num(e_a)),
            ]));
        }
        err_z0.push((method, ez));
        err_alpha.push((method, ea));
    }
    print_series("Fig 4(a): relative error in dL/dz0 vs T", "T", &ts, &err_z0);
    print_series("Fig 4(b): relative error in dL/dα vs T", "T", &ts, &err_alpha);

    // ---- panel (c): memory vs tolerance on an MLP Neural ODE -------------
    let tols: Vec<f64> = scale.pick(
        vec![1e-2, 1e-4, 1e-6],
        vec![1e-1, 1e-2, 1e-3, 1e-4, 1e-5, 1e-6, 1e-7],
    );
    let mut mem_series: Vec<(&str, Vec<f64>)> = Vec::new();
    for method in METHODS {
        let m = grad_by_name(method)?;
        let solver = solver_by_name(solver_for(method))?;
        let mut mems = Vec::new();
        for &tol in &tols {
            let mut rng = crate::util::rng::Rng::new(17);
            let mlp = MlpDynamics::new(16, 32, &mut rng);
            let mut z = vec![0.0f32; 16];
            rng.fill_uniform_sym(&mut z, 0.5);
            let spec = IvpSpec::adaptive(0.0, 5.0, tol, tol * 0.1);
            let tracker = MemTracker::new();
            let res = grad_batched(
                &*m,
                &mlp,
                &*solver,
                &spec,
                &z,
                &BatchSpec::new(1, 16),
                &SquareLoss,
                tracker,
            )?;
            mems.push(res.stats.peak_mem_bytes as f64);
            rows.push(Json::obj(vec![
                ("method", Json::Str(method.into())),
                ("tol", Json::Num(tol)),
                ("peak_mem_bytes", Json::Num(res.stats.peak_mem_bytes as f64)),
                ("n_steps", Json::Num(res.stats.fwd.n_accepted as f64)),
            ]));
        }
        mem_series.push((method, mems));
    }
    print_series(
        "Fig 4(c): retained memory (bytes) vs tolerance",
        "tol",
        &tols,
        &mem_series,
    );

    // ---- method-comparison grid: five protocols × three solvers ----------
    //
    // One T on the toy problem per supported (method, solver) pair — the
    // convergence/memory-law result the source paper doesn't have.  Rows
    // carry a "solver" key, so the canonical per-method rows above stay
    // first for the figure filters.
    let t_grid = 5.0;
    let mut grid_table = Table::new(
        "Fig 4 grid: gradient error by method × solver (T = 5)",
        &["method", "solver", "err_dz0", "err_dalpha"],
    );
    for method in METHODS {
        for sname in GRID_SOLVERS {
            if !supports(method, sname) {
                continue;
            }
            let m = grad_by_name(method)?;
            let solver = solver_by_name(sname)?;
            let toy = LinearToy::new(alpha, 1);
            let (gz_ref, ga_ref) = toy.analytic_grads(&z0, t_grid);
            let spec = IvpSpec::adaptive(0.0, t_grid, rtol, atol);
            let bspec = BatchSpec::new(z0.len(), 1);
            let tracker = MemTracker::new();
            let res =
                grad_batched(&*m, &toy, &*solver, &spec, &z0, &bspec, &SquareLoss, tracker)?;
            let ref_norm: f64 = gz_ref.iter().map(|&g| (g as f64).abs()).sum();
            let e_z: f64 = res
                .grad_z0
                .iter()
                .zip(&gz_ref)
                .map(|(a, b)| ((a - b) as f64).abs())
                .sum::<f64>()
                / ref_norm.max(1e-30);
            let e_a = (res.grad_theta[0] as f64 - ga_ref).abs() / ga_ref.abs().max(1e-30);
            grid_table.row(&[
                method.to_string(),
                sname.to_string(),
                format!("{e_z:.3e}"),
                format!("{e_a:.3e}"),
            ]);
            rows.push(Json::obj(vec![
                ("method", Json::Str(method.into())),
                ("solver", Json::Str(sname.into())),
                ("T", Json::Num(t_grid)),
                ("err_dz0", Json::Num(e_z)),
                ("err_dalpha", Json::Num(e_a)),
            ]));
        }
    }
    grid_table.print();

    // Headline checks the paper's figure makes visually:
    let mali_idx = METHODS.iter().position(|&m| m == "mali").unwrap();
    let adj_idx = METHODS.iter().position(|&m| m == "adjoint").unwrap();
    let naive_idx = METHODS.iter().position(|&m| m == "naive").unwrap();
    println!(
        "\nshape checks: MALI grad-err ≤ adjoint at max T: {} | MALI mem flat: {} | naive mem grows: {}",
        err_z0[mali_idx].1.last() <= err_z0[adj_idx].1.last(),
        mem_series[mali_idx].1.first() == mem_series[mali_idx].1.last(),
        mem_series[naive_idx].1.first() < mem_series[naive_idx].1.last(),
    );

    Ok(super::report::summary(
        rows,
        vec![
            ("alpha", Json::Num(alpha)),
            ("rtol", Json::Num(rtol)),
            ("atol", Json::Num(atol)),
        ],
    ))
}

/// Table 1: measured cost accounting per method on a mini-batch of MLP
/// problems, against the paper's formulas (N_z, N_f, N_t, m symbols
/// measured live).  Runs the batch-first path, so the memory law is
/// checked with `N_z → B·N_z`: per-sample adaptive control gives each row
/// its own `N_t`, and the table reports batch totals (`N_t` summed, `m`
/// the batch mean, graph depth the longest per-sample chain).
pub fn table1(scale: Scale, seed: u64) -> Result<Json> {
    let d = scale.pick(16, 64);
    let batch = scale.pick(4, 8);
    let mut rng = crate::util::rng::Rng::new(seed);
    let mlp = MlpDynamics::new(d, 2 * d, &mut rng);
    let bspec = BatchSpec::new(batch, d);
    let mut z0 = vec![0.0f32; bspec.flat_len()];
    rng.fill_uniform_sym(&mut z0, 0.5);
    let spec = IvpSpec::adaptive(0.0, 2.0, 1e-4, 1e-6);

    let mut table = Table::new(
        &format!("Table 1: empirical complexity per gradient method (B = {batch})"),
        &[
            "method", "f evals", "vjp evals", "N_t", "m", "peak mem", "graph depth",
        ],
    );
    let mut rows = Vec::new();
    let mut peak_by_method = std::collections::BTreeMap::new();
    for method in METHODS {
        let m = grad_by_name(method)?;
        // memory accounting is only comparable across solvers of the same
        // order: ALF is order 2, so the non-MALI methods run Heun–Euler
        let solver = solver_by_name(if method == "mali" { "alf" } else { "heun-euler" })?;
        let tracker = MemTracker::new();
        let res = grad_batched(&*m, &mlp, &*solver, &spec, &z0, &bspec, &SquareLoss, tracker)?;
        let s = &res.stats;
        table.row(&[
            method.to_string(),
            s.f_evals.to_string(),
            s.vjp_evals.to_string(),
            s.fwd.n_accepted.to_string(),
            format!("{:.2}", s.fwd.m()),
            fmt_bytes(s.peak_mem_bytes),
            s.graph_depth.to_string(),
        ]);
        peak_by_method.insert(method, s.peak_mem_bytes);
        rows.push(Json::obj(vec![
            ("method", Json::Str(method.into())),
            ("f_evals", Json::Num(s.f_evals as f64)),
            ("vjp_evals", Json::Num(s.vjp_evals as f64)),
            ("n_t", Json::Num(s.fwd.n_accepted as f64)),
            ("m", Json::Num(s.fwd.m())),
            ("peak_mem_bytes", Json::Num(s.peak_mem_bytes as f64)),
            ("graph_depth", Json::Num(s.graph_depth as f64)),
        ]));
    }
    table.print();

    // ---- method-comparison grid: the same accounting per solver ---------
    //
    // Rows carry a "solver" key so the canonical per-method rows above stay
    // first for the ordering filters.
    let mut grid_table = Table::new(
        "Table 1 grid: accounting by method × solver",
        &["method", "solver", "f evals", "vjp evals", "N_t", "peak mem"],
    );
    for method in METHODS {
        for sname in GRID_SOLVERS {
            if !supports(method, sname) {
                continue;
            }
            let m = grad_by_name(method)?;
            let solver = solver_by_name(sname)?;
            let tracker = MemTracker::new();
            let res =
                grad_batched(&*m, &mlp, &*solver, &spec, &z0, &bspec, &SquareLoss, tracker)?;
            let s = &res.stats;
            grid_table.row(&[
                method.to_string(),
                sname.to_string(),
                s.f_evals.to_string(),
                s.vjp_evals.to_string(),
                s.fwd.n_accepted.to_string(),
                fmt_bytes(s.peak_mem_bytes),
            ]);
            rows.push(Json::obj(vec![
                ("method", Json::Str(method.into())),
                ("solver", Json::Str(sname.into())),
                ("f_evals", Json::Num(s.f_evals as f64)),
                ("vjp_evals", Json::Num(s.vjp_evals as f64)),
                ("n_t", Json::Num(s.fwd.n_accepted as f64)),
                ("m", Json::Num(s.fwd.m())),
                ("peak_mem_bytes", Json::Num(s.peak_mem_bytes as f64)),
                ("graph_depth", Json::Num(s.graph_depth as f64)),
            ]));
        }
    }
    grid_table.print();
    // The paper's ordering: naive ≥ ACA > MALI ≈ adjoint in memory.
    println!(
        "ordering check (naive ≥ aca > mali, adjoint ≤ mali): {}",
        peak_by_method["naive"] >= peak_by_method["aca"]
            && peak_by_method["aca"] > peak_by_method["mali"]
            && peak_by_method["adjoint"] <= peak_by_method["mali"]
    );
    Ok(super::report::summary(
        rows,
        vec![
            ("d", Json::Num(d as f64)),
            ("batch", Json::Num(batch as f64)),
        ],
    ))
}

/// Appendix Fig. 1: damped-ALF stability-region areas + ASCII renders.
pub fn fig_a1(scale: Scale, _seed: u64) -> Result<Json> {
    let n = scale.pick(60, 240);
    let etas = [0.25, 0.7, 0.8, 1.0];
    let (re_lo, re_hi, im_lo, im_hi) = (-3.0, 0.5, -2.0, 2.0);
    let mut rows = Vec::new();
    let mut table = Table::new(
        "App. Fig. 1: damped-ALF A-stability region area (grid scan)",
        &["eta", "area", "non-empty"],
    );
    for &eta in &etas {
        let (area, mask) = stability_region(eta, re_lo, re_hi, im_lo, im_hi, n);
        table.row(&[
            format!("{eta}"),
            format!("{area:.4}"),
            (area > 0.0).to_string(),
        ]);
        if n <= 60 {
            println!("η = {eta}:");
            println!("{}", ascii_region(&mask, n));
        }
        rows.push(Json::obj(vec![
            ("eta", Json::Num(eta)),
            ("area", Json::Num(area)),
        ]));
    }
    table.print();
    Ok(super::report::summary(
        rows,
        vec![("grid", Json::Num(n as f64))],
    ))
}

/// Damped-solver helper shared with Table 7: `alf` with explicit η.
pub fn damped_solver(eta: f64) -> Result<Box<dyn crate::solvers::Solver + Send + Sync>> {
    by_name_eta("alf", eta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_shapes_hold_quick() {
        let summary = fig4(Scale::Quick, 0).unwrap();
        let rows = summary.get("rows").as_arr().unwrap();
        // pull the T=20 gradient errors per method
        let err_at = |method: &str| -> f64 {
            rows.iter()
                .filter(|r| {
                    r.get("method").as_str() == Some(method)
                        && r.get("T").as_f64() == Some(20.0)
                })
                .filter_map(|r| r.get("err_dz0").as_f64())
                .next()
                .unwrap()
        };
        // MALI and ACA beat the adjoint method on reverse accuracy
        assert!(err_at("mali") < err_at("adjoint"));
        assert!(err_at("aca") < err_at("adjoint"));

        // memory: MALI flat across tolerances, naive grows
        let mems = |method: &str| -> Vec<f64> {
            rows.iter()
                .filter(|r| {
                    r.get("method").as_str() == Some(method) && !r.get("tol").is_null()
                })
                .filter_map(|r| r.get("peak_mem_bytes").as_f64())
                .collect()
        };
        let mali = mems("mali");
        let naive = mems("naive");
        assert_eq!(mali.first(), mali.last(), "MALI memory not constant: {mali:?}");
        assert!(naive.last() > naive.first(), "naive memory flat: {naive:?}");

        // method grid: every supported protocol × solver pair reported
        let grid: Vec<_> = rows
            .iter()
            .filter(|r| !r.get("solver").is_null())
            .collect();
        assert_eq!(grid.len(), 14, "5 methods × 3 solvers − mali×heun-euler");
        for r in &grid {
            let e = r.get("err_dz0").as_f64().unwrap();
            assert!(e.is_finite() && e < 1.0, "grid row diverged: {e}");
        }
        let grid_err = |method: &str, solver: &str| -> f64 {
            grid.iter()
                .find(|r| {
                    r.get("method").as_str() == Some(method)
                        && r.get("solver").as_str() == Some(solver)
                })
                .and_then(|r| r.get("err_dz0").as_f64())
                .unwrap()
        };
        // the exact protocols track the analytic gradient on every solver
        for m in ["mali", "aca", "naive", "symplectic"] {
            for s in GRID_SOLVERS {
                if supports(m, s) {
                    assert!(grid_err(m, s) < 1e-2, "{m}×{s}: {}", grid_err(m, s));
                }
            }
        }
    }

    #[test]
    fn table1_ordering_holds() {
        let summary = table1(Scale::Quick, 3).unwrap();
        let rows = summary.get("rows").as_arr().unwrap();
        let peak = |m: &str| -> f64 {
            rows.iter()
                .find(|r| r.get("method").as_str() == Some(m))
                .and_then(|r| r.get("peak_mem_bytes").as_f64())
                .unwrap()
        };
        assert!(peak("naive") >= peak("aca"));
        assert!(peak("aca") > peak("mali"));
        assert!(peak("adjoint") <= peak("mali"));
        // symplectic holds the same checkpoint store as ACA at its peak
        assert!(peak("symplectic") <= peak("aca"));
        // method grid present for every supported pair
        let grid = rows
            .iter()
            .filter(|r| !r.get("solver").is_null())
            .count();
        assert_eq!(grid, 14, "5 methods × 3 solvers − mali×heun-euler");
    }

    #[test]
    fn fig_a1_area_shrinks_with_eta() {
        let summary = fig_a1(Scale::Quick, 0).unwrap();
        let rows = summary.get("rows").as_arr().unwrap();
        let area = |eta: f64| -> f64 {
            rows.iter()
                .find(|r| r.get("eta").as_f64() == Some(eta))
                .and_then(|r| r.get("area").as_f64())
                .unwrap()
        };
        assert!(area(0.25) > area(0.7));
        assert!(area(0.7) > area(0.8));
        assert_eq!(area(1.0), 0.0);
    }
}
