//! E14 — streaming sessions + hot-swap under load.
//!
//! Two entry points:
//!
//! * [`serve_session_bench`] (`mali run serve_session`) — the cost of
//!   *incremental* streaming inference.  S sessions each receive E
//!   irregular observation events; the **oneshot** strategy re-solves
//!   `[t0, t_now]` from scratch at every event (what a session-less
//!   server must do — quadratic in the stream length), the **session**
//!   strategy advances warm per-session state through
//!   [`Server::session_step`] (linear).  The two are asserted
//!   bitwise-equal on final states, and the session step totals must
//!   equal the final one-shot solve's — the serve-layer face of the
//!   equivalence `tests/session.rs` pins at the solver layer.
//!
//! * [`finetune_serve_cmd`] (`mali finetune-serve`) — continual
//!   fine-tuning while serving: loopback TCP session traffic runs
//!   against a model that a training loop keeps re-publishing through
//!   [`ModelRegistry::hot_swap`](crate::serve::ModelRegistry::hot_swap).
//!   Asserts the CoW pinning contract (a version snapshot held across N
//!   swaps never changes θ), zero failures, and exact admission/shed
//!   accounting on the transport.

use super::exp_serve::{client_z0, standard_registry, N_Z};
use super::Scale;
use crate::cli::Args;
use crate::serve::{RequestClass, Server, ServerConfig};
use crate::solvers::integrate::{ObsGrid, StepMode};
use crate::util::bench::{quantile, Table};
use crate::util::json::Json;
use crate::util::pool;
use crate::util::rng::Rng;
use anyhow::{ensure, Context, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Deterministic event time j of the standard stream (strictly
/// increasing, irregular): every strategy, process and test sees the
/// same grid.
fn event_time(j: usize) -> f64 {
    // irregular but reproducible: base spacing 0.06 with a ±40% wobble
    (0..=j).map(|i| 0.06 * (1.0 + 0.4 * ((i * 2654435761) % 100) as f64 / 100.0)).sum()
}

fn server(workers: usize) -> Server {
    Server::start(
        Arc::new(standard_registry()),
        ServerConfig {
            queue_capacity: 1024,
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            workers,
            shards: 1,
        },
    )
}

struct CellResult {
    latencies_s: Vec<f64>,
    wall_s: f64,
    /// Accepted solver steps (summed per-response, not from metrics, so
    /// the two strategies are compared on identical accounting).
    steps: u64,
    /// Final state per session, for the cross-strategy bitwise check.
    finals: Vec<Vec<f32>>,
}

/// One-shot re-solve baseline: at each event the full prefix grid is
/// solved again from `t0` through a fresh request class.
fn run_oneshot(mode: &StepMode, sessions: usize, events: usize, seed: u64) -> Result<CellResult> {
    let server = server(pool::num_threads().clamp(1, 2));
    let mut root = Rng::new(seed);
    let rngs: Vec<Rng> = (0..sessions).map(|i| root.fork(i as u64)).collect();
    let t0 = Instant::now();
    let per_session: Vec<Result<(Vec<f64>, u64, Vec<f32>)>> = pool::par_map(&rngs, |rng| {
        let mut rng = rng.clone();
        let z0 = client_z0(&mut rng);
        let mut lats = Vec::with_capacity(events);
        let mut last_steps = 0u64;
        let mut final_z = Vec::new();
        let mut grid_times = Vec::with_capacity(events);
        for j in 0..events {
            grid_times.push(event_time(j));
            let class = Arc::new(RequestClass::new(
                "lin8",
                "alf",
                N_Z,
                0.0,
                *grid_times.last().unwrap(),
                mode.clone(),
                ObsGrid::new(grid_times.clone())?,
            )?);
            let t = Instant::now();
            let resp = server.submit(&class, &z0).map_err(|e| anyhow::anyhow!("{e}"))?.wait()?;
            lats.push(t.elapsed().as_secs_f64());
            // only the last solve's counts matter: it covers the whole
            // stream, which is what the session strategy integrates once
            last_steps = resp.n_accepted as u64;
            final_z = resp.z_final;
        }
        Ok((lats, last_steps, final_z))
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let metrics = server.shutdown();
    ensure!(metrics.failed == 0, "{} serve failures", metrics.failed);
    let mut out = CellResult {
        latencies_s: Vec::new(),
        wall_s,
        steps: 0,
        finals: Vec::new(),
    };
    for r in per_session {
        let (lats, steps, final_z) = r?;
        out.latencies_s.extend(lats);
        out.steps += steps;
        out.finals.push(final_z);
    }
    Ok(out)
}

/// Streaming strategy: one warm session per stream, one incremental
/// [`Server::session_step`] per event.
fn run_session(mode: &StepMode, sessions: usize, events: usize, seed: u64) -> Result<CellResult> {
    let server = server(pool::num_threads().clamp(1, 2));
    let mut root = Rng::new(seed);
    let rngs: Vec<Rng> = (0..sessions).map(|i| root.fork(i as u64)).collect();
    let t0 = Instant::now();
    let per_session: Vec<Result<(Vec<f64>, u64, Vec<f32>)>> = pool::par_map(&rngs, |rng| {
        let mut rng = rng.clone();
        let z0 = client_z0(&mut rng);
        let sid = server
            .open_session("lin8", "alf", N_Z, 0.0, mode.clone(), &z0)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut lats = Vec::with_capacity(events);
        let mut steps = 0u64;
        let mut final_z = Vec::new();
        for j in 0..events {
            let t = Instant::now();
            let resp = server
                .session_step(sid, &[event_time(j)])
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .wait()?;
            lats.push(t.elapsed().as_secs_f64());
            steps += resp.n_accepted as u64;
            final_z = resp.z_final;
        }
        ensure!(server.close_session(sid), "session {sid} vanished");
        Ok((lats, steps, final_z))
    });
    let wall_s = t0.elapsed().as_secs_f64();
    ensure!(server.session_count() == 0, "sessions leaked past close");
    let metrics = server.shutdown();
    ensure!(metrics.failed == 0, "{} serve failures", metrics.failed);
    ensure!(
        metrics.session_steps == (sessions * events) as u64,
        "served {} session steps, expected {}",
        metrics.session_steps,
        sessions * events
    );
    let mut out = CellResult {
        latencies_s: Vec::new(),
        wall_s,
        steps: 0,
        finals: Vec::new(),
    };
    for r in per_session {
        let (lats, steps, final_z) = r?;
        out.latencies_s.extend(lats);
        out.steps += steps;
        out.finals.push(final_z);
    }
    Ok(out)
}

/// E14 runner: incremental session serving vs one-shot re-solve, fixed
/// and adaptive stepping.  Writes `runs/serve_session.json`.
pub fn serve_session_bench(scale: Scale, seed: u64) -> Result<Json> {
    let sessions = scale.pick(4, 8);
    let events = scale.pick(12, 96);
    let mut table = Table::new(
        "E14: streaming sessions — incremental advance vs one-shot re-solve (bitwise-equal states)",
        &["config", "events/s", "steps", "p50 ms", "p99 ms", "wall s"],
    );
    let mut rows = Vec::new();
    for adaptive in [false, true] {
        let mode = if adaptive {
            StepMode::adaptive(1e-4, 1e-6)
        } else {
            StepMode::Fixed { h: 0.01 }
        };
        let mode_name = if adaptive { "adaptive" } else { "fixed" };
        let oneshot = run_oneshot(&mode, sessions, events, seed)?;
        let session = run_session(&mode, sessions, events, seed)?;
        // the whole point: the cheap path must be the *same computation*
        ensure!(
            session.finals == oneshot.finals,
            "incremental sessions diverged from the one-shot re-solve ({mode_name})"
        );
        ensure!(
            session.steps == oneshot.steps,
            "session step totals {} ≠ final one-shot totals {} ({mode_name})",
            session.steps,
            oneshot.steps
        );
        for (strategy, cell) in [("oneshot", &oneshot), ("session", &session)] {
            let n = cell.latencies_s.len();
            let p50 = quantile(&cell.latencies_s, 0.50) * 1e3;
            let p99 = quantile(&cell.latencies_s, 0.99) * 1e3;
            let eps = n as f64 / cell.wall_s.max(1e-12);
            let config = format!("{mode_name}/{strategy}");
            table.row(&[
                config.clone(),
                format!("{eps:.0}"),
                cell.steps.to_string(),
                format!("{p50:.3}"),
                format!("{p99:.3}"),
                format!("{:.2}", cell.wall_s),
            ]);
            rows.push(Json::obj(vec![
                ("config", Json::Str(config)),
                ("mode", Json::Str(mode_name.into())),
                ("strategy", Json::Str(strategy.into())),
                ("events", Json::Num(n as f64)),
                ("wall_s", Json::Num(cell.wall_s)),
                ("p50_ms", Json::Num(p50)),
                ("p99_ms", Json::Num(p99)),
                ("events_per_sec", Json::Num(eps)),
                ("steps", Json::Num(cell.steps as f64)),
            ]));
        }
    }
    table.print();
    Ok(crate::coordinator::report::summary(
        rows,
        vec![
            ("bench", Json::Str("serve_session".into())),
            ("seed", Json::Num(seed as f64)),
            ("sessions", Json::Num(sessions as f64)),
            ("events_per_session", Json::Num(events as f64)),
            ("n_z", Json::Num(N_Z as f64)),
        ],
    ))
}

// ---------------------------------------------------------------------------
// mali finetune-serve — continual fine-tuning against live session traffic
// ---------------------------------------------------------------------------

/// `mali finetune-serve [--updates N] [--sessions S] [--events E]`:
/// loopback TCP session streams against a model being continually
/// fine-tuned and re-published with `hot_swap`.  Asserts version
/// pinning, zero failures, and exact admission accounting; exits
/// non-zero on any violation (the E14 CI smoke leg).
pub fn finetune_serve_cmd(args: &Args) -> Result<()> {
    use crate::grad::{IvpSpec, ObsSquareLoss};
    use crate::serve::transport::{
        Bridge, ClientEvent, ResponseFrame, TcpClient, TcpFront, TransportConfig,
    };
    use crate::serve::ModelRegistry;
    use crate::solvers::batch::BatchSpec;
    use crate::solvers::dynamics::MlpDynamics;
    use crate::util::mem::MemTracker;

    let updates = args.usize_opt("updates", 8);
    let sessions = args.usize_opt("sessions", 4);
    let events = args.usize_opt("events", 16);
    let d = 4usize;

    let mut registry = ModelRegistry::new();
    registry.register("mlp", Box::new(MlpDynamics::new(d, 8, &mut Rng::new(17))));
    let registry = Arc::new(registry);
    let server = Arc::new(Server::start(
        registry.clone(),
        ServerConfig {
            queue_capacity: 256,
            max_batch: 4,
            max_wait: Duration::from_micros(200),
            workers: pool::num_threads().clamp(1, 2),
            shards: 1,
        },
    ));
    let front = TcpFront::bind(
        "127.0.0.1:0",
        server.clone() as Arc<dyn Bridge>,
        TransportConfig::default(),
    )?;
    let addr = front.local_addr();

    // pin the pre-training version: after every swap below, this exact θ
    // must still be readable through the held Arc
    let id = registry.resolve("mlp").context("mlp just registered")?;
    let pinned = registry.snapshot(id).context("mlp snapshot")?;
    let theta0 = pinned.dynamics().params().to_vec();
    ensure!(pinned.version() == 1, "fresh model must be version 1");

    // loopback session clients: one stream each, one step in flight
    let mode = StepMode::Fixed { h: 0.05 };
    let clients: Vec<std::thread::JoinHandle<Result<u64>>> = (0..sessions)
        .map(|i| {
            let mode = mode.clone();
            std::thread::spawn(move || -> Result<u64> {
                let mut rng = Rng::new(100 + i as u64);
                let z0: Vec<f32> = (0..d).map(|_| rng.range(-1.0, 1.0) as f32).collect();
                let mut cl = TcpClient::connect(addr)?;
                let sid = cl.open_session(i as u64 + 1, "mlp", "alf", 0.0, &mode, &z0)?;
                let mut resp = ResponseFrame::default();
                let mut served = 0u64;
                for j in 0..events {
                    let req_id = (i * events + j) as u64 + 1;
                    cl.session_step(req_id, sid, &[event_time(j)])?;
                    match cl.next_event(&mut resp)? {
                        ClientEvent::Response => {
                            ensure!(resp.req_id == req_id, "out-of-order session response");
                            ensure!(resp.z_final.len() == d, "malformed step response");
                            served += 1;
                        }
                        other => anyhow::bail!("session step {req_id} got {other:?}"),
                    }
                }
                cl.close_session(sid)?;
                cl.goodbye()?;
                Ok(served)
            })
        })
        .collect();

    // the fine-tuning loop: gradient on the *current* version, publish
    // with hot_swap — never draining, never touching in-flight batches
    let method = crate::grad::by_name("mali")?;
    let solver = crate::solvers::by_name("alf")?;
    let spec = IvpSpec::fixed(0.0, 1.0, 0.1);
    let grid = ObsGrid::new(vec![0.5, 1.0])?;
    let head = ObsSquareLoss { weights: vec![1.0, 1.0] };
    let bspec = BatchSpec::new(4, d);
    let mut train_rng = Rng::new(7);
    let mut z0b = vec![0.0f32; bspec.flat_len()];
    let mut losses = Vec::with_capacity(updates);
    for u in 0..updates {
        for z in z0b.iter_mut() {
            *z = train_rng.range(-1.0, 1.0) as f32;
        }
        let current = registry.snapshot(id).context("mlp vanished")?;
        let res = crate::grad::batch_driver::grad_obs_batched(
            &*method,
            current.dynamics(),
            &*solver,
            &spec,
            &grid,
            &z0b,
            &bspec,
            &head,
            MemTracker::new(),
        )?;
        let lr = 0.02f32;
        let theta: Vec<f32> = current
            .dynamics()
            .params()
            .iter()
            .zip(&res.grad_theta)
            .map(|(p, g)| p - lr * g)
            .collect();
        let v = registry.hot_swap("mlp", &theta)?;
        ensure!(v == u as u64 + 2, "hot_swap published version {v}, expected {}", u + 2);
        // the pinning contract, checked after every single swap
        ensure!(
            pinned.dynamics().params() == &theta0[..],
            "hot_swap mutated a pinned version's θ (update {u})"
        );
        losses.push(res.loss);
    }

    let mut served_total = 0u64;
    for c in clients {
        served_total += c.join().map_err(|_| anyhow::anyhow!("client thread panicked"))??;
    }
    let expect = (sessions * events) as u64;
    ensure!(served_total == expect, "served {served_total} of {expect} session steps");

    // exact accounting: everything admitted completed, nothing shed, and
    // HEALTH's pre-divided shed rate agrees
    let admitted = front.admitted();
    let health = front.health_snapshot();
    ensure!(admitted == expect, "admitted {admitted}, expected {expect}");
    ensure!(health.sessions == 0, "sessions leaked: {}", health.sessions);
    ensure!(health.shed_total == 0, "unexpected shed under closed-loop load");
    ensure!(health.shed_rate == 0.0, "shed rate must be exactly 0.0");
    let drain = front.shutdown(Duration::from_secs(10));
    ensure!(drain.flushed, "drain left unflushed responses");
    // connection threads can hold a bridge reference for a beat after
    // the drain returns; bound the wait rather than racing it
    let mut server = server;
    let deadline = Instant::now() + Duration::from_secs(5);
    let metrics = loop {
        match Arc::try_unwrap(server) {
            Ok(s) => break s.shutdown(),
            Err(arc) => {
                ensure!(Instant::now() < deadline, "server still referenced at shutdown");
                server = arc;
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    };
    ensure!(metrics.failed == 0, "{} serve failures", metrics.failed);
    ensure!(
        metrics.session_steps == expect,
        "metrics counted {} session steps, expected {expect}",
        metrics.session_steps
    );

    println!(
        "finetune-serve OK: {updates} hot-swaps (final version {}), {served_total} session steps, \
         loss {:.4} → {:.4}, pinned θ intact",
        updates as u64 + 1,
        losses.first().copied().unwrap_or(0.0),
        losses.last().copied().unwrap_or(0.0),
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_times_are_strictly_increasing() {
        let ts: Vec<f64> = (0..64).map(event_time).collect();
        assert!(ts.windows(2).all(|w| w[1] > w[0]));
        assert!(ts[0] > 0.0);
    }

    /// The E14 grid at test scale: incremental ≡ one-shot, both modes.
    #[test]
    fn session_bench_smoke() {
        for adaptive in [false, true] {
            let mode = if adaptive {
                StepMode::adaptive(1e-4, 1e-6)
            } else {
                StepMode::Fixed { h: 0.02 }
            };
            let oneshot = run_oneshot(&mode, 2, 5, 11).unwrap();
            let session = run_session(&mode, 2, 5, 11).unwrap();
            assert_eq!(session.finals, oneshot.finals, "adaptive={adaptive}");
            assert_eq!(session.steps, oneshot.steps, "adaptive={adaptive}");
            assert_eq!(session.latencies_s.len(), 10);
        }
    }

    /// The full continual-fine-tuning loop over loopback TCP, tiny scale.
    #[test]
    fn finetune_serve_smoke() {
        let args = Args::parse(&[
            "finetune-serve".into(),
            "--updates".into(),
            "2".into(),
            "--sessions".into(),
            "2".into(),
            "--events".into(),
            "3".into(),
        ])
        .unwrap();
        finetune_serve_cmd(&args).unwrap();
    }
}
