//! E2–E5 — the image-recognition experiments (paper §4.2):
//!
//! * **Fig. 5**: Cifar-like corpus, Neural ODE trained with naive /
//!   adjoint / ACA / MALI vs the ResNet baseline — accuracy distribution
//!   across seeds, accuracy-vs-epoch, accuracy-vs-wall-clock.
//! * **Fig. 6**: ImageNet-like corpus with a device memory budget — naive
//!   and ACA are gated out (their retained state exceeds the budget),
//!   MALI vs adjoint training curves.
//! * **Table 2**: invariance to the discretization scheme — the trained
//!   ODE evaluated under solvers × stepsizes / tolerances it was never
//!   trained with; the ResNet collapses when re-discretized.
//! * **Table 3**: FGSM robustness, attack-solver × inference-solver grid.

use super::{report, Scale};
use crate::data::images::{generate, ImageSpec};
use crate::data::Dataset;
use crate::grad::IvpSpec;
use crate::models::image::{OdeImageClassifier, ResNetClassifier};
use crate::models::SolveCfg;
use crate::runtime::Engine;
use crate::train::attack::{ode_under_attack, resnet_under_attack};
use crate::train::metrics::fmt_mean_std;
use crate::train::trainer::{ImageTrainer, TrainCfg};
use crate::util::bench::{print_series, Table};
use crate::util::json::Json;
use crate::util::mem::fmt_bytes;
use crate::util::rng::Rng;
use anyhow::Result;
use std::rc::Rc;

/// Per-method training setup mirroring Appendix B.1: MALI on (damped) ALF,
/// ACA on Heun–Euler, naive/adjoint on Dopri5.
fn cfg_for(method: &str, epochs: usize, seed: u64) -> TrainCfg {
    let (solver, h, rtol, atol) = match method {
        "mali" => ("alf", 0.0, 1e-1, 1e-2),
        "aca" => ("heun-euler", 0.0, 1e-1, 1e-2),
        // paper uses rtol=atol=1e-5; CPU-scaled to keep runs tractable
        // while still ~10× tighter than the MALI/ACA tolerance
        _ => ("dopri5", 0.0, 1e-3, 1e-4),
    };
    TrainCfg {
        epochs,
        method: method.into(),
        solver: solver.into(),
        h,
        rtol,
        atol,
        lr: 0.05,
        lr_drops: vec![epochs * 1 / 3, epochs * 2 / 3],
        seed,
        ..TrainCfg::default()
    }
}

fn fig5_data(scale: Scale, seed: u64) -> (Dataset, Dataset) {
    let n = scale.pick(480 + 160, 1600 + 320);
    let n_test = scale.pick(160, 320);
    generate(&ImageSpec::cifar_like(), n, seed).split(n_test)
}

/// Fig. 5 — three panels as printed series + a seeds table.
pub fn fig5(scale: Scale, seed: u64) -> Result<Json> {
    let engine = Rc::new(Engine::from_env()?);
    let seeds: Vec<u64> = (0..scale.pick(2u64, 3u64)).map(|s| seed + s).collect();
    let epochs = scale.pick(3, 6);
    let (train, test) = fig5_data(scale, seed + 100);

    let mut rows = Vec::new();
    let mut final_accs: Vec<(String, Vec<f64>)> = Vec::new();
    let mut epoch_curves: Vec<(String, Vec<f64>)> = Vec::new();
    let mut time_axis: Vec<(String, f64)> = Vec::new();

    for method in ["mali", "aca", "naive", "adjoint"] {
        let mut accs = Vec::new();
        let mut curve_sum = vec![0.0f64; epochs];
        let mut total_time = 0.0f64;
        for &s in &seeds {
            let mut rng = Rng::new(s);
            let mut model = OdeImageClassifier::new(engine.clone(), "img16", &mut rng)?;
            let trainer = ImageTrainer::new(cfg_for(method, epochs, s));
            let rep = trainer.train_ode(&mut model, &train, &test)?;
            accs.push(rep.final_acc);
            for (k, e) in rep.epochs.iter().enumerate() {
                curve_sum[k] += e.test_acc;
            }
            total_time += rep.total_secs;
            rows.push(Json::obj(vec![
                ("method", Json::Str(method.into())),
                ("seed", Json::Num(s as f64)),
                ("final_acc", Json::Num(rep.final_acc)),
                ("total_secs", Json::Num(rep.total_secs)),
                ("peak_mem_bytes", Json::Num(rep.peak_mem_bytes as f64)),
            ]));
        }
        epoch_curves.push((
            method.to_string(),
            curve_sum.iter().map(|a| a / seeds.len() as f64).collect(),
        ));
        time_axis.push((method.to_string(), total_time / seeds.len() as f64));
        final_accs.push((method.to_string(), accs));
    }

    // ResNet baseline
    let mut resnet_accs = Vec::new();
    for &s in &seeds {
        let mut rng = Rng::new(s);
        let mut model = ResNetClassifier::new(engine.clone(), "img16", &mut rng)?;
        let trainer = ImageTrainer::new(cfg_for("mali", epochs, s)); // shared schedule
        let rep = trainer.train_resnet(&mut model, &train, &test)?;
        resnet_accs.push(rep.final_acc);
        rows.push(Json::obj(vec![
            ("method", Json::Str("resnet".into())),
            ("seed", Json::Num(s as f64)),
            ("final_acc", Json::Num(rep.final_acc)),
        ]));
    }
    final_accs.push(("resnet".to_string(), resnet_accs));

    let mut table = Table::new(
        "Fig 5 (panel 1): test accuracy across seeds",
        &["method", "accuracy", "mean train secs"],
    );
    for (m, accs) in &final_accs {
        let t = time_axis
            .iter()
            .find(|(n, _)| n == m)
            .map(|(_, t)| format!("{t:.1}"))
            .unwrap_or_else(|| "-".into());
        table.row(&[m.clone(), fmt_mean_std(accs, 3), t]);
    }
    table.print();

    let xs: Vec<f64> = (0..epochs).map(|e| e as f64).collect();
    let series: Vec<(&str, Vec<f64>)> = epoch_curves
        .iter()
        .map(|(m, c)| (m.as_str(), c.clone()))
        .collect();
    print_series("Fig 5 (panel 2): mean test acc vs epoch", "epoch", &xs, &series);

    Ok(report::summary(
        rows,
        vec![
            ("epochs", Json::Num(epochs as f64)),
            ("seeds", Json::Num(seeds.len() as f64)),
            ("train_n", Json::Num(train.len() as f64)),
        ],
    ))
}

/// One gradient step's retained-memory probe for the Fig. 6 budget gate.
fn probe_peak_mem(
    engine: &Rc<Engine>,
    method: &str,
    train: &Dataset,
    seed: u64,
) -> Result<usize> {
    let mut rng = Rng::new(seed);
    let mut model = OdeImageClassifier::new(engine.clone(), "img32", &mut rng)?;
    // probe at a common production tolerance on order-matched solvers
    // (ALF and Heun–Euler are both order 2) so trajectory-retaining
    // methods pay for the steps the accuracy actually requires
    let mut cfg = cfg_for(method, 1, seed);
    cfg.solver = if method == "mali" { "alf" } else { "heun-euler" }.into();
    cfg.h = 0.0;
    cfg.rtol = 1e-3;
    cfg.atol = 1e-4;
    let solver = cfg.solver()?;
    let method_obj = cfg.grad_method()?;
    let idx: Vec<usize> = (0..model.batch).collect();
    let x = train.gather(&idx);
    let y1h = train.one_hot(&idx);
    let scfg = SolveCfg {
        solver: &*solver,
        spec: cfg.ivp_spec(),
        method: &*method_obj,
    };
    let out = model.step(&x, &y1h, &scfg, false)?;
    Ok(out.peak_mem_bytes)
}

/// Fig. 6 — ImageNet-scale feasibility gate + MALI-vs-adjoint curves.
pub fn fig6(scale: Scale, seed: u64) -> Result<Json> {
    let engine = Rc::new(Engine::from_env()?);
    let n = scale.pick(320 + 160, 2400 + 480);
    let n_test = scale.pick(160, 480);
    let (train, test) = generate(&ImageSpec::imagenet_like(), n, seed + 300).split(n_test);
    let epochs = scale.pick(3, 6);

    // ---- feasibility gate -------------------------------------------------
    // The budget models the paper's 4×GTX-1080Ti ceiling: sized so the
    // constant-memory methods fit with ~2.5× headroom while anything that
    // retains the trajectory does not.
    let mali_peak = probe_peak_mem(&engine, "mali", &train, seed)?;
    let budget = mali_peak * 5 / 2;
    let mut gate_table = Table::new(
        &format!("Fig 6 gate: retained bytes vs budget {}", fmt_bytes(budget)),
        &["method", "peak bytes", "feasible"],
    );
    let mut rows = Vec::new();
    let mut feasible = Vec::new();
    for method in ["naive", "adjoint", "aca", "mali"] {
        let peak = probe_peak_mem(&engine, method, &train, seed)?;
        let fits = peak <= budget;
        gate_table.row(&[method.into(), fmt_bytes(peak), fits.to_string()]);
        rows.push(Json::obj(vec![
            ("method", Json::Str(method.into())),
            ("peak_mem_bytes", Json::Num(peak as f64)),
            ("feasible", Json::Bool(fits)),
        ]));
        if fits {
            feasible.push(method);
        }
    }
    gate_table.print();

    // ---- train the feasible methods (paper: fixed stepsize 0.25) ----------
    let mut curves: Vec<(String, Vec<f64>)> = Vec::new();
    for method in &feasible {
        // paper App. B.1.2: both MALI and adjoint train at fixed h = 0.25;
        // adjoint integrates with a comparable 2nd-order RK.
        let cfg = TrainCfg {
            epochs,
            method: method.to_string(),
            solver: if *method == "mali" { "alf" } else { "rk2" }.into(),
            h: 0.25,
            lr: 0.05,
            lr_drops: vec![epochs / 3, epochs * 2 / 3],
            seed,
            ..TrainCfg::default()
        };
        let mut rng = Rng::new(seed);
        let mut model = OdeImageClassifier::new(engine.clone(), "img32", &mut rng)?;
        let rep = ImageTrainer::new(cfg).train_ode(&mut model, &train, &test)?;
        curves.push((
            method.to_string(),
            rep.epochs.iter().map(|e| e.test_acc).collect(),
        ));
        rows.push(Json::obj(vec![
            ("method", Json::Str(method.to_string())),
            ("final_acc", Json::Num(rep.final_acc)),
            ("total_secs", Json::Num(rep.total_secs)),
        ]));
    }
    let xs: Vec<f64> = (0..epochs).map(|e| e as f64).collect();
    let series: Vec<(&str, Vec<f64>)> =
        curves.iter().map(|(m, c)| (m.as_str(), c.clone())).collect();
    print_series("Fig 6: top-1 accuracy vs epoch (feasible methods)", "epoch", &xs, &series);

    Ok(report::summary(
        rows,
        vec![
            ("budget_bytes", Json::Num(budget as f64)),
            ("epochs", Json::Num(epochs as f64)),
        ],
    ))
}

/// Shared by Tables 2/3: train one MALI ODE + one ResNet on the
/// ImageNet-like corpus and return them with the test set.
fn trained_img32(
    engine: &Rc<Engine>,
    scale: Scale,
    seed: u64,
) -> Result<(OdeImageClassifier, ResNetClassifier, Dataset)> {
    let n = scale.pick(320 + 160, 3200 + 640);
    let n_test = scale.pick(160, 640);
    let (train, test) = generate(&ImageSpec::imagenet_like(), n, seed + 500).split(n_test);
    let epochs = scale.pick(4, 12);
    let cfg = TrainCfg {
        epochs,
        method: "mali".into(),
        solver: "alf".into(),
        h: 0.25,
        lr: 0.1,
        lr_drops: vec![epochs * 3 / 4],
        seed,
        ..TrainCfg::default()
    };
    let mut rng = Rng::new(seed);
    let mut ode = OdeImageClassifier::new(engine.clone(), "img32", &mut rng)?;
    ImageTrainer::new(cfg.clone()).train_ode(&mut ode, &train, &test)?;
    let mut rng2 = Rng::new(seed);
    let mut resnet = ResNetClassifier::new(engine.clone(), "img32", &mut rng2)?;
    ImageTrainer::new(cfg).train_resnet(&mut resnet, &train, &test)?;
    Ok((ode, resnet, test))
}

fn eval_acc(
    model: &OdeImageClassifier,
    test: &Dataset,
    solver_name: &str,
    spec: IvpSpec,
) -> Result<f64> {
    let solver = crate::solvers::by_name(solver_name)?;
    let method = crate::grad::by_name("mali")?; // unused in inference
    ImageTrainer::evaluate(model, test, &*solver, &spec, &*method)
}

/// Table 2 — invariance to the discretization scheme.
pub fn table2(scale: Scale, seed: u64) -> Result<Json> {
    let engine = Rc::new(Engine::from_env()?);
    let (ode, resnet, test) = trained_img32(&engine, scale, seed)?;
    let mut rows = Vec::new();

    // fixed-stepsize grid
    let steps = [1.0, 0.5, 0.25, 0.15, 0.1];
    let fixed_solvers = [("mali", "alf"), ("euler", "euler"), ("rk2", "rk2"), ("rk4", "rk4")];
    let mut t_fixed = Table::new(
        "Table 2 (left): Neural ODE accuracy, fixed-stepsize solvers",
        &["solver \\ h", "1", "0.5", "0.25", "0.15", "0.1"],
    );
    for (label, solver) in fixed_solvers {
        let mut cells = vec![label.to_string()];
        for &h in &steps {
            let acc = eval_acc(&ode, &test, solver, IvpSpec::fixed(0.0, 1.0, h))?;
            cells.push(format!("{:.3}", acc));
            rows.push(Json::obj(vec![
                ("solver", Json::Str(label.into())),
                ("h", Json::Num(h)),
                ("acc", Json::Num(acc)),
            ]));
        }
        t_fixed.row(&cells);
    }
    t_fixed.print();

    // adaptive-tolerance grid
    let tols = [1.0, 1e-1, 1e-2];
    let adaptive_solvers = [
        ("mali", "alf"),
        ("heun-euler", "heun-euler"),
        ("rk23", "rk23"),
        ("dopri5", "dopri5"),
    ];
    let mut t_adapt = Table::new(
        "Table 2 (right): Neural ODE accuracy, adaptive solvers",
        &["solver \\ tol", "1e0", "1e-1", "1e-2"],
    );
    for (label, solver) in adaptive_solvers {
        let mut cells = vec![label.to_string()];
        for &tol in &tols {
            let acc = eval_acc(
                &ode,
                &test,
                solver,
                IvpSpec::adaptive(0.0, 1.0, tol, tol * 0.1),
            )?;
            cells.push(format!("{:.3}", acc));
            rows.push(Json::obj(vec![
                ("solver", Json::Str(label.into())),
                ("tol", Json::Num(tol)),
                ("acc", Json::Num(acc)),
            ]));
        }
        t_adapt.row(&cells);
    }
    t_adapt.print();

    // ResNet re-discretized: a 1-step Euler block re-run with other step
    // counts is no longer the trained function — accuracy collapses.
    let mut rng = Rng::new(seed + 1);
    let res_as_ode = resnet.as_ode(&mut rng)?;
    let mut t_res = Table::new(
        "Table 2 (bottom): ResNet re-discretized as an ODE",
        &["h", "accuracy"],
    );
    for &h in &[1.0, 0.5, 0.25] {
        let acc = eval_acc(&res_as_ode, &test, "euler", IvpSpec::fixed(0.0, 1.0, h))?;
        t_res.row(&[format!("{h}"), format!("{acc:.3}")]);
        rows.push(Json::obj(vec![
            ("solver", Json::Str("resnet-euler".into())),
            ("h", Json::Num(h)),
            ("acc", Json::Num(acc)),
        ]));
    }
    t_res.print();

    Ok(report::summary(rows, vec![("seed", Json::Num(seed as f64))]))
}

/// Table 3 — FGSM attack grid.
pub fn table3(scale: Scale, seed: u64) -> Result<Json> {
    let engine = Rc::new(Engine::from_env()?);
    let (mut ode, resnet, test) = trained_img32(&engine, scale, seed)?;
    let grid = [
        ("mali", "alf"),
        ("heun-euler", "heun-euler"),
        ("rk23", "rk23"),
        ("dopri5", "dopri5"),
    ];
    let epsilons = [1.0 / 255.0, 2.0 / 255.0];
    // gradient protocol per attack solver: MALI needs ψ⁻¹ (ALF only);
    // the RK-family attack columns use ACA (also reverse-accurate)
    let mali = crate::grad::by_name("mali")?;
    let aca = crate::grad::by_name("aca")?;
    let mut rows = Vec::new();

    for &eps in &epsilons {
        let mut table = Table::new(
            &format!("Table 3: top-1 under FGSM, ε = {:.4}", eps),
            &["attack \\ eval", "mali", "heun-euler", "rk23", "dopri5"],
        );
        for (atk_label, atk_solver) in grid {
            let atk = crate::solvers::by_name(atk_solver)?;
            let atk_method: &dyn crate::grad::GradMethod =
                if atk_solver == "alf" { &*mali } else { &*aca };
            let mut cells = vec![atk_label.to_string()];
            for (_, eval_solver) in grid {
                let ev = crate::solvers::by_name(eval_solver)?;
                let attack_cfg = SolveCfg {
                    solver: &*atk,
                    spec: IvpSpec::fixed(0.0, 1.0, 0.25),
                    method: atk_method,
                };
                let eval_cfg = SolveCfg {
                    solver: &*ev,
                    spec: IvpSpec::fixed(0.0, 1.0, 0.25),
                    method: &*mali,
                };
                let acc = ode_under_attack(&mut ode, &test, eps, &attack_cfg, &eval_cfg)?;
                cells.push(format!("{acc:.3}"));
                rows.push(Json::obj(vec![
                    ("eps", Json::Num(eps)),
                    ("attack", Json::Str(atk_label.into())),
                    ("eval", Json::Str(eval_solver.into())),
                    ("acc", Json::Num(acc)),
                ]));
            }
            table.row(&cells);
        }
        let res_acc = resnet_under_attack(&resnet, &test, eps)?;
        table.row(&["resnet".into(), format!("{res_acc:.3}"), "".into(), "".into(), "".into()]);
        rows.push(Json::obj(vec![
            ("eps", Json::Num(eps)),
            ("attack", Json::Str("resnet".into())),
            ("eval", Json::Str("resnet".into())),
            ("acc", Json::Num(res_acc)),
        ]));
        table.print();
    }

    Ok(report::summary(rows, vec![("seed", Json::Num(seed as f64))]))
}

/// Per-method solver for the native runs (no Dopri5 needed at this scale:
/// order-matched RK2-family everywhere, ALF for MALI).
fn native_solver_for(method: &str) -> &'static str {
    match method {
        "mali" => "alf",
        "aca" => "heun-euler",
        _ => "rk2",
    }
}

/// E2 **native** — the Fig. 5 protocol on the artifact-free
/// fused-dynamics classifier ([`crate::models::native::NativeOdeClassifier`]):
/// synthetic CIFAR-shaped data, conv-stem ODE dynamics through the SIMD
/// kernels, all four gradient methods.  Runs under plain `cargo test` /
/// CI with no PJRT and no `make artifacts`.
pub fn fig5_native(scale: Scale, seed: u64) -> Result<Json> {
    use crate::models::native::NativeOdeClassifier;

    let spec = ImageSpec {
        side: 8,
        channels: 3,
        classes: 4,
        jitter: 0.3,
    };
    let batch = 8;
    let n_test = scale.pick(16, 64);
    let n = scale.pick(64, 512) + n_test;
    let (train, test) = generate(&spec, n, seed + 100).split(n_test);
    let epochs = scale.pick(2, 10);
    let lr = 0.3f32;

    let mut table = Table::new(
        "E2 native: fused conv-stem ODE classifier (no artifacts)",
        &["method", "final CE", "test acc", "f evals"],
    );
    let mut rows = Vec::new();
    for method in ["mali", "aca", "naive", "adjoint"] {
        let mut rng = Rng::new(seed);
        let mut model = NativeOdeClassifier::new(&spec, &[4], &mut rng);
        let solver = crate::solvers::by_name(native_solver_for(method))?;
        let grad = crate::grad::by_name(method)?;
        let cfg = SolveCfg {
            solver: &*solver,
            spec: IvpSpec::fixed(0.0, 1.0, 0.25),
            method: &*grad,
        };
        let mut order_rng = Rng::new(seed + 7);
        let mut loss = f64::NAN;
        let mut f_evals = 0u64;
        for _ in 0..epochs {
            for idxs in train.epoch_batches(batch, &mut order_rng) {
                let x = train.gather(&idxs);
                let y1h = train.one_hot(&idxs);
                let out = model.step(&x, &y1h, &cfg)?;
                loss = out.loss;
                f_evals += out.f_evals;
                for (v, g) in model.head.value.iter_mut().zip(model.head.grad.clone()) {
                    *v -= lr * g;
                }
                let th: Vec<f32> = model
                    .dynamics
                    .params()
                    .iter()
                    .zip(&model.dyn_grad)
                    .map(|(p, g)| p - lr * g)
                    .collect();
                model.dynamics.set_params(&th);
            }
        }
        let mut correct = 0.0f64;
        let mut n_eval = 0usize;
        for idxs in test.eval_batches(batch) {
            let x = test.gather(&idxs);
            let logits = model.predict(&x, &cfg)?;
            let y: Vec<usize> = idxs.iter().map(|&i| test.y[i]).collect();
            correct += model.accuracy(&logits, &y) * y.len() as f64;
            n_eval += y.len();
        }
        let acc = correct / n_eval as f64;
        table.row(&[
            method.into(),
            format!("{loss:.4}"),
            format!("{acc:.3}"),
            f_evals.to_string(),
        ]);
        rows.push(Json::obj(vec![
            ("method", Json::Str(method.into())),
            ("final_loss", Json::Num(loss)),
            ("test_acc", Json::Num(acc)),
            ("f_evals", Json::Num(f_evals as f64)),
        ]));
    }
    table.print();
    Ok(report::summary(
        rows,
        vec![
            ("epochs", Json::Num(epochs as f64)),
            ("train_n", Json::Num(train.len() as f64)),
            ("native", Json::Bool(true)),
        ],
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The Fig-6 feasibility gate is the paper's central claim: naive and
    /// ACA retain trajectory-sized state; MALI and adjoint do not.
    #[test]
    fn fig6_gate_orders_methods() {
        // Self-skips in the offline stub build (no artifacts / PJRT).
        let Some(engine) = Engine::from_env_or_skip("fig6 gate test") else {
            return;
        };
        let (train, _) =
            generate(&ImageSpec::imagenet_like(), 64, 1).split(16);
        let mali = probe_peak_mem(&engine, "mali", &train, 1).unwrap();
        let adjoint = probe_peak_mem(&engine, "adjoint", &train, 1).unwrap();
        let aca = probe_peak_mem(&engine, "aca", &train, 1).unwrap();
        let naive = probe_peak_mem(&engine, "naive", &train, 1).unwrap();
        assert!(adjoint <= mali, "adjoint {adjoint} vs mali {mali}");
        assert!(mali < aca, "mali {mali} vs aca {aca}");
        assert!(aca < naive, "aca {aca} vs naive {naive}");
    }

    /// E2 native runs end-to-end with no artifacts and no PJRT — the
    /// tier-1 guarantee the HLO-backed fig5 cannot give.
    #[test]
    fn e2_native_smoke() {
        let summary = fig5_native(Scale::Quick, 3).unwrap();
        let s = summary.dump();
        for method in ["mali", "aca", "naive", "adjoint"] {
            assert!(s.contains(method), "method {method} missing from summary");
        }
    }
}
