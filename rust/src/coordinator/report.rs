//! Run-summary persistence: every experiment writes a JSON summary under
//! `runs/` so EXPERIMENTS.md numbers are regenerable and diffable.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::fs;
use std::path::Path;

/// Write `runs/<name>.json` (directory created on demand).
pub fn write_summary(dir: &str, name: &str, summary: &Json) -> Result<()> {
    let d = Path::new(dir);
    fs::create_dir_all(d).with_context(|| format!("create {dir}"))?;
    let path = d.join(format!("{name}.json"));
    fs::write(&path, summary.pretty())
        .with_context(|| format!("write {}", path.display()))?;
    Ok(())
}

/// Convenience: a `{"rows": [...], "meta": {...}}` summary object.
pub fn summary(rows: Vec<Json>, meta: Vec<(&str, Json)>) -> Json {
    Json::obj(vec![
        ("rows", Json::Arr(rows)),
        ("meta", Json::obj(meta)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_readable_json() {
        let dir = std::env::temp_dir().join("mali_report_test");
        let summary = summary(
            vec![Json::obj(vec![("k", Json::Num(1.0))])],
            vec![("seed", Json::Num(0.0))],
        );
        write_summary(dir.to_str().unwrap(), "unit", &summary).unwrap();
        let back = Json::parse_file(&dir.join("unit.json")).unwrap();
        assert_eq!(back.get("rows").idx(0).get("k").as_f64(), Some(1.0));
        fs::remove_dir_all(dir).ok();
    }
}
