//! The experiment coordinator: registry of every paper table/figure
//! reproduction (E1–E11 in DESIGN.md §5), the job runner behind the CLI,
//! and the report writer.
//!
//! Each experiment is a library function so the criterion-style bench
//! targets (`rust/benches/*.rs`), the `mali run <exp>` CLI and the test
//! suite all drive the same code with different scale knobs.

pub mod exp_flows;
pub mod exp_images;
pub mod exp_serve;
pub mod exp_serve_tcp;
pub mod exp_session;
pub mod exp_series;
pub mod exp_toy;
pub mod report;

use crate::cli::{Args, USAGE};
use crate::util::logging::{log, set_level, Level};
use anyhow::Result;

/// Scale knob: `Quick` for CI-sized runs (seconds–minutes), `Full` for the
/// EXPERIMENTS.md numbers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Full,
}

impl Scale {
    pub fn from_args(args: &Args) -> Scale {
        if args.flag("full") {
            Scale::Full
        } else {
            Scale::Quick
        }
    }

    /// Pick `q` under Quick, `f` under Full.
    pub fn pick<T>(&self, q: T, f: T) -> T {
        match self {
            Scale::Quick => q,
            Scale::Full => f,
        }
    }
}

/// Registered experiments: (name, paper artifact, runner).
type Runner = fn(Scale, u64) -> Result<crate::util::json::Json>;

pub fn registry() -> Vec<(&'static str, &'static str, Runner)> {
    vec![
        ("fig4", "Fig. 4 (a,b,c) toy gradient error + memory", exp_toy::fig4 as Runner),
        ("table1", "Table 1 complexity accounting", exp_toy::table1 as Runner),
        ("figA1", "App. Fig. 1 damped-ALF stability regions", exp_toy::fig_a1 as Runner),
        ("fig5", "Fig. 5 Cifar-like: 4 methods + ResNet", exp_images::fig5 as Runner),
        ("fig5-native", "E2 native: fused conv-stem ODE classifier (no artifacts)", exp_images::fig5_native as Runner),
        ("fig6", "Fig. 6 ImageNet-like: MALI vs adjoint", exp_images::fig6 as Runner),
        ("table2", "Table 2 invariance to discretization", exp_images::table2 as Runner),
        ("table3", "Table 3 FGSM robustness grid", exp_images::table3 as Runner),
        ("table4", "Table 4 latent-ODE MSE on hopper", exp_series::table4 as Runner),
        ("table4-native", "E6 native: fused-MLP latent ODE on hopper (no artifacts)", exp_series::table4_native as Runner),
        ("table5", "Table 5 Neural-CDE speech accuracy", exp_series::table5 as Runner),
        ("table7", "Table 7 damped-MALI η ablation", exp_series::table7 as Runner),
        ("table6", "Table 6 FFJORD BPD + RealNVP", exp_flows::table6 as Runner),
        ("serve", "E12 online micro-batching serve bench (latency/throughput)", exp_serve::serve_bench as Runner),
        ("serve_tcp", "E13 TCP front-end serve bench (client-observed latency vs in-process)", exp_serve_tcp::serve_tcp_bench as Runner),
        ("serve_session", "E14 streaming sessions: incremental advance vs one-shot re-solve (bitwise-checked)", exp_session::serve_session_bench as Runner),
    ]
}

/// CLI entry point (called from `main.rs`).
pub fn cli_main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run_cli(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

pub fn run_cli(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    if args.flag("verbose") {
        set_level(Level::Debug);
    }
    let seed = args.opt("seed").and_then(|s| s.parse().ok()).unwrap_or(0u64);
    let scale = Scale::from_args(&args);

    match args.command.as_str() {
        "" | "help" => println!("{USAGE}"),
        "list" => {
            for (name, desc, _) in registry() {
                println!("{name:10} {desc}");
            }
        }
        "run" => {
            let Some(name) = args.positional.first() else {
                anyhow::bail!("usage: mali run <experiment> [--full] [--seed N]");
            };
            if name == "all" {
                for (n, desc, _) in registry() {
                    log(Level::Info, &format!("=== {n}: {desc} ==="));
                    run_experiment(n, scale, seed, &args.opt_or("runs", "runs"))?;
                }
            } else {
                run_experiment(name, scale, seed, &args.opt_or("runs", "runs"))?;
            }
        }
        "train" => {
            let Some(path) = args.positional.first() else {
                anyhow::bail!("usage: mali train <config.json> [--set a.b=c]");
            };
            let mut cfg = crate::config::Config::load(std::path::Path::new(path))?;
            for (k, v) in &args.overrides {
                cfg.set(k, v)?;
            }
            train_from_config(&cfg, &args.opt_or("runs", "runs"))?;
        }
        "smoke" => smoke()?,
        // discoverable top-level alias for `mali run serve` (the E12
        // load generator) — same dispatch, same runs/serve.json
        "serve-bench" => run_experiment("serve", scale, seed, &args.opt_or("runs", "runs"))?,
        // the multi-process E13 halves: a TCP server that runs until a
        // client sends SHUTDOWN, and the load generator that drives it
        "serve-tcp" => exp_serve_tcp::serve_tcp_cmd(&args)?,
        "serve-client-bench" => exp_serve_tcp::client_bench_cmd(&args)?,
        // E14: continual fine-tuning (hot_swap) against live streaming
        // session traffic over loopback TCP — asserts version pinning,
        // zero failures and exact admission accounting
        "finetune-serve" => exp_session::finetune_serve_cmd(&args)?,
        "toy" => {
            exp_toy::fig4(Scale::Quick, seed)?;
        }
        "stability" => {
            exp_toy::fig_a1(Scale::Quick, seed)?;
        }
        other => anyhow::bail!("unknown command '{other}'\n{USAGE}"),
    }
    Ok(())
}

/// Run one registered experiment and write `runs/<name>.json` — the
/// single dispatch behind `mali run <name>` and its aliases.
pub fn run_experiment(name: &str, scale: Scale, seed: u64, runs_dir: &str) -> Result<()> {
    let reg = registry();
    let Some((n, _, runner)) = reg.iter().find(|(n, _, _)| n == name) else {
        anyhow::bail!("unknown experiment '{name}'; `mali list` shows the registry");
    };
    let summary = runner(scale, seed)?;
    report::write_summary(runs_dir, n, &summary)
}

/// Train an image classifier from a `configs/*.json` file — the
/// config-system entry point (`mali train configs/img16_mali.json`).
pub fn train_from_config(cfg: &crate::config::Config, runs_dir: &str) -> Result<()> {
    use crate::data::images::{generate, ImageSpec};
    use crate::models::image::OdeImageClassifier;
    use crate::train::trainer::{ImageTrainer, TrainCfg};
    use crate::util::json::Json;

    let model_key = cfg.str("model", "img16");
    let spec = match model_key.as_str() {
        "img16" => ImageSpec::cifar_like(),
        "img32" => ImageSpec::imagenet_like(),
        other => anyhow::bail!("config model must be img16|img32, got '{other}'"),
    };
    let n_train = cfg.usize("data.n_train", 1600);
    let n_test = cfg.usize("data.n_test", 320);
    let data_seed = cfg.u64("data.seed", 42);
    let (train, test) = generate(&spec, n_train + n_test, data_seed).split(n_test);

    let tc = TrainCfg {
        epochs: cfg.usize("train.epochs", 6),
        lr: cfg.f64("train.lr", 0.05),
        momentum: cfg.f64("train.momentum", 0.9),
        weight_decay: cfg.f64("train.weight_decay", 5e-4),
        lr_drops: cfg
            .f64_list("train.lr_drops", &[])
            .into_iter()
            .map(|v| v as usize)
            .collect(),
        optimizer: cfg.str("train.optimizer", "sgd"),
        method: cfg.str("train.method", "mali"),
        solver: cfg.str("train.solver", "alf"),
        eta: cfg.f64("train.eta", 1.0),
        h: cfg.f64("train.h", 0.0),
        rtol: cfg.f64("train.rtol", 1e-1),
        atol: cfg.f64("train.atol", 1e-2),
        t_end: cfg.f64("train.t_end", 1.0),
        seed: cfg.u64("train.seed", 0),
    };
    let engine = std::rc::Rc::new(crate::runtime::Engine::from_env()?);
    let mut rng = crate::util::rng::Rng::new(tc.seed);
    let mut model = OdeImageClassifier::new(engine, &model_key, &mut rng)?;
    let report = ImageTrainer::new(tc).train_ode(&mut model, &train, &test)?;
    println!(
        "final accuracy {:.3} in {:.1}s (peak solver-state {})",
        report.final_acc,
        report.total_secs,
        crate::util::mem::fmt_bytes(report.peak_mem_bytes)
    );
    let rows: Vec<Json> = report
        .epochs
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("epoch", Json::Num(e.epoch as f64)),
                ("train_loss", Json::Num(e.train_loss)),
                ("test_acc", Json::Num(e.test_acc)),
            ])
        })
        .collect();
    report::write_summary(
        runs_dir,
        &format!("train_{}", cfg.name),
        &report::summary(rows, vec![("final_acc", Json::Num(report.final_acc))]),
    )?;
    Ok(())
}

/// Load + execute every artifact once — the runtime health check.
pub fn smoke() -> Result<()> {
    use crate::runtime::Engine;
    let engine = Engine::from_env()?;
    let names: Vec<String> = engine.manifest.entries.keys().cloned().collect();
    let mut ok = 0usize;
    for name in &names {
        let spec = engine.manifest.entry(name)?.clone();
        let inputs: Vec<Vec<f32>> = spec
            .inputs
            .iter()
            .map(|t| vec![0.1f32; t.len().max(1)])
            .collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        match engine.call(name, &refs) {
            Ok(outs) => {
                ok += 1;
                log(
                    Level::Debug,
                    &format!("{name}: {} outputs OK", outs.len()),
                );
            }
            Err(e) => anyhow::bail!("artifact '{name}' failed: {e:#}"),
        }
    }
    println!("smoke OK: {ok}/{} artifacts execute", names.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_paper_artifact() {
        let names: Vec<&str> = registry().iter().map(|(n, _, _)| *n).collect();
        for required in [
            "fig4", "fig5", "fig6", "table1", "table2", "table3", "table4", "table5",
            "table6", "table7", "figA1", "fig5-native", "table4-native",
        ] {
            assert!(names.contains(&required), "{required} missing from registry");
        }
    }

    #[test]
    fn scale_picks() {
        assert_eq!(Scale::Quick.pick(1, 2), 1);
        assert_eq!(Scale::Full.pick(1, 2), 2);
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run_cli(&["bogus".into()]).is_err());
        assert!(run_cli(&["run".into(), "nope".into()]).is_err());
    }
}
