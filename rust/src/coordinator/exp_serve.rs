//! E12 — online-serving latency/throughput: dynamic micro-batching vs
//! naive one-request-one-integration.
//!
//! A closed-loop load generator (C client threads, R requests each,
//! seeded random `z₀` rows) drives the same request stream through three
//! strategies × two stepping modes:
//!
//! * **naive** — no server: each client integrates its own request
//!   inline through the allocating [`integrate_obs`] wrapper (fresh
//!   workspace per call) — the baseline every serving claim is measured
//!   against;
//! * **solo** — the full queue/worker pipeline with coalescing disabled
//!   (`max_batch = 1`): isolates the cost of the queue hop and the
//!   benefit of warm per-worker workspaces;
//! * **coalesced** — dynamic micro-batching (`max_batch = 32`): queued
//!   compatible requests ride one batched solve;
//! * **coalesced-sh2 / coalesced-sh4** — coalesced plus intra-batch
//!   sharding (`ServerConfig::shards` ∈ {2, 4}): each micro-batch is
//!   split into contiguous row ranges solved concurrently, with bitwise
//!   the same responses — the p99 column is where the latency win lands.
//!
//! Reported per config: client-observed p50/p99/mean latency (exact,
//! via [`bench::quantile`] over raw samples), requests/sec, solver
//! steps/sec, mean batch occupancy and shed count, plus the server-side
//! [`ServeMetrics`](crate::serve::ServeMetrics) JSON.  Responses are
//! spot-checked against solo integrations — micro-batching must be a
//! pure scheduling change (`tests/serve.rs` pins bitwise equality).

use super::Scale;
use crate::serve::{ModelRegistry, RequestClass, Server, ServerConfig};
use crate::solvers::by_name as solver_by_name;
use crate::solvers::dynamics::LinearToy;
use crate::solvers::integrate::{integrate_obs, ErrorNorm, ObsGrid, StepMode};
use crate::util::bench::{quantile, Table};
use crate::util::json::Json;
use crate::util::pool;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// State width of the standard serving models (shared with E13).
pub const N_Z: usize = 8;
const ALPHA: f64 = -0.4;
/// Integration horizon of the standard serving request classes.
pub const T_END: f64 = 1.0;
/// Seed for the natively-served MLP's synthetic weights — fixed so any
/// client (or test) can rebuild the exact model the server holds.
const NATIVE_SERVE_SEED: u64 = 9;

/// The standard serving registry — "lin8" (LinearToy) plus "mlp8" (the
/// fused native MLP, deterministically seeded).  E12, the E13 TCP bench
/// and the `mali serve-tcp` server all build it from this one function,
/// so separate processes hold bitwise-identical models.
pub fn standard_registry() -> ModelRegistry {
    let mut registry = ModelRegistry::new();
    registry.register("lin8", Box::new(LinearToy::new(ALPHA, N_Z)));
    registry.register(
        "mlp8",
        Box::new(crate::dynamics_native::MlpDynamics::new(
            N_Z,
            &[16],
            crate::dynamics_native::TimeMode::Concat,
            &mut Rng::new(NATIVE_SERVE_SEED),
        )),
    );
    registry
}

/// One strategy × mode cell of the E12 grid.
struct CellResult {
    latencies_s: Vec<f64>,
    wall_s: f64,
    steps: u64,
    occupancy: f64,
    shed: u64,
    server_json: Option<Json>,
}

fn mk_mode(adaptive: bool) -> StepMode {
    if adaptive {
        StepMode::adaptive(1e-4, 1e-6)
    } else {
        StepMode::Fixed { h: 0.01 }
    }
}

/// Per-client request rows: deterministic in (seed, client, request).
pub(crate) fn client_z0(rng: &mut Rng) -> Vec<f32> {
    (0..N_Z).map(|_| rng.range(-1.0, 1.0) as f32).collect()
}

/// Naive baseline: inline per-request integration, no queue, no warm
/// workspace (the allocating wrapper), one thread per client.
fn run_naive(mode: &StepMode, clients: usize, requests: usize, seed: u64) -> Result<CellResult> {
    let toy = LinearToy::new(ALPHA, N_Z);
    let solver = solver_by_name("alf")?;
    let mut root = Rng::new(seed);
    let rngs: Vec<Rng> = (0..clients).map(|i| root.fork(i as u64)).collect();
    let t0 = Instant::now();
    let per_client: Vec<Result<(Vec<f64>, u64)>> = pool::par_map(&rngs, |rng| {
        let mut rng = rng.clone();
        let mut lats = Vec::with_capacity(requests);
        let mut steps = 0u64;
        for _ in 0..requests {
            let z0 = client_z0(&mut rng);
            let t = Instant::now();
            let s0 = solver.init(&toy, 0.0, &z0);
            let (_, stats) = integrate_obs(
                &*solver,
                &toy,
                0.0,
                T_END,
                s0,
                mode,
                &ErrorNorm::Full,
                &ObsGrid::none(),
                &mut (),
            )?;
            lats.push(t.elapsed().as_secs_f64());
            steps += stats.n_accepted as u64;
        }
        Ok((lats, steps))
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let mut latencies_s = Vec::new();
    let mut steps = 0u64;
    for r in per_client {
        let (lats, s) = r?;
        latencies_s.extend(lats);
        steps += s;
    }
    Ok(CellResult {
        latencies_s,
        wall_s,
        steps,
        occupancy: 1.0,
        shed: 0,
        server_json: None,
    })
}

/// Server-backed strategies: `max_batch = 1` (solo) or > 1 (coalesced);
/// `shards > 1` additionally splits every micro-batch across intra-batch
/// shard workers (bitwise the same responses — sharding is a pure
/// latency knob, the E12 p99 column is where it shows).
fn run_served(
    mode: &StepMode,
    clients: usize,
    requests: usize,
    seed: u64,
    max_batch: usize,
    workers: usize,
    shards: usize,
) -> Result<CellResult> {
    // the registry carries the fused native "mlp8" alongside the toy;
    // the E12 grid itself keeps driving lin8 for comparability with
    // earlier baselines
    let server = Server::start(
        Arc::new(standard_registry()),
        ServerConfig {
            queue_capacity: 1024,
            max_batch,
            max_wait: Duration::from_micros(500),
            workers,
            shards,
        },
    );
    let class = Arc::new(RequestClass::new(
        "lin8",
        "alf",
        N_Z,
        0.0,
        T_END,
        mode.clone(),
        ObsGrid::none(),
    )?);
    let mut root = Rng::new(seed);
    let rngs: Vec<Rng> = (0..clients).map(|i| root.fork(i as u64)).collect();
    let t0 = Instant::now();
    let per_client: Vec<Result<Vec<f64>>> = pool::par_map(&rngs, |rng| {
        let mut rng = rng.clone();
        let mut lats = Vec::with_capacity(requests);
        for _ in 0..requests {
            let z0 = client_z0(&mut rng);
            let t = Instant::now();
            // closed-loop client: on shed, back off briefly and retry
            let resp = loop {
                match server.submit(&class, &z0) {
                    Ok(handle) => break handle.wait()?,
                    Err(crate::serve::SubmitError::Overloaded { .. }) => {
                        std::thread::sleep(Duration::from_micros(200));
                    }
                    Err(e) => anyhow::bail!("submit failed: {e}"),
                }
            };
            lats.push(t.elapsed().as_secs_f64());
            ensure!(
                resp.z_final.len() == N_Z && resp.n_accepted > 0,
                "malformed response"
            );
        }
        Ok(lats)
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let metrics = server.shutdown();
    let shed = metrics.shed;
    let mut latencies_s = Vec::new();
    for r in per_client {
        latencies_s.extend(r?);
    }
    ensure!(
        metrics.requests as usize == clients * requests,
        "served {} of {} requests",
        metrics.requests,
        clients * requests
    );
    ensure!(metrics.failed == 0, "{} serve failures", metrics.failed);
    Ok(CellResult {
        latencies_s,
        wall_s,
        steps: metrics.steps,
        occupancy: metrics.batch_occupancy(),
        shed,
        server_json: Some(metrics.to_json()),
    })
}

/// E12 runner: the full strategy × mode grid.  Returns the summary for
/// `runs/serve.json` (uploaded by CI next to `BENCH_hotpath.json`).
pub fn serve_bench(scale: Scale, seed: u64) -> Result<Json> {
    let clients = scale.pick(4, 8);
    let requests = scale.pick(50, 400);
    let workers = pool::num_threads().clamp(1, 2);
    let mut table = Table::new(
        "E12: online serving — micro-batched vs naive (lower latency / higher throughput is better)",
        &["config", "req/s", "steps/s", "p50 ms", "p99 ms", "occupancy", "shed"],
    );
    let mut rows = Vec::new();
    for adaptive in [false, true] {
        let mode = mk_mode(adaptive);
        let mode_name = if adaptive { "adaptive" } else { "fixed" };
        for strategy in ["naive", "solo", "coalesced", "coalesced-sh2", "coalesced-sh4"] {
            let cell = match strategy {
                "naive" => run_naive(&mode, clients, requests, seed)?,
                "solo" => run_served(&mode, clients, requests, seed, 1, workers, 1)?,
                "coalesced" => run_served(&mode, clients, requests, seed, 32, workers, 1)?,
                "coalesced-sh2" => run_served(&mode, clients, requests, seed, 32, workers, 2)?,
                _ => run_served(&mode, clients, requests, seed, 32, workers, 4)?,
            };
            let n = cell.latencies_s.len();
            let p50 = quantile(&cell.latencies_s, 0.50) * 1e3;
            let p99 = quantile(&cell.latencies_s, 0.99) * 1e3;
            let mean = cell.latencies_s.iter().sum::<f64>() / n.max(1) as f64 * 1e3;
            let rps = n as f64 / cell.wall_s.max(1e-12);
            let sps = cell.steps as f64 / cell.wall_s.max(1e-12);
            let config = format!("{mode_name}/{strategy}");
            table.row(&[
                config.clone(),
                format!("{rps:.0}"),
                format!("{sps:.0}"),
                format!("{p50:.3}"),
                format!("{p99:.3}"),
                format!("{:.2}", cell.occupancy),
                cell.shed.to_string(),
            ]);
            let mut row = vec![
                ("config", Json::Str(config)),
                ("mode", Json::Str(mode_name.into())),
                ("strategy", Json::Str(strategy.into())),
                ("requests", Json::Num(n as f64)),
                ("wall_s", Json::Num(cell.wall_s)),
                ("p50_ms", Json::Num(p50)),
                ("p99_ms", Json::Num(p99)),
                ("mean_ms", Json::Num(mean)),
                ("requests_per_sec", Json::Num(rps)),
                ("steps_per_sec", Json::Num(sps)),
                ("batch_occupancy", Json::Num(cell.occupancy)),
                ("shed", Json::Num(cell.shed as f64)),
            ];
            if let Some(srv) = cell.server_json {
                row.push(("server", srv));
            }
            rows.push(Json::obj(row));
        }
    }
    table.print();
    Ok(crate::coordinator::report::summary(
        rows,
        vec![
            ("bench", Json::Str("serve".into())),
            ("seed", Json::Num(seed as f64)),
            ("clients", Json::Num(clients as f64)),
            ("requests_per_client", Json::Num(requests as f64)),
            ("workers", Json::Num(workers as f64)),
            ("n_z", Json::Num(N_Z as f64)),
        ],
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The whole E12 grid runs at a tiny scale and reports every cell.
    #[test]
    fn serve_bench_smoke() {
        // shrink further than Quick for the test suite: 2 clients × 8
        // requests exercise every strategy without burning CI seconds
        let mode = mk_mode(false);
        let naive = run_naive(&mode, 2, 8, 7).unwrap();
        assert_eq!(naive.latencies_s.len(), 16);
        assert!(naive.steps >= 16 * 100); // 100 fixed steps per request
        let solo = run_served(&mode, 2, 8, 7, 1, 1, 1).unwrap();
        assert_eq!(solo.latencies_s.len(), 16);
        assert_eq!(solo.occupancy, 1.0, "max_batch = 1 never coalesces");
        let coal = run_served(&mode, 2, 8, 7, 8, 1, 1).unwrap();
        assert_eq!(coal.latencies_s.len(), 16);
        assert!(coal.occupancy >= 1.0);
        assert_eq!(coal.shed, 0, "closed-loop load never saturates the queue");
        // sharded serving is the same stream, same step totals
        let sh = run_served(&mode, 2, 8, 7, 8, 1, 2).unwrap();
        assert_eq!(sh.latencies_s.len(), 16);
        assert_eq!(sh.steps, coal.steps, "sharding must not change step counts");
    }

    /// The fused native MLP serves through the micro-batching server and
    /// returns bitwise the same terminal state as a solo integration of
    /// an identically-seeded model — serving a native model is a pure
    /// scheduling change too.
    #[test]
    fn native_model_serves_bitwise() {
        use crate::dynamics_native::{MlpDynamics, TimeMode};

        let mut registry = ModelRegistry::new();
        registry.register(
            "mlp8",
            Box::new(MlpDynamics::new(
                N_Z,
                &[16],
                TimeMode::Concat,
                &mut Rng::new(NATIVE_SERVE_SEED),
            )),
        );
        let server = Server::start(
            Arc::new(registry),
            ServerConfig {
                queue_capacity: 64,
                max_batch: 8,
                max_wait: Duration::from_micros(500),
                workers: 1,
                shards: 1,
            },
        );
        let mode = StepMode::Fixed { h: 0.05 };
        let class = Arc::new(
            RequestClass::new("mlp8", "alf", N_Z, 0.0, T_END, mode.clone(), ObsGrid::none())
                .unwrap(),
        );
        let mut rng = Rng::new(31);
        let z0 = client_z0(&mut rng);
        let resp = server.submit(&class, &z0).unwrap().wait().unwrap();
        server.shutdown();

        let reference = MlpDynamics::new(
            N_Z,
            &[16],
            TimeMode::Concat,
            &mut Rng::new(NATIVE_SERVE_SEED),
        );
        let solver = solver_by_name("alf").unwrap();
        let s0 = solver.init(&reference, 0.0, &z0);
        let (s_end, _) = integrate_obs(
            &*solver,
            &reference,
            0.0,
            T_END,
            s0,
            &mode,
            &ErrorNorm::Full,
            &ObsGrid::none(),
            &mut (),
        )
        .unwrap();
        assert_eq!(resp.z_final, s_end.z, "served ≠ solo for the native MLP");
    }
}
