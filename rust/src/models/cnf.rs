//! FFJORD continuous normalizing flow (Grathwohl et al. 2018) with the
//! RNODE regularizers (Finlay et al. 2020) — paper Table 6.
//!
//! Augmented state per example: `[z (dim) | Δlogp | E_kin | E_jac]` with
//! `Δlogp(T) = ∫₀ᵀ −div f dt`, so the data log-density is
//! `log p(y) = log N(z_T) − Δlogp(T)` (instantaneous change of variables:
//! contraction must *cost* log-density, or the NLL objective is unbounded).
//! The exported dynamics returns `[f, −εᵀ(∂f/∂z)ε, ‖f‖², ‖εᵀJ‖²]` with a fixed
//! Rademacher probe `ε` riding along as ctx (Hutchinson divergence
//! estimator) — the probe is constant for a whole solve, so MALI's ψ⁻¹
//! reconstruction is exact.
//!
//! Pixel corpora use the standard dequantize → logit preprocessing with
//! its change-of-variables bookkeeping, so reported BPD is comparable in
//! kind to the paper's MNIST/CIFAR numbers.

use super::{ParamBlock, SolveCfg, StepOutput};
use crate::grad::FnLoss;
use crate::runtime::{Engine, HloDynamics};
use crate::solvers::dynamics::Dynamics;
use crate::util::mem::MemTracker;
use crate::util::rng::Rng;
use anyhow::Result;
use std::rc::Rc;

const LN2: f64 = std::f64::consts::LN_2;

/// Logit-transform squashing parameter (FFJORD uses 1e-6 for MNIST, 0.05
/// for CIFAR; we use 0.05 everywhere for robustness on synthetic data).
const ALPHA: f64 = 0.05;

pub struct Ffjord {
    #[allow(dead_code)] // retained: keeps the engine (and its exec cache) alive
    engine: Rc<Engine>,
    pub key: String, // "cnf_mnist8" | "cnf_cifar8" | "cnf_density2d"
    pub batch: usize,
    pub dim: usize,
    pub dynamics: HloDynamics,
    pub params: ParamBlock, // mirror of dynamics θ for the optimizer
    pub dyn_grad: Vec<f32>,
    /// RNODE regularization weights (kinetic, Jacobian-Frobenius).
    pub lambda_k: f64,
    pub lambda_j: f64,
    /// Pixel data: apply dequantize+logit preprocessing and the +8 BPD
    /// offset; 2-D densities skip it.
    pub is_pixels: bool,
}

impl Ffjord {
    pub fn new(engine: Rc<Engine>, key: &str, rng: &mut Rng) -> Result<Ffjord> {
        let model = engine.manifest.model(key)?.clone();
        let mut dynamics = HloDynamics::new(engine.clone(), key)?;
        dynamics.init_params(rng)?;
        let dyn_grad = vec![0.0; dynamics.param_dim()];
        let params = ParamBlock::new("f", dynamics.params().to_vec());
        Ok(Ffjord {
            batch: model.dim("batch")?,
            dim: model.dim("dim")?,
            params,
            dyn_grad,
            lambda_k: 0.05,
            lambda_j: 0.05,
            is_pixels: key != "cnf_density2d",
            dynamics,
            key: key.to_string(),
            engine,
        })
    }

    pub fn param_count(&self) -> usize {
        self.dynamics.param_dim()
    }

    /// Dequantize + logit-transform a pixel batch; returns `(y, logdet)`
    /// where `logdet` is the per-batch-total preprocessing log-Jacobian
    /// (to be *added* to the model log-likelihood).
    pub fn preprocess(&self, x: &[f32], rng: &mut Rng) -> (Vec<f32>, f64) {
        if !self.is_pixels {
            return (x.to_vec(), 0.0);
        }
        let mut logdet = 0.0f64;
        let y = x
            .iter()
            .map(|&p| {
                let q = ((p as f64 * 255.0).floor() + rng.uniform()) / 256.0;
                let s = ALPHA + (1.0 - 2.0 * ALPHA) * q;
                logdet += (1.0 - 2.0 * ALPHA).ln() - s.ln() - (1.0 - s).ln();
                (s / (1.0 - s)).ln() as f32
            })
            .collect();
        (y, logdet)
    }

    /// Pack pixel batch rows into the augmented state `[y | 0 | 0 | 0]`.
    fn pack_state(&self, y: &[f32]) -> Vec<f32> {
        let sd = self.dim + 3;
        let mut s = vec![0.0f32; self.batch * sd];
        for b in 0..self.batch {
            s[b * sd..b * sd + self.dim].copy_from_slice(&y[b * self.dim..(b + 1) * self.dim]);
        }
        s
    }

    /// Fresh Rademacher probe as the ctx tensor.
    fn set_probe(&mut self, rng: &mut Rng) -> Result<()> {
        let probe: Vec<f32> = (0..self.batch * self.dim)
            .map(|_| rng.rademacher())
            .collect();
        self.dynamics.set_ctx(0, probe)
    }

    /// Terminal loss over the augmented state: mean BPD of the flow-space
    /// log-likelihood plus RNODE regularizers.  Returns `(loss, grad)`.
    fn terminal_loss(&self, state: &[f32]) -> (f64, Vec<f32>) {
        let sd = self.dim + 3;
        let b = self.batch as f64;
        let d = self.dim as f64;
        let nat_scale = 1.0 / (b * d * LN2); // nats → mean bits/dim
        let mut loss = 0.0f64;
        let mut grad = vec![0.0f32; state.len()];
        for i in 0..self.batch {
            let row = &state[i * sd..(i + 1) * sd];
            let z = &row[..self.dim];
            let dlogp = row[self.dim] as f64;
            let (ke, je) = (row[self.dim + 1] as f64, row[self.dim + 2] as f64);
            let z2: f64 = z.iter().map(|&v| (v as f64) * (v as f64)).sum();
            let log_n = -0.5 * z2 - 0.5 * d * (2.0 * std::f64::consts::PI).ln();
            // negative log-likelihood in bits/dim: log p(y) = logN − Δlogp
            loss += -(log_n - dlogp) * nat_scale;
            loss += (self.lambda_k * ke + self.lambda_j * je) / b;
            for j in 0..self.dim {
                grad[i * sd + j] = (row[j] as f64 * nat_scale) as f32; // −∂logN/∂z = z
            }
            grad[i * sd + self.dim] = nat_scale as f32;
            grad[i * sd + self.dim + 1] = (self.lambda_k / b) as f32;
            grad[i * sd + self.dim + 2] = (self.lambda_j / b) as f32;
        }
        (loss, grad)
    }

    /// One training step on a pixel/2-D batch `x` (`batch × dim`).
    pub fn step(&mut self, x: &[f32], cfg: &SolveCfg, rng: &mut Rng) -> Result<StepOutput> {
        self.set_probe(rng)?;
        let (y, _logdet) = self.preprocess(x, rng);
        let s0 = self.pack_state(&y);
        self.dynamics.set_params(&self.params.value);

        let res = {
            let this = &*self;
            let loss_head = FnLoss(|s_t: &[f32]| this.terminal_loss(s_t));
            let tracker = MemTracker::new();
            cfg.method.grad(
                &self.dynamics,
                cfg.solver,
                &cfg.spec,
                &s0,
                &loss_head,
                tracker,
            )?
        };
        self.dyn_grad.copy_from_slice(&res.grad_theta);
        self.params.grad.copy_from_slice(&res.grad_theta);
        Ok(StepOutput {
            loss: res.loss,
            peak_mem_bytes: res.stats.peak_mem_bytes,
            n_steps: res.stats.fwd.n_accepted,
            f_evals: res.stats.f_evals,
            ..StepOutput::default()
        })
    }

    /// Evaluation BPD (regularizers off, preprocessing bookkeeping in):
    /// the Table-6 metric.
    pub fn bpd(&mut self, x: &[f32], cfg: &SolveCfg, rng: &mut Rng) -> Result<f64> {
        self.set_probe(rng)?;
        let (y, logdet) = self.preprocess(x, rng);
        let s0 = self.pack_state(&y);
        self.dynamics.set_params(&self.params.value);
        let s0_state = cfg.solver.init(&self.dynamics, cfg.spec.t0, &s0);
        let (s_end, _) = crate::solvers::integrate::integrate(
            cfg.solver,
            &self.dynamics,
            cfg.spec.t0,
            cfg.spec.t1,
            s0_state,
            &cfg.spec.mode,
            &cfg.spec.norm,
            &mut (),
        )?;
        let sd = self.dim + 3;
        let (b, d) = (self.batch as f64, self.dim as f64);
        let mut nll_bits = 0.0f64; // mean bits/dim over the batch
        for i in 0..self.batch {
            let row = &s_end.z[i * sd..(i + 1) * sd];
            let z2: f64 = row[..self.dim]
                .iter()
                .map(|&v| (v as f64) * (v as f64))
                .sum();
            let log_n = -0.5 * z2 - 0.5 * d * (2.0 * std::f64::consts::PI).ln();
            nll_bits += -(log_n - row[self.dim] as f64) / (d * LN2);
        }
        nll_bits /= b;
        if self.is_pixels {
            // discrete BPD: subtract preprocessing log-det, add log2(256)
            Ok(nll_bits - logdet / (b * d * LN2) + 8.0)
        } else {
            Ok(nll_bits)
        }
    }

    /// Generate samples: integrate the flow in reverse from `z ~ N(0, I)`
    /// and undo the logit preprocessing.  Returns `batch × dim` in [0, 1]
    /// for pixel corpora (raw coordinates for 2-D).
    pub fn sample(&mut self, cfg: &SolveCfg, rng: &mut Rng) -> Result<Vec<f32>> {
        self.set_probe(rng)?;
        self.dynamics.set_params(&self.params.value);
        let sd = self.dim + 3;
        let mut s = vec![0.0f32; self.batch * sd];
        for b in 0..self.batch {
            for j in 0..self.dim {
                s[b * sd + j] = rng.normal() as f32;
            }
        }
        let s0 = cfg.solver.init(&self.dynamics, cfg.spec.t1, &s);
        let (s_end, _) = crate::solvers::integrate::integrate(
            cfg.solver,
            &self.dynamics,
            cfg.spec.t1,
            cfg.spec.t0, // reverse time
            s0,
            &cfg.spec.mode,
            &cfg.spec.norm,
            &mut (),
        )?;
        let mut out = Vec::with_capacity(self.batch * self.dim);
        for b in 0..self.batch {
            for j in 0..self.dim {
                let y = s_end.z[b * sd + j] as f64;
                if self.is_pixels {
                    let sgm = 1.0 / (1.0 + (-y).exp());
                    out.push((((sgm - ALPHA) / (1.0 - 2.0 * ALPHA)).clamp(0.0, 1.0)) as f32);
                } else {
                    out.push(y as f32);
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::density::{self, Density2D};
    use crate::grad::IvpSpec;
    use crate::solvers::by_name;

    fn engine() -> Option<Rc<Engine>> {
        Engine::from_env_or_skip("model test")
    }

    fn cfg<'a>(
        solver: &'a dyn crate::solvers::Solver,
        method: &'a dyn crate::grad::GradMethod,
    ) -> SolveCfg<'a> {
        SolveCfg {
            solver,
            spec: IvpSpec::fixed(0.0, 1.0, 0.25),
            method,
        }
    }

    #[test]
    fn terminal_loss_grad_matches_fd() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(1);
        let m = Ffjord::new(e, "cnf_density2d", &mut rng).unwrap();
        let sd = m.dim + 3;
        let mut state = vec![0.0f32; m.batch * sd];
        rng.fill_normal(&mut state, 0.7);
        let (_, grad) = m.terminal_loss(&state);
        let eps = 1e-3f32;
        for &k in &[0usize, m.dim, m.dim + 1, sd + 2, 3 * sd] {
            let mut sp = state.clone();
            sp[k] += eps;
            let mut sm = state.clone();
            sm[k] -= eps;
            let fd = (m.terminal_loss(&sp).0 - m.terminal_loss(&sm).0) / (2.0 * eps as f64);
            assert!(
                (fd - grad[k] as f64).abs() < 1e-3,
                "state[{k}]: fd {fd} vs {}",
                grad[k]
            );
        }
    }

    #[test]
    fn density2d_trains_and_bpd_drops() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(2);
        let mut m = Ffjord::new(e, "cnf_density2d", &mut rng).unwrap();
        m.lambda_k = 0.01;
        m.lambda_j = 0.01;
        let solver = by_name("alf").unwrap();
        let method = crate::grad::by_name("mali").unwrap();
        let c = cfg(&*solver, &*method);
        let x = Density2D::EightGaussians.sample_n(m.batch, &mut rng);
        let before = m.bpd(&x, &c, &mut rng).unwrap();
        let lr = 0.02f32;
        for _ in 0..12 {
            m.step(&x, &c, &mut rng).unwrap();
            for (v, g) in m.params.value.iter_mut().zip(m.dyn_grad.clone()) {
                *v -= lr * g;
            }
        }
        let after = m.bpd(&x, &c, &mut rng).unwrap();
        assert!(
            after < before,
            "BPD did not improve: {before} → {after}"
        );
    }

    #[test]
    fn pixel_bpd_bookkeeping_in_sane_range() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(3);
        let mut m = Ffjord::new(e, "cnf_mnist8", &mut rng).unwrap();
        let ds = density::mnist8(m.batch, 4);
        let solver = by_name("alf").unwrap();
        let method = crate::grad::by_name("mali").unwrap();
        let c = cfg(&*solver, &*method);
        let bpd = m.bpd(&ds.x[..m.batch * m.dim], &c, &mut rng).unwrap();
        // untrained flow ≈ identity: BPD should be finite and near the
        // dequantized-uniform baseline (≈ 8-ish bits), not astronomical
        assert!(bpd.is_finite() && bpd > 0.0 && bpd < 30.0, "bpd {bpd}");
    }

    #[test]
    fn sample_roundtrip_shapes() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(5);
        let mut m = Ffjord::new(e, "cnf_density2d", &mut rng).unwrap();
        let solver = by_name("alf").unwrap();
        let method = crate::grad::by_name("mali").unwrap();
        let c = cfg(&*solver, &*method);
        let s = m.sample(&c, &mut rng).unwrap();
        assert_eq!(s.len(), m.batch * m.dim);
        assert!(s.iter().all(|v| v.is_finite()));
    }
}
