//! Artifact-free counterparts of the E2 / E8 experiment models, built on
//! the fused [`crate::dynamics_native`] backends instead of AOT HLO
//! executables — so the full train/predict loop runs under plain
//! `cargo test` with synthetic weights (DESIGN.md §5).
//!
//! * [`NativeOdeClassifier`] — E2's CIFAR-shaped ODE classifier: the image
//!   itself is the ODE state, a [`ConvStemDynamics`] conv stack is the
//!   right-hand side, and a linear softmax-CE head reads the terminal
//!   state.  Stems/heads stay on the host; the gradient method under test
//!   only ever sees the fused dynamics.
//! * [`NativeLatentOde`] — E8's latent ODE: linear encoder over the
//!   observed prefix → latent [`MlpDynamics`] (time-concat) → linear
//!   decoder, trained with per-frame MSE on the prediction grid through
//!   `grad_obs_batched` exactly like the HLO-backed [`super::latent`].

use super::{ParamBlock, SolveCfg, StepOutput};
use crate::data::images::ImageSpec;
use crate::dynamics_native::{ConvStemDynamics, MlpDynamics, TimeMode};
use crate::grad::batch_driver::{grad_batched, grad_obs_batched};
use crate::grad::{BatchLossHead, FusedObsLoss, ObsGrid};
use crate::solvers::batch::BatchSpec;
use crate::solvers::dynamics::Dynamics;
use crate::solvers::integrate::StepObserver;
use crate::solvers::State;
use crate::tensor::{argmax_rows, axpy, matmul_into};
use crate::util::mem::MemTracker;
use crate::util::rng::Rng;
use anyhow::Result;
use std::cell::RefCell;

/// `x · W + b` for row-major `x: [batch, din]`, `W: [din, dout]`.
fn linear_fwd(x: &[f32], w: &[f32], b: &[f32], batch: usize, din: usize, dout: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; batch * dout];
    matmul_into(x, w, batch, din, dout, &mut out);
    for r in 0..batch {
        axpy(1.0, b, &mut out[r * dout..(r + 1) * dout]);
    }
    out
}

/// `a · Wᵀ` — the input cotangent of [`linear_fwd`].
fn linear_bwd_x(a: &[f32], w: &[f32], batch: usize, din: usize, dout: usize) -> Vec<f32> {
    let mut wt = vec![0.0f32; dout * din];
    for i in 0..din {
        for o in 0..dout {
            wt[o * din + i] = w[i * dout + o];
        }
    }
    let mut ax = vec![0.0f32; batch * din];
    matmul_into(a, &wt, batch, dout, din, &mut ax);
    ax
}

/// Accumulate `gw += xᵀ·a`, `gb += column-sums(a)`.
fn linear_grads(
    x: &[f32],
    a: &[f32],
    batch: usize,
    din: usize,
    dout: usize,
    gw: &mut [f32],
    gb: &mut [f32],
) {
    let mut xt = vec![0.0f32; din * batch];
    for r in 0..batch {
        for i in 0..din {
            xt[i * batch + r] = x[r * din + i];
        }
    }
    let mut dw = vec![0.0f32; din * dout];
    matmul_into(&xt, a, din, batch, dout, &mut dw);
    axpy(1.0, &dw, gw);
    for r in 0..batch {
        axpy(1.0, &a[r * dout..(r + 1) * dout], gb);
    }
}

// ---------------------------------------------------------------------------
// E2: native ODE image classifier
// ---------------------------------------------------------------------------

/// Neural-ODE image classifier over synthetic CIFAR-shaped data with the
/// conv-stem dynamics as the ODE block and a host-side linear softmax-CE
/// head.  The image is the ODE state (`z₀ = x`), as in the paper's
/// "replace the residual block" construction.
pub struct NativeOdeClassifier {
    pub spec: ImageSpec,
    /// Flattened state dimension `side²·channels`.
    pub d: usize,
    /// Linear head: `W [d × classes]` then `b [classes]`, one flat block.
    pub head: ParamBlock,
    pub dynamics: ConvStemDynamics,
    /// Gradient of the dynamics parameters from the last [`Self::step`].
    pub dyn_grad: Vec<f32>,
}

impl NativeOdeClassifier {
    /// Build for an [`ImageSpec`] with intermediate conv channel widths
    /// `mid` (the dynamics chain is `channels → mid… → channels`).
    pub fn new(spec: &ImageSpec, mid: &[usize], rng: &mut Rng) -> NativeOdeClassifier {
        let d = spec.dim();
        let dynamics = ConvStemDynamics::new(spec.side, spec.channels, mid, TimeMode::Affine, rng);
        let mut head_init = vec![0.0f32; d * spec.classes + spec.classes];
        rng.fill_normal(&mut head_init[..d * spec.classes], 0.8 / (d as f64).sqrt());
        let dyn_grad = vec![0.0; dynamics.param_dim()];
        NativeOdeClassifier {
            spec: spec.clone(),
            d,
            head: ParamBlock::new("head", head_init),
            dynamics,
            dyn_grad,
        }
    }

    pub fn param_count(&self) -> usize {
        self.head.len() + self.dynamics.param_dim()
    }

    /// Batch-mean softmax cross entropy of the linear head on terminal
    /// states `z`: returns `(loss, logits, a_z, a_θ_head)`.
    fn head_loss(&self, z: &[f32], y1h: &[f32], batch: usize) -> (f64, Vec<f32>, Vec<f32>, Vec<f32>) {
        let c = self.spec.classes;
        let w = &self.head.value[..self.d * c];
        let b = &self.head.value[self.d * c..];
        let logits = linear_fwd(z, w, b, batch, self.d, c);
        let mut loss = 0.0f64;
        let mut a_logits = vec![0.0f32; batch * c];
        let inv_b = 1.0 / batch as f64;
        for r in 0..batch {
            let row = &logits[r * c..(r + 1) * c];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f64> = row.iter().map(|&l| ((l - m) as f64).exp()).collect();
            let denom: f64 = exps.iter().sum();
            for j in 0..c {
                let p = exps[j] / denom;
                let y = y1h[r * c + j] as f64;
                if y > 0.0 {
                    loss -= y * (p.max(1e-12)).ln();
                }
                a_logits[r * c + j] = ((p - y) * inv_b) as f32;
            }
        }
        loss *= inv_b;
        let a_z = linear_bwd_x(&a_logits, w, batch, self.d, c);
        let mut ath = vec![0.0f32; self.head.len()];
        {
            let (gw, gb) = ath.split_at_mut(self.d * c);
            linear_grads(z, &a_logits, batch, self.d, c, gw, gb);
        }
        (loss, logits, a_z, ath)
    }

    /// Inference logits for a flat `[batch, d]` image block.
    pub fn predict(&self, x: &[f32], cfg: &SolveCfg) -> Result<Vec<f32>> {
        let batch = x.len() / self.d;
        let s0 = cfg.solver.init(&self.dynamics, cfg.spec.t0, x);
        let (s_end, _) = crate::solvers::integrate::integrate(
            cfg.solver,
            &self.dynamics,
            cfg.spec.t0,
            cfg.spec.t1,
            s0,
            &cfg.spec.mode,
            &cfg.spec.norm,
            &mut (),
        )?;
        let dummy = vec![0.0f32; batch * self.spec.classes];
        let (_, logits, _, _) = self.head_loss(&s_end.z, &dummy, batch);
        Ok(logits)
    }

    pub fn accuracy(&self, logits: &[f32], y: &[usize]) -> f64 {
        let pred = argmax_rows(logits, y.len(), self.spec.classes);
        let correct = pred.iter().zip(y).filter(|(p, t)| p == t).count();
        correct as f64 / y.len() as f64
    }

    /// One training step on a flat `[batch, d]` image block with one-hot
    /// labels; gradients land in `head.grad` / `dyn_grad`.
    pub fn step(&mut self, x: &[f32], y1h: &[f32], cfg: &SolveCfg) -> Result<StepOutput> {
        let batch = x.len() / self.d;
        let (res, logits, ath) = {
            let stash: RefCell<(Vec<f32>, Vec<f32>)> = RefCell::new((vec![], vec![]));
            let head = NativeImageHead {
                model: self,
                y1h,
                batch,
                stash: &stash,
            };
            let res = grad_batched(
                cfg.method,
                &self.dynamics,
                cfg.solver,
                &cfg.spec,
                x,
                &BatchSpec::new(batch, self.d),
                &head,
                MemTracker::new(),
            )?;
            let (logits, ath) = stash.into_inner();
            (res, logits, ath)
        };
        self.head.grad.copy_from_slice(&ath);
        self.dyn_grad.copy_from_slice(&res.grad_theta);
        Ok(StepOutput {
            loss: res.loss,
            logits,
            peak_mem_bytes: res.stats.peak_mem_bytes,
            n_steps: res.stats.fwd.n_accepted,
            f_evals: res.stats.f_evals,
            ..StepOutput::default()
        })
    }
}

/// Host-side linear softmax-CE head; reports one batch total and stashes
/// `(logits, a_θ_head)` like the fused device head it mirrors.
struct NativeImageHead<'a> {
    model: &'a NativeOdeClassifier,
    y1h: &'a [f32],
    batch: usize,
    stash: &'a RefCell<(Vec<f32>, Vec<f32>)>,
}

impl BatchLossHead for NativeImageHead<'_> {
    fn loss_grad_batch(&self, z_t: &[f32], _spec: &BatchSpec) -> (Vec<f64>, Vec<f32>) {
        let (loss, logits, az, ath) = self.model.head_loss(z_t, self.y1h, self.batch);
        *self.stash.borrow_mut() = (logits, ath);
        (vec![loss], az)
    }

    /// The head itself is row-separable, but the stash side-channel is
    /// not `Sync`; run it unsharded.
    fn separable(&self) -> bool {
        false
    }
}

// ---------------------------------------------------------------------------
// E8: native latent ODE
// ---------------------------------------------------------------------------

/// Latent ODE for the hopper time-series task with every stage native:
/// deterministic linear encoder over the flattened observed prefix,
/// time-concat [`MlpDynamics`] in latent space, linear decoder + per-frame
/// MSE on the prediction grid (one `grad_obs_batched` pass, as in the
/// HLO-backed model — MALI keeps its single continuous ψ⁻¹ reverse sweep).
pub struct NativeLatentOde {
    pub obs: usize,
    pub t_len: usize,
    pub t_out: usize,
    pub latent: usize,
    /// Encoder: `W [t_len·obs × latent]` then `b [latent]`.
    pub enc: ParamBlock,
    /// Decoder: `W [latent × obs]` then `b [obs]`.
    pub dec: ParamBlock,
    pub dynamics: MlpDynamics,
    pub dyn_grad: Vec<f32>,
}

impl NativeLatentOde {
    pub fn new(
        obs: usize,
        t_len: usize,
        t_out: usize,
        latent: usize,
        hidden: &[usize],
        rng: &mut Rng,
    ) -> NativeLatentOde {
        let d_in = t_len * obs;
        let mut enc_init = vec![0.0f32; d_in * latent + latent];
        rng.fill_normal(&mut enc_init[..d_in * latent], 1.0 / (d_in as f64).sqrt());
        let mut dec_init = vec![0.0f32; latent * obs + obs];
        rng.fill_normal(&mut dec_init[..latent * obs], 1.0 / (latent as f64).sqrt());
        let dynamics = MlpDynamics::new(latent, hidden, TimeMode::Concat, rng);
        let dyn_grad = vec![0.0; dynamics.param_dim()];
        NativeLatentOde {
            obs,
            t_len,
            t_out,
            latent,
            enc: ParamBlock::new("enc", enc_init),
            dec: ParamBlock::new("dec", dec_init),
            dynamics,
            dyn_grad,
        }
    }

    pub fn param_count(&self) -> usize {
        self.enc.len() + self.dec.len() + self.dynamics.param_dim()
    }

    fn encode(&self, seq: &[f32], batch: usize) -> Vec<f32> {
        let d_in = self.t_len * self.obs;
        let w = &self.enc.value[..d_in * self.latent];
        let b = &self.enc.value[d_in * self.latent..];
        linear_fwd(seq, w, b, batch, d_in, self.latent)
    }

    fn decode(&self, z: &[f32], batch: usize) -> Vec<f32> {
        let w = &self.dec.value[..self.latent * self.obs];
        let b = &self.dec.value[self.latent * self.obs..];
        linear_fwd(z, w, b, batch, self.latent, self.obs)
    }

    /// Prediction times for the `t_out` future frames, uniform on `(0, 1]`.
    fn pred_times(&self) -> Vec<f64> {
        (1..=self.t_out)
            .map(|k| k as f64 / self.t_out as f64)
            .collect()
    }

    /// Predict the future frames for the observed prefix: one
    /// observation-aware integration, decoding the exact-hit states.
    /// Returns `batch × t_out × obs`.
    pub fn predict(&self, seq: &[f32], batch: usize, cfg: &SolveCfg) -> Result<Vec<f32>> {
        let z0 = self.encode(seq, batch);
        let grid = ObsGrid::new(self.pred_times())?;
        struct Frames(Vec<Vec<f32>>);
        impl StepObserver for Frames {
            fn on_observation(&mut self, _k: usize, _t: f64, state: &State) {
                self.0.push(state.z.clone());
            }
        }
        let s0 = cfg.solver.init(&self.dynamics, cfg.spec.t0, &z0);
        let mut frames = Frames(Vec::with_capacity(self.t_out));
        crate::solvers::integrate::integrate_obs(
            cfg.solver,
            &self.dynamics,
            cfg.spec.t0,
            cfg.spec.t1,
            s0,
            &cfg.spec.mode,
            &cfg.spec.norm,
            &grid,
            &mut frames,
        )?;
        let mut out = vec![0.0f32; batch * self.t_out * self.obs];
        for (k, z) in frames.0.iter().enumerate() {
            let block = self.decode(z, batch);
            for b in 0..batch {
                let dst = (b * self.t_out + k) * self.obs;
                out[dst..dst + self.obs]
                    .copy_from_slice(&block[b * self.obs..(b + 1) * self.obs]);
            }
        }
        Ok(out)
    }

    /// Mean squared error over a `batch × t_out × obs` prediction block.
    pub fn mse(preds: &[f32], target: &[f32]) -> f64 {
        preds
            .iter()
            .zip(target)
            .map(|(p, t)| ((p - t) as f64).powi(2))
            .sum::<f64>()
            / preds.len() as f64
    }

    /// One training step: `seq` is `batch × t_len × obs`, `target` is
    /// `batch × t_out × obs` (time-major per example, hopper layout).
    pub fn step(&mut self, seq: &[f32], target: &[f32], cfg: &SolveCfg) -> Result<StepOutput> {
        let d_in = self.t_len * self.obs;
        let batch = seq.len() / d_in;
        let z0 = self.encode(seq, batch);
        let n_total = (batch * self.t_out * self.obs) as f64;
        let dec_grad = RefCell::new(vec![0.0f32; self.dec.len()]);
        let res = {
            let this = &*self;
            let head = FusedObsLoss(|k: usize, _t: f64, z: &[f32]| {
                let pred = this.decode(z, batch);
                let mut loss_k = 0.0f64;
                let mut a_obs = vec![0.0f32; pred.len()];
                for b in 0..batch {
                    for j in 0..this.obs {
                        let diff =
                            pred[b * this.obs + j] - target[(b * this.t_out + k) * this.obs + j];
                        loss_k += (diff as f64) * (diff as f64);
                        a_obs[b * this.obs + j] = 2.0 * diff / n_total as f32;
                    }
                }
                let w = &this.dec.value[..this.latent * this.obs];
                let az = linear_bwd_x(&a_obs, w, batch, this.latent, this.obs);
                {
                    let mut dg = dec_grad.borrow_mut();
                    let (gw, gb) = dg.split_at_mut(this.latent * this.obs);
                    linear_grads(z, &a_obs, batch, this.latent, this.obs, gw, gb);
                }
                (loss_k / n_total, az)
            });
            let grid = ObsGrid::new(this.pred_times())?;
            grad_obs_batched(
                cfg.method,
                &this.dynamics,
                cfg.solver,
                &cfg.spec,
                &grid,
                &z0,
                &BatchSpec::new(batch, this.latent),
                &head,
                MemTracker::new(),
            )?
        };
        self.dyn_grad.copy_from_slice(&res.grad_theta);
        // encoder backward from a_z0
        self.enc.zero_grad();
        {
            let (gw, gb) = self.enc.grad.split_at_mut(d_in * self.latent);
            linear_grads(seq, &res.grad_z0, batch, d_in, self.latent, gw, gb);
        }
        self.dec.grad.copy_from_slice(&dec_grad.into_inner());
        Ok(StepOutput {
            loss: res.loss,
            peak_mem_bytes: res.stats.peak_mem_bytes,
            n_steps: res.stats.fwd.n_accepted,
            f_evals: res.stats.f_evals,
            ..StepOutput::default()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::images;
    use crate::grad::IvpSpec;
    use crate::sim::hopper;
    use crate::solvers::by_name;

    fn cfg<'a>(
        solver: &'a dyn crate::solvers::Solver,
        method: &'a dyn crate::grad::GradMethod,
    ) -> SolveCfg<'a> {
        SolveCfg {
            solver,
            spec: IvpSpec::fixed(0.0, 1.0, 0.25),
            method,
        }
    }

    /// E2 native: the classifier trains end-to-end on synthetic images
    /// under `cargo test` — no artifacts, no PJRT.
    #[test]
    fn native_classifier_step_and_learns() {
        let spec = ImageSpec {
            side: 8,
            channels: 3,
            classes: 4,
            jitter: 0.3,
        };
        let mut rng = Rng::new(11);
        let mut m = NativeOdeClassifier::new(&spec, &[4], &mut rng);
        let ds = images::generate(&spec, 8, 21);
        let idx: Vec<usize> = (0..8).collect();
        let (x, y1h) = (ds.gather(&idx), ds.one_hot(&idx));
        let solver = by_name("alf").unwrap();
        let method = crate::grad::by_name("mali").unwrap();
        let c = cfg(&*solver, &*method);
        let out0 = m.step(&x, &y1h, &c).unwrap();
        assert!(out0.loss.is_finite() && out0.loss > 0.0);
        assert_eq!(out0.logits.len(), 8 * spec.classes);
        assert!(m.head.grad.iter().any(|&g| g != 0.0), "head grad zero");
        assert!(m.dyn_grad.iter().any(|&g| g != 0.0), "dynamics grad zero");
        let lr = 0.4f32;
        let mut last = out0.loss;
        for _ in 0..12 {
            for (v, g) in m.head.value.iter_mut().zip(m.head.grad.clone()) {
                *v -= lr * g;
            }
            let th: Vec<f32> = m
                .dynamics
                .params()
                .iter()
                .zip(&m.dyn_grad)
                .map(|(p, g)| p - lr * g)
                .collect();
            m.dynamics.set_params(&th);
            last = m.step(&x, &y1h, &c).unwrap().loss;
        }
        assert!(last < out0.loss, "CE did not decrease: {} → {last}", out0.loss);
        let logits = m.predict(&x, &c).unwrap();
        let acc = m.accuracy(&logits, &ds.y[..8]);
        assert!((0.0..=1.0).contains(&acc));
    }

    /// E6 native: the latent ODE trains on hopper sequences under
    /// `cargo test`, and all four gradient methods produce close dynamics
    /// gradients on it.
    #[test]
    fn native_latent_ode_step_and_learns() {
        let (batch, t_len, t_out) = (4, 6, 3);
        let mut rng = Rng::new(13);
        let mut m = NativeLatentOde::new(hopper::OBS_DIM, t_len, t_out, 6, &[12], &mut rng);
        let ds = hopper::generate(batch, t_len, t_out, 3.0, 23);
        let mut seq = Vec::new();
        let mut tgt = Vec::new();
        for i in 0..batch {
            seq.extend_from_slice(ds.observed(i, t_len));
            tgt.extend_from_slice(ds.target(i, t_len, t_out));
        }
        let solver = by_name("alf").unwrap();
        let method = crate::grad::by_name("mali").unwrap();
        let c = cfg(&*solver, &*method);
        let out0 = m.step(&seq, &tgt, &c).unwrap();
        assert!(out0.loss.is_finite() && out0.loss > 0.0);
        assert!(m.enc.grad.iter().any(|&g| g != 0.0), "encoder grad zero");
        assert!(m.dec.grad.iter().any(|&g| g != 0.0), "decoder grad zero");
        assert!(m.dyn_grad.iter().any(|&g| g != 0.0), "dynamics grad zero");
        let lr = 0.05f32;
        let mut last = out0.loss;
        for _ in 0..10 {
            for (v, g) in m.enc.value.iter_mut().zip(m.enc.grad.clone()) {
                *v -= lr * g;
            }
            for (v, g) in m.dec.value.iter_mut().zip(m.dec.grad.clone()) {
                *v -= lr * g;
            }
            let th: Vec<f32> = m
                .dynamics
                .params()
                .iter()
                .zip(&m.dyn_grad)
                .map(|(p, g)| p - lr * g)
                .collect();
            m.dynamics.set_params(&th);
            last = m.step(&seq, &tgt, &c).unwrap().loss;
        }
        assert!(last < out0.loss, "MSE did not decrease: {} → {last}", out0.loss);
        let p = m.predict(&seq, batch, &c).unwrap();
        assert_eq!(p.len(), tgt.len());
        assert!(NativeLatentOde::mse(&p, &tgt).is_finite());
    }

    /// The four gradient protocols agree on the native latent model's
    /// dynamics gradient (fixed grid, smooth dynamics).
    #[test]
    fn native_latent_grad_methods_agree() {
        let (batch, t_len, t_out) = (3, 5, 2);
        let mut rng = Rng::new(17);
        let mut m = NativeLatentOde::new(hopper::OBS_DIM, t_len, t_out, 5, &[8], &mut rng);
        let ds = hopper::generate(batch, t_len, t_out, 3.0, 29);
        let mut seq = Vec::new();
        let mut tgt = Vec::new();
        for i in 0..batch {
            seq.extend_from_slice(ds.observed(i, t_len));
            tgt.extend_from_slice(ds.target(i, t_len, t_out));
        }
        let solver = by_name("alf").unwrap();
        let mut grads: Vec<Vec<f32>> = Vec::new();
        for name in ["naive", "adjoint", "aca", "mali"] {
            let method = crate::grad::by_name(name).unwrap();
            let c = cfg(&*solver, &*method);
            m.step(&seq, &tgt, &c).unwrap();
            grads.push(m.dyn_grad.clone());
        }
        for (i, g) in grads.iter().enumerate().skip(1) {
            let max_abs: f32 = g
                .iter()
                .zip(&grads[0])
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f32::max);
            assert!(max_abs < 1e-2, "method {i} diverges from naive: {max_abs}");
        }
    }
}
