//! Image classifiers (paper §4.2): a Neural ODE built by replacing a
//! residual block `y = x + f(x)` with `y = x + ∫₀ᵀ f(z) dt` — the ODE and
//! the ResNet baseline share the same `f` parameterization, as in the
//! paper, so accuracy differences isolate the training protocol.
//!
//! Pipeline: `stem(x) → z₀ → [ODE block] → z_T → softmax-CE head`, all
//! three stages AOT-compiled; the gradient method under test (naive /
//! adjoint / ACA / MALI) handles only the ODE block, with stem/head
//! cotangents chained on the host.

use super::{ParamBlock, SolveCfg, StepOutput};
use crate::grad::{batch_driver, BatchGradResult, BatchLossHead};
use crate::runtime::{Engine, HloDynamics};
use crate::solvers::batch::BatchSpec;
use crate::solvers::dynamics::Dynamics;
use crate::tensor::argmax_rows;
use crate::util::mem::MemTracker;
use crate::util::rng::Rng;
use anyhow::Result;
use std::cell::RefCell;
use std::rc::Rc;

/// Neural-ODE classifier bound to manifest model `img16` or `img32`.
pub struct OdeImageClassifier {
    engine: Rc<Engine>,
    pub key: String,
    pub batch: usize,
    pub d_in: usize,
    pub d: usize,
    pub classes: usize,
    pub stem: ParamBlock,
    pub head: ParamBlock,
    /// Owns the dynamics parameters θ_f.
    pub dynamics: HloDynamics,
    /// Gradient of the dynamics parameters from the last [`Self::step`].
    pub dyn_grad: Vec<f32>,
}

impl OdeImageClassifier {
    pub fn new(engine: Rc<Engine>, key: &str, rng: &mut Rng) -> Result<OdeImageClassifier> {
        let model = engine.manifest.model(key)?.clone();
        let batch = model.dim("batch")?;
        let d_in = model.dim("d_in")?;
        let d = model.dim("d")?;
        let classes = model.dim("classes")?;
        let stem = ParamBlock::new("stem", model.component("stem")?.init_params(rng));
        let head = ParamBlock::new("head", model.component("head")?.init_params(rng));
        let mut dynamics = HloDynamics::new(engine.clone(), key)?;
        dynamics.init_params(rng)?;
        let dyn_grad = vec![0.0; dynamics.param_dim()];
        Ok(OdeImageClassifier {
            engine,
            key: key.to_string(),
            batch,
            d_in,
            d,
            classes,
            stem,
            head,
            dynamics,
            dyn_grad,
        })
    }

    /// Trainable parameter count across all components.
    pub fn param_count(&self) -> usize {
        self.stem.len() + self.head.len() + self.dynamics.param_dim()
    }

    fn stem_fwd(&self, x: &[f32]) -> Result<Vec<f32>> {
        self.engine
            .call1(&format!("{}.stem", self.key), &[x, &self.stem.value])
    }

    /// `(loss, logits, a_z, a_θh)` for terminal state `z` and one-hot `y`.
    fn head_loss(&self, z: &[f32], y1h: &[f32]) -> Result<(f64, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let mut out = self.engine.call(
            &format!("{}.head_loss_grad", self.key),
            &[z, y1h, &self.head.value],
        )?;
        let ath = out.pop().unwrap();
        let az = out.pop().unwrap();
        let logits = out.pop().unwrap();
        let loss = out.pop().unwrap()[0] as f64;
        Ok((loss, logits, az, ath))
    }

    /// Inference: logits for batch `x` under the given solver.
    pub fn predict(&self, x: &[f32], cfg: &SolveCfg) -> Result<Vec<f32>> {
        let z0 = self.stem_fwd(x)?;
        let s0 = cfg.solver.init(&self.dynamics, cfg.spec.t0, &z0);
        let (s_end, _) = crate::solvers::integrate::integrate(
            cfg.solver,
            &self.dynamics,
            cfg.spec.t0,
            cfg.spec.t1,
            s0,
            &cfg.spec.mode,
            &cfg.spec.norm,
            &mut (),
        )?;
        let dummy_y = vec![0.0f32; self.batch * self.classes];
        let (_, logits, _, _) = self.head_loss(&s_end.z, &dummy_y)?;
        Ok(logits)
    }

    /// Batch accuracy of `logits` against labels.
    pub fn accuracy(&self, logits: &[f32], y: &[usize]) -> f64 {
        let pred = argmax_rows(logits, self.batch, self.classes);
        let correct = pred.iter().zip(y).filter(|(p, t)| p == t).count();
        correct as f64 / y.len() as f64
    }

    /// One training step: forward + full backward through head, ODE block
    /// (via `cfg.method`) and stem.  Gradients land in the `ParamBlock`s;
    /// `want_grad_x` additionally pulls `dL/dx` through the stem (FGSM).
    ///
    /// The mini-batch runs through `grad::batch_driver`: `HloDynamics` is
    /// device-batched, so the driver keeps one fused device call per
    /// evaluation (the `[batch, d]` layout the graphs were lowered with).
    pub fn step(
        &mut self,
        x: &[f32],
        y1h: &[f32],
        cfg: &SolveCfg,
        want_grad_x: bool,
    ) -> Result<StepOutput> {
        let z0 = self.stem_fwd(x)?;

        // The loss head runs inside the gradient method's terminal-loss
        // callback; stash (logits, a_θh) on the side.  Scoped so the
        // immutable self-borrows end before gradients are written back.
        let (res, logits, a_theta_head): (BatchGradResult, Vec<f32>, Vec<f32>) = {
            let stash: RefCell<(Vec<f32>, Vec<f32>)> = RefCell::new((vec![], vec![]));
            let loss_head = FusedImageHead {
                model: self,
                y1h,
                stash: &stash,
            };
            let tracker = MemTracker::new();
            let res = batch_driver::grad_batched(
                cfg.method,
                &self.dynamics,
                cfg.solver,
                &cfg.spec,
                &z0,
                &BatchSpec::new(self.batch, self.d),
                &loss_head,
                tracker,
            )?;
            let (logits, ath) = stash.into_inner();
            (res, logits, ath)
        };

        // chain through the stem: (a_x, a_θs) from a_z0
        let mut stem_out = self.engine.call(
            &format!("{}.stem_vjp", self.key),
            &[x, &self.stem.value, &res.grad_z0],
        )?;
        let a_theta_stem = stem_out.pop().unwrap();
        let a_x = stem_out.pop().unwrap();

        self.stem.grad.copy_from_slice(&a_theta_stem);
        self.head.grad.copy_from_slice(&a_theta_head);
        // dynamics grads are kept in a block-shaped buffer by the caller:
        self.dyn_grad = res.grad_theta.clone();

        Ok(StepOutput {
            loss: res.loss,
            logits,
            grad_x: if want_grad_x { a_x } else { vec![] },
            peak_mem_bytes: res.stats.peak_mem_bytes,
            n_steps: res.stats.fwd.n_accepted,
            f_evals: res.stats.f_evals,
        })
    }
}

/// Batch loss head for the fused device path: one `head_loss_grad`
/// execute computes the batch-summed cross entropy, the logits and both
/// cotangents.  Not separable per row, so it reports a single total (see
/// [`BatchLossHead`]); logits and `a_θh` are stashed for the caller.
struct FusedImageHead<'a> {
    model: &'a OdeImageClassifier,
    y1h: &'a [f32],
    stash: &'a RefCell<(Vec<f32>, Vec<f32>)>,
}

impl BatchLossHead for FusedImageHead<'_> {
    fn loss_grad_batch(&self, z_t: &[f32], _spec: &BatchSpec) -> (Vec<f64>, Vec<f32>) {
        let (loss, logits, az, ath) = self
            .model
            .head_loss(z_t, self.y1h)
            .expect("head loss executable");
        *self.stash.borrow_mut() = (logits, ath);
        (vec![loss], az)
    }

    /// One device call over the whole batch — cannot be sharded.
    fn separable(&self) -> bool {
        false
    }
}

/// The discrete ResNet baseline sharing the ODE's `f` (one-step Euler
/// residual block) — trained through a single fused loss+grad executable.
pub struct ResNetClassifier {
    engine: Rc<Engine>,
    pub key: String,
    pub batch: usize,
    pub classes: usize,
    pub stem: ParamBlock,
    pub f: ParamBlock,
    pub head: ParamBlock,
}

impl ResNetClassifier {
    pub fn new(engine: Rc<Engine>, key: &str, rng: &mut Rng) -> Result<ResNetClassifier> {
        let model = engine.manifest.model(key)?.clone();
        Ok(ResNetClassifier {
            batch: model.dim("batch")?,
            classes: model.dim("classes")?,
            stem: ParamBlock::new("stem", model.component("stem")?.init_params(rng)),
            f: ParamBlock::new("f", model.component("f")?.init_params(rng)),
            head: ParamBlock::new("head", model.component("head")?.init_params(rng)),
            key: key.to_string(),
            engine,
        })
    }

    pub fn param_count(&self) -> usize {
        self.stem.len() + self.f.len() + self.head.len()
    }

    /// One fused loss+grad step; gradients land in the blocks.
    pub fn step(&mut self, x: &[f32], y1h: &[f32]) -> Result<StepOutput> {
        let mut out = self.engine.call(
            &format!("{}.resnet_loss_grad", self.key),
            &[x, y1h, &self.stem.value, &self.f.value, &self.head.value],
        )?;
        let gh = out.pop().unwrap();
        let gf = out.pop().unwrap();
        let gs = out.pop().unwrap();
        let logits = out.pop().unwrap();
        let loss = out.pop().unwrap()[0] as f64;
        self.stem.grad.copy_from_slice(&gs);
        self.f.grad.copy_from_slice(&gf);
        self.head.grad.copy_from_slice(&gh);
        Ok(StepOutput {
            loss,
            logits,
            ..StepOutput::default()
        })
    }

    /// Loss + logits + `dL/dx` — the FGSM attack gradient.
    pub fn grad_x(&self, x: &[f32], y1h: &[f32]) -> Result<(f64, Vec<f32>, Vec<f32>)> {
        let mut out = self.engine.call(
            &format!("{}.resnet_grad_x", self.key),
            &[x, y1h, &self.stem.value, &self.f.value, &self.head.value],
        )?;
        let gx = out.pop().unwrap();
        let logits = out.pop().unwrap();
        let loss = out.pop().unwrap()[0] as f64;
        Ok((loss, logits, gx))
    }

    /// Inference logits (from the fused executable, ignoring the loss).
    pub fn predict(&self, x: &[f32]) -> Result<Vec<f32>> {
        let dummy = vec![0.0f32; self.batch * self.classes];
        let out = self.engine.call(
            &format!("{}.resnet_loss_grad", self.key),
            &[x, &dummy, &self.stem.value, &self.f.value, &self.head.value],
        )?;
        Ok(out[1].clone())
    }

    pub fn accuracy(&self, logits: &[f32], y: &[usize]) -> f64 {
        let pred = argmax_rows(logits, self.batch, self.classes);
        let correct = pred.iter().zip(y).filter(|(p, t)| p == t).count();
        correct as f64 / y.len() as f64
    }

    /// Re-discretization probe (paper Table 2, last row): interpret this
    /// ResNet's residual block as ODE dynamics and integrate it with an
    /// arbitrary solver — the paper shows accuracy collapses because a
    /// one-step-Euler block is not a meaningful dynamical system.
    pub fn as_ode(&self, rng_unused: &mut Rng) -> Result<OdeImageClassifier> {
        let mut ode = OdeImageClassifier::new(self.engine.clone(), &self.key, rng_unused)?;
        ode.stem.value.copy_from_slice(&self.stem.value);
        ode.head.value.copy_from_slice(&self.head.value);
        ode.dynamics.set_params(&self.f.value);
        Ok(ode)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::IvpSpec;
    use crate::solvers::by_name;

    fn engine() -> Option<Rc<Engine>> {
        Engine::from_env_or_skip("model test")
    }

    fn batch(engine: &Engine, key: &str, seed: u64) -> (Vec<f32>, Vec<usize>, Vec<f32>) {
        let model = engine.manifest.model(key).unwrap();
        let (b, d_in, classes) = (
            model.dim("batch").unwrap(),
            model.dim("d_in").unwrap(),
            model.dim("classes").unwrap(),
        );
        let mut rng = Rng::new(seed);
        let mut x = vec![0.0f32; b * d_in];
        rng.fill_uniform_sym(&mut x, 0.5);
        let y: Vec<usize> = (0..b).map(|i| i % classes).collect();
        let mut y1h = vec![0.0f32; b * classes];
        for (i, &c) in y.iter().enumerate() {
            y1h[i * classes + c] = 1.0;
        }
        (x, y, y1h)
    }

    #[test]
    fn ode_step_produces_finite_grads() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(1);
        let mut m = OdeImageClassifier::new(e.clone(), "img16", &mut rng).unwrap();
        let (x, _y, y1h) = batch(&e, "img16", 2);
        let solver = by_name("alf").unwrap();
        let method = crate::grad::by_name("mali").unwrap();
        let cfg = SolveCfg {
            solver: &*solver,
            spec: IvpSpec::fixed(0.0, 1.0, 0.25),
            method: &*method,
        };
        let out = m.step(&x, &y1h, &cfg, true).unwrap();
        assert!(out.loss.is_finite() && out.loss > 0.0);
        assert_eq!(out.logits.len(), m.batch * m.classes);
        assert_eq!(out.grad_x.len(), x.len());
        for block in [&m.stem, &m.head] {
            assert!(block.grad.iter().any(|&g| g != 0.0), "{} grad zero", block.name);
            assert!(block.grad.iter().all(|g| g.is_finite()));
        }
        assert!(m.dyn_grad.iter().any(|&g| g != 0.0));
    }

    #[test]
    fn mali_and_aca_agree_on_real_model() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(3);
        let mut m = OdeImageClassifier::new(e.clone(), "img16", &mut rng).unwrap();
        let (x, _y, y1h) = batch(&e, "img16", 4);
        let solver = by_name("alf").unwrap();
        let spec = IvpSpec::fixed(0.0, 1.0, 0.25);
        let mut grads = vec![];
        for name in ["mali", "aca"] {
            let method = crate::grad::by_name(name).unwrap();
            let cfg = SolveCfg {
                solver: &*solver,
                spec: spec.clone(),
                method: &*method,
            };
            m.step(&x, &y1h, &cfg, false).unwrap();
            grads.push(m.dyn_grad.clone());
        }
        let max_rel: f32 = grads[0]
            .iter()
            .zip(&grads[1])
            .map(|(a, b)| (a - b).abs() / (a.abs() + 1e-6))
            .fold(0.0, f32::max);
        assert!(max_rel < 1e-2, "MALI vs ACA dynamics grads differ: {max_rel}");
    }

    #[test]
    fn resnet_step_and_attack_grad() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(5);
        let mut m = ResNetClassifier::new(e.clone(), "img16", &mut rng).unwrap();
        let (x, y, y1h) = batch(&e, "img16", 6);
        let out = m.step(&x, &y1h).unwrap();
        assert!(out.loss.is_finite());
        assert!(m.f.grad.iter().any(|&g| g != 0.0));
        let (_, logits, gx) = m.grad_x(&x, &y1h).unwrap();
        assert_eq!(gx.len(), x.len());
        assert!(gx.iter().any(|&g| g != 0.0));
        let acc = m.accuracy(&logits, &y);
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn resnet_reinterpreted_as_ode_runs() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(7);
        let res = ResNetClassifier::new(e.clone(), "img16", &mut rng).unwrap();
        let ode = res.as_ode(&mut rng).unwrap();
        let (x, _y, _y1h) = batch(&e, "img16", 8);
        let solver = by_name("euler").unwrap();
        let method = crate::grad::by_name("aca").unwrap();
        let cfg = SolveCfg {
            solver: &*solver,
            spec: IvpSpec::fixed(0.0, 1.0, 1.0), // 1 Euler step = the ResNet itself
            method: &*method,
        };
        let logits_ode = ode.predict(&x, &cfg).unwrap();
        let logits_res = res.predict(&x).unwrap();
        for (a, b) in logits_ode.iter().zip(&logits_res) {
            assert!((a - b).abs() < 1e-4, "1-step Euler ≠ residual block: {a} vs {b}");
        }
    }
}
