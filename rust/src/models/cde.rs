//! Neural Controlled Differential Equation (Kidger et al. 2020b) for the
//! synthetic speech-command experiment (paper Table 5).
//!
//! `dz = f_θ(z)·Ẋ(t) dt` where `X` is the natural-cubic-spline control
//! path through the irregular observations.  Spline *fitting* happens here
//! on the host (data preparation, per batch); spline *evaluation* happens
//! inside the exported dynamics graph, which indexes a per-example
//! coefficient tensor `ctx: (batch, channels, pieces, 4)` on a uniform
//! grid — the two implementations are cross-checked in the tests.

use super::{ParamBlock, SolveCfg, StepOutput};
use crate::data::SequenceDataset;
use crate::grad::batch_driver::grad_obs_batched;
use crate::grad::{BatchObsGradResult, FusedObsLoss, ObsGrid};
use crate::runtime::{Engine, HloDynamics};
use crate::solvers::batch::BatchSpec;
use crate::solvers::dynamics::Dynamics;
use crate::spline::CubicSpline;
use crate::tensor::argmax_rows;
use crate::util::mem::MemTracker;
use crate::util::rng::Rng;
use anyhow::Result;
use std::cell::RefCell;
use std::rc::Rc;

pub struct NeuralCde {
    engine: Rc<Engine>,
    pub batch: usize,
    pub channels: usize,
    pub pieces: usize,
    pub t_total: f64,
    pub d: usize,
    pub classes: usize,
    pub stem: ParamBlock,
    pub head: ParamBlock,
    pub dynamics: HloDynamics,
    pub dyn_grad: Vec<f32>,
}

impl NeuralCde {
    pub fn new(engine: Rc<Engine>, rng: &mut Rng) -> Result<NeuralCde> {
        let model = engine.manifest.model("cde")?.clone();
        let mut dynamics = HloDynamics::new(engine.clone(), "cde")?;
        dynamics.init_params(rng)?;
        let dyn_grad = vec![0.0; dynamics.param_dim()];
        Ok(NeuralCde {
            batch: model.dim("batch")?,
            channels: model.dim("channels")?,
            pieces: model.dim("pieces")?,
            t_total: model.dims.get("t_total").copied().unwrap_or(1.0),
            d: model.dim("d")?,
            classes: model.dim("classes")?,
            stem: ParamBlock::new("stem", model.component("stem")?.init_params(rng)),
            head: ParamBlock::new("head", model.component("head")?.init_params(rng)),
            dynamics,
            dyn_grad,
            engine,
        })
    }

    pub fn param_count(&self) -> usize {
        self.stem.len() + self.head.len() + self.dynamics.param_dim()
    }

    /// Fit the control-path splines for one example and return
    /// `(uniform-grid coefficients [channels × pieces × 4], X(0) [channels])`.
    ///
    /// The irregular observations are first interpolated by a natural
    /// spline on their own knots, then re-fit on the uniform grid the
    /// device graph indexes — C¹-equivalent up to spline error.
    pub fn fit_example(
        &self,
        times: &[f64],
        values: &[f32],
    ) -> (Vec<f32>, Vec<f32>) {
        fit_uniform_ctx(times, values, self.channels, self.pieces, self.t_total)
    }

    /// Build the batched ctx tensor + initial observations for examples
    /// `idx` of `ds`, and the one-hot labels.
    pub fn prepare_batch(
        &self,
        ds: &SequenceDataset,
        idx: &[usize],
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<usize>) {
        assert_eq!(idx.len(), self.batch);
        let mut ctx = Vec::with_capacity(self.batch * self.channels * self.pieces * 4);
        let mut x0 = Vec::with_capacity(self.batch * self.channels);
        let mut y1h = vec![0.0f32; self.batch * self.classes];
        let mut y = Vec::with_capacity(self.batch);
        for (r, &i) in idx.iter().enumerate() {
            let (c, x) = self.fit_example(&ds.times[i], &ds.values[i]);
            ctx.extend_from_slice(&c);
            x0.extend_from_slice(&x);
            y1h[r * self.classes + ds.y[i]] = 1.0;
            y.push(ds.y[i]);
        }
        (ctx, x0, y1h, y)
    }

    fn stem_fwd(&self, x0: &[f32]) -> Result<Vec<f32>> {
        self.engine.call1("cde.stem", &[x0, &self.stem.value])
    }

    fn head_loss(&self, z: &[f32], y1h: &[f32]) -> Result<(f64, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let mut out = self
            .engine
            .call("cde.head_loss_grad", &[z, y1h, &self.head.value])?;
        let ath = out.pop().unwrap();
        let az = out.pop().unwrap();
        let logits = out.pop().unwrap();
        let loss = out.pop().unwrap()[0] as f64;
        Ok((loss, logits, az, ath))
    }

    /// Inference logits for a prepared batch.
    pub fn predict(&mut self, ctx: Vec<f32>, x0: &[f32], cfg: &SolveCfg) -> Result<Vec<f32>> {
        self.dynamics.set_ctx(0, ctx)?;
        let z0 = self.stem_fwd(x0)?;
        let s0 = cfg.solver.init(&self.dynamics, cfg.spec.t0, &z0);
        let (s_end, _) = crate::solvers::integrate::integrate(
            cfg.solver,
            &self.dynamics,
            cfg.spec.t0,
            cfg.spec.t1,
            s0,
            &cfg.spec.mode,
            &cfg.spec.norm,
            &mut (),
        )?;
        let dummy = vec![0.0f32; self.batch * self.classes];
        let (_, logits, _, _) = self.head_loss(&s_end.z, &dummy)?;
        Ok(logits)
    }

    pub fn accuracy(&self, logits: &[f32], y: &[usize]) -> f64 {
        let pred = argmax_rows(logits, self.batch, self.classes);
        pred.iter().zip(y).filter(|(p, t)| p == t).count() as f64 / y.len() as f64
    }

    /// One training step on a prepared batch.
    ///
    /// The classification loss reads only the terminal state, which on
    /// the observation-grid path is a grid with the single observation
    /// `t1` — the CDE rides the same centralized multi-observation
    /// machinery as the latent ODE (and per-observation heads become a
    /// one-line change here when a time-distributed CDE loss is wanted).
    pub fn step(
        &mut self,
        ctx: Vec<f32>,
        x0: &[f32],
        y1h: &[f32],
        cfg: &SolveCfg,
    ) -> Result<StepOutput> {
        self.dynamics.set_ctx(0, ctx)?;
        let z0 = self.stem_fwd(x0)?;

        let (res, logits, a_theta_head): (BatchObsGradResult, Vec<f32>, Vec<f32>) = {
            let stash: RefCell<(Vec<f32>, Vec<f32>)> = RefCell::new((vec![], vec![]));
            let this = &*self;
            let loss_head = FusedObsLoss(|_k: usize, _t: f64, z_t: &[f32]| {
                let (loss, logits, az, ath) =
                    this.head_loss(z_t, y1h).expect("head loss executable");
                *stash.borrow_mut() = (logits, ath);
                (loss, az)
            });
            let grid = ObsGrid::new(vec![cfg.spec.t1])?;
            let bspec = BatchSpec::new(self.batch, self.d);
            let res = grad_obs_batched(
                cfg.method,
                &self.dynamics,
                cfg.solver,
                &cfg.spec,
                &grid,
                &z0,
                &bspec,
                &loss_head,
                MemTracker::new(),
            )?;
            let (logits, ath) = stash.into_inner();
            (res, logits, ath)
        };

        let mut stem_out = self.engine.call(
            "cde.stem_vjp",
            &[x0, &self.stem.value, &res.grad_z0],
        )?;
        let a_theta_stem = stem_out.pop().unwrap();

        self.stem.grad.copy_from_slice(&a_theta_stem);
        self.head.grad.copy_from_slice(&a_theta_head);
        self.dyn_grad.copy_from_slice(&res.grad_theta);

        Ok(StepOutput {
            loss: res.loss,
            logits,
            peak_mem_bytes: res.stats.peak_mem_bytes,
            n_steps: res.stats.fwd.n_accepted,
            f_evals: res.stats.f_evals,
            ..StepOutput::default()
        })
    }
}

/// The host-side control-path fit shared by [`NeuralCde::fit_example`]
/// and [`StreamingPath`]: interpolate the irregular observations by a
/// natural spline on their own knots, then re-fit on the uniform grid
/// the device graph indexes.  Feature channels (`c > 0`) are
/// standardized — the spline is differentiated by the CDE, so channel
/// *scale* directly multiplies `dz/dt`.  Returns
/// `(uniform-grid coefficients [channels × pieces × 4], X(0) [channels])`.
pub fn fit_uniform_ctx(
    times: &[f64],
    values: &[f32],
    channels: usize,
    pieces: usize,
    t_total: f64,
) -> (Vec<f32>, Vec<f32>) {
    let knots: Vec<f64> = (0..=pieces)
        .map(|k| t_total * k as f64 / pieces as f64)
        .collect();
    let mut coeffs = Vec::with_capacity(channels * pieces * 4);
    let mut x0 = Vec::with_capacity(channels);
    for c in 0..channels {
        let mut ys: Vec<f64> = (0..times.len())
            .map(|k| values[k * channels + c] as f64)
            .collect();
        // time channel c = 0 stays raw; see the doc comment for why the
        // feature channels are standardized
        if c > 0 {
            let mean = ys.iter().sum::<f64>() / ys.len() as f64;
            let var = ys.iter().map(|y| (y - mean).powi(2)).sum::<f64>()
                / ys.len() as f64;
            let scale = 0.15 / var.sqrt().max(1e-6);
            for y in &mut ys {
                *y = (*y - mean) * scale;
            }
        }
        let irregular = CubicSpline::fit(times, &ys);
        let uniform_ys: Vec<f64> = knots.iter().map(|&t| irregular.eval(t)).collect();
        let uniform = CubicSpline::fit(&knots, &uniform_ys);
        coeffs.extend_from_slice(&uniform.coeffs_flat());
        x0.push(uniform_ys[0] as f32);
    }
    (coeffs, x0)
}

/// Incremental control-path builder for streaming CDE inference: buffer
/// irregular observation rows as they arrive over a session, then fit
/// the same uniform-grid coefficient tensor the batch path builds — the
/// streaming client never needs the whole sequence up front, and
/// [`StreamingPath::fit_ctx`] over incrementally pushed rows is
/// identical to a one-shot [`fit_uniform_ctx`] over the full arrays
/// (pinned by the tests).
#[derive(Debug, Clone)]
pub struct StreamingPath {
    channels: usize,
    times: Vec<f64>,
    /// Row-major `[k × channels]`, matching `SequenceDataset::values`.
    values: Vec<f32>,
}

impl StreamingPath {
    pub fn new(channels: usize) -> StreamingPath {
        assert!(channels > 0, "a control path needs at least one channel");
        StreamingPath {
            channels,
            times: Vec::new(),
            values: Vec::new(),
        }
    }

    /// Append one observation row at time `t` (strictly after the last).
    pub fn push(&mut self, t: f64, x: &[f32]) -> Result<()> {
        anyhow::ensure!(t.is_finite(), "observation time {t} is not finite");
        anyhow::ensure!(
            x.len() == self.channels,
            "observation row has {} channels, path has {}",
            x.len(),
            self.channels
        );
        if let Some(&last) = self.times.last() {
            anyhow::ensure!(
                t > last,
                "observation times must be strictly increasing ({t} after {last})"
            );
        }
        self.times.push(t);
        self.values.extend_from_slice(x);
        Ok(())
    }

    /// Observation rows buffered so far.
    pub fn len(&self) -> usize {
        self.times.len()
    }

    pub fn is_empty(&self) -> bool {
        self.times.is_empty()
    }

    /// The buffered observation times.
    pub fn times(&self) -> &[f64] {
        &self.times
    }

    /// Fit the uniform-grid coefficients over everything pushed so far —
    /// bit-identical to [`fit_uniform_ctx`] on the same data.  Needs at
    /// least two rows (a spline through fewer is underdetermined).
    pub fn fit_ctx(&self, pieces: usize, t_total: f64) -> Result<(Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(
            self.times.len() >= 2,
            "control-path fit needs ≥ 2 observations, have {}",
            self.times.len()
        );
        Ok(fit_uniform_ctx(
            &self.times,
            &self.values,
            self.channels,
            pieces,
            t_total,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::speech::{self, SpeechSpec};
    use crate::grad::IvpSpec;
    use crate::solvers::by_name;

    #[test]
    fn streaming_path_matches_one_shot_fit() {
        // tier-1 (no engine): rows pushed one at a time must fit to the
        // exact coefficients of the one-shot batch-path fit
        let channels = 3;
        let times: Vec<f64> = vec![0.0, 0.13, 0.31, 0.48, 0.77, 1.0];
        let mut values = Vec::new();
        for (k, &t) in times.iter().enumerate() {
            values.push(t as f32); // time channel
            values.push((1.7 * t).sin() as f32 + 0.1 * k as f32);
            values.push((0.9 * t).cos() as f32 - 0.05 * k as f32);
        }
        let mut path = StreamingPath::new(channels);
        for (k, &t) in times.iter().enumerate() {
            path.push(t, &values[k * channels..(k + 1) * channels]).unwrap();
        }
        assert_eq!(path.len(), times.len());
        let (inc_ctx, inc_x0) = path.fit_ctx(8, 1.0).unwrap();
        let (one_ctx, one_x0) = fit_uniform_ctx(&times, &values, channels, 8, 1.0);
        assert_eq!(inc_ctx, one_ctx, "coefficients must be bit-identical");
        assert_eq!(inc_x0, one_x0);

        // ordering and shape violations are refused
        assert!(path.clone().push(0.5, &[0.0; 3]).is_err(), "non-increasing t");
        assert!(path.clone().push(1.5, &[0.0; 2]).is_err(), "wrong width");
        assert!(StreamingPath::new(2).fit_ctx(4, 1.0).is_err(), "underdetermined");
    }

    fn engine() -> Option<Rc<Engine>> {
        Engine::from_env_or_skip("model test")
    }

    #[test]
    fn spline_ctx_matches_device_dynamics() {
        // host spline derivative must agree with the device graph's
        // piecewise-cubic lookup: compare f eval via HLO against a host
        // computation using the same coefficients.
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(1);
        let mut m = NeuralCde::new(e, &mut rng).unwrap();
        let ds = speech::generate(&SpeechSpec::commands10(), m.batch, 2);
        let idx: Vec<usize> = (0..m.batch).collect();
        let (ctx, x0, _y1h, _y) = m.prepare_batch(&ds, &idx);

        // device-side dX/dt is embedded in f; we check the ctx layout by
        // evaluating the uniform spline derivative on the host for one
        // (example, channel, t) and recomputing from the flat ctx tensor.
        let (t_probe, ex, ch) = (0.37f64, 3usize, 2usize);
        let dt_piece = m.t_total / m.pieces as f64;
        let piece = ((t_probe / dt_piece).floor() as usize).min(m.pieces - 1);
        let u = t_probe - piece as f64 * dt_piece;
        let base = ((ex * m.channels + ch) * m.pieces + piece) * 4;
        let (b, c, d) = (ctx[base + 1] as f64, ctx[base + 2] as f64, ctx[base + 3] as f64);
        let from_ctx = b + 2.0 * c * u + 3.0 * d * u * u;

        let (coeffs, _) = m.fit_example(&ds.times[ex], &ds.values[ex]);
        let knots: Vec<f64> = (0..=m.pieces)
            .map(|k| m.t_total * k as f64 / m.pieces as f64)
            .collect();
        // rebuild the channel spline and compare derivatives
        let ys: Vec<f64> = (0..=m.pieces)
            .map(|k| {
                // value at knot k = coefficient a of piece k (or last piece end)
                if k < m.pieces {
                    coeffs[(ch * m.pieces + k) * 4] as f64
                } else {
                    let p = m.pieces - 1;
                    let bb = (ch * m.pieces + p) * 4;
                    let h = knots[1] - knots[0];
                    coeffs[bb] as f64
                        + coeffs[bb + 1] as f64 * h
                        + coeffs[bb + 2] as f64 * h * h
                        + coeffs[bb + 3] as f64 * h * h * h
                }
            })
            .collect();
        let s = CubicSpline::fit(&knots, &ys);
        assert!(
            (s.deriv(t_probe) - from_ctx).abs() < 1e-3,
            "ctx layout mismatch: {} vs {from_ctx}",
            s.deriv(t_probe)
        );
        let _ = x0;
    }

    #[test]
    fn cde_step_trains() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(3);
        let mut m = NeuralCde::new(e, &mut rng).unwrap();
        let ds = speech::generate(&SpeechSpec::commands10(), m.batch, 4);
        let idx: Vec<usize> = (0..m.batch).collect();
        let (ctx, x0, y1h, y) = m.prepare_batch(&ds, &idx);
        let solver = by_name("alf").unwrap();
        let method = crate::grad::by_name("mali").unwrap();
        let cfg = SolveCfg {
            solver: &*solver,
            spec: IvpSpec::fixed(0.0, 1.0, 0.25),
            method: &*method,
        };
        let out0 = m.step(ctx.clone(), &x0, &y1h, &cfg).unwrap();
        assert!(out0.loss.is_finite() && out0.loss > 0.0);
        assert!(m.dyn_grad.iter().any(|&g| g != 0.0));
        assert!(m.stem.grad.iter().any(|&g| g != 0.0));

        // a few SGD steps reduce the loss on the fixed batch
        let lr = 0.05f32;
        let mut loss = out0.loss;
        for _ in 0..8 {
            for (v, g) in m.stem.value.iter_mut().zip(m.stem.grad.clone()) {
                *v -= lr * g;
            }
            for (v, g) in m.head.value.iter_mut().zip(m.head.grad.clone()) {
                *v -= lr * g;
            }
            let th: Vec<f32> = m
                .dynamics
                .params()
                .iter()
                .zip(&m.dyn_grad)
                .map(|(p, g)| p - lr * g)
                .collect();
            m.dynamics.set_params(&th);
            loss = m.step(ctx.clone(), &x0, &y1h, &cfg).unwrap().loss;
        }
        assert!(loss < out0.loss, "CDE loss did not decrease: {} → {loss}", out0.loss);
        let logits = m.predict(ctx, &x0, &cfg).unwrap();
        let acc = m.accuracy(&logits, &y);
        assert!((0.0..=1.0).contains(&acc));
    }
}
