//! Latent ODE (Rubanova et al. 2019) for the hopper time-series experiment
//! (paper Table 4), plus the RNN / GRU sequence baselines.
//!
//! Pipeline: GRU encoder over the observed prefix (run backwards in time)
//! → `(μ, log σ²)` → reparameterized `z₀` → latent ODE integrated through
//! the prediction times → linear decoder → per-time MSE (+ β·KL).
//!
//! The multi-observation loss is handled segment-wise: the forward pass
//! checkpoints the latent state at each observation (those states are
//! needed to decode anyway); the backward pass walks segments in reverse,
//! adding each observation's decoder cotangent to the running adjoint and
//! pulling it through the segment with the gradient method under test —
//! so naive / adjoint / ACA / MALI keep their per-segment memory and
//! accuracy signatures.

use super::{ParamBlock, SolveCfg, StepOutput};
use crate::grad::FnLoss;
use crate::runtime::{Engine, HloDynamics};
use crate::solvers::dynamics::Dynamics;
use crate::util::mem::MemTracker;
use crate::util::rng::Rng;
use anyhow::Result;
use std::cell::RefCell;
use std::rc::Rc;

pub struct LatentOde {
    engine: Rc<Engine>,
    pub batch: usize,
    pub obs: usize,
    pub t_len: usize,
    pub t_out: usize,
    pub latent: usize,
    pub enc: ParamBlock,
    pub dec: ParamBlock,
    pub dynamics: HloDynamics,
    pub dyn_grad: Vec<f32>,
    /// ELBO KL weight.
    pub beta: f64,
}

impl LatentOde {
    pub fn new(engine: Rc<Engine>, rng: &mut Rng) -> Result<LatentOde> {
        let model = engine.manifest.model("latent")?.clone();
        let mut dynamics = HloDynamics::new(engine.clone(), "latent")?;
        dynamics.init_params(rng)?;
        let dyn_grad = vec![0.0; dynamics.param_dim()];
        Ok(LatentOde {
            batch: model.dim("batch")?,
            obs: model.dim("obs")?,
            t_len: model.dim("t_len")?,
            t_out: model.dim("t_out")?,
            latent: model.dim("latent")?,
            enc: ParamBlock::new("enc", model.component("enc")?.init_params(rng)),
            dec: ParamBlock::new("dec", model.component("dec")?.init_params(rng)),
            dynamics,
            dyn_grad,
            beta: 1e-3,
            engine,
        })
    }

    pub fn param_count(&self) -> usize {
        self.enc.len() + self.dec.len() + self.dynamics.param_dim()
    }

    fn encode(&self, seq: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut out = self
            .engine
            .call("latent.enc", &[seq, &self.enc.value])?;
        let logvar = out.pop().unwrap();
        let mu = out.pop().unwrap();
        Ok((mu, logvar))
    }

    fn decode(&self, z: &[f32]) -> Result<Vec<f32>> {
        self.engine.call1("latent.dec", &[z, &self.dec.value])
    }

    fn decode_vjp(&self, z: &[f32], a_obs: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut out = self
            .engine
            .call("latent.dec_vjp", &[z, &self.dec.value, a_obs])?;
        let ath = out.pop().unwrap();
        let az = out.pop().unwrap();
        Ok((az, ath))
    }

    /// Prediction times for the `t_out` future observations, uniform on
    /// `(0, 1]` in latent time.
    fn pred_times(&self) -> Vec<f64> {
        (1..=self.t_out)
            .map(|k| k as f64 / self.t_out as f64)
            .collect()
    }

    /// Integrate one latent segment forward (no gradient bookkeeping).
    fn advance(
        &self,
        cfg: &SolveCfg,
        t0: f64,
        t1: f64,
        z: &[f32],
    ) -> Result<Vec<f32>> {
        let s0 = cfg.solver.init(&self.dynamics, t0, z);
        let (s_end, _) = crate::solvers::integrate::integrate(
            cfg.solver,
            &self.dynamics,
            t0,
            t1,
            s0,
            &cfg.spec.mode,
            &cfg.spec.norm,
            &mut (),
        )?;
        Ok(s_end.z)
    }

    /// Predict the `t_out` future frames for the observed prefix (mean
    /// latent path, no sampling): returns `batch × t_out × obs`.
    pub fn predict(&self, seq: &[f32], cfg: &SolveCfg) -> Result<Vec<f32>> {
        let (mu, _) = self.encode(seq)?;
        let mut preds = Vec::with_capacity(self.batch * self.t_out * self.obs);
        let mut z = mu;
        let mut t_prev = 0.0;
        for &t in &self.pred_times() {
            z = self.advance(cfg, t_prev, t, &z)?;
            preds.push(self.decode(&z)?);
            t_prev = t;
        }
        // interleave per-time blocks into (batch, t_out, obs)
        let mut out = vec![0.0f32; self.batch * self.t_out * self.obs];
        for (k, block) in preds.iter().enumerate() {
            for b in 0..self.batch {
                let src = &block[b * self.obs..(b + 1) * self.obs];
                let dst = (b * self.t_out + k) * self.obs;
                out[dst..dst + self.obs].copy_from_slice(src);
            }
        }
        Ok(out)
    }

    /// Mean squared error of `predict` output vs target (`batch × t_out × obs`).
    pub fn mse(preds: &[f32], target: &[f32]) -> f64 {
        preds
            .iter()
            .zip(target)
            .map(|(p, t)| ((p - t) as f64).powi(2))
            .sum::<f64>()
            / preds.len() as f64
    }

    /// One training step on a batch: `seq` is the observed prefix
    /// (`batch × t_len × obs`), `target` the future frames
    /// (`batch × t_out × obs`, time-major per example as produced by
    /// `sim::hopper::HopperDataset`).
    pub fn step(
        &mut self,
        seq: &[f32],
        target: &[f32],
        cfg: &SolveCfg,
        rng: &mut Rng,
    ) -> Result<StepOutput> {
        let (mu, logvar) = self.encode(seq)?;
        let nz = mu.len();

        // reparameterize z₀ = μ + σ·ε
        let mut eps = vec![0.0f32; nz];
        rng.fill_normal(&mut eps, 1.0);
        let sigma: Vec<f32> = logvar.iter().map(|&lv| (0.5 * lv).exp()).collect();
        let z0: Vec<f32> = mu
            .iter()
            .zip(&sigma)
            .zip(&eps)
            .map(|((&m, &s), &e)| m + s * e)
            .collect();

        // ---- forward through prediction times, checkpoint latent states --
        let times = self.pred_times();
        let mut checkpoints: Vec<Vec<f32>> = Vec::with_capacity(times.len() + 1);
        checkpoints.push(z0.clone());
        let mut mse_acc = 0.0f64;
        let mut dec_cots: Vec<Vec<f32>> = Vec::with_capacity(times.len());
        let n_total = (self.batch * self.t_out * self.obs) as f64;
        {
            let mut z = z0.clone();
            let mut t_prev = 0.0;
            for (k, &t) in times.iter().enumerate() {
                z = self.advance(cfg, t_prev, t, &z)?;
                checkpoints.push(z.clone());
                let pred = self.decode(&z)?;
                // target frame k across the batch
                let mut a_obs = vec![0.0f32; pred.len()];
                for b in 0..self.batch {
                    for j in 0..self.obs {
                        let p = pred[b * self.obs + j];
                        let tgt = target[(b * self.t_out + k) * self.obs + j];
                        let diff = p - tgt;
                        mse_acc += (diff as f64) * (diff as f64);
                        a_obs[b * self.obs + j] = 2.0 * diff / n_total as f32;
                    }
                }
                dec_cots.push(a_obs);
                t_prev = t;
            }
        }
        let mse = mse_acc / n_total;

        // ---- backward: walk segments in reverse with the grad method ----
        self.dyn_grad.iter_mut().for_each(|g| *g = 0.0);
        let mut dec_grad = vec![0.0f32; self.dec.len()];
        let mut a_z = vec![0.0f32; nz];
        let mut peak_mem = 0usize;
        let mut n_steps = 0usize;
        let mut f_evals = 0u64;
        for k in (0..times.len()).rev() {
            // decoder cotangent at t_k
            let (az_dec, ath_dec) = self.decode_vjp(&checkpoints[k + 1], &dec_cots[k])?;
            for (a, d) in a_z.iter_mut().zip(&az_dec) {
                *a += d;
            }
            for (g, d) in dec_grad.iter_mut().zip(&ath_dec) {
                *g += d;
            }
            // pull a_z through segment [t_{k-1}, t_k]
            let t0 = if k == 0 { 0.0 } else { times[k - 1] };
            let t1 = times[k];
            let seg_spec = crate::grad::IvpSpec {
                t0,
                t1,
                mode: cfg.spec.mode.clone(),
                norm: cfg.spec.norm.clone(),
            };
            let a_snapshot = RefCell::new(a_z.clone());
            let loss_head = FnLoss(|_z: &[f32]| (0.0, a_snapshot.borrow().clone()));
            let tracker = MemTracker::new();
            let res = cfg.method.grad(
                &self.dynamics,
                cfg.solver,
                &seg_spec,
                &checkpoints[k],
                &loss_head,
                tracker,
            )?;
            for (g, d) in self.dyn_grad.iter_mut().zip(&res.grad_theta) {
                *g += d;
            }
            a_z = res.grad_z0;
            peak_mem = peak_mem.max(res.stats.peak_mem_bytes);
            n_steps += res.stats.fwd.n_accepted;
            f_evals += res.stats.f_evals;
        }

        // ---- reparameterization + KL back to the encoder ----------------
        // a_μ = a_z0 + β·∂KL/∂μ;  a_logvar = a_z0·ε·σ/2 + β·∂KL/∂logvar
        let scale = 1.0 / self.batch as f64;
        let a_mu: Vec<f32> = a_z
            .iter()
            .zip(&mu)
            .map(|(&a, &m)| a + (self.beta * scale) as f32 * m)
            .collect();
        let a_logvar: Vec<f32> = a_z
            .iter()
            .zip(&eps)
            .zip(&sigma)
            .zip(&logvar)
            .map(|(((&a, &e), &s), &lv)| {
                a * e * s * 0.5 + (self.beta * scale * 0.5) as f32 * (lv.exp() - 1.0)
            })
            .collect();
        let kl: f64 = mu
            .iter()
            .zip(&logvar)
            .map(|(&m, &lv)| {
                0.5 * ((m as f64).powi(2) + (lv as f64).exp() - 1.0 - lv as f64)
            })
            .sum::<f64>()
            * scale;

        let mut enc_out = self
            .engine
            .call("latent.enc_vjp", &[seq, &self.enc.value, &a_mu, &a_logvar])?;
        let enc_grad = enc_out.pop().unwrap();
        self.enc.grad.copy_from_slice(&enc_grad);
        self.dec.grad.copy_from_slice(&dec_grad);

        Ok(StepOutput {
            loss: mse + self.beta * kl,
            peak_mem_bytes: peak_mem,
            n_steps,
            f_evals,
            ..StepOutput::default()
        })
    }
}

/// RNN / GRU sequence baselines (Table 4): one fused loss+grad executable.
pub struct SeqBaseline {
    engine: Rc<Engine>,
    pub key: String, // "rnn" | "gru"
    pub params: ParamBlock,
}

impl SeqBaseline {
    pub fn new(engine: Rc<Engine>, key: &str, rng: &mut Rng) -> Result<SeqBaseline> {
        let model = engine.manifest.model(key)?.clone();
        Ok(SeqBaseline {
            params: ParamBlock::new("all", model.component("all")?.init_params(rng)),
            key: key.to_string(),
            engine,
        })
    }

    pub fn step(&mut self, seq: &[f32], target: &[f32]) -> Result<StepOutput> {
        let mut out = self.engine.call(
            &format!("{}.loss_grad", self.key),
            &[seq, target, &self.params.value],
        )?;
        let g = out.pop().unwrap();
        let loss = out.pop().unwrap()[0] as f64;
        self.params.grad.copy_from_slice(&g);
        Ok(StepOutput {
            loss,
            ..StepOutput::default()
        })
    }

    pub fn predict(&self, seq: &[f32]) -> Result<Vec<f32>> {
        self.engine
            .call1(&format!("{}.predict", self.key), &[seq, &self.params.value])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::IvpSpec;
    use crate::sim::hopper;
    use crate::solvers::by_name;

    fn engine() -> Option<Rc<Engine>> {
        Engine::from_env_or_skip("model test")
    }

    fn hopper_batch(m: &LatentOde, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let ds = hopper::generate(m.batch, m.t_len, m.t_out, 3.0, seed);
        let mut seq = Vec::new();
        let mut tgt = Vec::new();
        for i in 0..m.batch {
            seq.extend_from_slice(ds.observed(i, m.t_len));
            tgt.extend_from_slice(ds.target(i, m.t_len, m.t_out));
        }
        (seq, tgt)
    }

    #[test]
    fn latent_ode_step_finite_and_loss_decreases() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(1);
        let mut m = LatentOde::new(e, &mut rng).unwrap();
        let (seq, tgt) = hopper_batch(&m, 2);
        let solver = by_name("alf").unwrap();
        let method = crate::grad::by_name("mali").unwrap();
        let cfg = SolveCfg {
            solver: &*solver,
            spec: IvpSpec::fixed(0.0, 1.0, 0.25),
            method: &*method,
        };
        let out0 = m.step(&seq, &tgt, &cfg, &mut rng).unwrap();
        assert!(out0.loss.is_finite());
        assert!(m.dyn_grad.iter().any(|&g| g != 0.0), "dynamics grad all zero");
        assert!(m.enc.grad.iter().any(|&g| g != 0.0), "encoder grad all zero");
        assert!(m.dec.grad.iter().any(|&g| g != 0.0), "decoder grad all zero");

        // a few plain-SGD steps should reduce the loss on a fixed batch
        let lr = 0.05f32;
        let mut last = out0.loss;
        for it in 0..8 {
            for (v, g) in m.enc.value.iter_mut().zip(m.enc.grad.clone()) {
                *v -= lr * g;
            }
            for (v, g) in m.dec.value.iter_mut().zip(m.dec.grad.clone()) {
                *v -= lr * g;
            }
            let th: Vec<f32> = m
                .dynamics
                .params()
                .iter()
                .zip(&m.dyn_grad)
                .map(|(p, g)| p - lr * g)
                .collect();
            m.dynamics.set_params(&th);
            let out = m.step(&seq, &tgt, &cfg, &mut rng).unwrap();
            last = out.loss;
            let _ = it;
        }
        assert!(
            last < out0.loss,
            "loss did not decrease: {} → {last}",
            out0.loss
        );
    }

    #[test]
    fn predict_shape_and_mse() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(3);
        let m = LatentOde::new(e, &mut rng).unwrap();
        let (seq, tgt) = hopper_batch(&m, 4);
        let solver = by_name("alf").unwrap();
        let method = crate::grad::by_name("mali").unwrap();
        let cfg = SolveCfg {
            solver: &*solver,
            spec: IvpSpec::fixed(0.0, 1.0, 0.25),
            method: &*method,
        };
        let p = m.predict(&seq, &cfg).unwrap();
        assert_eq!(p.len(), tgt.len());
        let mse = LatentOde::mse(&p, &tgt);
        assert!(mse.is_finite() && mse > 0.0);
    }

    #[test]
    fn seq_baselines_step() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(5);
        for key in ["rnn", "gru"] {
            let mut m = SeqBaseline::new(e.clone(), key, &mut rng).unwrap();
            let latent = LatentOde::new(e.clone(), &mut rng).unwrap();
            let (seq, tgt) = hopper_batch(&latent, 6);
            let out = m.step(&seq, &tgt).unwrap();
            assert!(out.loss.is_finite(), "{key}");
            assert!(m.params.grad.iter().any(|&g| g != 0.0), "{key} grad zero");
            let p = m.predict(&seq).unwrap();
            assert_eq!(p.len(), tgt.len(), "{key}");
        }
    }
}
