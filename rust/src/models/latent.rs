//! Latent ODE (Rubanova et al. 2019) for the hopper time-series experiment
//! (paper Table 4), plus the RNN / GRU sequence baselines.
//!
//! Pipeline: GRU encoder over the observed prefix (run backwards in time)
//! → `(μ, log σ²)` → reparameterized `z₀` → latent ODE integrated through
//! the prediction times → linear decoder → per-time MSE (+ β·KL).
//!
//! The multi-observation loss `L = Σ_k MSE(dec(z(t_k)), x_k)` rides the
//! first-class observation-grid path: the prediction times form an
//! [`ObsGrid`], the decoder + per-frame MSE is one [`FusedObsLoss`] head
//! (a fused device call per observation, coupling the batch rows), and
//! `grad::batch_driver::grad_obs_batched` runs the gradient method under
//! test in **one** pass over the whole span — MALI does a single
//! continuous ψ⁻¹ reverse sweep with cotangent injections at the
//! observations (no per-segment re-initialisation of `v`, constant
//! memory in both the step count and the number of frames), the adjoint
//! one reverse augmented IVP with jumps, naive/ACA one tape/checkpoint
//! replay with injections — so the four methods keep their Table-1
//! memory and accuracy signatures on the paper's actual time-series
//! workload.

use super::{ParamBlock, SolveCfg, StepOutput};
use crate::grad::batch_driver::grad_obs_batched;
use crate::grad::{FusedObsLoss, ObsGrid};
use crate::runtime::{Engine, HloDynamics};
use crate::solvers::batch::BatchSpec;
use crate::solvers::dynamics::Dynamics;
use crate::solvers::integrate::StepObserver;
use crate::solvers::State;
use crate::util::mem::MemTracker;
use crate::util::rng::Rng;
use anyhow::Result;
use std::cell::RefCell;
use std::rc::Rc;

pub struct LatentOde {
    engine: Rc<Engine>,
    pub batch: usize,
    pub obs: usize,
    pub t_len: usize,
    pub t_out: usize,
    pub latent: usize,
    pub enc: ParamBlock,
    pub dec: ParamBlock,
    pub dynamics: HloDynamics,
    pub dyn_grad: Vec<f32>,
    /// ELBO KL weight.
    pub beta: f64,
}

impl LatentOde {
    pub fn new(engine: Rc<Engine>, rng: &mut Rng) -> Result<LatentOde> {
        let model = engine.manifest.model("latent")?.clone();
        let mut dynamics = HloDynamics::new(engine.clone(), "latent")?;
        dynamics.init_params(rng)?;
        let dyn_grad = vec![0.0; dynamics.param_dim()];
        Ok(LatentOde {
            batch: model.dim("batch")?,
            obs: model.dim("obs")?,
            t_len: model.dim("t_len")?,
            t_out: model.dim("t_out")?,
            latent: model.dim("latent")?,
            enc: ParamBlock::new("enc", model.component("enc")?.init_params(rng)),
            dec: ParamBlock::new("dec", model.component("dec")?.init_params(rng)),
            dynamics,
            dyn_grad,
            beta: 1e-3,
            engine,
        })
    }

    pub fn param_count(&self) -> usize {
        self.enc.len() + self.dec.len() + self.dynamics.param_dim()
    }

    fn encode(&self, seq: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut out = self
            .engine
            .call("latent.enc", &[seq, &self.enc.value])?;
        let logvar = out.pop().unwrap();
        let mu = out.pop().unwrap();
        Ok((mu, logvar))
    }

    fn decode(&self, z: &[f32]) -> Result<Vec<f32>> {
        self.engine.call1("latent.dec", &[z, &self.dec.value])
    }

    fn decode_vjp(&self, z: &[f32], a_obs: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut out = self
            .engine
            .call("latent.dec_vjp", &[z, &self.dec.value, a_obs])?;
        let ath = out.pop().unwrap();
        let az = out.pop().unwrap();
        Ok((az, ath))
    }

    /// Prediction times for the `t_out` future observations, uniform on
    /// `(0, 1]` in latent time.
    fn pred_times(&self) -> Vec<f64> {
        (1..=self.t_out)
            .map(|k| k as f64 / self.t_out as f64)
            .collect()
    }

    /// Predict the `t_out` future frames for the observed prefix (mean
    /// latent path, no sampling): one continuous observation-aware
    /// integration, decoding the exact-hit frames.  Returns
    /// `batch × t_out × obs`.
    pub fn predict(&self, seq: &[f32], cfg: &SolveCfg) -> Result<Vec<f32>> {
        let (mu, _) = self.encode(seq)?;
        let grid = ObsGrid::new(self.pred_times())?;
        struct Frames(Vec<Vec<f32>>);
        impl StepObserver for Frames {
            fn on_observation(&mut self, _k: usize, _t: f64, state: &State) {
                self.0.push(state.z.clone());
            }
        }
        let s0 = cfg.solver.init(&self.dynamics, cfg.spec.t0, &mu);
        let mut frames = Frames(Vec::with_capacity(self.t_out));
        crate::solvers::integrate::integrate_obs(
            cfg.solver,
            &self.dynamics,
            cfg.spec.t0,
            cfg.spec.t1,
            s0,
            &cfg.spec.mode,
            &cfg.spec.norm,
            &grid,
            &mut frames,
        )?;
        // decode and interleave per-time blocks into (batch, t_out, obs)
        let mut out = vec![0.0f32; self.batch * self.t_out * self.obs];
        for (k, z) in frames.0.iter().enumerate() {
            let block = self.decode(z)?;
            for b in 0..self.batch {
                let src = &block[b * self.obs..(b + 1) * self.obs];
                let dst = (b * self.t_out + k) * self.obs;
                out[dst..dst + self.obs].copy_from_slice(src);
            }
        }
        Ok(out)
    }

    /// Mean squared error of `predict` output vs target (`batch × t_out × obs`).
    pub fn mse(preds: &[f32], target: &[f32]) -> f64 {
        preds
            .iter()
            .zip(target)
            .map(|(p, t)| ((p - t) as f64).powi(2))
            .sum::<f64>()
            / preds.len() as f64
    }

    /// One training step on a batch: `seq` is the observed prefix
    /// (`batch × t_len × obs`), `target` the future frames
    /// (`batch × t_out × obs`, time-major per example as produced by
    /// `sim::hopper::HopperDataset`).
    pub fn step(
        &mut self,
        seq: &[f32],
        target: &[f32],
        cfg: &SolveCfg,
        rng: &mut Rng,
    ) -> Result<StepOutput> {
        let (mu, logvar) = self.encode(seq)?;
        let nz = mu.len();

        // reparameterize z₀ = μ + σ·ε
        let mut eps = vec![0.0f32; nz];
        rng.fill_normal(&mut eps, 1.0);
        let sigma: Vec<f32> = logvar.iter().map(|&lv| (0.5 * lv).exp()).collect();
        let z0: Vec<f32> = mu
            .iter()
            .zip(&sigma)
            .zip(&eps)
            .map(|((&m, &s), &e)| m + s * e)
            .collect();

        // ---- one centralized multi-observation gradient pass -----------
        // The prediction times are the observation grid; the decoder +
        // per-frame MSE is one fused observation head (a device call per
        // frame, coupling the batch rows), evaluated wherever the method
        // reads its states — forward tape/checkpoint states for
        // naive/ACA, stored forward frames for the adjoint, the
        // ψ⁻¹-reconstructed states for MALI's continuous reverse sweep.
        let n_total = (self.batch * self.t_out * self.obs) as f64;
        let dec_grad = RefCell::new(vec![0.0f32; self.dec.len()]);
        let res = {
            let this = &*self;
            let head = FusedObsLoss(|k: usize, _t: f64, z: &[f32]| {
                let pred = this.decode(z).expect("latent.dec executable");
                let mut loss_k = 0.0f64;
                let mut a_obs = vec![0.0f32; pred.len()];
                for b in 0..this.batch {
                    for j in 0..this.obs {
                        let p = pred[b * this.obs + j];
                        let tgt = target[(b * this.t_out + k) * this.obs + j];
                        let diff = p - tgt;
                        loss_k += (diff as f64) * (diff as f64);
                        a_obs[b * this.obs + j] = 2.0 * diff / n_total as f32;
                    }
                }
                let (az, ath) = this
                    .decode_vjp(z, &a_obs)
                    .expect("latent.dec_vjp executable");
                crate::tensor::axpy(1.0, &ath, &mut dec_grad.borrow_mut());
                (loss_k / n_total, az)
            });
            let grid = ObsGrid::new(this.pred_times())?;
            let bspec = BatchSpec::new(this.batch, this.latent);
            grad_obs_batched(
                cfg.method,
                &this.dynamics,
                cfg.solver,
                &cfg.spec,
                &grid,
                &z0,
                &bspec,
                &head,
                MemTracker::new(),
            )?
        };
        let mse = res.loss;
        self.dyn_grad.copy_from_slice(&res.grad_theta);
        let a_z = res.grad_z0;
        let dec_grad = dec_grad.into_inner();
        let (peak_mem, n_steps, f_evals) = (
            res.stats.peak_mem_bytes,
            res.stats.fwd.n_accepted,
            res.stats.f_evals,
        );

        // ---- reparameterization + KL back to the encoder ----------------
        // a_μ = a_z0 + β·∂KL/∂μ;  a_logvar = a_z0·ε·σ/2 + β·∂KL/∂logvar
        let scale = 1.0 / self.batch as f64;
        let a_mu: Vec<f32> = a_z
            .iter()
            .zip(&mu)
            .map(|(&a, &m)| a + (self.beta * scale) as f32 * m)
            .collect();
        let a_logvar: Vec<f32> = a_z
            .iter()
            .zip(&eps)
            .zip(&sigma)
            .zip(&logvar)
            .map(|(((&a, &e), &s), &lv)| {
                a * e * s * 0.5 + (self.beta * scale * 0.5) as f32 * (lv.exp() - 1.0)
            })
            .collect();
        let kl: f64 = mu
            .iter()
            .zip(&logvar)
            .map(|(&m, &lv)| {
                0.5 * ((m as f64).powi(2) + (lv as f64).exp() - 1.0 - lv as f64)
            })
            .sum::<f64>()
            * scale;

        let mut enc_out = self
            .engine
            .call("latent.enc_vjp", &[seq, &self.enc.value, &a_mu, &a_logvar])?;
        let enc_grad = enc_out.pop().unwrap();
        self.enc.grad.copy_from_slice(&enc_grad);
        self.dec.grad.copy_from_slice(&dec_grad);

        Ok(StepOutput {
            loss: mse + self.beta * kl,
            peak_mem_bytes: peak_mem,
            n_steps,
            f_evals,
            ..StepOutput::default()
        })
    }
}

/// Streaming latent filter: the host-side, embeddable face of the serve
/// layer's session machinery (DESIGN.md §12).  Holds a warm
/// [`ResumeState`](crate::solvers::integrate::ResumeState) +
/// [`SolverWorkspace`](crate::solvers::workspace::SolverWorkspace) over
/// any [`Dynamics`] and advances the latent trajectory **incrementally**
/// as irregular observation events arrive — each [`LatentFilter::advance`]
/// integrates only `(t_last, t_new]`, never re-solving from `t0`, and the
/// concatenated result is bitwise-identical to a one-shot
/// `integrate_obs` over all event times.
///
/// This is what a `mali serve` session does per connection, without the
/// server: use it to embed streaming filtering in a training loop, a
/// simulator, or a test.
pub struct LatentFilter<'a> {
    dynamics: &'a dyn Dynamics,
    solver: Box<dyn crate::solvers::Solver + Send + Sync>,
    mode: crate::solvers::integrate::StepMode,
    resume: crate::solvers::integrate::ResumeState,
    ws: crate::solvers::workspace::SolverWorkspace,
    stats: crate::solvers::integrate::IntStats,
}

impl<'a> LatentFilter<'a> {
    /// A fresh filter at `(t0, z0)`.  `solver` is a registry name
    /// (`"alf"`, `"rk4"`, …); the solver's augmented state is built
    /// lazily at the first advance.
    pub fn new(
        dynamics: &'a dyn Dynamics,
        solver: &str,
        t0: f64,
        z0: Vec<f32>,
        mode: crate::solvers::integrate::StepMode,
    ) -> Result<LatentFilter<'a>> {
        anyhow::ensure!(
            z0.len() == dynamics.dim(),
            "z0 has {} elements, dynamics is {}-dimensional",
            z0.len(),
            dynamics.dim()
        );
        Ok(LatentFilter {
            dynamics,
            solver: crate::solvers::by_name(solver)?,
            mode,
            resume: crate::solvers::integrate::ResumeState::new(t0, z0),
            ws: crate::solvers::workspace::SolverWorkspace::new(),
            stats: crate::solvers::integrate::IntStats::default(),
        })
    }

    /// Advance to each event time in `times` (strictly beyond the
    /// current barrier, in the session's integration direction),
    /// appending the `dim`-wide state at each event to `frames`.  After
    /// the first call, an advance allocates nothing beyond what `frames`
    /// itself grows.  On error the carried state stays at the last
    /// successful barrier and the filter is still usable.
    pub fn advance(&mut self, times: &[f64], frames: &mut Vec<f32>) -> Result<()> {
        struct Append<'b>(&'b mut Vec<f32>);
        impl StepObserver for Append<'_> {
            fn on_observation(&mut self, _k: usize, _t: f64, state: &State) {
                self.0.extend_from_slice(&state.z);
            }
        }
        let mut obs = Append(frames);
        let s = crate::solvers::integrate::integrate_obs_resume_ws(
            self.solver.as_ref(),
            self.dynamics,
            &mut self.resume,
            times,
            &self.mode,
            &crate::solvers::integrate::ErrorNorm::Full,
            &mut obs,
            &mut self.ws,
        )?;
        self.stats.n_accepted += s.n_accepted;
        self.stats.n_trials += s.n_trials;
        self.stats.f_evals += s.f_evals;
        Ok(())
    }

    /// Current barrier time (the last delivered event, or `t0`).
    pub fn t(&self) -> f64 {
        self.resume.t()
    }

    /// Current state `z(t)`.
    pub fn z(&self) -> &[f32] {
        self.resume.z()
    }

    /// Cumulative integration stats across every advance.
    pub fn stats(&self) -> &crate::solvers::integrate::IntStats {
        &self.stats
    }
}

/// RNN / GRU sequence baselines (Table 4): one fused loss+grad executable.
pub struct SeqBaseline {
    engine: Rc<Engine>,
    pub key: String, // "rnn" | "gru"
    pub params: ParamBlock,
}

impl SeqBaseline {
    pub fn new(engine: Rc<Engine>, key: &str, rng: &mut Rng) -> Result<SeqBaseline> {
        let model = engine.manifest.model(key)?.clone();
        Ok(SeqBaseline {
            params: ParamBlock::new("all", model.component("all")?.init_params(rng)),
            key: key.to_string(),
            engine,
        })
    }

    pub fn step(&mut self, seq: &[f32], target: &[f32]) -> Result<StepOutput> {
        let mut out = self.engine.call(
            &format!("{}.loss_grad", self.key),
            &[seq, target, &self.params.value],
        )?;
        let g = out.pop().unwrap();
        let loss = out.pop().unwrap()[0] as f64;
        self.params.grad.copy_from_slice(&g);
        Ok(StepOutput {
            loss,
            ..StepOutput::default()
        })
    }

    pub fn predict(&self, seq: &[f32]) -> Result<Vec<f32>> {
        self.engine
            .call1(&format!("{}.predict", self.key), &[seq, &self.params.value])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grad::IvpSpec;
    use crate::sim::hopper;
    use crate::solvers::by_name;

    fn engine() -> Option<Rc<Engine>> {
        Engine::from_env_or_skip("model test")
    }

    fn hopper_batch(m: &LatentOde, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let ds = hopper::generate(m.batch, m.t_len, m.t_out, 3.0, seed);
        let mut seq = Vec::new();
        let mut tgt = Vec::new();
        for i in 0..m.batch {
            seq.extend_from_slice(ds.observed(i, m.t_len));
            tgt.extend_from_slice(ds.target(i, m.t_len, m.t_out));
        }
        (seq, tgt)
    }

    #[test]
    fn latent_ode_step_finite_and_loss_decreases() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(1);
        let mut m = LatentOde::new(e, &mut rng).unwrap();
        let (seq, tgt) = hopper_batch(&m, 2);
        let solver = by_name("alf").unwrap();
        let method = crate::grad::by_name("mali").unwrap();
        let cfg = SolveCfg {
            solver: &*solver,
            spec: IvpSpec::fixed(0.0, 1.0, 0.25),
            method: &*method,
        };
        let out0 = m.step(&seq, &tgt, &cfg, &mut rng).unwrap();
        assert!(out0.loss.is_finite());
        assert!(m.dyn_grad.iter().any(|&g| g != 0.0), "dynamics grad all zero");
        assert!(m.enc.grad.iter().any(|&g| g != 0.0), "encoder grad all zero");
        assert!(m.dec.grad.iter().any(|&g| g != 0.0), "decoder grad all zero");

        // a few plain-SGD steps should reduce the loss on a fixed batch
        let lr = 0.05f32;
        let mut last = out0.loss;
        for it in 0..8 {
            for (v, g) in m.enc.value.iter_mut().zip(m.enc.grad.clone()) {
                *v -= lr * g;
            }
            for (v, g) in m.dec.value.iter_mut().zip(m.dec.grad.clone()) {
                *v -= lr * g;
            }
            let th: Vec<f32> = m
                .dynamics
                .params()
                .iter()
                .zip(&m.dyn_grad)
                .map(|(p, g)| p - lr * g)
                .collect();
            m.dynamics.set_params(&th);
            let out = m.step(&seq, &tgt, &cfg, &mut rng).unwrap();
            last = out.loss;
            let _ = it;
        }
        assert!(
            last < out0.loss,
            "loss did not decrease: {} → {last}",
            out0.loss
        );
    }

    #[test]
    fn predict_shape_and_mse() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(3);
        let m = LatentOde::new(e, &mut rng).unwrap();
        let (seq, tgt) = hopper_batch(&m, 4);
        let solver = by_name("alf").unwrap();
        let method = crate::grad::by_name("mali").unwrap();
        let cfg = SolveCfg {
            solver: &*solver,
            spec: IvpSpec::fixed(0.0, 1.0, 0.25),
            method: &*method,
        };
        let p = m.predict(&seq, &cfg).unwrap();
        assert_eq!(p.len(), tgt.len());
        let mse = LatentOde::mse(&p, &tgt);
        assert!(mse.is_finite() && mse > 0.0);
    }

    #[test]
    fn latent_filter_matches_one_shot_bitwise() {
        // tier-1 (no engine): the streaming filter over chunked event
        // times must reproduce the one-shot observation solve bitwise —
        // frames, final state, and step/trial counts
        use crate::solvers::dynamics::MlpDynamics;
        use crate::solvers::integrate::{
            integrate_obs, ErrorNorm, ObsGrid, StepMode,
        };
        let mut rng = Rng::new(11);
        let dynamics = MlpDynamics::new(4, 8, &mut rng);
        let z0: Vec<f32> = (0..4).map(|i| 0.3 + 0.1 * i as f32).collect();
        let times = [0.15, 0.4, 0.55, 0.9, 1.3];
        for mode in [
            StepMode::Fixed { h: 0.1 },
            StepMode::adaptive(1e-5, 1e-7),
        ] {
            let mut filter =
                LatentFilter::new(&dynamics, "alf", 0.0, z0.clone(), mode.clone()).unwrap();
            let mut frames = Vec::new();
            filter.advance(&times[..2], &mut frames).unwrap();
            filter.advance(&times[2..3], &mut frames).unwrap();
            filter.advance(&times[3..], &mut frames).unwrap();
            assert_eq!(frames.len(), times.len() * 4);

            struct Frames(Vec<f32>);
            impl StepObserver for Frames {
                fn on_observation(&mut self, _k: usize, _t: f64, state: &State) {
                    self.0.extend_from_slice(&state.z);
                }
            }
            let solver = by_name("alf").unwrap();
            let grid = ObsGrid::new(times.to_vec()).unwrap();
            let s0 = solver.init(&dynamics, 0.0, &z0);
            let mut one_shot = Frames(Vec::new());
            let (s_end, stats) = integrate_obs(
                solver.as_ref(),
                &dynamics,
                0.0,
                *times.last().unwrap(),
                s0,
                &mode,
                &ErrorNorm::Full,
                &grid,
                &mut one_shot,
            )
            .unwrap();
            assert_eq!(frames, one_shot.0, "per-event frames ({mode:?})");
            assert_eq!(filter.z(), &s_end.z[..], "final state ({mode:?})");
            assert_eq!(filter.t(), *times.last().unwrap());
            assert_eq!(filter.stats().n_accepted, stats.n_accepted, "{mode:?}");
            assert_eq!(filter.stats().n_trials, stats.n_trials, "{mode:?}");
        }
    }

    #[test]
    fn seq_baselines_step() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(5);
        for key in ["rnn", "gru"] {
            let mut m = SeqBaseline::new(e.clone(), key, &mut rng).unwrap();
            let latent = LatentOde::new(e.clone(), &mut rng).unwrap();
            let (seq, tgt) = hopper_batch(&latent, 6);
            let out = m.step(&seq, &tgt).unwrap();
            assert!(out.loss.is_finite(), "{key}");
            assert!(m.params.grad.iter().any(|&g| g != 0.0), "{key} grad zero");
            let p = m.predict(&seq).unwrap();
            assert_eq!(p.len(), tgt.len(), "{key}");
        }
    }
}
