//! RealNVP (Dinh et al. 2016) — the discrete-flow baseline column of
//! paper Table 6, trained through one fused BPD-loss+grad executable.
//!
//! Uses the same dequantize+logit preprocessing as the CNF (`models::cnf`)
//! so BPD numbers are directly comparable.

use super::{ParamBlock, StepOutput};
use crate::runtime::Engine;
use crate::util::rng::Rng;
use anyhow::Result;
use std::rc::Rc;

const LN2: f64 = std::f64::consts::LN_2;
const ALPHA: f64 = 0.05;

pub struct RealNvp {
    engine: Rc<Engine>,
    pub key: String, // "realnvp_mnist8" | "realnvp_cifar8"
    pub batch: usize,
    pub dim: usize,
    pub params: ParamBlock,
}

impl RealNvp {
    pub fn new(engine: Rc<Engine>, key: &str, rng: &mut Rng) -> Result<RealNvp> {
        let model = engine.manifest.model(key)?.clone();
        Ok(RealNvp {
            batch: model.dim("batch")?,
            dim: model.dim("dim")?,
            params: ParamBlock::new("all", model.component("all")?.init_params(rng)),
            key: key.to_string(),
            engine,
        })
    }

    pub fn param_count(&self) -> usize {
        self.params.len()
    }

    /// Same preprocessing as [`crate::models::cnf::Ffjord::preprocess`].
    pub fn preprocess(&self, x: &[f32], rng: &mut Rng) -> (Vec<f32>, f64) {
        let mut logdet = 0.0f64;
        let y = x
            .iter()
            .map(|&p| {
                let q = ((p as f64 * 255.0).floor() + rng.uniform()) / 256.0;
                let s = ALPHA + (1.0 - 2.0 * ALPHA) * q;
                logdet += (1.0 - 2.0 * ALPHA).ln() - s.ln() - (1.0 - s).ln();
                (s / (1.0 - s)).ln() as f32
            })
            .collect();
        (y, logdet)
    }

    /// One fused loss+grad step on raw pixels.
    pub fn step(&mut self, x: &[f32], rng: &mut Rng) -> Result<StepOutput> {
        let (y, _) = self.preprocess(x, rng);
        let mut out = self
            .engine
            .call(&format!("{}.loss_grad", self.key), &[&y, &self.params.value])?;
        let g = out.pop().unwrap();
        let loss = out.pop().unwrap()[0] as f64;
        self.params.grad.copy_from_slice(&g);
        Ok(StepOutput {
            loss,
            ..StepOutput::default()
        })
    }

    /// Discrete BPD on raw pixels (preprocessing bookkeeping included).
    pub fn bpd(&self, x: &[f32], rng: &mut Rng) -> Result<f64> {
        let (y, logdet) = self.preprocess(x, rng);
        let per_sample = self
            .engine
            .call1(&format!("{}.bpd", self.key), &[&y, &self.params.value])?;
        let mean_bits: f64 =
            per_sample.iter().map(|&b| b as f64).sum::<f64>() / per_sample.len() as f64;
        let d = self.dim as f64;
        Ok(mean_bits - logdet / (self.batch as f64 * d * LN2) + 8.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::density;

    fn engine() -> Option<Rc<Engine>> {
        Engine::from_env_or_skip("model test")
    }

    #[test]
    fn realnvp_trains_on_glyphs() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(1);
        let mut m = RealNvp::new(e, "realnvp_mnist8", &mut rng).unwrap();
        let ds = density::mnist8(m.batch, 2);
        let x = &ds.x[..m.batch * m.dim];
        // Adam makes progress on a flow where plain SGD barely moves
        use crate::opt::Optimizer as _;
        let mut opt = crate::opt::Adam::new(5e-3, m.param_count());
        let mut first = f64::NAN;
        let mut last = f64::NAN;
        for it in 0..60 {
            let out = m.step(x, &mut rng).unwrap();
            if it == 0 {
                first = out.loss;
            }
            last = out.loss;
            let g = m.params.grad.clone();
            opt.step(&mut m.params.value, &g);
        }
        assert!(
            last < first - 0.05,
            "RealNVP loss did not drop: {first} → {last}"
        );
        let bpd = m.bpd(x, &mut rng).unwrap();
        assert!(bpd.is_finite());
    }

    #[test]
    fn bpd_deterministic_given_rng() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(3);
        let m = RealNvp::new(e, "realnvp_cifar8", &mut rng).unwrap();
        let ds = density::cifar8(m.batch, 4);
        let x = &ds.x[..m.batch * m.dim];
        let a = m.bpd(x, &mut Rng::new(9)).unwrap();
        let b = m.bpd(x, &mut Rng::new(9)).unwrap();
        assert_eq!(a, b);
    }
}
