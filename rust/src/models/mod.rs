//! Experiment models: each binds manifest components to AOT executables and
//! exposes `loss + gradients` steps the trainer drives (DESIGN.md §2/§5).
//!
//! | module      | paper experiment                         | manifest models |
//! |-------------|------------------------------------------|-----------------|
//! | [`image`]   | Fig. 5 / Fig. 6 / Tables 2–3 classifiers | `img16`, `img32`|
//! | [`latent`]  | Table 4 latent-ODE + RNN/GRU baselines   | `latent`, `rnn`, `gru` |
//! | [`cde`]     | Table 5 Neural CDE                       | `cde`           |
//! | [`cnf`]     | Table 6 FFJORD                           | `cnf_*`         |
//! | [`realnvp`] | Table 6 discrete-flow baseline           | `realnvp_*`     |
//! | [`native`]  | E2 / E8 artifact-free fused-dynamics runs | — (no manifest) |
//!
//! Every model takes the gradient-estimation [`GradMethod`]
//! (naive / adjoint / ACA / MALI) as a parameter — the experiments are
//! *about* swapping that while the model stays fixed.

pub mod cde;
pub mod cnf;
pub mod image;
pub mod latent;
pub mod native;
pub mod realnvp;

use crate::grad::GradMethod;
use crate::solvers::Solver;

/// A named flat parameter block plus its gradient accumulator — the unit
/// the optimizer steps over.
#[derive(Debug, Clone)]
pub struct ParamBlock {
    pub name: String,
    pub value: Vec<f32>,
    pub grad: Vec<f32>,
}

impl ParamBlock {
    pub fn new(name: &str, value: Vec<f32>) -> ParamBlock {
        let n = value.len();
        ParamBlock {
            name: name.to_string(),
            value,
            grad: vec![0.0; n],
        }
    }

    pub fn zero_grad(&mut self) {
        self.grad.iter_mut().for_each(|g| *g = 0.0);
    }

    pub fn len(&self) -> usize {
        self.value.len()
    }

    pub fn is_empty(&self) -> bool {
        self.value.is_empty()
    }
}

/// What one training step reports back to the trainer.
#[derive(Debug, Clone, Default)]
pub struct StepOutput {
    pub loss: f64,
    /// Classification logits (empty for regression/likelihood models).
    pub logits: Vec<f32>,
    /// `dL/dx` when requested (FGSM); empty otherwise.
    pub grad_x: Vec<f32>,
    /// Peak retained-state bytes of the gradient method this step.
    pub peak_mem_bytes: usize,
    /// Forward accepted steps (N_t) and total f evaluations.
    pub n_steps: usize,
    pub f_evals: u64,
}

/// Solver + integration-spec bundle passed into every model step.
pub struct SolveCfg<'a> {
    pub solver: &'a dyn Solver,
    pub spec: crate::grad::IvpSpec,
    pub method: &'a dyn GradMethod,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_block_zeroes() {
        let mut p = ParamBlock::new("w", vec![1.0, 2.0]);
        p.grad = vec![3.0, 4.0];
        p.zero_grad();
        assert_eq!(p.grad, vec![0.0, 0.0]);
        assert_eq!(p.len(), 2);
    }
}
