//! The artifact manifest: `artifacts/manifest.json` written by
//! `python/compile/aot.py` is the single source of truth binding the Rust
//! coordinator to the AOT-compiled HLO graphs — entry input/output shapes,
//! per-model dimensions and parameter-component specs (shape + init scheme).

use crate::util::json::Json;
use crate::util::rng::{Init, Rng};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shape + dtype of one executable input or output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_scalar(&self) -> bool {
        self.shape.is_empty()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow!("tensor spec missing 'shape'"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad shape dim")))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec {
            shape,
            dtype: j.get("dtype").as_str().unwrap_or("float32").to_string(),
        })
    }
}

/// One AOT artifact (an `<name>.hlo.txt` file plus its signature).
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub name: String,
    pub file: String,
    pub doc: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One trainable parameter component (e.g. `w1` of the dynamics MLP) with
/// its initialization scheme — mirrored from `families.py::param_spec`.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: Init,
}

impl ParamSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<ParamSpec> {
        let shape: Vec<usize> = j
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow!("param spec missing 'shape'"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let init = match j.get("init").as_str().unwrap_or("zeros") {
            "zeros" => Init::Zeros,
            "ones" => Init::Ones,
            "glorot_uniform" => {
                let fan_in = j.get("fan_in").as_usize().unwrap_or(1);
                let fan_out = j.get("fan_out").as_usize().unwrap_or(1);
                Init::GlorotUniform { fan_in, fan_out }
            }
            other => bail!("unknown init scheme '{other}'"),
        };
        Ok(ParamSpec {
            name: j.get("name").as_str().unwrap_or("?").to_string(),
            shape,
            init,
        })
    }
}

/// A named group of parameters (stem / f / head / enc / dec / all).
#[derive(Debug, Clone)]
pub struct Component {
    pub params: Vec<ParamSpec>,
    pub len: usize,
}

impl Component {
    /// Initialize a flat parameter vector per the component's specs.
    pub fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        let mut theta = vec![0.0f32; self.len];
        let mut ofs = 0;
        for p in &self.params {
            let n = p.len();
            p.init.fill(rng, &mut theta[ofs..ofs + n]);
            ofs += n;
        }
        theta
    }
}

/// Per-model dimensions and components, from the manifest's `models` map.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub dims: BTreeMap<String, f64>,
    pub components: BTreeMap<String, Component>,
}

impl ModelSpec {
    pub fn dim(&self, key: &str) -> Result<usize> {
        self.dims
            .get(key)
            .map(|&v| v as usize)
            .with_context(|| format!("model '{}' has no dim '{key}'", self.name))
    }

    pub fn dim_or(&self, key: &str, default: usize) -> usize {
        self.dims.get(key).map(|&v| v as usize).unwrap_or(default)
    }

    pub fn component(&self, name: &str) -> Result<&Component> {
        self.components
            .get(name)
            .with_context(|| format!("model '{}' has no component '{name}'", self.name))
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, EntrySpec>,
    pub models: BTreeMap<String, ModelSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let root = Json::parse_file(&path)
            .map_err(|e| anyhow!("manifest {}: {e}", path.display()))?;

        let mut entries = BTreeMap::new();
        for (name, j) in root
            .get("entries")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest missing 'entries'"))?
        {
            let inputs = j
                .get("inputs")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("entry '{name}' inputs"))?;
            let outputs = j
                .get("outputs")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("entry '{name}' outputs"))?;
            entries.insert(
                name.clone(),
                EntrySpec {
                    name: name.clone(),
                    file: j
                        .get("file")
                        .as_str()
                        .unwrap_or(&format!("{name}.hlo.txt"))
                        .to_string(),
                    doc: j.get("doc").as_str().unwrap_or("").to_string(),
                    inputs,
                    outputs,
                },
            );
        }

        let mut models = BTreeMap::new();
        if let Some(m) = root.get("models").as_obj() {
            for (name, j) in m {
                let mut dims = BTreeMap::new();
                if let Some(obj) = j.as_obj() {
                    for (k, v) in obj {
                        if let Some(n) = v.as_f64() {
                            dims.insert(k.clone(), n);
                        }
                    }
                }
                let mut components = BTreeMap::new();
                if let Some(comps) = j.get("components").as_obj() {
                    for (cname, cj) in comps {
                        let params = cj
                            .get("params")
                            .as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .map(ParamSpec::from_json)
                            .collect::<Result<Vec<_>>>()
                            .with_context(|| format!("model '{name}' comp '{cname}'"))?;
                        let len = cj
                            .get("len")
                            .as_usize()
                            .unwrap_or_else(|| params.iter().map(ParamSpec::len).sum());
                        components.insert(cname.clone(), Component { params, len });
                    }
                }
                models.insert(
                    name.clone(),
                    ModelSpec {
                        name: name.clone(),
                        dims,
                        components,
                    },
                );
            }
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            entries,
            models,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .with_context(|| format!("manifest has no entry '{name}'"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .get(name)
            .with_context(|| format!("manifest has no model '{name}'"))
    }

    pub fn hlo_path(&self, entry: &EntrySpec) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// `None` (test skipped) only when the AOT artifacts have not been
    /// built at all — the manifest is generated by `python/compile/aot.py`.
    /// A *present but unloadable* manifest.json must fail loudly: catching
    /// that is exactly what these tests are for.
    fn manifest() -> Option<Manifest> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!(
                "skipping manifest test: {} not built (run `make artifacts`)",
                dir.join("manifest.json").display()
            );
            return None;
        }
        Some(Manifest::load(&dir).expect("artifacts/manifest.json exists but fails to load"))
    }

    /// Offline-runnable coverage of the parser: a miniature manifest with
    /// one entry and one model round-trips through [`Manifest::load`].
    #[test]
    fn parses_minimal_manifest_from_disk() {
        // pid-unique dir: concurrent `cargo test` runs must not collide
        let dir = std::env::temp_dir()
            .join(format!("mali_manifest_unit_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "entries": {
                "toy.f": {
                  "file": "toy.f.hlo.txt",
                  "doc": "dz = alpha*z",
                  "inputs": [{"shape": [], "dtype": "float32"},
                             {"shape": [4], "dtype": "float32"},
                             {"shape": [1], "dtype": "float32"}],
                  "outputs": [{"shape": [4], "dtype": "float32"}]
                }
              },
              "models": {
                "toy": {
                  "d": 4,
                  "components": {
                    "f": {"params": [{"name": "alpha", "shape": [1], "init": "ones"}]}
                  }
                }
              }
            }"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let e = m.entry("toy.f").unwrap();
        assert_eq!(e.inputs.len(), 3);
        assert!(e.inputs[0].is_scalar());
        assert_eq!(e.outputs[0].len(), 4);
        assert_eq!(m.hlo_path(e), dir.join("toy.f.hlo.txt"));
        let model = m.model("toy").unwrap();
        assert_eq!(model.dim("d").unwrap(), 4);
        let comp = model.component("f").unwrap();
        assert_eq!(comp.len, 1);
        let mut rng = Rng::new(1);
        assert_eq!(comp.init_params(&mut rng), vec![1.0]);
        assert!(m.entry("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = manifest() else { return };
        // every family exports the standard executable set
        for fam in ["toy", "img16", "img32", "latent", "cde"] {
            for suffix in ["f", "f_vjp", "step", "inv", "step_vjp"] {
                assert!(
                    m.entries.contains_key(&format!("{fam}.{suffix}")),
                    "{fam}.{suffix}"
                );
            }
        }
        // model specs carry component lengths
        let img16 = m.model("img16").unwrap();
        let f = img16.component("f").unwrap();
        assert_eq!(f.len, f.params.iter().map(ParamSpec::len).sum::<usize>());
        assert!(img16.dim("d").unwrap() > 0);
    }

    #[test]
    fn entry_shapes_are_consistent() {
        let Some(m) = manifest() else { return };
        let e = m.entry("toy.step").unwrap();
        // (z, v, t, h, eta, theta) → (z', v', err)
        assert_eq!(e.inputs.len(), 6);
        assert_eq!(e.outputs.len(), 3);
        assert_eq!(e.inputs[0].shape, e.outputs[0].shape);
        assert!(e.inputs[2].is_scalar());
        // the HLO file exists on disk
        assert!(m.hlo_path(e).exists(), "{:?}", m.hlo_path(e));
    }

    #[test]
    fn component_init_respects_scheme() {
        let Some(m) = manifest() else { return };
        let comp = m.model("toy").unwrap().component("f").unwrap();
        let mut rng = Rng::new(1);
        let theta = comp.init_params(&mut rng);
        assert_eq!(theta, vec![1.0]); // toy α initialized to ones

        let f = m.model("img16").unwrap().component("f").unwrap();
        let theta = f.init_params(&mut rng);
        assert_eq!(theta.len(), f.len);
        // glorot weights are non-zero, biases zero: some of each
        assert!(theta.iter().any(|&x| x != 0.0));
        assert!(theta.iter().any(|&x| x == 0.0));
    }

    #[test]
    fn missing_entry_is_an_error() {
        let Some(m) = manifest() else { return };
        assert!(m.entry("nope.f").is_err());
        assert!(m.model("nope").is_err());
    }
}
