//! The AOT runtime: PJRT client + compiled-executable cache ([`Engine`]),
//! the manifest contract with `python/compile/aot.py` ([`Manifest`]), and
//! the HLO-backed [`Dynamics`](crate::solvers::dynamics::Dynamics)
//! implementation ([`HloDynamics`]).
//!
//! Python runs once at `make artifacts`; everything here is pure Rust over
//! the `xla` crate's PJRT CPU client.  In the offline build the PJRT
//! bindings are provided by [`xla_stub`] (same surface, always-erroring
//! constructors), so the whole layer compiles and everything above it is
//! testable; [`Engine::new`] reports a descriptive error until the real
//! `xla` crate is vendored (DESIGN.md §2).

pub mod engine;
pub mod hlo_dynamics;
pub mod manifest;
pub mod xla_stub;

pub use engine::{Engine, EngineStats};
pub use hlo_dynamics::HloDynamics;
pub use manifest::{Component, EntrySpec, Manifest, ModelSpec, ParamSpec, TensorSpec};
