//! The AOT runtime: PJRT client + compiled-executable cache ([`Engine`]),
//! the manifest contract with `python/compile/aot.py` ([`Manifest`]), and
//! the HLO-backed [`Dynamics`](crate::solvers::dynamics::Dynamics)
//! implementation ([`HloDynamics`]).
//!
//! Python runs once at `make artifacts`; everything here is pure Rust over
//! the `xla` crate's PJRT CPU client.  Reference wiring is documented in
//! `/opt/xla-example/README.md`.

pub mod engine;
pub mod hlo_dynamics;
pub mod manifest;

pub use engine::{Engine, EngineStats};
pub use hlo_dynamics::HloDynamics;
pub use manifest::{Component, EntrySpec, Manifest, ModelSpec, ParamSpec, TensorSpec};
