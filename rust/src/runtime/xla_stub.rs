//! Offline stub of the `xla` (xla-rs / PJRT) crate surface that
//! [`engine`](super::engine) compiles against.
//!
//! The build image does not vendor the `xla` crate or the `xla_extension`
//! C++ runtime, so this module provides the exact API shape the engine
//! uses — every constructor returns a descriptive error, making the L3
//! coordinator fully compilable and testable while device execution is
//! unavailable.  Code that needs a live runtime (engine/model tests, the
//! HLO examples) detects the error and skips gracefully.
//!
//! Swapping back to the real backend is a two-line change: add the
//! vendored `xla` crate to `Cargo.toml` and replace the
//! `use super::xla_stub as xla;` import in `engine.rs` — no call-site
//! changes (the signatures below mirror the real crate as used).

use std::fmt;
use std::path::Path;

/// Error type for every stubbed call; interoperates with `anyhow` via
/// `std::error::Error`.
#[derive(Debug)]
pub struct XlaError {
    msg: String,
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for XlaError {}

type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError {
        msg: format!(
            "{what}: XLA/PJRT runtime unavailable — this build uses the offline \
             stub (`runtime::xla_stub`); vendor the `xla` crate to enable \
             device execution (DESIGN.md §2, docs/adr/001)"
        ),
    }
}

/// Stub of `xla::PjRtClient` (CPU PJRT client).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Real crate: create the CPU PJRT client.  Stub: always errors.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Real crate: compile an [`XlaComputation`] to a loaded executable.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    /// Real crate: copy a host `f32` buffer to a device buffer with the
    /// given shape (`layout: None` = default row-major).
    pub fn buffer_from_host_buffer(
        &self,
        _data: &[f32],
        _shape: &[usize],
        _layout: Option<&[i64]>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

/// Stub of `xla::HloModuleProto` (parsed HLO text).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Real crate: parse an `*.hlo.txt` file (reassigning instruction ids).
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Real crate: wrap a module proto as a compilable computation.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Real crate: execute with explicit device buffers; returns per-device
    /// output buffer lists.
    pub fn execute_b(&self, _buffers: &[PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// Stub of `xla::PjRtBuffer` (a device buffer).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Real crate: synchronously copy the device buffer to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub of `xla::Literal` (a host tensor value).
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Real crate: destructure a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// Real crate: copy out as a typed host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_calls_error_with_guidance() {
        let err = PjRtClient::cpu().err().expect("stub must error");
        let msg = err.to_string();
        assert!(msg.contains("PJRT runtime unavailable"), "{msg}");
        assert!(msg.contains("xla_stub"), "{msg}");
    }

    #[test]
    fn error_interops_with_anyhow() {
        use anyhow::Context as _;
        let r: anyhow::Result<PjRtClient> =
            PjRtClient::cpu().context("PJRT CPU client");
        let e = r.err().unwrap();
        assert!(format!("{e:#}").starts_with("PJRT CPU client: "));
    }
}
