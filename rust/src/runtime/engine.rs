//! The PJRT execution engine: loads `artifacts/*.hlo.txt`, compiles each
//! once on the CPU PJRT client, and executes with `Vec<f32>` host buffers.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
//! rejects; `HloModuleProto::from_text_file` reassigns ids cleanly.  See
//! `python/compile/aot.py` and DESIGN.md §2.

use super::manifest::{EntrySpec, Manifest, TensorSpec};
// The PJRT bindings: the offline image ships a stub with the same surface
// (always-erroring constructors); swap this import for the vendored `xla`
// crate to enable real device execution (see `runtime::xla_stub`).
use super::xla_stub as xla;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

/// Cumulative engine counters (the L3 perf pass reads these).
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    pub compiles: u64,
    pub executions: u64,
    pub compile_secs: f64,
    pub execute_secs: f64,
}

/// A compiled executable plus its manifest signature.
struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    spec: EntrySpec,
}

/// PJRT client + lazily-compiled executable cache, driven by the manifest.
///
/// The engine is deliberately single-threaded (`RefCell` caches): PJRT CPU
/// execution already uses all cores internally, and the coordinator's
/// parallelism lives at the experiment level where each job owns an engine.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: RefCell<BTreeMap<String, std::rc::Rc<Compiled>>>,
    stats: RefCell<EngineStats>,
}

impl Engine {
    /// Create an engine over an artifact directory (must contain
    /// `manifest.json`; HLO files compile lazily on first call).
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Engine {
            manifest,
            client,
            cache: RefCell::new(BTreeMap::new()),
            stats: RefCell::new(EngineStats::default()),
        })
    }

    /// Default artifact location relative to the crate root, overridable via
    /// `MALI_ARTIFACTS`.
    pub fn artifacts_dir() -> std::path::PathBuf {
        if let Ok(dir) = std::env::var("MALI_ARTIFACTS") {
            return dir.into();
        }
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Convenience constructor over [`Engine::artifacts_dir`].
    pub fn from_env() -> Result<Engine> {
        Engine::new(&Engine::artifacts_dir())
    }

    /// Test support: `Some(engine)` where device execution is possible,
    /// `None` (with a stderr note) where the AOT artifacts or the PJRT
    /// runtime are absent — the offline build stubs PJRT
    /// (`runtime::xla_stub`), so engine-dependent tests self-skip through
    /// this single helper instead of failing.
    ///
    /// Artifact-equipped CI must set `MALI_REQUIRE_ENGINE=1`, which turns
    /// the skip into a panic — otherwise a regression that breaks engine
    /// construction would make the whole device suite vacuously green.
    #[doc(hidden)]
    pub fn from_env_or_skip(what: &str) -> Option<std::rc::Rc<Engine>> {
        match Engine::from_env() {
            Ok(e) => Some(std::rc::Rc::new(e)),
            Err(e) => {
                let required = std::env::var("MALI_REQUIRE_ENGINE")
                    .map(|v| !v.is_empty() && v != "0" && v != "false")
                    .unwrap_or(false);
                if required {
                    panic!("MALI_REQUIRE_ENGINE set but engine unavailable ({what}): {e:#}");
                }
                eprintln!("skipping {what}: {e:#}");
                None
            }
        }
    }

    pub fn stats(&self) -> EngineStats {
        self.stats.borrow().clone()
    }

    /// Compile (or fetch from cache) the named entry.
    fn compiled(&self, name: &str) -> Result<std::rc::Rc<Compiled>> {
        if let Some(c) = self.cache.borrow().get(name) {
            return Ok(c.clone());
        }
        let spec = self.manifest.entry(name)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse HLO {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile '{name}'"))?;
        {
            let mut s = self.stats.borrow_mut();
            s.compiles += 1;
            s.compile_secs += t0.elapsed().as_secs_f64();
        }
        let rc = std::rc::Rc::new(Compiled { exe, spec });
        self.cache.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Eagerly compile every entry with the given name prefix (warmup).
    pub fn precompile(&self, prefix: &str) -> Result<usize> {
        let names: Vec<String> = self
            .manifest
            .entries
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect();
        for n in &names {
            self.compiled(n)?;
        }
        Ok(names.len())
    }

    fn buffer_for(
        &self,
        spec: &TensorSpec,
        data: &[f32],
    ) -> Result<xla::PjRtBuffer> {
        if spec.dtype != "float32" {
            bail!("only float32 inputs are exported (got {})", spec.dtype);
        }
        if data.len() != spec.len() {
            bail!(
                "input length {} does not match shape {:?} ({} elements)",
                data.len(),
                spec.shape,
                spec.len()
            );
        }
        Ok(self
            .client
            .buffer_from_host_buffer(data, &spec.shape, None)?)
    }

    /// Execute entry `name` with flat f32 inputs (shaped per the manifest);
    /// returns flat f32 outputs in manifest order.
    ///
    /// This is the request-path hot call: one host→device transfer per
    /// input, one execute, one device→host per output.  Inputs go through
    /// `buffer_from_host_buffer` + `execute_b` — the crate's literal-based
    /// `execute` leaks its implicitly-created input device buffers
    /// (~input-size bytes per call, DESIGN.md §9), while buffers we create
    /// ourselves are freed by their `Drop`.
    pub fn call(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let c = self.compiled(name)?;
        if inputs.len() != c.spec.inputs.len() {
            bail!(
                "'{name}' expects {} inputs, got {}",
                c.spec.inputs.len(),
                inputs.len()
            );
        }
        let buffers = inputs
            .iter()
            .zip(&c.spec.inputs)
            .enumerate()
            .map(|(i, (data, spec))| {
                self.buffer_for(spec, data)
                    .with_context(|| format!("'{name}' input {i}"))
            })
            .collect::<Result<Vec<_>>>()?;

        let t0 = Instant::now();
        let result = c
            .exe
            .execute_b(&buffers)
            .with_context(|| format!("execute '{name}'"))?[0][0]
            .to_literal_sync()?;
        {
            let mut s = self.stats.borrow_mut();
            s.executions += 1;
            s.execute_secs += t0.elapsed().as_secs_f64();
        }

        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = result.to_tuple()?;
        if parts.len() != c.spec.outputs.len() {
            bail!(
                "'{name}' returned {} outputs, manifest says {}",
                parts.len(),
                c.spec.outputs.len()
            );
        }
        parts
            .into_iter()
            .enumerate()
            .map(|(i, lit)| {
                let v = lit
                    .to_vec::<f32>()
                    .with_context(|| format!("'{name}' output {i}"))?;
                let want = c.spec.outputs[i].len();
                if v.len() != want {
                    bail!("'{name}' output {i}: got {} elements, want {want}", v.len());
                }
                Ok(v)
            })
            .collect()
    }

    /// Like [`Engine::call`] but asserts a single output and unwraps it.
    pub fn call1(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let mut out = self.call(name, inputs)?;
        if out.len() != 1 {
            bail!("'{name}' has {} outputs, expected 1", out.len());
        }
        Ok(out.pop().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `None` (test skipped) when the AOT artifacts or the PJRT runtime are
    /// absent — the offline build stubs PJRT (`runtime::xla_stub`), so these
    /// tests only run where device execution is actually possible.
    fn engine() -> Option<std::rc::Rc<Engine>> {
        Engine::from_env_or_skip("engine test")
    }

    /// toy.f computes α·z — cross-check the whole load/compile/execute path
    /// against arithmetic we can do by hand.
    #[test]
    fn toy_f_is_alpha_z() {
        let Some(e) = engine() else { return };
        let z = [1.0f32, -2.0, 0.5, 3.0];
        let alpha = [0.75f32];
        let out = e.call1("toy.f", &[&[0.3], &z, &alpha]).unwrap();
        for (o, zi) in out.iter().zip(&z) {
            assert!((o - 0.75 * zi).abs() < 1e-6, "{o} vs {}", 0.75 * zi);
        }
    }

    #[test]
    fn toy_step_matches_native_alf() {
        use crate::solvers::alf::AlfSolver;
        use crate::solvers::dynamics::{Dynamics, LinearToy};
        let Some(e) = engine() else { return };
        let toy = LinearToy::new(0.75, 4);
        let z = [1.0f32, -2.0, 0.5, 3.0];
        let v = toy.f(0.0, &z);
        let (h, eta) = (0.2f64, 1.0f64);
        let native = AlfSolver::new(eta).psi(&toy, 0.0, h, &z, &v);
        let hlo = e
            .call(
                "toy.step",
                &[&z, &v, &[0.0], &[h as f32], &[eta as f32], &[0.75]],
            )
            .unwrap();
        for i in 0..4 {
            assert!((native.0[i] - hlo[0][i]).abs() < 1e-5, "z[{i}]");
            assert!((native.1[i] - hlo[1][i]).abs() < 1e-5, "v[{i}]");
            assert!((native.2[i] - hlo[2][i]).abs() < 1e-5, "err[{i}]");
        }
    }

    #[test]
    fn input_validation_errors() {
        let Some(e) = engine() else { return };
        // wrong arity
        assert!(e.call("toy.f", &[&[0.0]]).is_err());
        // wrong input length
        assert!(e.call("toy.f", &[&[0.0], &[1.0, 2.0], &[1.0]]).is_err());
        // unknown entry
        assert!(e.call("toy.bogus", &[]).is_err());
    }

    #[test]
    fn cache_compiles_once() {
        let Some(e) = engine() else { return };
        let z = [0.0f32; 4];
        e.call1("toy.f", &[&[0.0], &z, &[1.0]]).unwrap();
        e.call1("toy.f", &[&[0.0], &z, &[1.0]]).unwrap();
        let s = e.stats();
        assert_eq!(s.compiles, 1);
        assert_eq!(s.executions, 2);
    }
}
