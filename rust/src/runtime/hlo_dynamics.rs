//! [`HloDynamics`]: the [`Dynamics`] implementation backed by AOT-compiled
//! HLO graphs — the production path where every `f` / ψ / ψ⁻¹ / ψ-vjp
//! evaluation is one PJRT execute of an L2 graph (containing the L1 Pallas
//! kernels), with Rust supplying only control flow.
//!
//! Each dynamics *family* (`toy`, `img16`, `img32`, `latent`, `cde`,
//! `cnf_*`) exports the standard executable set (see `families.py`):
//!
//! | entry             | signature                                           |
//! |-------------------|-----------------------------------------------------|
//! | `<fam>.f`         | `(t, z, *ctx, θ) → dz`                              |
//! | `<fam>.f_vjp`     | `(t, z, *ctx, θ, a) → (aᵀ∂f/∂z, aᵀ∂f/∂θ)`           |
//! | `<fam>.step`      | `(z, v, t, h, η, *ctx, θ) → (z', v', err)`          |
//! | `<fam>.inv`       | `(z', v', t', h, η, *ctx, θ) → (z, v)`              |
//! | `<fam>.step_vjp`  | `(z, v, t, h, η, *ctx, θ, a_z', a_v') → (a_z, a_v, a_θ)` |
//!
//! `ctx` tensors (CDE spline coefficients, the CNF Hutchinson probe) ride
//! along per solve and are not differentiated.

use super::engine::Engine;
use crate::solvers::batch::BatchSpec;
use crate::solvers::dynamics::{Dynamics, EvalCounters};
use anyhow::{bail, Context, Result};
use std::rc::Rc;

pub struct HloDynamics {
    engine: Rc<Engine>,
    family: String,
    /// Flattened state size (batch × state_dim).
    dim: usize,
    theta: Vec<f32>,
    /// Context tensors in manifest order (between `z` and `θ` in `f`).
    ctx: Vec<Vec<f32>>,
    counters: EvalCounters,
    nf: usize,
    /// Route ψ/ψ⁻¹/ψ-vjp through the fused per-step executables (one PJRT
    /// call) instead of composing them from `f` on the host.
    pub use_fused: bool,
}

impl HloDynamics {
    /// Bind to a family; θ starts at the manifest's init scheme if the
    /// model declares an `f` component, else zeros.
    pub fn new(engine: Rc<Engine>, family: &str) -> Result<HloDynamics> {
        let f_entry = engine
            .manifest
            .entry(&format!("{family}.f"))
            .with_context(|| format!("family '{family}'"))?;
        // (t, z, *ctx, θ): at least 3 inputs
        if f_entry.inputs.len() < 3 {
            bail!("'{family}.f' has {} inputs, expected ≥ 3", f_entry.inputs.len());
        }
        let dim = f_entry.inputs[1].len();
        let n_in = f_entry.inputs.len();
        let ctx: Vec<Vec<f32>> = f_entry.inputs[2..n_in - 1]
            .iter()
            .map(|s| vec![0.0f32; s.len()])
            .collect();
        let theta_len = f_entry.inputs[n_in - 1].len();
        // A family's "depth" N_f: 2 matmul layers for every exported MLP
        // dynamics (Table-1 accounting).
        let nf = 2;
        Ok(HloDynamics {
            engine,
            family: family.to_string(),
            dim,
            theta: vec![0.0f32; theta_len],
            ctx,
            counters: EvalCounters::default(),
            nf,
            use_fused: true,
        })
    }

    /// Initialize θ from the model's `f` component spec.
    pub fn init_params(&mut self, rng: &mut crate::util::rng::Rng) -> Result<()> {
        let comp = self
            .engine
            .manifest
            .model(&self.family)?
            .component("f")?
            .clone();
        if comp.len != self.theta.len() {
            bail!(
                "model '{}' f-component len {} vs entry θ len {}",
                self.family,
                comp.len,
                self.theta.len()
            );
        }
        self.theta = comp.init_params(rng);
        Ok(())
    }

    pub fn engine(&self) -> &Rc<Engine> {
        &self.engine
    }

    pub fn family(&self) -> &str {
        &self.family
    }

    pub fn n_ctx(&self) -> usize {
        self.ctx.len()
    }

    /// Replace context tensor `i` (length-checked).
    pub fn set_ctx(&mut self, i: usize, data: Vec<f32>) -> Result<()> {
        if i >= self.ctx.len() {
            bail!("family '{}' has {} ctx tensors", self.family, self.ctx.len());
        }
        if data.len() != self.ctx[i].len() {
            bail!(
                "ctx {i}: got {} elements, want {}",
                data.len(),
                self.ctx[i].len()
            );
        }
        self.ctx[i] = data;
        Ok(())
    }

    fn entry(&self, suffix: &str) -> String {
        format!("{}.{}", self.family, suffix)
    }

    /// Assemble `[fixed..., ctx..., tail...]` input lists.
    fn with_ctx<'a>(&'a self, head: &[&'a [f32]], tail: &[&'a [f32]]) -> Vec<&'a [f32]> {
        let mut v: Vec<&[f32]> = Vec::with_capacity(head.len() + self.ctx.len() + tail.len());
        v.extend_from_slice(head);
        for c in &self.ctx {
            v.push(c.as_slice());
        }
        v.extend_from_slice(tail);
        v
    }
}

impl Dynamics for HloDynamics {
    fn dim(&self) -> usize {
        self.dim
    }

    fn param_dim(&self) -> usize {
        self.theta.len()
    }

    fn f(&self, t: f64, z: &[f32]) -> Vec<f32> {
        self.counters.f_evals.add(1);
        let ts = [t as f32];
        let inputs = self.with_ctx(&[&ts, z], &[&self.theta]);
        self.engine
            .call1(&self.entry("f"), &inputs)
            .expect("HLO f eval")
    }

    fn f_vjp(&self, t: f64, z: &[f32], a: &[f32]) -> (Vec<f32>, Vec<f32>) {
        self.counters.vjp_evals.add(1);
        let ts = [t as f32];
        let inputs = self.with_ctx(&[&ts, z], &[&self.theta, a]);
        let mut out = self
            .engine
            .call(&self.entry("f_vjp"), &inputs)
            .expect("HLO f_vjp eval");
        let ath = out.pop().unwrap();
        let az = out.pop().unwrap();
        (az, ath)
    }

    fn params(&self) -> &[f32] {
        &self.theta
    }

    fn set_params(&mut self, theta: &[f32]) {
        self.theta.copy_from_slice(theta);
    }

    fn counters(&self) -> &EvalCounters {
        &self.counters
    }

    fn depth_nf(&self) -> usize {
        self.nf
    }

    /// The batch dimension is baked into the AOT executables, so the
    /// batch driver must keep one fused device call (DESIGN.md §3).
    fn is_device_batched(&self) -> bool {
        true
    }

    /// Device-batched evaluation: the compiled graph already spans the
    /// whole `[B·n_z]` buffer, so a batched call that matches the
    /// compiled layout (and a single shared time — device graphs take a
    /// scalar `t`) is exactly one `f` execute, counted as **one device
    /// evaluation** (see [`EvalCounters`]: device counts are per execute,
    /// not per sample).  Anything else (row sub-batches, desynchronized
    /// per-row times) cannot be expressed against a fixed-shape
    /// executable and is a dispatch bug upstream.
    fn f_batch(&self, ts: &[f64], z: &[f32], spec: &BatchSpec) -> Vec<f32> {
        assert_eq!(
            spec.flat_len(),
            self.dim,
            "HloDynamics '{}' is device-batched over {} states; got a [{}, {}] host batch — \
             route batched gradients through grad::batch_driver",
            self.family,
            self.dim,
            spec.batch,
            spec.n_z
        );
        assert!(
            ts.windows(2).all(|w| w[0] == w[1]),
            "HloDynamics '{}' takes one scalar t; got desynchronized per-row times",
            self.family
        );
        self.f(ts[0], z)
    }

    /// See [`HloDynamics::f_batch`] — one fused device vjp call.
    fn f_vjp_batch(
        &self,
        ts: &[f64],
        z: &[f32],
        a: &[f32],
        spec: &BatchSpec,
    ) -> (Vec<f32>, Vec<f32>) {
        assert_eq!(
            spec.flat_len(),
            self.dim,
            "HloDynamics '{}' is device-batched over {} states; got a [{}, {}] host batch",
            self.family,
            self.dim,
            spec.batch,
            spec.n_z
        );
        assert!(
            ts.windows(2).all(|w| w[0] == w[1]),
            "HloDynamics '{}' takes one scalar t",
            self.family
        );
        self.f_vjp(ts[0], z, a)
    }

    fn fused_alf(
        &self,
        z: &[f32],
        v: &[f32],
        t: f64,
        h: f64,
        eta: f64,
    ) -> Option<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        if !self.use_fused {
            return None;
        }
        self.counters.f_evals.add(1);
        let (ts, hs, es) = ([t as f32], [h as f32], [eta as f32]);
        let inputs = self.with_ctx(&[z, v, &ts, &hs, &es], &[&self.theta]);
        let mut out = self
            .engine
            .call(&self.entry("step"), &inputs)
            .expect("HLO fused ψ");
        let err = out.pop().unwrap();
        let v_out = out.pop().unwrap();
        let z_out = out.pop().unwrap();
        Some((z_out, v_out, err))
    }

    fn fused_alf_inv(
        &self,
        z: &[f32],
        v: &[f32],
        t_out: f64,
        h: f64,
        eta: f64,
    ) -> Option<(Vec<f32>, Vec<f32>)> {
        if !self.use_fused {
            return None;
        }
        self.counters.f_evals.add(1);
        let (ts, hs, es) = ([t_out as f32], [h as f32], [eta as f32]);
        let inputs = self.with_ctx(&[z, v, &ts, &hs, &es], &[&self.theta]);
        let mut out = self
            .engine
            .call(&self.entry("inv"), &inputs)
            .expect("HLO fused ψ⁻¹");
        let v_in = out.pop().unwrap();
        let z_in = out.pop().unwrap();
        Some((z_in, v_in))
    }

    fn fused_alf_vjp(
        &self,
        z: &[f32],
        v: &[f32],
        t: f64,
        h: f64,
        eta: f64,
        az_out: &[f32],
        av_out: &[f32],
    ) -> Option<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        if !self.use_fused {
            return None;
        }
        self.counters.vjp_evals.add(1);
        let (ts, hs, es) = ([t as f32], [h as f32], [eta as f32]);
        let inputs = self.with_ctx(&[z, v, &ts, &hs, &es], &[&self.theta, az_out, av_out]);
        let mut out = self
            .engine
            .call(&self.entry("step_vjp"), &inputs)
            .expect("HLO fused ψ-vjp");
        let ath = out.pop().unwrap();
        let av = out.pop().unwrap();
        let az = out.pop().unwrap();
        Some((az, av, ath))
    }

    fn fused_alf_bwd(
        &self,
        z_out: &[f32],
        v_out: &[f32],
        t_out: f64,
        h: f64,
        eta: f64,
        az_out: &[f32],
        av_out: &[f32],
    ) -> Option<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        if !self.use_fused {
            return None;
        }
        // one PJRT call covering ψ⁻¹ + ψ-vjp; fall back to the composed
        // path when the artifact set predates the `.bwd` export
        self.engine.manifest.entry(&self.entry("bwd")).ok()?;
        self.counters.f_evals.add(1);
        self.counters.vjp_evals.add(1);
        let (ts, hs, es) = ([t_out as f32], [h as f32], [eta as f32]);
        let inputs =
            self.with_ctx(&[z_out, v_out, &ts, &hs, &es], &[&self.theta, az_out, av_out]);
        let mut out = self
            .engine
            .call(&self.entry("bwd"), &inputs)
            .expect("HLO fused MALI backward");
        let ath = out.pop().unwrap();
        let av = out.pop().unwrap();
        let az = out.pop().unwrap();
        let v_in = out.pop().unwrap();
        let z_in = out.pop().unwrap();
        Some((z_in, v_in, az, av, ath))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::alf::AlfSolver;
    use crate::solvers::dynamics::LinearToy;

    fn engine() -> Option<Rc<Engine>> {
        Engine::from_env_or_skip("HLO-dynamics test")
    }

    #[test]
    fn toy_hlo_matches_native() {
        let Some(e) = engine() else { return };
        let mut d = HloDynamics::new(e, "toy").unwrap();
        d.set_params(&[0.6]);
        let native = LinearToy::new(0.6, 4);
        let z = [1.0f32, 2.0, -0.5, 0.25];
        let fh = d.f(0.0, &z);
        let fn_ = native.f(0.0, &z);
        for i in 0..4 {
            assert!((fh[i] - fn_[i]).abs() < 1e-6);
        }
        // vjp
        let a = [1.0f32, -1.0, 0.5, 2.0];
        let (az_h, ath_h) = d.f_vjp(0.0, &z, &a);
        let (az_n, ath_n) = native.f_vjp(0.0, &z, &a);
        for i in 0..4 {
            assert!((az_h[i] - az_n[i]).abs() < 1e-6);
        }
        assert!((ath_h[0] - ath_n[0]).abs() < 1e-5);
    }

    /// Fused ψ / ψ⁻¹ via HLO round-trips exactly like the native path —
    /// the invertibility MALI rests on, through the real AOT artifacts.
    #[test]
    fn fused_step_roundtrip() {
        let Some(e) = engine() else { return };
        let mut d = HloDynamics::new(e, "toy").unwrap();
        d.set_params(&[0.8]);
        let solver = AlfSolver::new(1.0);
        let z: Vec<f32> = vec![1.0, -0.5, 2.0, 0.1];
        let v = d.f(0.0, &z);
        let (z1, v1, _) = solver.psi(&d, 0.0, 0.25, &z, &v);
        let (z0, v0) = solver.psi_inv(&d, 0.25, 0.25, &z1, &v1);
        for i in 0..4 {
            assert!((z0[i] - z[i]).abs() < 1e-5, "z[{i}]");
            assert!((v0[i] - v[i]).abs() < 1e-5, "v[{i}]");
        }
    }

    /// Fused ψ-vjp agrees with the host-composed vjp (which uses f_vjp).
    #[test]
    fn fused_vjp_matches_composed() {
        let Some(e) = engine() else { return };
        let mut d = HloDynamics::new(e, "toy").unwrap();
        d.set_params(&[0.45]);
        let solver = AlfSolver::new(0.9);
        let z: Vec<f32> = vec![0.4, -0.8, 1.2, 0.05];
        let v = d.f(0.0, &z);
        let az_out = [1.0f32, 0.5, -0.25, 2.0];
        let av_out = [0.1f32, -0.2, 0.3, 0.4];
        let fused = solver.psi_vjp(&d, 0.1, 0.2, &z, &v, &az_out, &av_out);
        d.use_fused = false;
        let composed = solver.psi_vjp(&d, 0.1, 0.2, &z, &v, &az_out, &av_out);
        for i in 0..4 {
            assert!((fused.0[i] - composed.0[i]).abs() < 1e-5, "a_z[{i}]");
            assert!((fused.1[i] - composed.1[i]).abs() < 1e-5, "a_v[{i}]");
        }
        assert!((fused.2[0] - composed.2[0]).abs() < 1e-4, "a_θ");
    }

    #[test]
    fn ctx_validation() {
        let Some(e) = engine() else { return };
        let mut d = HloDynamics::new(e.clone(), "toy").unwrap();
        assert_eq!(d.n_ctx(), 0);
        assert!(d.set_ctx(0, vec![]).is_err());

        // CNF family carries a probe ctx tensor
        let mut c = HloDynamics::new(e, "cnf_density2d").unwrap();
        assert_eq!(c.n_ctx(), 1);
        let probe_len = 64 * 2; // batch × dim per the manifest
        assert!(c.set_ctx(0, vec![1.0; probe_len]).is_ok());
        assert!(c.set_ctx(0, vec![1.0; 3]).is_err());
    }
}
