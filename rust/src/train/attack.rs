//! FGSM adversarial attack (Goodfellow et al. 2014) — paper Table 3.
//!
//! `x_adv = clamp(x + ε·sign(∂L/∂x))`.  Every gradient method already
//! produces `dL/dx` (through the stem vjp), so the attack composes from
//! model steps; since Neural ODEs are invariant to the discretization
//! scheme, the paper derives the attack with one solver and evaluates on
//! the perturbed images with another — the `attack_solver × eval_solver`
//! grid this module reproduces.

use crate::data::Dataset;
use crate::models::image::{OdeImageClassifier, ResNetClassifier};
use crate::models::SolveCfg;
use crate::train::metrics::AccuracyMeter;
use anyhow::Result;

/// Perturb a batch along the gradient sign; pixels clamped to [0, 1].
pub fn fgsm_perturb(x: &[f32], grad_x: &[f32], eps: f64) -> Vec<f32> {
    x.iter()
        .zip(grad_x)
        .map(|(&xi, &g)| {
            // sign(0) = 0 (f32::signum(0.0) is +1, which would perturb
            // pixels the loss is flat in)
            let s = if g == 0.0 { 0.0 } else { g.signum() };
            (xi + eps as f32 * s).clamp(0.0, 1.0)
        })
        .collect()
}

/// Accuracy of the ODE model on FGSM examples: gradients from
/// `attack_cfg`'s solver, inference with `eval_cfg`'s solver.
pub fn ode_under_attack(
    model: &mut OdeImageClassifier,
    test: &Dataset,
    eps: f64,
    attack_cfg: &SolveCfg,
    eval_cfg: &SolveCfg,
) -> Result<f64> {
    let mut meter = AccuracyMeter::default();
    for idx in test.eval_batches(model.batch) {
        let x = test.gather(&idx);
        let y1h = test.one_hot(&idx);
        let out = model.step(&x, &y1h, attack_cfg, true)?;
        let x_adv = fgsm_perturb(&x, &out.grad_x, eps);
        let logits = model.predict(&x_adv, eval_cfg)?;
        let pred = crate::tensor::argmax_rows(&logits, model.batch, model.classes);
        let truth: Vec<usize> = idx.iter().map(|&i| test.y[i]).collect();
        let uniq = idx.iter().collect::<std::collections::BTreeSet<_>>().len();
        meter.add_masked(&pred, &truth, uniq);
    }
    Ok(meter.value())
}

/// Accuracy of the ResNet baseline under FGSM (white-box, same model).
pub fn resnet_under_attack(
    model: &ResNetClassifier,
    test: &Dataset,
    eps: f64,
) -> Result<f64> {
    let mut meter = AccuracyMeter::default();
    for idx in test.eval_batches(model.batch) {
        let x = test.gather(&idx);
        let y1h = test.one_hot(&idx);
        let (_, _, gx) = model.grad_x(&x, &y1h)?;
        let x_adv = fgsm_perturb(&x, &gx, eps);
        let logits = model.predict(&x_adv)?;
        let pred = crate::tensor::argmax_rows(&logits, model.batch, model.classes);
        let truth: Vec<usize> = idx.iter().map(|&i| test.y[i]).collect();
        let uniq = idx.iter().collect::<std::collections::BTreeSet<_>>().len();
        meter.add_masked(&pred, &truth, uniq);
    }
    Ok(meter.value())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::images::{generate, ImageSpec};
    use crate::grad::IvpSpec;
    use crate::runtime::Engine;
    use crate::solvers::by_name;
    use crate::util::rng::Rng;
    use std::rc::Rc;

    #[test]
    fn perturbation_bounded_and_directional() {
        let x = vec![0.5f32, 0.0, 1.0, 0.3];
        let g = vec![1.0f32, -2.0, 3.0, 0.0];
        let adv = fgsm_perturb(&x, &g, 0.1);
        assert_eq!(adv, vec![0.6, 0.0, 1.0, 0.3]); // clamped at bounds, 0-grad untouched
    }

    fn engine() -> Option<Rc<Engine>> {
        Engine::from_env_or_skip("attack test")
    }

    #[test]
    fn attack_reduces_accuracy_of_trained_resnet() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(4);
        let mut model = ResNetClassifier::new(e, "img16", &mut rng).unwrap();
        let ds = generate(&ImageSpec::cifar_like(), 224, 5);
        let (train, test) = ds.split(64);
        // brief training so there is accuracy to destroy
        let mut opt = crate::opt::Sgd::new(0.05, 0.9, 0.0, model.f.len());
        let mut opt_s = crate::opt::Sgd::new(0.05, 0.9, 0.0, model.stem.len());
        let mut opt_h = crate::opt::Sgd::new(0.05, 0.9, 0.0, model.head.len());
        use crate::opt::Optimizer;
        for _ in 0..4 {
            for idx in train.epoch_batches(model.batch, &mut rng) {
                let x = train.gather(&idx);
                let y1h = train.one_hot(&idx);
                model.step(&x, &y1h).unwrap();
                opt_s.step(&mut model.stem.value, &model.stem.grad);
                opt.step(&mut model.f.value, &model.f.grad);
                opt_h.step(&mut model.head.value, &model.head.grad);
            }
        }
        let clean = resnet_under_attack(&model, &test, 0.0).unwrap();
        let attacked = resnet_under_attack(&model, &test, 8.0 / 255.0).unwrap();
        assert!(clean > 0.2, "baseline failed to train: {clean}");
        assert!(
            attacked < clean,
            "FGSM did not reduce accuracy: {clean} → {attacked}"
        );
    }

    #[test]
    fn ode_attack_grid_runs() {
        let Some(e) = engine() else { return };
        let mut rng = Rng::new(6);
        let mut model = OdeImageClassifier::new(e, "img16", &mut rng).unwrap();
        let ds = generate(&ImageSpec::cifar_like(), 96, 9);
        let (_, test) = ds.split(64);
        let alf = by_name("alf").unwrap();
        let heun = by_name("heun-euler").unwrap();
        let method = crate::grad::by_name("mali").unwrap();
        let attack_cfg = SolveCfg {
            solver: &*alf,
            spec: IvpSpec::fixed(0.0, 1.0, 0.25),
            method: &*method,
        };
        let eval_cfg = SolveCfg {
            solver: &*heun,
            spec: IvpSpec::fixed(0.0, 1.0, 0.25),
            method: &*method,
        };
        let acc = ode_under_attack(&mut model, &test, 1.0 / 255.0, &attack_cfg, &eval_cfg)
            .unwrap();
        assert!((0.0..=1.0).contains(&acc));
    }
}
