//! Aggregation helpers for the report tables: mean ± std across seeds,
//! running loss averages, simple accuracy bookkeeping.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// `"m ± s"` with the given precision — the table-cell format.
pub fn fmt_mean_std(xs: &[f64], prec: usize) -> String {
    format!("{:.p$} ± {:.p$}", mean(xs), std_dev(xs), p = prec)
}

/// Exponentially-weighted running average (training-loss smoothing).
#[derive(Debug, Clone)]
pub struct Ewma {
    pub value: f64,
    alpha: f64,
    initialized: bool,
}

impl Ewma {
    pub fn new(alpha: f64) -> Ewma {
        Ewma {
            value: 0.0,
            alpha,
            initialized: false,
        }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        if self.initialized {
            self.value = self.alpha * x + (1.0 - self.alpha) * self.value;
        } else {
            self.value = x;
            self.initialized = true;
        }
        self.value
    }
}

/// Accumulates correct/total over batches.
#[derive(Debug, Clone, Default)]
pub struct AccuracyMeter {
    pub correct: usize,
    pub total: usize,
}

impl AccuracyMeter {
    pub fn add(&mut self, pred: &[usize], truth: &[usize]) {
        debug_assert_eq!(pred.len(), truth.len());
        self.correct += pred.iter().zip(truth).filter(|(p, t)| p == t).count();
        self.total += truth.len();
    }

    /// Add only the first `n` entries (masking eval-batch padding).
    pub fn add_masked(&mut self, pred: &[usize], truth: &[usize], n: usize) {
        self.add(&pred[..n], &truth[..n]);
    }

    pub fn value(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.correct as f64 / self.total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.2909944).abs() < 1e-6);
        assert_eq!(std_dev(&[5.0]), 0.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn fmt_matches_pattern() {
        assert_eq!(fmt_mean_std(&[1.0, 2.0], 2), "1.50 ± 0.71");
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        e.update(10.0);
        assert_eq!(e.value, 10.0);
        for _ in 0..30 {
            e.update(0.0);
        }
        assert!(e.value < 1e-6);
    }

    #[test]
    fn accuracy_meter_masks_padding() {
        let mut m = AccuracyMeter::default();
        m.add_masked(&[1, 2, 3, 0], &[1, 2, 9, 0], 3);
        assert_eq!(m.correct, 2);
        assert_eq!(m.total, 3);
        assert!((m.value() - 2.0 / 3.0).abs() < 1e-12);
    }
}
