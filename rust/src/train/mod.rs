//! Training, evaluation and attack drivers over the models layer.
//!
//! * [`trainer`] — the image-classifier training loop (paper Fig. 5/6):
//!   epochs of minibatch SGD, per-component optimizers, LR schedule,
//!   test-set evaluation, wall-clock + memory telemetry.
//! * [`attack`] — FGSM adversarial evaluation (paper Table 3).
//! * [`metrics`] — mean/std aggregation across seeds for the report
//!   tables.

pub mod attack;
pub mod metrics;
pub mod trainer;

pub use trainer::{ImageTrainer, TrainCfg, TrainReport};
