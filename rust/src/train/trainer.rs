//! The image-classifier training loop (paper §4.2 / Appendix B.1): SGD +
//! momentum with step-decay, one gradient method under test, per-epoch
//! test accuracy, and memory / wall-clock / f-eval telemetry — the data
//! behind Fig. 5's three panels and Fig. 6.
//!
//! Every gradient step runs through the batch-first path
//! (`grad::batch_driver` inside `OdeImageClassifier::step`): the model's
//! `HloDynamics` is device-batched, so each mini-batch stays one fused
//! device call per solver evaluation, while the same trainer recipe on a
//! native dynamics would shard rows across `util::pool` workers.

use crate::data::Dataset;
use crate::grad::{by_name as grad_by_name, GradMethod, IvpSpec};
use crate::models::image::{OdeImageClassifier, ResNetClassifier};
use crate::models::SolveCfg;
use crate::opt::{by_name as opt_by_name, Schedule};
use crate::solvers::dynamics::Dynamics;
use crate::solvers::{by_name_eta, Solver};
use crate::train::metrics::AccuracyMeter;
use crate::util::logging::{log, Level};
use anyhow::Result;
use std::time::Instant;

/// Training configuration (defaults mirror Appendix B.1.1 scaled to the
/// synthetic corpus).
#[derive(Debug, Clone)]
pub struct TrainCfg {
    pub epochs: usize,
    pub lr: f64,
    pub momentum: f64,
    pub weight_decay: f64,
    /// Epochs at which LR decays ×0.1 (paper: 30/60 of 90).
    pub lr_drops: Vec<usize>,
    pub optimizer: String,
    /// Gradient method: "mali" | "aca" | "naive" | "adjoint" | "seminorm".
    pub method: String,
    /// Training solver name + damping η.
    pub solver: String,
    pub eta: f64,
    /// Fixed stepsize (`h > 0`) or adaptive (`h = 0` → rtol/atol).
    pub h: f64,
    pub rtol: f64,
    pub atol: f64,
    pub t_end: f64,
    pub seed: u64,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            epochs: 9,
            lr: 0.01,
            momentum: 0.9,
            weight_decay: 5e-4,
            lr_drops: vec![3, 6],
            optimizer: "sgd".into(),
            method: "mali".into(),
            solver: "alf".into(),
            eta: 1.0,
            h: 0.25,
            rtol: 1e-1,
            atol: 1e-2,
            t_end: 1.0,
            seed: 0,
        }
    }
}

impl TrainCfg {
    pub fn ivp_spec(&self) -> IvpSpec {
        if self.h > 0.0 {
            IvpSpec::fixed(0.0, self.t_end, self.h)
        } else {
            IvpSpec::adaptive(0.0, self.t_end, self.rtol, self.atol)
        }
    }

    pub fn solver(&self) -> Result<Box<dyn Solver + Send + Sync>> {
        by_name_eta(&self.solver, self.eta)
    }

    pub fn grad_method(&self) -> Result<Box<dyn GradMethod + Send + Sync>> {
        grad_by_name(&self.method)
    }
}

/// Per-epoch record of one training run.
#[derive(Debug, Clone)]
pub struct EpochRecord {
    pub epoch: usize,
    pub train_loss: f64,
    pub test_acc: f64,
    pub wall_secs: f64,
    pub peak_mem_bytes: usize,
    pub f_evals: u64,
}

/// Full run output: epoch curve + final summary.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub method: String,
    pub epochs: Vec<EpochRecord>,
    pub final_acc: f64,
    pub total_secs: f64,
    pub peak_mem_bytes: usize,
}

/// Drives an [`OdeImageClassifier`] through the full recipe.
pub struct ImageTrainer {
    pub cfg: TrainCfg,
}

impl ImageTrainer {
    pub fn new(cfg: TrainCfg) -> ImageTrainer {
        ImageTrainer { cfg }
    }

    /// Evaluate test accuracy under the given solver/spec.
    pub fn evaluate(
        model: &OdeImageClassifier,
        test: &Dataset,
        solver: &dyn Solver,
        spec: &IvpSpec,
        method: &dyn GradMethod,
    ) -> Result<f64> {
        let mut meter = AccuracyMeter::default();
        let cfg = SolveCfg {
            solver,
            spec: spec.clone(),
            method,
        };
        for idx in test.eval_batches(model.batch) {
            let x = test.gather(&idx);
            let logits = model.predict(&x, &cfg)?;
            let pred = crate::tensor::argmax_rows(&logits, model.batch, model.classes);
            let truth: Vec<usize> = idx.iter().map(|&i| test.y[i]).collect();
            // eval batches pad by wrapping — score the distinct prefix only
            let uniq = idx
                .iter()
                .collect::<std::collections::BTreeSet<_>>()
                .len();
            meter.add_masked(&pred, &truth, uniq);
        }
        Ok(meter.value())
    }

    /// Train an ODE classifier; returns the epoch curve.
    pub fn train_ode(
        &self,
        model: &mut OdeImageClassifier,
        train: &Dataset,
        test: &Dataset,
    ) -> Result<TrainReport> {
        let cfg = &self.cfg;
        let solver = cfg.solver()?;
        let method = cfg.grad_method()?;
        let spec = cfg.ivp_spec();
        let schedule = Schedule::StepDecay {
            milestones: cfg.lr_drops.clone(),
            factor: 0.1,
        };

        let mut opt_stem = opt_by_name(&cfg.optimizer, cfg.lr, model.stem.len())?;
        let mut opt_head = opt_by_name(&cfg.optimizer, cfg.lr, model.head.len())?;
        let mut opt_dyn = opt_by_name(&cfg.optimizer, cfg.lr, model.dynamics.param_dim())?;

        let mut rng = crate::util::rng::Rng::new(cfg.seed);
        let mut epochs = Vec::with_capacity(cfg.epochs);
        let t_start = Instant::now();
        let mut peak_mem = 0usize;

        for epoch in 0..cfg.epochs {
            let lr = schedule.lr_at(cfg.lr, epoch);
            opt_stem.set_lr(lr);
            opt_head.set_lr(lr);
            opt_dyn.set_lr(lr);

            let e_start = Instant::now();
            let mut loss_sum = 0.0f64;
            let mut f_evals = 0u64;
            let batches = train.epoch_batches(model.batch, &mut rng);
            let n_batches = batches.len().max(1);
            for idx in &batches {
                let x = train.gather(idx);
                let y1h = train.one_hot(idx);
                let scfg = SolveCfg {
                    solver: &*solver,
                    spec: spec.clone(),
                    method: &*method,
                };
                let out = model.step(&x, &y1h, &scfg, false)?;
                loss_sum += out.loss;
                f_evals += out.f_evals;
                peak_mem = peak_mem.max(out.peak_mem_bytes);
                // clip: the adjoint's reverse-time error at coarse fixed
                // steps can produce occasional huge gradients (Thm. 2.1);
                // clipping keeps every method's recipe identical and stable
                crate::opt::clip_grad_norm(&mut model.stem.grad, 10.0);
                crate::opt::clip_grad_norm(&mut model.head.grad, 10.0);
                crate::opt::clip_grad_norm(&mut model.dyn_grad, 10.0);
                opt_stem.step(&mut model.stem.value, &model.stem.grad);
                opt_head.step(&mut model.head.value, &model.head.grad);
                let mut theta = model.dynamics.params().to_vec();
                opt_dyn.step(&mut theta, &model.dyn_grad);
                model.dynamics.set_params(&theta);
            }
            let test_acc = Self::evaluate(model, test, &*solver, &spec, &*method)?;
            let rec = EpochRecord {
                epoch,
                train_loss: loss_sum / n_batches as f64,
                test_acc,
                wall_secs: e_start.elapsed().as_secs_f64(),
                peak_mem_bytes: peak_mem,
                f_evals,
            };
            log(
                Level::Info,
                &format!(
                    "[{} e{epoch:02}] loss {:.4} acc {:.3} ({:.1}s, {} f-evals)",
                    cfg.method, rec.train_loss, rec.test_acc, rec.wall_secs, rec.f_evals
                ),
            );
            epochs.push(rec);
        }
        let final_acc = epochs.last().map(|e| e.test_acc).unwrap_or(0.0);
        Ok(TrainReport {
            method: cfg.method.clone(),
            epochs,
            final_acc,
            total_secs: t_start.elapsed().as_secs_f64(),
            peak_mem_bytes: peak_mem,
        })
    }

    /// Train the ResNet baseline with the same schedule.
    pub fn train_resnet(
        &self,
        model: &mut ResNetClassifier,
        train: &Dataset,
        test: &Dataset,
    ) -> Result<TrainReport> {
        let cfg = &self.cfg;
        let schedule = Schedule::StepDecay {
            milestones: cfg.lr_drops.clone(),
            factor: 0.1,
        };
        let mut opts = [
            opt_by_name(&cfg.optimizer, cfg.lr, model.stem.len())?,
            opt_by_name(&cfg.optimizer, cfg.lr, model.f.len())?,
            opt_by_name(&cfg.optimizer, cfg.lr, model.head.len())?,
        ];
        let mut rng = crate::util::rng::Rng::new(cfg.seed);
        let mut epochs = Vec::with_capacity(cfg.epochs);
        let t_start = Instant::now();
        for epoch in 0..cfg.epochs {
            let lr = schedule.lr_at(cfg.lr, epoch);
            opts.iter_mut().for_each(|o| o.set_lr(lr));
            let e_start = Instant::now();
            let mut loss_sum = 0.0f64;
            let batches = train.epoch_batches(model.batch, &mut rng);
            let n_batches = batches.len().max(1);
            for idx in &batches {
                let x = train.gather(idx);
                let y1h = train.one_hot(idx);
                let out = model.step(&x, &y1h)?;
                loss_sum += out.loss;
                opts[0].step(&mut model.stem.value, &model.stem.grad);
                opts[1].step(&mut model.f.value, &model.f.grad);
                opts[2].step(&mut model.head.value, &model.head.grad);
            }
            // test accuracy
            let mut meter = AccuracyMeter::default();
            for idx in test.eval_batches(model.batch) {
                let x = test.gather(&idx);
                let logits = model.predict(&x)?;
                let pred = crate::tensor::argmax_rows(&logits, model.batch, model.classes);
                let truth: Vec<usize> = idx.iter().map(|&i| test.y[i]).collect();
                meter.add(&pred, &truth);
            }
            epochs.push(EpochRecord {
                epoch,
                train_loss: loss_sum / n_batches as f64,
                test_acc: meter.value(),
                wall_secs: e_start.elapsed().as_secs_f64(),
                peak_mem_bytes: 0,
                f_evals: 0,
            });
        }
        let final_acc = epochs.last().map(|e| e.test_acc).unwrap_or(0.0);
        Ok(TrainReport {
            method: "resnet".into(),
            epochs,
            final_acc,
            total_secs: t_start.elapsed().as_secs_f64(),
            peak_mem_bytes: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::images::{generate, ImageSpec};
    use crate::runtime::Engine;
    use std::rc::Rc;

    fn engine() -> Option<Rc<Engine>> {
        Engine::from_env_or_skip("trainer test")
    }

    #[test]
    fn short_ode_training_learns() {
        let Some(e) = engine() else { return };
        let mut rng = crate::util::rng::Rng::new(1);
        let mut model = OdeImageClassifier::new(e, "img16", &mut rng).unwrap();
        let ds = generate(&ImageSpec::cifar_like(), 160 + 64, 7);
        let (train, test) = ds.split(64);
        let cfg = TrainCfg {
            epochs: 3,
            lr: 0.05,
            lr_drops: vec![],
            ..TrainCfg::default()
        };
        let trainer = ImageTrainer::new(cfg);
        let report = trainer.train_ode(&mut model, &train, &test).unwrap();
        assert_eq!(report.epochs.len(), 3);
        // learning happened: loss fell and accuracy beats 10-class chance
        assert!(report.epochs[2].train_loss < report.epochs[0].train_loss);
        assert!(report.final_acc > 0.15, "acc {}", report.final_acc);
        assert!(report.peak_mem_bytes > 0);
    }

    #[test]
    fn short_resnet_training_learns() {
        let Some(e) = engine() else { return };
        let mut rng = crate::util::rng::Rng::new(2);
        let mut model = ResNetClassifier::new(e, "img16", &mut rng).unwrap();
        let ds = generate(&ImageSpec::cifar_like(), 160 + 64, 8);
        let (train, test) = ds.split(64);
        let cfg = TrainCfg {
            epochs: 3,
            lr: 0.05,
            lr_drops: vec![],
            ..TrainCfg::default()
        };
        let trainer = ImageTrainer::new(cfg);
        let report = trainer.train_resnet(&mut model, &train, &test).unwrap();
        assert!(report.epochs[2].train_loss < report.epochs[0].train_loss);
        assert!(report.final_acc > 0.15, "acc {}", report.final_acc);
    }
}
