fn main() { mali_ode::coordinator::cli_main(); }
