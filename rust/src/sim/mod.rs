//! Physics simulation substrates.
//!
//! The paper's Table-4 experiment uses "Hopper" trajectories from the
//! DeepMind control suite (Mujoco).  Mujoco is unavailable offline, so
//! `hopper` implements the canonical reduced model of hopping locomotion —
//! the Spring-Loaded Inverted Pendulum (SLIP) — as the trajectory source:
//! smooth ballistic flight punctuated by stiff spring-stance contact
//! dynamics, i.e. exactly the mixture of smooth segments and contact
//! nonlinearity that makes hopper time series a meaningful latent-ODE
//! benchmark (DESIGN.md §4).

pub mod hopper;

pub use hopper::{HopperSpec, HopperState, SlipHopper};
