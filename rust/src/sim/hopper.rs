//! Spring-Loaded Inverted Pendulum (SLIP) hopper — the Mujoco-"Hopper"
//! stand-in that generates ground-truth trajectories for the latent-ODE
//! experiment (paper Table 4).
//!
//! Model: a point-mass body on a massless springy leg.
//!
//! * **Flight**: ballistic — `ẍ = 0, z̈ = −g`; the leg swings to a fixed
//!   touchdown angle α.  Touchdown when the foot reaches the ground:
//!   `z ≤ l₀·cos α`.
//! * **Stance**: the foot pins to the ground; the spring pushes the body
//!   along the leg with `F = k(l₀ − l)`: `ẍ = (F/m)·(x−x_f)/l`,
//!   `z̈ = (F/m)·z/l − g`.  Liftoff when `l ≥ l₀` again.
//!
//! The system is conservative (no damping), so hops are sustained over the
//! simulated horizon; per-trajectory initial energy / touchdown angle vary
//! with the seed, giving a family of distinct rhythms for the latent ODE
//! to capture.  Dynamics are integrated with classic RK4 at a fine fixed
//! step with bisection refinement of the contact events.

use crate::util::rng::Rng;

/// Physical parameters of the SLIP model.
#[derive(Debug, Clone, Copy)]
pub struct HopperSpec {
    pub mass: f64,
    pub g: f64,
    /// Spring rest length l₀.
    pub l0: f64,
    /// Spring constant k.
    pub k: f64,
    /// Touchdown-angle offset added to the Raibert neutral point
    /// (radians; small values shift the gait's asymmetry per trajectory).
    pub alpha: f64,
}

impl Default for HopperSpec {
    fn default() -> Self {
        HopperSpec {
            mass: 1.0,
            g: 9.81,
            l0: 1.0,
            k: 300.0,
            alpha: 0.0,
        }
    }
}

impl HopperSpec {
    /// Raibert neutral-point touchdown angle for forward speed `vx`:
    /// place the foot half a stance-sweep ahead, `sin α = vx·T_s / (2 l₀)`
    /// with stance period `T_s ≈ π √(m/k)` — the classic controller that
    /// makes SLIP hopping speed-stable (Raibert 1986).
    pub fn touchdown_angle(&self, vx: f64) -> f64 {
        let ts = std::f64::consts::PI * (self.mass / self.k).sqrt();
        let s = (vx * ts / (2.0 * self.l0)).clamp(-0.45, 0.45);
        s.asin() + self.alpha
    }
}

/// Simulation phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Flight,
    Stance,
}

/// Full simulator state.
#[derive(Debug, Clone, Copy)]
pub struct HopperState {
    pub t: f64,
    pub x: f64,
    pub z: f64,
    pub vx: f64,
    pub vz: f64,
    pub phase: Phase,
    /// Foot anchor x-position (valid in stance).
    pub foot_x: f64,
}

/// The number of observation channels [`SlipHopper::observe`] emits —
/// matches the latent model's `obs` dim in the manifest.
pub const OBS_DIM: usize = 8;

pub struct SlipHopper {
    pub spec: HopperSpec,
}

impl SlipHopper {
    pub fn new(spec: HopperSpec) -> SlipHopper {
        SlipHopper { spec }
    }

    /// Initial state: apex of flight at height `z0` with forward speed `vx0`.
    pub fn init(&self, z0: f64, vx0: f64) -> HopperState {
        HopperState {
            t: 0.0,
            x: 0.0,
            z: z0,
            vx: vx0,
            vz: 0.0,
            phase: Phase::Flight,
            foot_x: 0.0,
        }
    }

    /// Acceleration field of the current phase.
    fn accel(&self, s: &HopperState) -> (f64, f64) {
        match s.phase {
            Phase::Flight => (0.0, -self.spec.g),
            Phase::Stance => {
                let dx = s.x - s.foot_x;
                let l = (dx * dx + s.z * s.z).sqrt().max(1e-9);
                let f = self.spec.k * (self.spec.l0 - l) / self.spec.mass;
                (f * dx / l, f * s.z / l - self.spec.g)
            }
        }
    }

    /// One RK4 step of size `h` holding the phase fixed.
    fn rk4(&self, s: &HopperState, h: f64) -> HopperState {
        let deriv = |st: &HopperState| -> [f64; 4] {
            let (ax, az) = self.accel(st);
            [st.vx, st.vz, ax, az]
        };
        let apply = |st: &HopperState, d: &[f64; 4], dt: f64| -> HopperState {
            HopperState {
                t: st.t + dt,
                x: st.x + d[0] * dt,
                z: st.z + d[1] * dt,
                vx: st.vx + d[2] * dt,
                vz: st.vz + d[3] * dt,
                ..*st
            }
        };
        let k1 = deriv(s);
        let k2 = deriv(&apply(s, &k1, h / 2.0));
        let k3 = deriv(&apply(s, &k2, h / 2.0));
        let k4 = deriv(&apply(s, &k3, h));
        let combined = [
            (k1[0] + 2.0 * k2[0] + 2.0 * k3[0] + k4[0]) / 6.0,
            (k1[1] + 2.0 * k2[1] + 2.0 * k3[1] + k4[1]) / 6.0,
            (k1[2] + 2.0 * k2[2] + 2.0 * k3[2] + k4[2]) / 6.0,
            (k1[3] + 2.0 * k2[3] + 2.0 * k3[3] + k4[3]) / 6.0,
        ];
        apply(s, &combined, h)
    }

    /// Event function: touchdown (flight) / liftoff (stance) crossing.
    fn event(&self, s: &HopperState) -> f64 {
        match s.phase {
            // foot height: z − l₀·cos α; touchdown when ≤ 0 while falling
            Phase::Flight => s.z - self.spec.l0 * self.spec.touchdown_angle(s.vx).cos(),
            // spring extension: l − l₀; liftoff when ≥ 0 while extending
            Phase::Stance => {
                let dx = s.x - s.foot_x;
                (dx * dx + s.z * s.z).sqrt() - self.spec.l0
            }
        }
    }

    /// Advance by exactly `h`, handling phase transitions with bisection.
    pub fn step(&self, s: &HopperState, h: f64) -> HopperState {
        // Degenerate flight: already at/below touchdown height and falling
        // (a low-apex hop after an angled liftoff) — touch down immediately
        // rather than waiting for a sign change that can never come.
        if s.phase == Phase::Flight && self.event(s) <= 0.0 && s.vz < 0.0 {
            let alpha = self.spec.touchdown_angle(s.vx);
            let mut grounded = *s;
            grounded.phase = Phase::Stance;
            grounded.foot_x = s.x + self.spec.l0 * alpha.sin();
            return self.step(&grounded, h);
        }
        let next = self.rk4(s, h);
        // radial (leg-extension) velocity, for the liftoff guard
        let radial = |st: &HopperState| -> f64 {
            let dx = st.x - st.foot_x;
            let l = (dx * dx + st.z * st.z).sqrt().max(1e-9);
            (dx * st.vx + st.z * st.vz) / l
        };
        let crossing = match s.phase {
            Phase::Flight => self.event(s) > 0.0 && self.event(&next) <= 0.0 && next.vz < 0.0,
            Phase::Stance => self.event(s) < 0.0 && self.event(&next) >= 0.0 && radial(&next) > 0.0,
        };
        if !crossing {
            return next;
        }
        // bisect the step to locate the event, then switch phase
        let (mut lo, mut hi) = (0.0f64, h);
        let mut mid_state = next;
        for _ in 0..30 {
            let mid = 0.5 * (lo + hi);
            mid_state = self.rk4(s, mid);
            let e = self.event(&mid_state);
            let hit = match s.phase {
                Phase::Flight => e <= 0.0,
                Phase::Stance => e >= 0.0,
            };
            if hit {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        let mut switched = mid_state;
        match s.phase {
            Phase::Flight => {
                switched.phase = Phase::Stance;
                // foot lands ahead of the body at the Raibert neutral point
                let alpha = self.spec.touchdown_angle(switched.vx);
                switched.foot_x = switched.x + self.spec.l0 * alpha.sin();
            }
            Phase::Stance => {
                switched.phase = Phase::Flight;
                switched.foot_x = 0.0;
            }
        }
        // finish the remainder of the step in the new phase
        let remaining = s.t + h - switched.t;
        if remaining > 1e-12 {
            self.step(&switched, remaining)
        } else {
            switched
        }
    }

    /// Observation vector (normalized to roughly O(1)):
    /// `[z, vx, vz, leg length, leg dx, compression, contact, hop-phase]`.
    pub fn observe(&self, s: &HopperState) -> [f32; OBS_DIM] {
        let (l, dx, contact) = match s.phase {
            Phase::Flight => (
                self.spec.l0,
                self.spec.l0 * self.spec.touchdown_angle(s.vx).sin(),
                0.0,
            ),
            Phase::Stance => {
                let dxx = s.x - s.foot_x;
                ((dxx * dxx + s.z * s.z).sqrt(), dxx, 1.0)
            }
        };
        let compression = (self.spec.l0 - l).max(0.0) / self.spec.l0;
        [
            s.z as f32,
            (s.vx / 3.0) as f32,
            (s.vz / 3.0) as f32,
            l as f32,
            dx as f32,
            (compression * 5.0) as f32,
            contact as f32,
            (s.vz.atan2(s.vx.max(0.1)) / std::f64::consts::PI) as f32,
        ]
    }

    /// Simulate and sample observations at the given times (must be
    /// non-decreasing).  `dt_sim` is the internal integrator step.
    pub fn trajectory(&self, s0: HopperState, times: &[f64], dt_sim: f64) -> Vec<f32> {
        let mut out = Vec::with_capacity(times.len() * OBS_DIM);
        let mut s = s0;
        for &t_target in times {
            while s.t < t_target - 1e-12 {
                let h = dt_sim.min(t_target - s.t);
                s = self.step(&s, h);
            }
            out.extend_from_slice(&self.observe(&s));
        }
        out
    }
}

/// The Table-4 dataset: `n` hopper trajectories sampled at `t_len + t_out`
/// regular times over `[0, horizon]`, with per-trajectory initial energy
/// and touchdown angle drawn from the seed.  Returned flat:
/// `n × (t_len+t_out) × OBS_DIM`.
pub struct HopperDataset {
    pub seqs: Vec<f32>,
    pub n: usize,
    pub t_total: usize,
    pub obs: usize,
}

impl HopperDataset {
    pub fn seq(&self, i: usize) -> &[f32] {
        let stride = self.t_total * self.obs;
        &self.seqs[i * stride..(i + 1) * stride]
    }

    /// First `t_len` frames of sequence `i` (encoder input).
    pub fn observed(&self, i: usize, t_len: usize) -> &[f32] {
        &self.seq(i)[..t_len * self.obs]
    }

    /// Frames `t_len..t_len+t_out` (prediction target).
    pub fn target(&self, i: usize, t_len: usize, t_out: usize) -> &[f32] {
        &self.seq(i)[t_len * self.obs..(t_len + t_out) * self.obs]
    }
}

pub fn generate(n: usize, t_len: usize, t_out: usize, horizon: f64, seed: u64) -> HopperDataset {
    let mut rng = Rng::new(seed);
    let t_total = t_len + t_out;
    let times: Vec<f64> = (0..t_total)
        .map(|k| horizon * k as f64 / (t_total - 1) as f64)
        .collect();
    let mut seqs = Vec::with_capacity(n * t_total * OBS_DIM);
    for _ in 0..n {
        let spec = HopperSpec {
            alpha: rng.range(-0.03, 0.03),
            k: 250.0 + 150.0 * rng.uniform(),
            ..HopperSpec::default()
        };
        let sim = SlipHopper::new(spec);
        let s0 = sim.init(1.05 + 0.25 * rng.uniform(), 0.5 + 1.5 * rng.uniform());
        seqs.extend_from_slice(&sim.trajectory(s0, &times, 1e-3));
    }
    HopperDataset {
        seqs,
        n,
        t_total,
        obs: OBS_DIM,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flight_is_ballistic() {
        let sim = SlipHopper::new(HopperSpec::default());
        let s0 = sim.init(2.0, 1.0);
        let s1 = sim.step(&s0, 0.05);
        // analytic ballistic update
        assert!((s1.x - 0.05).abs() < 1e-9);
        let z_exp = 2.0 - 0.5 * 9.81 * 0.05 * 0.05;
        assert!((s1.z - z_exp).abs() < 1e-9, "{} vs {z_exp}", s1.z);
        assert_eq!(s1.phase, Phase::Flight);
    }

    #[test]
    fn hops_alternate_phases() {
        let sim = SlipHopper::new(HopperSpec::default());
        let mut s = sim.init(1.2, 1.0);
        let mut transitions = 0;
        let mut last = s.phase;
        for _ in 0..4000 {
            s = sim.step(&s, 1e-3);
            if s.phase != last {
                transitions += 1;
                last = s.phase;
            }
        }
        assert!(transitions >= 4, "only {transitions} phase transitions in 4s");
        assert!(s.z > 0.2, "hopper collapsed: z = {}", s.z);
    }

    /// Conservative SLIP: total energy is preserved across many hops.
    #[test]
    fn energy_conserved() {
        let spec = HopperSpec::default();
        let sim = SlipHopper::new(spec);
        let energy = |s: &HopperState| -> f64 {
            let kinetic = 0.5 * spec.mass * (s.vx * s.vx + s.vz * s.vz);
            let potential = spec.mass * spec.g * s.z;
            let spring = match s.phase {
                Phase::Flight => 0.0,
                Phase::Stance => {
                    let dx = s.x - s.foot_x;
                    let l = (dx * dx + s.z * s.z).sqrt();
                    0.5 * spec.k * (spec.l0 - l).powi(2)
                }
            };
            kinetic + potential + spring
        };
        let mut s = sim.init(1.2, 1.5);
        let e0 = energy(&s);
        for _ in 0..3000 {
            s = sim.step(&s, 1e-3);
        }
        let e1 = energy(&s);
        assert!(
            ((e1 - e0) / e0).abs() < 0.02,
            "energy drifted: {e0} → {e1}"
        );
    }

    #[test]
    fn trajectory_shapes_and_determinism() {
        let a = generate(4, 32, 16, 3.0, 9);
        let b = generate(4, 32, 16, 3.0, 9);
        assert_eq!(a.seqs, b.seqs);
        assert_eq!(a.seqs.len(), 4 * 48 * OBS_DIM);
        assert_eq!(a.observed(1, 32).len(), 32 * OBS_DIM);
        assert_eq!(a.target(1, 32, 16).len(), 16 * OBS_DIM);
        // observations stay bounded (normalization sane)
        for &v in &a.seqs {
            assert!(v.is_finite() && v.abs() < 10.0, "obs out of range: {v}");
        }
    }

    #[test]
    fn contact_flag_toggles_in_trajectory() {
        let ds = generate(2, 32, 16, 3.0, 1);
        for i in 0..2 {
            let seq = ds.seq(i);
            let contact: Vec<f32> = (0..48).map(|k| seq[k * OBS_DIM + 6]).collect();
            assert!(contact.iter().any(|&c| c == 0.0), "never in flight");
            assert!(contact.iter().any(|&c| c == 1.0), "never in stance");
        }
    }
}
