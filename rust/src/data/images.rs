//! Class-conditioned synthetic image corpora — the CIFAR-10 / ImageNet
//! stand-ins (DESIGN.md §4).
//!
//! Each class is a distinct family of oriented Gabor textures with a
//! class-specific colour palette; per-sample jitter (orientation, phase,
//! frequency, translation, additive noise) makes the task non-trivial while
//! keeping classes separable — the point is to exercise the full
//! stem → ODE-block → head training path, where relative method ordering
//! comes from gradient fidelity, not dataset content.

use super::Dataset;
use crate::util::rng::Rng;

/// Parameters of one synthetic image corpus.
#[derive(Debug, Clone, Copy)]
pub struct ImageSpec {
    pub side: usize,
    pub channels: usize,
    pub classes: usize,
    /// Per-sample jitter scale in (0, 1]; higher = harder.
    pub jitter: f64,
}

impl ImageSpec {
    /// 16×16×3, 10 classes — the Cifar10 stand-in (model `img16`).
    pub fn cifar_like() -> ImageSpec {
        ImageSpec {
            side: 16,
            channels: 3,
            classes: 10,
            jitter: 0.35,
        }
    }

    /// 32×32×3, 100 classes — the ImageNet stand-in (model `img32`).
    pub fn imagenet_like() -> ImageSpec {
        ImageSpec {
            side: 32,
            channels: 3,
            classes: 100,
            jitter: 0.45,
        }
    }

    pub fn dim(&self) -> usize {
        self.side * self.side * self.channels
    }
}

/// Class-deterministic texture parameters: every class gets a unique
/// (orientation, frequency, palette, waveform) tuple spread over the space.
fn class_params(class: usize, classes: usize) -> (f64, f64, [f64; 3], bool) {
    let g = 0.618_033_988_749_895; // golden-ratio low-discrepancy spread
    let u = (class as f64 * g).fract();
    let orient = std::f64::consts::PI * u;
    let freq = 1.5 + 4.0 * ((class as f64 * g * 7.0).fract());
    let palette = [
        0.5 + 0.5 * (2.0 * std::f64::consts::PI * u).sin(),
        0.5 + 0.5 * (2.0 * std::f64::consts::PI * (u + 1.0 / 3.0)).sin(),
        0.5 + 0.5 * (2.0 * std::f64::consts::PI * (u + 2.0 / 3.0)).sin(),
    ];
    // half the classes use square-wave gratings instead of sinusoids
    let square = class % 2 == 1 && classes > 2;
    (orient, freq, palette, square)
}

/// Render one sample of `class` into `out` (length `spec.dim()`), pixel
/// values in [0, 1], channel-minor layout (HWC flattened).
fn render(spec: &ImageSpec, class: usize, rng: &mut Rng, out: &mut [f32]) {
    let (orient0, freq0, palette, square) = class_params(class, spec.classes);
    let j = spec.jitter;
    let orient = orient0 + j * rng.range(-0.3, 0.3);
    let freq = freq0 * (1.0 + j * rng.range(-0.25, 0.25));
    let phase = rng.range(0.0, 2.0 * std::f64::consts::PI);
    let (dx, dy) = (rng.range(-0.2, 0.2), rng.range(-0.2, 0.2));
    let sigma = 0.45 + 0.2 * rng.uniform(); // Gabor envelope width
    let (co, si) = (orient.cos(), orient.sin());
    let s = spec.side as f64;
    for yy in 0..spec.side {
        for xx in 0..spec.side {
            // centered, unit-square coordinates with translation jitter
            let x = (xx as f64 + 0.5) / s - 0.5 + dx;
            let y = (yy as f64 + 0.5) / s - 0.5 + dy;
            let xr = co * x + si * y;
            let r2 = x * x + y * y;
            let carrier = (2.0 * std::f64::consts::PI * freq * xr + phase).sin();
            let wave = if square { carrier.signum() * 0.9 } else { carrier };
            let envelope = (-r2 / (2.0 * sigma * sigma)).exp();
            let g = 0.5 + 0.5 * wave * envelope;
            let base = (yy * spec.side + xx) * spec.channels;
            for c in 0..spec.channels {
                let tint = palette[c % 3];
                let noise = j * 0.15 * rng.normal();
                out[base + c] =
                    ((g * tint + (1.0 - tint) * 0.25) + noise).clamp(0.0, 1.0) as f32;
            }
        }
    }
}

/// Generate `n` examples (classes interleaved round-robin so any prefix or
/// suffix is class-balanced).
pub fn generate(spec: &ImageSpec, n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let d = spec.dim();
    let mut x = vec![0.0f32; n * d];
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % spec.classes;
        render(spec, class, &mut rng, &mut x[i * d..(i + 1) * d]);
        y.push(class);
    }
    Dataset {
        x,
        y,
        d,
        classes: spec.classes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let spec = ImageSpec::cifar_like();
        let a = generate(&spec, 20, 7);
        let b = generate(&spec, 20, 7);
        let c = generate(&spec, 20, 8);
        assert_eq!(a.x, b.x);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn pixels_bounded_and_nontrivial() {
        let spec = ImageSpec::cifar_like();
        let ds = generate(&spec, 30, 1);
        assert!(ds.x.iter().all(|&p| (0.0..=1.0).contains(&p)));
        let mean: f32 = ds.x.iter().sum::<f32>() / ds.x.len() as f32;
        assert!(mean > 0.05 && mean < 0.95, "degenerate images: mean {mean}");
        // variance within one image must be non-zero (not flat)
        let r = ds.row(0);
        let m: f32 = r.iter().sum::<f32>() / r.len() as f32;
        let var: f32 = r.iter().map(|&p| (p - m) * (p - m)).sum::<f32>() / r.len() as f32;
        assert!(var > 1e-4, "flat image, var {var}");
    }

    #[test]
    fn classes_interleaved() {
        let spec = ImageSpec::cifar_like();
        let ds = generate(&spec, 25, 3);
        assert_eq!(&ds.y[..12], &[0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 0, 1]);
    }

    /// Classes must be statistically distinguishable: the nearest-centroid
    /// classifier on raw pixels should beat chance by a wide margin —
    /// otherwise no training method could ever separate them.
    #[test]
    fn nearest_centroid_beats_chance() {
        let spec = ImageSpec::cifar_like();
        let ds = generate(&spec, 400, 5);
        let (train, test) = ds.split(100);
        let d = train.d;
        let mut centroids = vec![0.0f64; spec.classes * d];
        let mut counts = vec![0usize; spec.classes];
        for i in 0..train.len() {
            let c = train.y[i];
            counts[c] += 1;
            for (k, &v) in train.row(i).iter().enumerate() {
                centroids[c * d + k] += v as f64;
            }
        }
        for c in 0..spec.classes {
            for k in 0..d {
                centroids[c * d + k] /= counts[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..test.len() {
            let r = test.row(i);
            let best = (0..spec.classes)
                .min_by(|&a, &b| {
                    let da: f64 = r
                        .iter()
                        .enumerate()
                        .map(|(k, &v)| (v as f64 - centroids[a * d + k]).powi(2))
                        .sum();
                    let db: f64 = r
                        .iter()
                        .enumerate()
                        .map(|(k, &v)| (v as f64 - centroids[b * d + k]).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == test.y[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.5, "centroid accuracy {acc} ≤ chance-ish");
    }

    #[test]
    fn imagenet_like_dims() {
        let spec = ImageSpec::imagenet_like();
        assert_eq!(spec.dim(), 3072);
        let ds = generate(&spec, 100, 2);
        assert_eq!(ds.classes, 100);
        assert_eq!(ds.d, 3072);
    }
}
