//! Synthetic datasets — the substitutions for the paper's CIFAR-10 /
//! ImageNet / Speech-Commands / MNIST corpora (DESIGN.md §4).
//!
//! Every generator is deterministic in its seed, produces a train/test
//! split, and exercises exactly the code path the paper's dataset would:
//! multi-epoch minibatch SGD through stem → ODE block → head for images,
//! irregularly-sampled sequences → spline → CDE for speech, and
//! dequantized bounded pixels → CNF for the generative experiments.

pub mod density;
pub mod images;
pub mod speech;

use crate::util::rng::Rng;

/// A labelled classification dataset with flat f32 features.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Row-major features, `n × d`.
    pub x: Vec<f32>,
    pub y: Vec<usize>,
    pub d: usize,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.d..(i + 1) * self.d]
    }

    /// Split off the last `n_test` examples (generators interleave classes,
    /// so the tail is class-balanced).
    pub fn split(self, n_test: usize) -> (Dataset, Dataset) {
        assert!(n_test < self.len());
        let n_train = self.len() - n_test;
        let (d, classes) = (self.d, self.classes);
        let test = Dataset {
            x: self.x[n_train * d..].to_vec(),
            y: self.y[n_train..].to_vec(),
            d,
            classes,
        };
        let train = Dataset {
            x: self.x[..n_train * d].to_vec(),
            y: self.y[..n_train].to_vec(),
            d,
            classes,
        };
        (train, test)
    }

    /// One-hot encode labels for rows `idx`.
    pub fn one_hot(&self, idx: &[usize]) -> Vec<f32> {
        let mut out = vec![0.0f32; idx.len() * self.classes];
        for (r, &i) in idx.iter().enumerate() {
            out[r * self.classes + self.y[i]] = 1.0;
        }
        out
    }

    /// Gather rows `idx` into a dense batch.
    pub fn gather(&self, idx: &[usize]) -> Vec<f32> {
        let mut out = Vec::with_capacity(idx.len() * self.d);
        for &i in idx {
            out.extend_from_slice(self.row(i));
        }
        out
    }

    /// Shuffled epoch of fixed-size batches (drops the ragged tail, like
    /// the reference training loops).
    pub fn epoch_batches(&self, batch: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
        let mut order: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut order);
        order
            .chunks(batch)
            .filter(|c| c.len() == batch)
            .map(|c| c.to_vec())
            .collect()
    }

    /// Deterministic evaluation batches (padded by wrapping, so callers can
    /// mask the duplicates and score every example exactly once).
    pub fn eval_batches(&self, batch: usize) -> Vec<Vec<usize>> {
        (0..self.len())
            .collect::<Vec<_>>()
            .chunks(batch)
            .map(|c| {
                let mut idx = c.to_vec();
                while idx.len() < batch {
                    idx.push(idx[idx.len() % c.len()]);
                }
                idx
            })
            .collect()
    }
}

/// A set of irregularly-sampled multichannel sequences (speech / hopper).
#[derive(Debug, Clone)]
pub struct SequenceDataset {
    /// Per-example observation times in `[0, 1]`, strictly increasing.
    pub times: Vec<Vec<f64>>,
    /// Per-example observations, `times[i].len() × channels`, row-major.
    pub values: Vec<Vec<f32>>,
    pub channels: usize,
    pub y: Vec<usize>,
    pub classes: usize,
}

impl SequenceDataset {
    pub fn len(&self) -> usize {
        self.times.len()
    }

    pub fn is_empty(&self) -> bool {
        self.times.is_empty()
    }

    pub fn split(mut self, n_test: usize) -> (SequenceDataset, SequenceDataset) {
        assert!(n_test < self.len());
        let n_train = self.len() - n_test;
        let test = SequenceDataset {
            times: self.times.split_off(n_train),
            values: self.values.split_off(n_train),
            channels: self.channels,
            y: self.y.split_off(n_train),
            classes: self.classes,
        };
        (self, test)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            x: (0..20).map(|i| i as f32).collect(),
            y: vec![0, 1, 0, 1, 0],
            d: 4,
            classes: 2,
        }
    }

    #[test]
    fn rows_and_gather() {
        let d = tiny();
        assert_eq!(d.row(1), &[4.0, 5.0, 6.0, 7.0]);
        let b = d.gather(&[0, 2]);
        assert_eq!(b, vec![0.0, 1.0, 2.0, 3.0, 8.0, 9.0, 10.0, 11.0]);
    }

    #[test]
    fn one_hot_encoding() {
        let d = tiny();
        let oh = d.one_hot(&[0, 1]);
        assert_eq!(oh, vec![1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn split_preserves_rows() {
        let d = tiny();
        let (tr, te) = d.clone().split(2);
        assert_eq!(tr.len(), 3);
        assert_eq!(te.len(), 2);
        assert_eq!(te.row(0), d.row(3));
        assert_eq!(te.y, &d.y[3..]);
    }

    #[test]
    fn epoch_batches_cover_without_ragged_tail() {
        let d = tiny();
        let mut rng = Rng::new(1);
        let bs = d.epoch_batches(2, &mut rng);
        assert_eq!(bs.len(), 2); // 5 examples, batch 2 → 2 full batches
        for b in &bs {
            assert_eq!(b.len(), 2);
        }
    }

    #[test]
    fn eval_batches_pad_by_wrapping() {
        let d = tiny();
        let bs = d.eval_batches(3);
        assert_eq!(bs.len(), 2);
        assert_eq!(bs[0].len(), 3);
        assert_eq!(bs[1].len(), 3); // padded from the 2 remaining
        assert_eq!(bs[1][2], bs[1][0]);
    }
}
