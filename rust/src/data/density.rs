//! Generative-modeling corpora (paper Table 6, DESIGN.md §4):
//!
//! * procedural 8×8 digit glyphs — the MNIST stand-in (`cnf_mnist8`);
//! * 8×8×3 Gabor textures   — the CIFAR10 stand-in (`cnf_cifar8`), reusing
//!   the classifier texture generator;
//! * classic 2-D toy densities (pinwheel, moons, 8-gaussians,
//!   checkerboard, spirals) for the density-estimation sanity experiment
//!   (`cnf_density2d`).
//!
//! Pixel corpora come dequantized to `[0,1]`; the logit preprocessing and
//! its BPD bookkeeping live with the CNF model (`models/cnf.rs`).

use super::images::{generate as gen_images, ImageSpec};
use super::Dataset;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// 8×8 digit glyphs
// ---------------------------------------------------------------------------

/// 5×7 bitmap font for digits 0–9, row-major, one bit per pixel.
const GLYPHS: [[u8; 7]; 10] = [
    // each row is 5 bits, MSB = leftmost column
    [0b01110, 0b10001, 0b10011, 0b10101, 0b11001, 0b10001, 0b01110], // 0
    [0b00100, 0b01100, 0b00100, 0b00100, 0b00100, 0b00100, 0b01110], // 1
    [0b01110, 0b10001, 0b00001, 0b00110, 0b01000, 0b10000, 0b11111], // 2
    [0b01110, 0b10001, 0b00001, 0b00110, 0b00001, 0b10001, 0b01110], // 3
    [0b00010, 0b00110, 0b01010, 0b10010, 0b11111, 0b00010, 0b00010], // 4
    [0b11111, 0b10000, 0b11110, 0b00001, 0b00001, 0b10001, 0b01110], // 5
    [0b01110, 0b10000, 0b11110, 0b10001, 0b10001, 0b10001, 0b01110], // 6
    [0b11111, 0b00001, 0b00010, 0b00100, 0b01000, 0b01000, 0b01000], // 7
    [0b01110, 0b10001, 0b10001, 0b01110, 0b10001, 0b10001, 0b01110], // 8
    [0b01110, 0b10001, 0b10001, 0b01111, 0b00001, 0b00001, 0b01110], // 9
];

/// Render one jittered 8×8 digit glyph: random sub-pixel shift, intensity
/// scale, box blur and additive noise — enough variation that a flow has a
/// real density to learn, while digits stay visually recognizable.
fn render_glyph(digit: usize, rng: &mut Rng, out: &mut [f32]) {
    debug_assert_eq!(out.len(), 64);
    let glyph = &GLYPHS[digit];
    // place the 5×7 glyph inside 8×8 with jittered offset
    let ox = 1 + rng.below(2) as i32; // 1..=2
    let oy = rng.below(2) as i32; // 0..=1
    let intensity = 0.75 + 0.25 * rng.uniform() as f32 as f64;
    let mut img = [0.0f32; 64];
    for (r, bits) in glyph.iter().enumerate() {
        for c in 0..5 {
            if bits & (1 << (4 - c)) != 0 {
                let x = c as i32 + ox;
                let y = r as i32 + oy;
                if (0..8).contains(&x) && (0..8).contains(&y) {
                    img[(y * 8 + x) as usize] = intensity as f32;
                }
            }
        }
    }
    // 3×3 box blur with small weight (anti-aliasing)
    let blur_w = 0.15f32;
    for y in 0..8i32 {
        for x in 0..8i32 {
            let mut acc = 0.0f32;
            let mut cnt = 0;
            for dy in -1..=1i32 {
                for dx in -1..=1i32 {
                    let (nx, ny) = (x + dx, y + dy);
                    if (0..8).contains(&nx) && (0..8).contains(&ny) {
                        acc += img[(ny * 8 + nx) as usize];
                        cnt += 1;
                    }
                }
            }
            let base = img[(y * 8 + x) as usize];
            let px = (1.0 - blur_w) * base + blur_w * acc / cnt as f32;
            let noise = 0.03 * rng.normal() as f32;
            out[(y * 8 + x) as usize] = (px + noise).clamp(0.0, 1.0);
        }
    }
}

/// The synth-MNIST corpus: `n` jittered glyphs, classes interleaved.
pub fn mnist8(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = vec![0.0f32; n * 64];
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let digit = i % 10;
        render_glyph(digit, &mut rng, &mut x[i * 64..(i + 1) * 64]);
        y.push(digit);
    }
    Dataset {
        x,
        y,
        d: 64,
        classes: 10,
    }
}

/// The synth-CIFAR corpus: 8×8×3 Gabor textures (dim 192).
pub fn cifar8(n: usize, seed: u64) -> Dataset {
    let spec = ImageSpec {
        side: 8,
        channels: 3,
        classes: 10,
        jitter: 0.35,
    };
    gen_images(&spec, n, seed)
}

// ---------------------------------------------------------------------------
// 2-D toy densities
// ---------------------------------------------------------------------------

/// The classic flow-paper 2-D target densities.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Density2D {
    Pinwheel,
    TwoMoons,
    EightGaussians,
    Checkerboard,
    TwoSpirals,
}

impl Density2D {
    pub fn by_name(name: &str) -> anyhow::Result<Density2D> {
        Ok(match name {
            "pinwheel" => Density2D::Pinwheel,
            "moons" | "two-moons" => Density2D::TwoMoons,
            "8gaussians" => Density2D::EightGaussians,
            "checkerboard" => Density2D::Checkerboard,
            "spirals" | "two-spirals" => Density2D::TwoSpirals,
            other => anyhow::bail!("unknown 2-D density '{other}'"),
        })
    }

    /// Draw one sample.
    pub fn sample(&self, rng: &mut Rng) -> [f32; 2] {
        match self {
            Density2D::Pinwheel => {
                let k = rng.below(5);
                let rad = 0.3 + 0.05 * rng.normal();
                let r = rad + rng.uniform() * 0.9;
                let base = k as f64 * 2.0 * std::f64::consts::PI / 5.0;
                let ang = base + 0.8 * (r - rad); // arms curve with radius
                let (x, y) = (r * ang.cos(), r * ang.sin());
                [
                    (x + 0.05 * rng.normal()) as f32,
                    (y + 0.05 * rng.normal()) as f32,
                ]
            }
            Density2D::TwoMoons => {
                let upper = rng.below(2) == 0;
                let t = rng.uniform() * std::f64::consts::PI;
                let (x, y) = if upper {
                    (t.cos(), t.sin() - 0.25)
                } else {
                    (1.0 - t.cos(), -t.sin() + 0.25)
                };
                [
                    (x - 0.5 + 0.08 * rng.normal()) as f32,
                    (y + 0.08 * rng.normal()) as f32,
                ]
            }
            Density2D::EightGaussians => {
                let k = rng.below(8) as f64;
                let ang = k * std::f64::consts::PI / 4.0;
                [
                    (2.0 * ang.cos() + 0.15 * rng.normal()) as f32,
                    (2.0 * ang.sin() + 0.15 * rng.normal()) as f32,
                ]
            }
            Density2D::Checkerboard => loop {
                let x = rng.range(-2.0, 2.0);
                let y = rng.range(-2.0, 2.0);
                let (cx, cy) = ((x + 2.0).floor() as i64, (y + 2.0).floor() as i64);
                if (cx + cy) % 2 == 0 {
                    return [x as f32, y as f32];
                }
            },
            Density2D::TwoSpirals => {
                let arm = if rng.below(2) == 0 { 1.0 } else { -1.0 };
                let t = (rng.uniform()).sqrt() * 3.0 * std::f64::consts::PI;
                let r = t / (3.0 * std::f64::consts::PI) * 2.0;
                [
                    (arm * r * t.cos() + 0.05 * rng.normal()) as f32,
                    (arm * r * t.sin() + 0.05 * rng.normal()) as f32,
                ]
            }
        }
    }

    /// Draw `n` samples as a flat `n × 2` buffer.
    pub fn sample_n(&self, n: usize, rng: &mut Rng) -> Vec<f32> {
        let mut out = Vec::with_capacity(n * 2);
        for _ in 0..n {
            let [x, y] = self.sample(rng);
            out.push(x);
            out.push(y);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glyphs_are_recognizable_bitmaps() {
        let ds = mnist8(30, 4);
        assert_eq!(ds.d, 64);
        // ink fraction is moderate: neither empty nor full
        for i in 0..10 {
            let ink: f32 = ds.row(i).iter().filter(|&&p| p > 0.4).count() as f32 / 64.0;
            assert!(
                (0.08..0.6).contains(&ink),
                "digit {} ink fraction {ink}",
                ds.y[i]
            );
        }
        // distinct digits differ
        let d01: f32 = ds
            .row(0)
            .iter()
            .zip(ds.row(1))
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(d01 > 1.0, "digit 0 vs 1 too similar: {d01}");
    }

    #[test]
    fn mnist8_deterministic() {
        assert_eq!(mnist8(10, 1).x, mnist8(10, 1).x);
        assert_ne!(mnist8(10, 1).x, mnist8(10, 2).x);
    }

    #[test]
    fn cifar8_has_expected_dim() {
        let ds = cifar8(12, 1);
        assert_eq!(ds.d, 192);
        assert!(ds.x.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }

    #[test]
    fn densities_sample_bounded() {
        let mut rng = Rng::new(7);
        for d in [
            Density2D::Pinwheel,
            Density2D::TwoMoons,
            Density2D::EightGaussians,
            Density2D::Checkerboard,
            Density2D::TwoSpirals,
        ] {
            let xs = d.sample_n(500, &mut rng);
            assert_eq!(xs.len(), 1000);
            for &v in &xs {
                assert!(v.abs() < 6.0, "{d:?} sample out of range: {v}");
            }
            // non-degenerate spread
            let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
            let var: f32 =
                xs.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / xs.len() as f32;
            assert!(var > 0.05, "{d:?} collapsed: var {var}");
        }
    }

    #[test]
    fn checkerboard_respects_parity() {
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let [x, y] = Density2D::Checkerboard.sample(&mut rng);
            let (cx, cy) = ((x + 2.0).floor() as i64, (y + 2.0).floor() as i64);
            assert_eq!((cx + cy) % 2, 0);
        }
    }

    #[test]
    fn density_name_lookup() {
        assert!(Density2D::by_name("pinwheel").is_ok());
        assert!(Density2D::by_name("nope").is_err());
    }
}
