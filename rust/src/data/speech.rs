//! Synthetic spoken-command corpus — the Speech-Commands stand-in for the
//! Neural-CDE experiment (paper Table 5, DESIGN.md §4).
//!
//! Each class is a distinct harmonic-chirp "word": a fundamental frequency,
//! chirp rate, harmonic amplitude profile and amplitude-modulation rate.
//! Observations are log filterbank energies (Goertzel band magnitudes over
//! a short analysis window) taken at *irregular* times in [0, 1] — exactly
//! the irregularly-sampled setting Neural CDEs are built for.  Channel
//! layout: `[t, e_0 .. e_{C-2}]` — time is included as a channel, the
//! standard Neural-CDE convention (Kidger et al. 2020).

use super::SequenceDataset;
use crate::util::rng::Rng;

/// Parameters of the synthetic command corpus.
#[derive(Debug, Clone, Copy)]
pub struct SpeechSpec {
    pub classes: usize,
    /// Total channels including the time channel.
    pub channels: usize,
    /// Observations per sequence.
    pub n_obs: usize,
    /// Samples per analysis window.
    pub window: usize,
    /// Waveform sample rate (samples per unit time).
    pub sample_rate: f64,
}

impl SpeechSpec {
    /// Matches the `cde` manifest model: 6 channels (1 time + 5 bands).
    pub fn commands10() -> SpeechSpec {
        SpeechSpec {
            classes: 10,
            channels: 6,
            n_obs: 40,
            window: 48,
            sample_rate: 2048.0,
        }
    }
}

/// Class-deterministic "word" parameters.
fn word_params(class: usize) -> (f64, f64, [f64; 4], f64) {
    let g = 0.618_033_988_749_895;
    let u = (class as f64 * g).fract();
    let f0 = 60.0 + 300.0 * u; // fundamental
    let chirp = -80.0 + 160.0 * ((class as f64 * g * 3.0).fract()); // Hz per unit t
    // harmonic profile: each class emphasizes different overtones
    let harm = [
        1.0,
        0.2 + 0.8 * ((class as f64 * g * 5.0).fract()),
        0.1 + 0.6 * ((class as f64 * g * 11.0).fract()),
        0.05 + 0.4 * ((class as f64 * g * 17.0).fract()),
    ];
    let am = 2.0 + 10.0 * ((class as f64 * g * 23.0).fract()); // AM rate
    (f0, chirp, harm, am)
}

/// Waveform of `class` at time `t` with per-sample jitter baked into the
/// passed parameters.
fn waveform(t: f64, f0: f64, chirp: f64, harm: &[f64; 4], am: f64, phase: f64) -> f64 {
    let inst = f0 * t + 0.5 * chirp * t * t; // integrated instantaneous freq
    let env = 0.6 + 0.4 * (2.0 * std::f64::consts::PI * am * t).sin();
    let mut w = 0.0;
    for (k, &a) in harm.iter().enumerate() {
        w += a * (2.0 * std::f64::consts::PI * (k + 1) as f64 * inst + phase).sin();
    }
    env * w
}

/// Goertzel-style band magnitude: `|Σ_n w(t_n) e^{-2πi f_b t_n}|` over a
/// window of samples centred at `t_c`.
fn band_energy(
    t_c: f64,
    f_band: f64,
    spec: &SpeechSpec,
    f0: f64,
    chirp: f64,
    harm: &[f64; 4],
    am: f64,
    phase: f64,
    noise: &mut impl FnMut() -> f64,
) -> f64 {
    let dt = 1.0 / spec.sample_rate;
    let half = spec.window / 2;
    let (mut re, mut im) = (0.0f64, 0.0f64);
    for n in 0..spec.window {
        let t = t_c + (n as f64 - half as f64) * dt;
        let w = waveform(t, f0, chirp, harm, am, phase) + 0.05 * noise();
        let ang = -2.0 * std::f64::consts::PI * f_band * t;
        re += w * ang.cos();
        im += w * ang.sin();
    }
    let mag = (re * re + im * im).sqrt() / spec.window as f64;
    (1e-4 + mag).ln()
}

/// Generate `n` irregularly-sampled sequences (classes interleaved).
pub fn generate(spec: &SpeechSpec, n: usize, seed: u64) -> SequenceDataset {
    let mut rng = Rng::new(seed);
    let bands: Vec<f64> = (0..spec.channels - 1)
        .map(|b| 80.0 * 2.0f64.powf(b as f64 * 0.8)) // log-spaced 80..~740 Hz
        .collect();
    let mut times = Vec::with_capacity(n);
    let mut values = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % spec.classes;
        let (f0_0, chirp0, harm, am0) = word_params(class);
        // per-utterance jitter (speaker variation)
        let f0 = f0_0 * (1.0 + rng.range(-0.06, 0.06));
        let chirp = chirp0 * (1.0 + rng.range(-0.15, 0.15));
        let am = am0 * (1.0 + rng.range(-0.1, 0.1));
        let phase = rng.range(0.0, 2.0 * std::f64::consts::PI);

        // irregular observation times: uniform jittered grid, sorted,
        // endpoints pinned so the spline covers [0, 1]
        let mut ts: Vec<f64> = (0..spec.n_obs)
            .map(|k| {
                let base = k as f64 / (spec.n_obs - 1) as f64;
                (base + rng.range(-0.4, 0.4) / spec.n_obs as f64).clamp(0.0, 1.0)
            })
            .collect();
        ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ts[0] = 0.0;
        let last = ts.len() - 1;
        ts[last] = 1.0;
        // enforce strict monotonicity (spline requirement)
        for k in 1..ts.len() {
            if ts[k] <= ts[k - 1] {
                ts[k] = ts[k - 1] + 1e-4;
            }
        }

        let mut vals = Vec::with_capacity(spec.n_obs * spec.channels);
        for &t in &ts {
            vals.push(t as f32); // time channel
            for &fb in &bands {
                let mut noise = || rng.normal();
                let e = band_energy(t, fb, spec, f0, chirp, &harm, am, phase, &mut noise);
                vals.push(e as f32);
            }
        }
        times.push(ts);
        values.push(vals);
        y.push(class);
    }
    SequenceDataset {
        times,
        values,
        channels: spec.channels,
        y,
        classes: spec.classes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let spec = SpeechSpec::commands10();
        let a = generate(&spec, 12, 3);
        let b = generate(&spec, 12, 3);
        assert_eq!(a.values, b.values);
        assert_eq!(a.len(), 12);
        for i in 0..a.len() {
            assert_eq!(a.times[i].len(), spec.n_obs);
            assert_eq!(a.values[i].len(), spec.n_obs * spec.channels);
        }
    }

    #[test]
    fn times_strictly_increasing_and_span_unit() {
        let spec = SpeechSpec::commands10();
        let ds = generate(&spec, 8, 11);
        for ts in &ds.times {
            assert_eq!(ts[0], 0.0);
            assert!((ts[ts.len() - 1] - 1.0).abs() < 1e-12);
            for w in ts.windows(2) {
                assert!(w[1] > w[0]);
            }
        }
    }

    #[test]
    fn time_channel_matches_times() {
        let spec = SpeechSpec::commands10();
        let ds = generate(&spec, 4, 5);
        for i in 0..ds.len() {
            for (k, &t) in ds.times[i].iter().enumerate() {
                let stored = ds.values[i][k * spec.channels];
                assert!((stored as f64 - t).abs() < 1e-6);
            }
        }
    }

    /// Different classes must produce separated filterbank trajectories —
    /// mean band-energy vectors across classes should differ measurably.
    #[test]
    fn classes_are_separated() {
        let spec = SpeechSpec::commands10();
        let ds = generate(&spec, 40, 9);
        let feat = |i: usize| -> Vec<f64> {
            // average energies per band over the sequence
            let mut acc = vec![0.0f64; spec.channels - 1];
            for k in 0..spec.n_obs {
                for b in 0..spec.channels - 1 {
                    acc[b] += ds.values[i][k * spec.channels + 1 + b] as f64;
                }
            }
            acc.iter().map(|a| a / spec.n_obs as f64).collect()
        };
        // same-class distance (examples 0 and 10 are both class 0) must be
        // smaller than cross-class distance (0 vs 5) on average
        let d = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>()
        };
        let same = d(&feat(0), &feat(10)) + d(&feat(1), &feat(11));
        let cross = d(&feat(0), &feat(5)) + d(&feat(1), &feat(6));
        assert!(
            cross > same,
            "classes not separated: same {same} cross {cross}"
        );
    }
}
