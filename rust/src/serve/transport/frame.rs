//! The wire codec: frame grammar, primitive little-endian readers and
//! writers, and the incremental [`FrameReader`] state machine.
//!
//! Everything on the wire is little-endian.  A connection opens with an
//! 8-byte preamble (`b"MALI"` + protocol version `u16` + flags `u16`);
//! after that both directions speak length-prefixed frames:
//!
//! ```text
//! [len: u32][type: u8][body: len-1 bytes]
//! ```
//!
//! `len` counts the type byte plus the body, so the smallest legal frame
//! is `len == 1`.  The full grammar (field layouts per type) is in
//! DESIGN.md §11; the encode/parse pairs in this module are the single
//! source of truth the server connection loop, the client and the tests
//! all share.
//!
//! Encoders append to a caller-owned `Vec<u8>` — after warmup the
//! buffer's capacity is stable, so encoding a response frame performs no
//! heap allocation (`tests/alloc_serve.rs` pins the server side of
//! this).  [`FrameReader`] likewise reuses one body buffer across
//! frames and survives short reads: a read timeout returns
//! [`ReadOutcome::Idle`] with all partial progress kept, which is what
//! lets the connection loop use the socket timeout as a poll interval
//! while still detecting mid-frame stalls (slow-loris defense).

use crate::serve::{Pending, RequestClass};
use crate::solvers::integrate::StepMode;
use anyhow::{bail, ensure, Result};
use std::io::{self, ErrorKind, Read};

// ---------------------------------------------------------------------------
// Protocol constants
// ---------------------------------------------------------------------------

/// Connection preamble magic.
pub const MAGIC: [u8; 4] = *b"MALI";
/// Protocol version (bumped on any incompatible grammar change;
/// docs/adr/006 records the versioning policy).  v2 added the
/// `SESSION_*` frames and extended the HEALTH_OK body with admission
/// totals and the pre-divided shed rate (docs/adr/007).
pub const VERSION: u16 = 2;
/// Preamble length: magic + version `u16` + flags `u16`.
pub const PREAMBLE_LEN: usize = 8;

/// Client → server: declare a request class under a client-chosen id.
pub const T_OPEN_CLASS: u8 = 0x01;
/// Client → server: one request (`req_id`, `class_id`, `z0` payload).
pub const T_SUBMIT: u8 = 0x02;
/// Client → server: health/readiness probe.
pub const T_HEALTH: u8 = 0x03;
/// Client → server: polite end-of-session (server acks, then the client
/// closes).
pub const T_GOODBYE: u8 = 0x04;
/// Client → server: ask the server process to drain and exit (the
/// multi-process harness's remote off-switch).
pub const T_SHUTDOWN: u8 = 0x05;
/// Client → server: open a streaming session (pins the current model
/// version, seeds the carried state at `(t0, z0)`).
pub const T_SESSION_OPEN: u8 = 0x06;
/// Client → server: advance a session through new event times
/// (`req_id`, `sid`, `times`); answered with RESPONSE / REQ_ERR / RETRY
/// like a SUBMIT.
pub const T_SESSION_STEP: u8 = 0x07;
/// Client → server: close a session (idempotent; acked with SESSION_OK
/// carrying token 0).
pub const T_SESSION_CLOSE: u8 = 0x08;

/// Server → client: class accepted; carries the interned model id.
pub const T_CLASS_OK: u8 = 0x81;
/// Server → client: class rejected (validation / unknown model).
pub const T_CLASS_ERR: u8 = 0x82;
/// Server → client: a served response (out-of-order by `req_id`).
pub const T_RESPONSE: u8 = 0x83;
/// Server → client: this request failed (solver error, bad shape).
pub const T_REQ_ERR: u8 = 0x84;
/// Server → client: request shed/refused — retry after the hint.
pub const T_RETRY: u8 = 0x85;
/// Server → client: health report.
pub const T_HEALTH_OK: u8 = 0x86;
/// Server → client: goodbye/shutdown acknowledged.
pub const T_GOODBYE_OK: u8 = 0x87;
/// Server → client: session opened (echoes the open token + new session
/// id) or closed (token 0 + the closed id).
pub const T_SESSION_OK: u8 = 0x88;
/// Server → client: session open/close refused (echoes the token, or 0
/// for a close; carries the reason).
pub const T_SESSION_ERR: u8 = 0x89;

/// Step-mode tag inside OPEN_CLASS: `StepMode::Fixed`.
pub const MODE_FIXED: u8 = 0;
/// Step-mode tag inside OPEN_CLASS: `StepMode::Adaptive`.
pub const MODE_ADAPTIVE: u8 = 1;

// ---------------------------------------------------------------------------
// Primitive little-endian writers
// ---------------------------------------------------------------------------

#[inline]
pub fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

#[inline]
pub fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

#[inline]
pub fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// `u16`-length-prefixed UTF-8 string (names, error messages).  Payloads
/// longer than `u16::MAX` are truncated at a char boundary — error
/// messages are the only variable-length strings and a 64 KiB prefix of
/// one is as useful as the whole.
pub fn put_str16(buf: &mut Vec<u8>, s: &str) {
    let mut end = s.len().min(u16::MAX as usize);
    while !s.is_char_boundary(end) {
        end -= 1;
    }
    put_u16(buf, end as u16);
    buf.extend_from_slice(&s.as_bytes()[..end]);
}

/// Raw `f32` run (no length prefix — the frame layout implies it).
pub fn put_f32s(buf: &mut Vec<u8>, src: &[f32]) {
    for v in src {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Open a frame: reserve the 4-byte length slot, write the type byte,
/// and return the slot offset for [`end_frame`].
pub fn begin_frame(buf: &mut Vec<u8>, ftype: u8) -> usize {
    let at = buf.len();
    buf.extend_from_slice(&[0u8; 4]);
    buf.push(ftype);
    at
}

/// Close a frame opened with [`begin_frame`]: patch the length slot
/// with the bytes written since (type byte included).
pub fn end_frame(buf: &mut [u8], at: usize) {
    let len = (buf.len() - at - 4) as u32;
    buf[at..at + 4].copy_from_slice(&len.to_le_bytes());
}

// ---------------------------------------------------------------------------
// Primitive reader
// ---------------------------------------------------------------------------

/// Bounds-checked little-endian cursor over one frame body.
pub struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(b: &'a [u8]) -> Cursor<'a> {
        Cursor { b, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    /// Error unless the body was consumed exactly — trailing garbage is
    /// a protocol violation, not padding.
    pub fn done(&self) -> Result<()> {
        ensure!(
            self.remaining() == 0,
            "frame has {} trailing bytes",
            self.remaining()
        );
        Ok(())
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.remaining() >= n,
            "frame truncated: wanted {n} bytes, {} left",
            self.remaining()
        );
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn str16(&mut self) -> Result<&'a str> {
        let n = self.u16()? as usize;
        let raw = self.take(n)?;
        std::str::from_utf8(raw).map_err(|e| anyhow::anyhow!("frame string not UTF-8: {e}"))
    }

    /// Copy exactly `dst.len()` `f32`s out of the body — the zero-copy
    /// half of SUBMIT/RESPONSE decoding (straight into a pooled buffer).
    pub fn f32s_into(&mut self, dst: &mut [f32]) -> Result<()> {
        let raw = self.take(dst.len() * 4)?;
        for (d, c) in dst.iter_mut().zip(raw.chunks_exact(4)) {
            *d = f32::from_le_bytes(c.try_into().unwrap());
        }
        Ok(())
    }

    /// Copy exactly `dst.len()` `f64`s out of the body (SESSION_STEP's
    /// event times, straight into a pooled buffer).
    pub fn f64s_into(&mut self, dst: &mut [f64]) -> Result<()> {
        let raw = self.take(dst.len() * 8)?;
        for (d, c) in dst.iter_mut().zip(raw.chunks_exact(8)) {
            *d = f64::from_le_bytes(c.try_into().unwrap());
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Preamble
// ---------------------------------------------------------------------------

/// Append the connection preamble (client sends this once at connect).
pub fn write_preamble(buf: &mut Vec<u8>) {
    buf.extend_from_slice(&MAGIC);
    put_u16(buf, VERSION);
    put_u16(buf, 0); // flags, reserved
}

/// Validate a received preamble (magic + exact version match; flags are
/// reserved and ignored).
pub fn check_preamble(b: &[u8; PREAMBLE_LEN]) -> Result<()> {
    ensure!(b[..4] == MAGIC, "bad preamble magic {:?}", &b[..4]);
    let version = u16::from_le_bytes([b[4], b[5]]);
    ensure!(
        version == VERSION,
        "protocol version mismatch: peer speaks v{version}, this build speaks v{VERSION}"
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Typed frame encoders
// ---------------------------------------------------------------------------

/// Encode a [`StepMode`] (tag byte + parameters) — shared by OPEN_CLASS
/// and SESSION_OPEN.
pub fn put_mode(buf: &mut Vec<u8>, mode: &StepMode) {
    match *mode {
        StepMode::Fixed { h } => {
            put_u8(buf, MODE_FIXED);
            put_f64(buf, h);
        }
        StepMode::Adaptive {
            rtol,
            atol,
            h_init,
            h_min,
            h_max,
        } => {
            put_u8(buf, MODE_ADAPTIVE);
            put_f64(buf, rtol);
            put_f64(buf, atol);
            put_f64(buf, h_init);
            put_f64(buf, h_min);
            put_f64(buf, h_max);
        }
    }
}

/// Decode a [`StepMode`] written by [`put_mode`].
pub fn parse_mode(c: &mut Cursor<'_>) -> Result<StepMode> {
    Ok(match c.u8()? {
        MODE_FIXED => StepMode::Fixed { h: c.f64()? },
        MODE_ADAPTIVE => StepMode::Adaptive {
            rtol: c.f64()?,
            atol: c.f64()?,
            h_init: c.f64()?,
            h_min: c.f64()?,
            h_max: c.f64()?,
        },
        other => bail!("unknown step-mode tag {other}"),
    })
}

/// OPEN_CLASS: the whole validated class description travels once at
/// handshake; every later SUBMIT names it by `class_id` (no per-request
/// strings on the wire, mirroring the interned registry lookup).
pub fn open_class(buf: &mut Vec<u8>, class_id: u32, class: &RequestClass) {
    let at = begin_frame(buf, T_OPEN_CLASS);
    put_u32(buf, class_id);
    put_str16(buf, &class.model);
    put_str16(buf, &class.solver);
    put_u32(buf, class.n_z as u32);
    put_f64(buf, class.t0);
    put_f64(buf, class.t1);
    put_mode(buf, &class.mode);
    let times = class.grid.times();
    put_u32(buf, times.len() as u32);
    for t in times {
        put_f64(buf, *t);
    }
    end_frame(buf, at);
}

/// A parsed OPEN_CLASS body (server side; allocation here is fine —
/// class construction is the handshake, not the request path).
#[derive(Debug)]
pub struct OpenClassFrame {
    pub class_id: u32,
    pub model: String,
    pub solver: String,
    pub n_z: usize,
    pub t0: f64,
    pub t1: f64,
    pub mode: StepMode,
    pub grid: Vec<f64>,
}

pub fn parse_open_class(body: &[u8]) -> Result<OpenClassFrame> {
    let mut c = Cursor::new(body);
    let class_id = c.u32()?;
    let model = c.str16()?.to_string();
    let solver = c.str16()?.to_string();
    let n_z = c.u32()? as usize;
    let t0 = c.f64()?;
    let t1 = c.f64()?;
    let mode = parse_mode(&mut c)?;
    let k = c.u32()? as usize;
    ensure!(
        c.remaining() == k * 8,
        "OPEN_CLASS grid length mismatch: {} bytes for k = {k}",
        c.remaining()
    );
    let mut grid = Vec::with_capacity(k);
    for _ in 0..k {
        grid.push(c.f64()?);
    }
    c.done()?;
    Ok(OpenClassFrame {
        class_id,
        model,
        solver,
        n_z,
        t0,
        t1,
        mode,
        grid,
    })
}

pub fn class_ok(buf: &mut Vec<u8>, class_id: u32, model_id: u32) {
    let at = begin_frame(buf, T_CLASS_OK);
    put_u32(buf, class_id);
    put_u32(buf, model_id);
    end_frame(buf, at);
}

pub fn class_err(buf: &mut Vec<u8>, class_id: u32, msg: &str) {
    let at = begin_frame(buf, T_CLASS_ERR);
    put_u32(buf, class_id);
    put_str16(buf, msg);
    end_frame(buf, at);
}

/// SUBMIT: correlation id + interned class id + the raw `z0` row.
pub fn submit(buf: &mut Vec<u8>, req_id: u64, class_id: u32, z0: &[f32]) {
    let at = begin_frame(buf, T_SUBMIT);
    put_u64(buf, req_id);
    put_u32(buf, class_id);
    put_f32s(buf, z0);
    end_frame(buf, at);
}

/// SESSION_OPEN: the session's whole description travels once (like
/// OPEN_CLASS); `token` is a client-chosen correlation id echoed by the
/// SESSION_OK / SESSION_ERR answer.
pub fn session_open(
    buf: &mut Vec<u8>,
    token: u64,
    model: &str,
    solver: &str,
    t0: f64,
    mode: &StepMode,
    z0: &[f32],
) {
    let at = begin_frame(buf, T_SESSION_OPEN);
    put_u64(buf, token);
    put_str16(buf, model);
    put_str16(buf, solver);
    put_u32(buf, z0.len() as u32);
    put_f64(buf, t0);
    put_mode(buf, mode);
    put_f32s(buf, z0);
    end_frame(buf, at);
}

/// A parsed SESSION_OPEN body (server side; allocation is fine — opens
/// are the handshake of a long-lived session, not the step path).
#[derive(Debug)]
pub struct SessionOpenFrame {
    pub token: u64,
    pub model: String,
    pub solver: String,
    pub n_z: usize,
    pub t0: f64,
    pub mode: StepMode,
    pub z0: Vec<f32>,
}

pub fn parse_session_open(body: &[u8]) -> Result<SessionOpenFrame> {
    let mut c = Cursor::new(body);
    let token = c.u64()?;
    let model = c.str16()?.to_string();
    let solver = c.str16()?.to_string();
    let n_z = c.u32()? as usize;
    let t0 = c.f64()?;
    let mode = parse_mode(&mut c)?;
    ensure!(
        c.remaining() == n_z * 4,
        "SESSION_OPEN z0 length mismatch: {} bytes for n_z = {n_z}",
        c.remaining()
    );
    let mut z0 = vec![0.0f32; n_z];
    c.f32s_into(&mut z0)?;
    c.done()?;
    Ok(SessionOpenFrame {
        token,
        model,
        solver,
        n_z,
        t0,
        mode,
        z0,
    })
}

/// SESSION_STEP: correlation id + session id + the new event times
/// (strictly monotone; the first may coincide with the session's current
/// barrier).  Answered like a SUBMIT: RESPONSE / REQ_ERR / RETRY.
pub fn session_step(buf: &mut Vec<u8>, req_id: u64, sid: u64, times: &[f64]) {
    let at = begin_frame(buf, T_SESSION_STEP);
    put_u64(buf, req_id);
    put_u64(buf, sid);
    put_u32(buf, times.len() as u32);
    for t in times {
        put_f64(buf, *t);
    }
    end_frame(buf, at);
}

/// Parse a SESSION_STEP header, leaving the cursor at the times run so
/// the connection loop can size a pooled buffer and bulk-copy
/// ([`Cursor::f64s_into`]) without allocating.  Returns
/// `(req_id, sid, k)` and the positioned cursor.
pub fn parse_session_step_header<'a>(body: &'a [u8]) -> Result<(u64, u64, usize, Cursor<'a>)> {
    let mut c = Cursor::new(body);
    let req_id = c.u64()?;
    let sid = c.u64()?;
    let k = c.u32()? as usize;
    ensure!(
        c.remaining() == k * 8,
        "SESSION_STEP times length mismatch: {} bytes for k = {k}",
        c.remaining()
    );
    Ok((req_id, sid, k, c))
}

/// SESSION_CLOSE: close a session (idempotent).
pub fn session_close(buf: &mut Vec<u8>, sid: u64) {
    let at = begin_frame(buf, T_SESSION_CLOSE);
    put_u64(buf, sid);
    end_frame(buf, at);
}

pub fn parse_session_close(body: &[u8]) -> Result<u64> {
    let mut c = Cursor::new(body);
    let sid = c.u64()?;
    c.done()?;
    Ok(sid)
}

/// SESSION_OK: acks an open (echoing its token, carrying the new id) or
/// a close (token 0, the closed id).
pub fn session_ok(buf: &mut Vec<u8>, token: u64, sid: u64) {
    let at = begin_frame(buf, T_SESSION_OK);
    put_u64(buf, token);
    put_u64(buf, sid);
    end_frame(buf, at);
}

pub fn parse_session_ok(body: &[u8]) -> Result<(u64, u64)> {
    let mut c = Cursor::new(body);
    let token = c.u64()?;
    let sid = c.u64()?;
    c.done()?;
    Ok((token, sid))
}

/// SESSION_ERR: an open/close refusal with the reason.
pub fn session_err(buf: &mut Vec<u8>, token: u64, msg: &str) {
    let at = begin_frame(buf, T_SESSION_ERR);
    put_u64(buf, token);
    put_str16(buf, msg);
    end_frame(buf, at);
}

pub fn parse_session_err(body: &[u8]) -> Result<(u64, String)> {
    let mut c = Cursor::new(body);
    let token = c.u64()?;
    let msg = c.str16()?.to_string();
    c.done()?;
    Ok((token, msg))
}

/// RESPONSE, encoded straight from the served envelope (self-describing
/// widths so the client needs no side table to size the payload).
/// Session step envelopes carry their observation count in `times`
/// (their class grid is a placeholder); one-shot envelopes use the
/// class grid.
pub fn response(buf: &mut Vec<u8>, p: &Pending) {
    let n_z = p.class.n_z;
    let k = if p.session_id != 0 {
        p.times.len()
    } else {
        p.class.grid.len()
    };
    let at = begin_frame(buf, T_RESPONSE);
    put_u64(buf, p.req_id);
    put_u32(buf, p.n_accepted as u32);
    put_u32(buf, p.n_trials as u32);
    put_u32(buf, n_z as u32);
    put_u32(buf, k as u32);
    put_f64(buf, p.queue_wait_s);
    put_f64(buf, p.service_s);
    put_f32s(buf, &p.z_final[..n_z]);
    put_f32s(buf, &p.obs[..k * n_z]);
    end_frame(buf, at);
}

/// A decoded RESPONSE (client side).  Reused across
/// [`parse_response_into`] calls — the payload vectors keep their
/// capacity, so a warmed client read loop does not allocate either.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ResponseFrame {
    pub req_id: u64,
    pub n_accepted: usize,
    pub n_trials: usize,
    pub queue_wait_s: f64,
    pub service_s: f64,
    /// Length `n_z`.
    pub z_final: Vec<f32>,
    /// Length `k * n_z` (row-major `[K, n_z]`).
    pub obs: Vec<f32>,
}

pub fn parse_response_into(body: &[u8], out: &mut ResponseFrame) -> Result<()> {
    let mut c = Cursor::new(body);
    out.req_id = c.u64()?;
    out.n_accepted = c.u32()? as usize;
    out.n_trials = c.u32()? as usize;
    let n_z = c.u32()? as usize;
    let k = c.u32()? as usize;
    out.queue_wait_s = c.f64()?;
    out.service_s = c.f64()?;
    ensure!(
        c.remaining() == (n_z + k * n_z) * 4,
        "RESPONSE payload length mismatch"
    );
    crate::solvers::workspace::ensure(&mut out.z_final, n_z);
    crate::solvers::workspace::ensure(&mut out.obs, k * n_z);
    c.f32s_into(&mut out.z_final)?;
    c.f32s_into(&mut out.obs)?;
    c.done()
}

pub fn req_err(buf: &mut Vec<u8>, req_id: u64, msg: &str) {
    let at = begin_frame(buf, T_REQ_ERR);
    put_u64(buf, req_id);
    put_str16(buf, msg);
    end_frame(buf, at);
}

/// RETRY: explicit backpressure.  `backoff_hint_us` is the server's
/// suggested minimum wait; `draining != 0` means the server is shutting
/// down and this connection should give up rather than retry.
pub fn retry(buf: &mut Vec<u8>, req_id: u64, backoff_hint_us: u32, draining: bool) {
    let at = begin_frame(buf, T_RETRY);
    put_u64(buf, req_id);
    put_u32(buf, backoff_hint_us);
    put_u8(buf, draining as u8);
    end_frame(buf, at);
}

pub fn health(buf: &mut Vec<u8>, probe_id: u64) {
    let at = begin_frame(buf, T_HEALTH);
    put_u64(buf, probe_id);
    end_frame(buf, at);
}

/// The health/readiness report (HEALTH_OK body), shared by the server
/// encoder and the client parser.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct HealthFrame {
    /// Echo of the probe's id.
    pub probe_id: u64,
    /// Queue depth at probe time (racy snapshot).
    pub queue_depth: u32,
    /// The queue's fixed capacity.
    pub queue_capacity: u32,
    /// Requests shed at the queue since server start.
    pub shed_total: u64,
    /// RETRY frames this transport has sent (sheds + quota/drain
    /// refusals) since bind.
    pub retries_sent: u64,
    /// Requests admitted via this transport and not yet completed.
    pub inflight: u32,
    /// Requests admitted via this transport since bind (v2).
    pub admitted: u64,
    /// Live streaming sessions (v2).
    pub sessions: u32,
    /// Shed fraction `shed / (admitted + shed)` since bind, pre-divided
    /// server-side so a zero-traffic snapshot reports an exact `0.0`
    /// instead of `0/0` (v2).
    pub shed_rate: f64,
    /// Nonzero once graceful drain has begun.
    pub draining: bool,
    /// Readiness: accepting work (not draining, queue not closed).
    pub ready: bool,
}

impl HealthFrame {
    /// The well-defined shed fraction: `shed / (admitted + shed)`, and
    /// exactly `0.0` when nothing has been observed (no `0/0 = NaN`).
    pub fn shed_rate_of(admitted: u64, shed: u64) -> f64 {
        let total = admitted + shed;
        if total == 0 {
            0.0
        } else {
            shed as f64 / total as f64
        }
    }
}

pub fn health_ok(buf: &mut Vec<u8>, h: &HealthFrame) {
    let at = begin_frame(buf, T_HEALTH_OK);
    put_u64(buf, h.probe_id);
    put_u32(buf, h.queue_depth);
    put_u32(buf, h.queue_capacity);
    put_u64(buf, h.shed_total);
    put_u64(buf, h.retries_sent);
    put_u32(buf, h.inflight);
    put_u64(buf, h.admitted);
    put_u32(buf, h.sessions);
    put_f64(buf, h.shed_rate);
    put_u8(buf, h.draining as u8);
    put_u8(buf, h.ready as u8);
    end_frame(buf, at);
}

pub fn parse_health_ok(body: &[u8]) -> Result<HealthFrame> {
    let mut c = Cursor::new(body);
    let h = HealthFrame {
        probe_id: c.u64()?,
        queue_depth: c.u32()?,
        queue_capacity: c.u32()?,
        shed_total: c.u64()?,
        retries_sent: c.u64()?,
        inflight: c.u32()?,
        admitted: c.u64()?,
        sessions: c.u32()?,
        shed_rate: c.f64()?,
        draining: c.u8()? != 0,
        ready: c.u8()? != 0,
    };
    c.done()?;
    Ok(h)
}

pub fn goodbye(buf: &mut Vec<u8>) {
    let at = begin_frame(buf, T_GOODBYE);
    end_frame(buf, at);
}

pub fn goodbye_ok(buf: &mut Vec<u8>) {
    let at = begin_frame(buf, T_GOODBYE_OK);
    end_frame(buf, at);
}

pub fn shutdown(buf: &mut Vec<u8>) {
    let at = begin_frame(buf, T_SHUTDOWN);
    end_frame(buf, at);
}

// ---------------------------------------------------------------------------
// Incremental frame reader
// ---------------------------------------------------------------------------

/// What one [`FrameReader::poll`] call produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadOutcome {
    /// A complete frame is buffered ([`FrameReader::frame_type`] /
    /// [`FrameReader::body`]); call [`FrameReader::reset`] when done.
    Frame,
    /// The read timed out (or would block) before a frame completed.
    /// All partial progress is kept — poll again.  Check
    /// [`FrameReader::buffered`] to distinguish an idle connection
    /// (nothing buffered, harmless) from a mid-frame stall.
    Idle,
    /// Clean EOF at a frame boundary — the peer closed the connection.
    Closed,
}

/// Resumable length-prefixed frame decoder.  `std::io::Read::read_exact`
/// loses its position on a timeout; this state machine instead keeps the
/// partial header/body across calls, so the connection loop can use a
/// short socket read timeout as its poll interval without ever
/// corrupting the stream framing.  One body buffer is reused for every
/// frame (allocation only while it grows toward the largest frame seen).
pub struct FrameReader {
    max_frame: usize,
    head: [u8; 5],
    have_head: usize,
    body: Vec<u8>,
    have_body: usize,
}

impl FrameReader {
    /// A reader enforcing `max_frame` as the largest admissible body
    /// (length-prefix values beyond it kill the connection before any
    /// buffer grows to match — a 4 GiB length prefix must not become a
    /// 4 GiB allocation).
    pub fn new(max_frame: usize) -> FrameReader {
        FrameReader {
            max_frame,
            head: [0; 5],
            have_head: 0,
            body: Vec::new(),
            have_body: 0,
        }
    }

    /// Bytes of the in-progress frame buffered so far (0 ⇔ at a frame
    /// boundary).
    pub fn buffered(&self) -> usize {
        self.have_head + self.have_body
    }

    /// The buffered frame's type byte (valid after
    /// [`ReadOutcome::Frame`]).
    pub fn frame_type(&self) -> u8 {
        self.head[4]
    }

    /// The buffered frame's body (valid after [`ReadOutcome::Frame`]).
    pub fn body(&self) -> &[u8] {
        &self.body[..self.have_body]
    }

    /// Forget the buffered frame and return to the boundary state.
    pub fn reset(&mut self) {
        self.have_head = 0;
        self.have_body = 0;
    }

    fn body_len(&self) -> io::Result<usize> {
        let len = u32::from_le_bytes(self.head[..4].try_into().unwrap()) as usize;
        if len == 0 {
            return Err(io::Error::new(
                ErrorKind::InvalidData,
                "frame length 0 (missing type byte)",
            ));
        }
        let body = len - 1;
        if body > self.max_frame {
            return Err(io::Error::new(
                ErrorKind::InvalidData,
                format!("frame body {body} B exceeds max_frame {} B", self.max_frame),
            ));
        }
        Ok(body)
    }

    /// Pump bytes from `r` until a frame completes, the read times out,
    /// or the peer closes.  IO errors (including oversized frames and
    /// EOF mid-frame) surface as `Err` — the connection is unusable.
    pub fn poll<R: Read>(&mut self, r: &mut R) -> io::Result<ReadOutcome> {
        loop {
            if self.have_head < 5 {
                match r.read(&mut self.head[self.have_head..5]) {
                    Ok(0) => {
                        return if self.buffered() == 0 {
                            Ok(ReadOutcome::Closed)
                        } else {
                            Err(ErrorKind::UnexpectedEof.into())
                        };
                    }
                    Ok(n) => {
                        self.have_head += n;
                        if self.have_head == 5 {
                            let need = self.body_len()?;
                            // reuse the buffer; growth only toward the
                            // largest frame this connection has seen
                            self.body.resize(need, 0);
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(e)
                        if e.kind() == ErrorKind::WouldBlock
                            || e.kind() == ErrorKind::TimedOut =>
                    {
                        return Ok(ReadOutcome::Idle);
                    }
                    Err(e) => return Err(e),
                }
                continue;
            }
            let need = self.body.len();
            if self.have_body == need {
                return Ok(ReadOutcome::Frame);
            }
            match r.read(&mut self.body[self.have_body..need]) {
                Ok(0) => return Err(ErrorKind::UnexpectedEof.into()),
                Ok(n) => self.have_body += n,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e)
                    if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
                {
                    return Ok(ReadOutcome::Idle);
                }
                Err(e) => return Err(e),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::integrate::ObsGrid;

    fn toy_class(grid: ObsGrid) -> RequestClass {
        RequestClass::new("toy", "alf", 3, 0.0, 1.0, StepMode::Fixed { h: 0.1 }, grid).unwrap()
    }

    #[test]
    fn open_class_round_trips() {
        let class = toy_class(ObsGrid::new(vec![0.25, 1.0]).unwrap());
        let mut buf = Vec::new();
        open_class(&mut buf, 7, &class);
        // strip the envelope
        let len = u32::from_le_bytes(buf[..4].try_into().unwrap()) as usize;
        assert_eq!(len, buf.len() - 4);
        assert_eq!(buf[4], T_OPEN_CLASS);
        let parsed = parse_open_class(&buf[5..]).unwrap();
        assert_eq!(parsed.class_id, 7);
        assert_eq!(parsed.model, "toy");
        assert_eq!(parsed.solver, "alf");
        assert_eq!(parsed.n_z, 3);
        assert_eq!(parsed.grid, vec![0.25, 1.0]);
        assert!(matches!(parsed.mode, StepMode::Fixed { h } if h == 0.1));

        let adaptive = RequestClass::new(
            "toy",
            "alf",
            3,
            0.0,
            1.0,
            StepMode::adaptive(1e-4, 1e-6),
            ObsGrid::none(),
        )
        .unwrap();
        buf.clear();
        open_class(&mut buf, 8, &adaptive);
        let parsed = parse_open_class(&buf[5..]).unwrap();
        assert_eq!(parsed.mode, adaptive.mode);
        assert!(parsed.grid.is_empty());
    }

    #[test]
    fn response_round_trips_including_timings() {
        use std::sync::Arc;
        let class = Arc::new(toy_class(ObsGrid::new(vec![0.5]).unwrap()));
        let mut p = Pending::new(class, vec![1.0, 2.0, 3.0]);
        p.req_id = 99;
        p.n_accepted = 10;
        p.n_trials = 12;
        p.queue_wait_s = 0.5;
        p.service_s = 0.25;
        p.z_final.copy_from_slice(&[4.0, 5.0, 6.0]);
        p.obs.copy_from_slice(&[7.0, 8.0, 9.0]);
        let mut buf = Vec::new();
        response(&mut buf, &p);
        let mut out = ResponseFrame::default();
        parse_response_into(&buf[5..], &mut out).unwrap();
        assert_eq!(out.req_id, 99);
        assert_eq!(out.n_accepted, 10);
        assert_eq!(out.n_trials, 12);
        assert_eq!(out.queue_wait_s, 0.5);
        assert_eq!(out.service_s, 0.25);
        assert_eq!(out.z_final, vec![4.0, 5.0, 6.0]);
        assert_eq!(out.obs, vec![7.0, 8.0, 9.0]);
    }

    #[test]
    fn health_round_trips() {
        let h = HealthFrame {
            probe_id: 3,
            queue_depth: 5,
            queue_capacity: 8,
            shed_total: 21,
            retries_sent: 34,
            inflight: 2,
            admitted: 55,
            sessions: 3,
            shed_rate: HealthFrame::shed_rate_of(55, 21),
            draining: true,
            ready: false,
        };
        let mut buf = Vec::new();
        health_ok(&mut buf, &h);
        assert_eq!(buf[4], T_HEALTH_OK);
        assert_eq!(parse_health_ok(&buf[5..]).unwrap(), h);
    }

    #[test]
    fn shed_rate_is_defined_at_zero_traffic() {
        assert_eq!(HealthFrame::shed_rate_of(0, 0), 0.0);
        assert_eq!(HealthFrame::shed_rate_of(10, 0), 0.0);
        assert_eq!(HealthFrame::shed_rate_of(0, 10), 1.0);
        assert_eq!(HealthFrame::shed_rate_of(3, 1), 0.25);
    }

    #[test]
    fn session_frames_round_trip() {
        // OPEN
        let mut buf = Vec::new();
        let mode = StepMode::adaptive(1e-4, 1e-6);
        session_open(&mut buf, 17, "toy", "alf", 0.5, &mode, &[1.0, -2.0, 3.0]);
        assert_eq!(buf[4], T_SESSION_OPEN);
        let open = parse_session_open(&buf[5..]).unwrap();
        assert_eq!(open.token, 17);
        assert_eq!(open.model, "toy");
        assert_eq!(open.solver, "alf");
        assert_eq!(open.n_z, 3);
        assert_eq!(open.t0, 0.5);
        assert_eq!(open.mode, mode);
        assert_eq!(open.z0, vec![1.0, -2.0, 3.0]);

        // STEP: header parse leaves the cursor at the times run so the
        // connection layer can bulk-copy into a pooled f64 buffer
        buf.clear();
        session_step(&mut buf, 42, 9, &[0.75, 1.0, 1.5]);
        assert_eq!(buf[4], T_SESSION_STEP);
        let (req_id, sid, k, mut c) = parse_session_step_header(&buf[5..]).unwrap();
        assert_eq!((req_id, sid, k), (42, 9, 3));
        let mut times = vec![0.0f64; k];
        c.f64s_into(&mut times).unwrap();
        c.done().unwrap();
        assert_eq!(times, vec![0.75, 1.0, 1.5]);

        // CLOSE
        buf.clear();
        session_close(&mut buf, 9);
        assert_eq!(buf[4], T_SESSION_CLOSE);
        assert_eq!(parse_session_close(&buf[5..]).unwrap(), 9);

        // OK / ERR acks
        buf.clear();
        session_ok(&mut buf, 17, 9);
        assert_eq!(buf[4], T_SESSION_OK);
        assert_eq!(parse_session_ok(&buf[5..]).unwrap(), (17, 9));
        buf.clear();
        session_err(&mut buf, 17, "no such model");
        assert_eq!(buf[4], T_SESSION_ERR);
        let (tok, msg) = parse_session_err(&buf[5..]).unwrap();
        assert_eq!(tok, 17);
        assert_eq!(msg, "no such model");
    }

    #[test]
    fn session_step_response_sizes_obs_by_times_not_class_grid() {
        use std::sync::Arc;
        // session classes carry an empty grid; the response must size the
        // observation block from the step's own `times`
        let class = Arc::new(toy_class(ObsGrid::none()));
        let mut p = Pending::new(class, vec![1.0, 2.0, 3.0]);
        p.req_id = 7;
        p.session_id = 5;
        p.times.extend_from_slice(&[0.25, 0.5]);
        p.obs.resize(2 * 3, 0.0);
        p.obs.copy_from_slice(&[1., 2., 3., 4., 5., 6.]);
        p.z_final.copy_from_slice(&[4.0, 5.0, 6.0]);
        let mut buf = Vec::new();
        response(&mut buf, &p);
        let mut out = ResponseFrame::default();
        parse_response_into(&buf[5..], &mut out).unwrap();
        assert_eq!(out.req_id, 7);
        assert_eq!(out.obs, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(out.z_final, vec![4.0, 5.0, 6.0]);
    }

    #[test]
    fn frame_reader_reassembles_byte_by_byte() {
        let mut wire = Vec::new();
        submit(&mut wire, 42, 1, &[1.5, -2.5]);
        retry(&mut wire, 43, 1000, false);
        // feed one byte at a time through a reader that times out after
        // each byte — partial progress must survive every Idle
        struct OneByte<'a>(&'a [u8], usize);
        impl Read for OneByte<'_> {
            fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
                if self.1 >= self.0.len() {
                    return Err(ErrorKind::WouldBlock.into());
                }
                buf[0] = self.0[self.1];
                self.1 += 1;
                if self.1 % 2 == 0 {
                    // every other byte: pretend the timeout fired
                    Err(ErrorKind::WouldBlock.into())
                } else {
                    Ok(1)
                }
            }
        }
        let mut src = OneByte(&wire, 0);
        let mut fr = FrameReader::new(1 << 20);
        let mut seen = Vec::new();
        loop {
            match fr.poll(&mut src).unwrap() {
                ReadOutcome::Frame => {
                    seen.push((fr.frame_type(), fr.body().to_vec()));
                    fr.reset();
                    if seen.len() == 2 {
                        break;
                    }
                }
                ReadOutcome::Idle => continue,
                ReadOutcome::Closed => panic!("no close in this stream"),
            }
        }
        assert_eq!(seen[0].0, T_SUBMIT);
        let mut c = Cursor::new(&seen[0].1);
        assert_eq!(c.u64().unwrap(), 42);
        assert_eq!(c.u32().unwrap(), 1);
        let mut z0 = [0.0f32; 2];
        c.f32s_into(&mut z0).unwrap();
        c.done().unwrap();
        assert_eq!(z0, [1.5, -2.5]);
        assert_eq!(seen[1].0, T_RETRY);
    }

    #[test]
    fn frame_reader_rejects_oversized_and_truncated() {
        // length prefix far beyond max_frame: must error before
        // allocating the claimed size
        let huge = [0xFF, 0xFF, 0xFF, 0x7F, T_SUBMIT];
        let mut fr = FrameReader::new(1 << 20);
        let err = fr.poll(&mut &huge[..]).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::InvalidData);

        // zero-length frame (no type byte) is malformed
        let zero = [0u8, 0, 0, 0];
        let mut fr = FrameReader::new(1 << 20);
        assert!(fr.poll(&mut &zero[..]).is_err());

        // EOF mid-frame is an UnexpectedEof, not a clean close
        let mut wire = Vec::new();
        submit(&mut wire, 1, 0, &[1.0]);
        wire.truncate(wire.len() - 2);
        let mut fr = FrameReader::new(1 << 20);
        let err = fr.poll(&mut &wire[..]).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::UnexpectedEof);

        // EOF at a boundary is a clean close
        let mut fr = FrameReader::new(1 << 20);
        assert_eq!(fr.poll(&mut &[][..]).unwrap(), ReadOutcome::Closed);
    }

    #[test]
    fn preamble_checks_magic_and_version() {
        let mut buf = Vec::new();
        write_preamble(&mut buf);
        assert_eq!(buf.len(), PREAMBLE_LEN);
        let ok: [u8; PREAMBLE_LEN] = buf[..].try_into().unwrap();
        check_preamble(&ok).unwrap();
        let mut bad_magic = ok;
        bad_magic[0] = b'X';
        assert!(check_preamble(&bad_magic).is_err());
        let mut bad_version = ok;
        bad_version[4] = 0xFE;
        assert!(check_preamble(&bad_version).is_err());
    }

    #[test]
    fn str16_truncates_at_char_boundary() {
        let long = "é".repeat(40_000); // 80 000 bytes of 2-byte chars
        let mut buf = Vec::new();
        put_str16(&mut buf, &long);
        let n = u16::from_le_bytes(buf[..2].try_into().unwrap()) as usize;
        assert!(n <= u16::MAX as usize);
        assert!(std::str::from_utf8(&buf[2..2 + n]).is_ok());
    }
}
