//! The TCP front-end: a dependency-free, allocation-disciplined network
//! transport in front of the in-process [`Server`](crate::serve::Server)
//! (DESIGN.md §11, docs/adr/006).
//!
//! Per ADR-002 there is no async runtime: the transport is pure
//! `std::net` + threads.  Each accepted connection gets a **reader**
//! thread (decodes length-prefixed frames straight into pooled
//! [`Pending`] envelopes and submits them) and a **writer** thread
//! (drains a per-connection completion queue, coalesces many frames
//! into one buffered write, recycles the envelopes back into the pool).
//! Many requests may be in flight per connection; responses complete
//! out of order, correlated by `req_id` — that pipelining is what lets
//! a single connection saturate the coalescing batcher.
//!
//! The serve core stays transport-agnostic: workers deliver through
//! [`CompletionSink`](crate::serve::CompletionSink) and the connection
//! layer reaches the server only through the [`Bridge`] trait, so the
//! same workers can later sit behind a different front end.
//!
//! Resilience surface:
//!
//! * **Backpressure** — `SubmitError::Overloaded` becomes an explicit
//!   RETRY frame with a backoff hint; [`client::Backoff`] implements
//!   capped exponential backoff with jitter on top of it.
//! * **Health/readiness** — HEALTH frames report queue depth, shed
//!   totals and in-flight counts.
//! * **Graceful drain** — [`TcpFront::shutdown`]: stop accepting,
//!   answer new submits with RETRY(draining), flush every accepted
//!   in-flight response, then close.
//! * **Limits** — max frame size, per-connection max in-flight, mid-
//!   frame read (stall) timeout, connection cap, per-model admission
//!   quotas: one bad client cannot wedge a reader or the server.

pub mod client;
pub mod conn;
pub mod frame;

pub use client::{Backoff, ClientEvent, TcpClient};
pub use conn::{DrainOutcome, TcpFront};
pub use frame::{FrameReader, HealthFrame, ReadOutcome, ResponseFrame};

use crate::serve::{Pending, RequestClass, Server, SubmitError};
use std::sync::Arc;
use std::time::Duration;

/// What the connection layer needs from the serve core — nothing else
/// crosses the boundary, so workers never learn about sockets and a
/// test can stand in a scripted bridge.
pub trait Bridge: Send + Sync + 'static {
    /// Handshake-time class admission: validate the class against the
    /// registry (model exists, dynamically batchable, width matches)
    /// and intern its model name, returning the raw model id.  Called
    /// once per OPEN_CLASS — per-request frames never carry strings.
    fn open_class(&self, class: &Arc<RequestClass>) -> Result<u32, String>;

    /// Submit a pooled envelope (see
    /// [`Server::submit_pooled`](crate::serve::Server::submit_pooled)):
    /// refusals return the envelope so its buffers go back to the pool.
    fn submit(&self, pending: Pending) -> Result<(), (SubmitError, Pending)>;

    /// Registered model count (sizes the per-model quota table at bind).
    fn model_count(&self) -> usize;

    /// Current queue depth (health reporting).
    fn queue_depth(&self) -> usize;

    /// The queue's capacity (health reporting).
    fn queue_capacity(&self) -> usize;

    /// Requests shed at the queue since server start (health reporting
    /// and exact shed accounting in the overload tests).
    fn shed_count(&self) -> u64;

    /// Open a streaming session (SESSION_OPEN): validate + pin the model
    /// version, seed the carried state at `(t0, z0)`, and return the new
    /// session id plus the synthetic class its step envelopes ride.
    /// Default: sessions unsupported (test bridges stay minimal).
    #[allow(clippy::too_many_arguments)]
    fn open_session(
        &self,
        _model: &str,
        _solver: &str,
        _n_z: usize,
        _t0: f64,
        _mode: &crate::solvers::integrate::StepMode,
        _z0: &[f32],
    ) -> Result<(u64, Arc<RequestClass>), String> {
        Err("this bridge does not support sessions".to_string())
    }

    /// Close a session (idempotent; connection teardown calls this for
    /// every session the connection opened).
    fn close_session(&self, _sid: u64) -> bool {
        false
    }

    /// Live session count (health reporting).
    fn session_count(&self) -> usize {
        0
    }
}

impl Bridge for Server {
    fn open_class(&self, class: &Arc<RequestClass>) -> Result<u32, String> {
        let reg = self.registry();
        let Some(id) = reg.resolve_cached(class) else {
            return Err(format!(
                "unknown model '{}' (registered: {:?})",
                class.model,
                reg.names()
            ));
        };
        let model = reg.snapshot(id).expect("freshly resolved id");
        if model.is_device_batched() {
            return Err(format!(
                "model '{}' is device-batched and cannot be dynamically micro-batched",
                class.model
            ));
        }
        if model.dim() != class.n_z {
            return Err(format!(
                "model '{}' has state width {}, class expects n_z = {}",
                class.model,
                model.dim(),
                class.n_z
            ));
        }
        Ok(id.raw())
    }

    fn submit(&self, pending: Pending) -> Result<(), (SubmitError, Pending)> {
        self.submit_pooled(pending)
    }

    fn model_count(&self) -> usize {
        self.registry().len()
    }

    fn queue_depth(&self) -> usize {
        self.queue_depth()
    }

    fn queue_capacity(&self) -> usize {
        self.config().queue_capacity
    }

    fn shed_count(&self) -> u64 {
        self.shed_count()
    }

    fn open_session(
        &self,
        model: &str,
        solver: &str,
        n_z: usize,
        t0: f64,
        mode: &crate::solvers::integrate::StepMode,
        z0: &[f32],
    ) -> Result<(u64, Arc<RequestClass>), String> {
        let sid = self
            .open_session(model, solver, n_z, t0, mode.clone(), z0)
            .map_err(|e| e.to_string())?;
        let class = self
            .sessions()
            .class_of(sid)
            .expect("freshly opened session");
        Ok((sid, class))
    }

    fn close_session(&self, sid: u64) -> bool {
        self.close_session(sid)
    }

    fn session_count(&self) -> usize {
        self.session_count()
    }
}

/// Connection-layer knobs (defaults are production-shaped; tests tighten
/// them to force the failure paths).
#[derive(Debug, Clone)]
pub struct TransportConfig {
    /// Largest admissible frame body in bytes.  A length prefix beyond
    /// this kills the connection before any allocation matches it.
    pub max_frame: usize,
    /// Per-connection in-flight request cap; submits beyond it get
    /// RETRY.  Bounds the envelope pool (and so the memory) one
    /// connection can pin.
    pub max_inflight: usize,
    /// Accepted-connection cap; connections beyond it are closed
    /// immediately.
    pub max_conns: usize,
    /// Mid-frame stall bound: a connection that starts a frame and then
    /// feeds no byte for this long is closed (slow-loris defense).
    /// Idle connections *between* frames are not timed out.
    pub read_timeout: Duration,
    /// Per-model in-flight admission quota across all connections;
    /// `0` = unlimited.  Quota refusals get RETRY.
    pub model_quota: usize,
    /// Backoff hint carried by RETRY frames.
    pub backoff_hint: Duration,
    /// Per-connection request-class table cap (class ids must be below
    /// this).
    pub max_classes: usize,
    /// Per-connection live-session cap; SESSION_OPEN beyond it is
    /// refused with SESSION_ERR.  Bounds the warm solver state one
    /// connection can pin in the worker pool.
    pub max_sessions: usize,
}

impl Default for TransportConfig {
    fn default() -> TransportConfig {
        TransportConfig {
            max_frame: 1 << 20,
            max_inflight: 256,
            max_conns: 64,
            read_timeout: Duration::from_secs(30),
            model_quota: 0,
            backoff_hint: Duration::from_millis(1),
            max_classes: 64,
            max_sessions: 16,
        }
    }
}
