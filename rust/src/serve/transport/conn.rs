//! Server side of the transport: the listener, the per-connection
//! reader/writer thread pair, pooled request envelopes, admission
//! control and graceful drain.
//!
//! Per connection (DESIGN.md §11):
//!
//! * the **reader** thread owns the socket's read half: an incremental
//!   [`FrameReader`] decodes frames across read-timeout boundaries, and
//!   SUBMIT bodies are copied straight into a pooled [`Pending`]'s
//!   `z0` buffer — after warmup the read → submit path performs no heap
//!   allocation;
//! * the **writer** thread owns the write half: it drains the
//!   connection's completion queue, encodes *every* queued frame into
//!   one reusable buffer and issues a single `write_all` (write
//!   coalescing), then recycles the envelopes into the pool.
//!
//! Completions travel worker → writer through the connection's
//! [`CompletionSink`] impl, so responses complete **out of order** by
//! `req_id` — a slow batch never heads-of-line-blocks a fast one on the
//! same connection.
//!
//! Backpressure/abuse mapping (the table in DESIGN.md §11): queue shed
//! → RETRY, drain → RETRY(draining), per-connection in-flight cap →
//! RETRY, per-model quota → RETRY, oversized frame / unknown type /
//! mid-frame stall / outbound backlog overflow → connection closed.

use super::frame::{self, FrameReader, HealthFrame, ReadOutcome};
use super::{Bridge, TransportConfig};
use crate::serve::{Completion, CompletionSink, Delivery, Pending, RequestClass, SubmitError};
use crate::solvers::integrate::ObsGrid;
use crate::solvers::workspace::{ensure, ensure_f64};
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Socket read-timeout used as the reader's poll tick (the *stall*
/// bound is `TransportConfig::read_timeout`; this just sets how often
/// the reader wakes to check it).
const POLL_TICK: Duration = Duration::from_millis(100);

/// Control frames (RETRY/HEALTH_OK/...) the reader may queue beyond the
/// in-flight completions before the connection counts as "client is not
/// reading" and is closed.
const CONTROL_BACKLOG: usize = 256;

// ---------------------------------------------------------------------------
// Shared transport state
// ---------------------------------------------------------------------------

struct Shared {
    bridge: Arc<dyn Bridge>,
    cfg: TransportConfig,
    /// Graceful drain has begun: stop accepting, refuse submits with
    /// RETRY(draining).
    draining: AtomicBool,
    /// A client sent SHUTDOWN — the embedding process (the `serve-tcp`
    /// CLI) polls this and runs the drain.
    shutdown_req: AtomicBool,
    /// Requests admitted through this transport, not yet completed.
    inflight: AtomicUsize,
    /// Requests admitted through this transport since bind (one-shot
    /// submits + session steps); with the bridge's shed count this gives
    /// the exact, well-defined shed rate HEALTH reports.
    admitted: AtomicU64,
    /// Per-model in-flight counts, indexed by raw model id (sized at
    /// bind; admission quota + health reporting).
    model_inflight: Vec<AtomicUsize>,
    /// RETRY frames sent (sheds + quota/drain refusals).
    retries_sent: AtomicU64,
    conn_count: AtomicUsize,
    conns: Mutex<BTreeMap<u64, ConnReg>>,
}

struct ConnReg {
    /// A clone of the connection's stream, kept so drain/drop can force
    /// it closed.
    stream: TcpStream,
    conn: Arc<ConnShared>,
}

// ---------------------------------------------------------------------------
// Per-connection state
// ---------------------------------------------------------------------------

/// One queued outbound message.  Small and fixed-size (error strings
/// ride the non-steady-state paths), so the queue itself never
/// reallocates once warm.
enum OutMsg {
    Done(Completion),
    ClassOk { class_id: u32, model_id: u32 },
    ClassErr { class_id: u32, msg: String },
    Retry { req_id: u64, hint_us: u32, draining: bool },
    ReqErr { req_id: u64, msg: String },
    SessionOk { token: u64, sid: u64 },
    SessionErr { token: u64, msg: String },
    Health(HealthFrame),
    GoodbyeOk,
}

struct OutState {
    msgs: VecDeque<OutMsg>,
    /// The reader thread has exited; once in-flight hits zero and the
    /// queue drains, the writer exits too.
    reader_gone: bool,
    /// The writer is mid-`write_all` on messages already popped — drain
    /// must not declare the connection flushed yet.
    writing: bool,
}

/// State shared by one connection's reader, writer and completion sink.
struct ConnShared {
    out: Mutex<OutState>,
    cv: Condvar,
    /// Requests admitted on this connection whose completion has not
    /// yet been queued (the per-connection `max_inflight` bound).
    inflight: AtomicUsize,
    /// Recycled request envelopes (reader pops, writer pushes back).
    pool: Mutex<Vec<Pending>>,
}

impl ConnShared {
    fn new() -> ConnShared {
        ConnShared {
            out: Mutex::new(OutState {
                msgs: VecDeque::new(),
                reader_gone: false,
                writing: false,
            }),
            cv: Condvar::new(),
            inflight: AtomicUsize::new(0),
            pool: Mutex::new(Vec::new()),
        }
    }
}

/// The worker-facing end of a connection: completions are queued for
/// the writer and the in-flight counters are released.  The counter
/// decrements happen *after* the push (under the queue lock), so the
/// writer can never observe "all done" with a completion still
/// unqueued.
struct ConnSink {
    conn: Arc<ConnShared>,
    shared: Arc<Shared>,
}

impl CompletionSink for ConnSink {
    fn complete(&self, done: Completion) {
        let model_raw = match &done {
            Completion::Ok(p) | Completion::Failed(p, _) => p.model_raw,
        };
        let mut st = self.conn.out.lock().expect("outbound poisoned");
        st.msgs.push_back(OutMsg::Done(done));
        self.shared.inflight.fetch_sub(1, Ordering::SeqCst);
        if let Some(c) = self.shared.model_inflight.get(model_raw as usize) {
            c.fetch_sub(1, Ordering::SeqCst);
        }
        self.conn.inflight.fetch_sub(1, Ordering::SeqCst);
        self.conn.cv.notify_all();
    }
}

/// A class opened on this connection: the immutable class handle plus
/// its interned raw model id.
struct ConnClass {
    class: Arc<RequestClass>,
    model_raw: u32,
}

// ---------------------------------------------------------------------------
// The front-end handle
// ---------------------------------------------------------------------------

/// What [`TcpFront::shutdown`] achieved.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DrainOutcome {
    /// Every accepted in-flight request completed *and* every response
    /// was written to its socket before the deadline.
    pub flushed: bool,
    /// Connections force-closed at the end of the drain (clients that
    /// had not hung up on their own).
    pub forced_conns: usize,
}

/// The TCP front-end: owns the listener/accept thread and the shared
/// transport state.  Bind with [`TcpFront::bind`], stop with
/// [`TcpFront::shutdown`] (graceful drain).
pub struct TcpFront {
    local: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
}

impl TcpFront {
    /// Bind `addr` (use port 0 for an ephemeral port) and start
    /// accepting connections over `bridge`.
    pub fn bind(
        addr: impl ToSocketAddrs,
        bridge: Arc<dyn Bridge>,
        cfg: TransportConfig,
    ) -> Result<TcpFront> {
        let listener = TcpListener::bind(addr).context("transport bind")?;
        let local = listener.local_addr().context("transport local_addr")?;
        let model_inflight = (0..bridge.model_count()).map(|_| AtomicUsize::new(0)).collect();
        let shared = Arc::new(Shared {
            bridge,
            cfg,
            draining: AtomicBool::new(false),
            shutdown_req: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            admitted: AtomicU64::new(0),
            model_inflight,
            retries_sent: AtomicU64::new(0),
            conn_count: AtomicUsize::new(0),
            conns: Mutex::new(BTreeMap::new()),
        });
        let accept_shared = shared.clone();
        let accept = std::thread::Builder::new()
            .name("mali-accept".into())
            .spawn(move || accept_loop(listener, accept_shared))
            .context("spawn accept thread")?;
        Ok(TcpFront {
            local,
            shared,
            accept: Some(accept),
        })
    }

    /// The bound address (the actual port when bound to port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// RETRY frames sent so far (sheds + quota/drain refusals).
    pub fn retries_sent(&self) -> u64 {
        self.shared.retries_sent.load(Ordering::SeqCst)
    }

    /// Requests admitted via this transport and not yet completed.
    pub fn inflight(&self) -> usize {
        self.shared.inflight.load(Ordering::SeqCst)
    }

    /// Requests admitted via this transport since bind (one-shot submits
    /// + session steps) — the denominator of the HEALTH shed rate.
    pub fn admitted(&self) -> u64 {
        self.shared.admitted.load(Ordering::SeqCst)
    }

    /// Live connections.
    pub fn conn_count(&self) -> usize {
        self.shared.conn_count.load(Ordering::SeqCst)
    }

    /// True once graceful drain has begun.
    pub fn draining(&self) -> bool {
        self.shared.draining.load(Ordering::SeqCst)
    }

    /// True once any client has sent a SHUTDOWN frame (the `serve-tcp`
    /// CLI polls this, then calls [`TcpFront::shutdown`]).
    pub fn shutdown_requested(&self) -> bool {
        self.shared.shutdown_req.load(Ordering::SeqCst)
    }

    /// Flip into draining mode without blocking: new connections are
    /// refused and new submits answered with RETRY(draining); accepted
    /// work keeps flowing.
    pub fn begin_drain(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
    }

    /// Graceful drain: stop accepting, wait (up to `timeout`) for every
    /// accepted in-flight request to complete and every response to be
    /// written, then close all connections and stop.
    pub fn shutdown(mut self, timeout: Duration) -> DrainOutcome {
        let deadline = Instant::now() + timeout;
        self.begin_drain();
        // wake the blocking accept() so the thread sees the flag
        let _ = TcpStream::connect(self.local);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // phase 1: all admitted requests complete (queued → writer)
        let mut flushed = true;
        while self.shared.inflight.load(Ordering::SeqCst) > 0 {
            if Instant::now() >= deadline {
                flushed = false;
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        // phase 2: every outbound queue written to its socket
        if flushed {
            let conns: Vec<Arc<ConnShared>> = {
                let regs = self.shared.conns.lock().expect("conns poisoned");
                regs.values().map(|r| r.conn.clone()).collect()
            };
            'conns: for c in conns {
                let mut st = c.out.lock().expect("outbound poisoned");
                while !st.msgs.is_empty() || st.writing {
                    if Instant::now() >= deadline {
                        flushed = false;
                        break 'conns;
                    }
                    let (g, _) = c
                        .cv
                        .wait_timeout(st, Duration::from_millis(5))
                        .expect("outbound poisoned");
                    st = g;
                }
            }
        }
        // phase 3: close every connection (the kick makes readers exit)
        let forced = {
            let regs = self.shared.conns.lock().expect("conns poisoned");
            for r in regs.values() {
                let _ = r.stream.shutdown(Shutdown::Both);
            }
            regs.len()
        };
        let grace = deadline.max(Instant::now() + Duration::from_secs(2));
        while self.shared.conn_count.load(Ordering::SeqCst) > 0 && Instant::now() < grace {
            std::thread::sleep(Duration::from_millis(1));
        }
        DrainOutcome {
            flushed,
            forced_conns: forced,
        }
    }

    /// A health snapshot identical to what a HEALTH frame reports.
    pub fn health_snapshot(&self) -> HealthFrame {
        health_snapshot(&self.shared, 0)
    }
}

impl Drop for TcpFront {
    fn drop(&mut self) {
        // a dropped (not shutdown()) front still stops its threads —
        // quickly, without the graceful flush
        if let Some(h) = self.accept.take() {
            self.shared.draining.store(true, Ordering::SeqCst);
            let _ = TcpStream::connect(self.local);
            let _ = h.join();
            {
                let regs = self.shared.conns.lock().expect("conns poisoned");
                for r in regs.values() {
                    let _ = r.stream.shutdown(Shutdown::Both);
                }
            }
            let deadline = Instant::now() + Duration::from_secs(2);
            while self.shared.conn_count.load(Ordering::SeqCst) > 0 && Instant::now() < deadline
            {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }
}

fn health_snapshot(shared: &Shared, probe_id: u64) -> HealthFrame {
    let draining = shared.draining.load(Ordering::SeqCst);
    let shed_total = shared.bridge.shed_count();
    let admitted = shared.admitted.load(Ordering::SeqCst);
    HealthFrame {
        probe_id,
        queue_depth: shared.bridge.queue_depth() as u32,
        queue_capacity: shared.bridge.queue_capacity() as u32,
        shed_total,
        retries_sent: shared.retries_sent.load(Ordering::SeqCst),
        inflight: shared.inflight.load(Ordering::SeqCst) as u32,
        admitted,
        sessions: shared.bridge.session_count() as u32,
        // pre-divided server-side so a zero-traffic probe reads an exact
        // 0.0 rather than 0/0
        shed_rate: HealthFrame::shed_rate_of(admitted, shed_total),
        draining,
        ready: !draining,
    }
}

// ---------------------------------------------------------------------------
// Accept loop
// ---------------------------------------------------------------------------

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    let mut next_id: u64 = 0;
    for incoming in listener.incoming() {
        if shared.draining.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = incoming else { continue };
        if shared.conn_count.load(Ordering::SeqCst) >= shared.cfg.max_conns {
            // connection cap: refuse by closing; the client's connect
            // succeeds but the first read sees EOF
            drop(stream);
            continue;
        }
        let Ok(reg_stream) = stream.try_clone() else {
            continue;
        };
        let conn = Arc::new(ConnShared::new());
        let id = next_id;
        next_id += 1;
        shared.conn_count.fetch_add(1, Ordering::SeqCst);
        shared.conns.lock().expect("conns poisoned").insert(
            id,
            ConnReg {
                stream: reg_stream,
                conn: conn.clone(),
            },
        );
        let conn_shared = shared.clone();
        let spawned = std::thread::Builder::new()
            .name(format!("mali-conn-{id}"))
            .spawn(move || serve_conn(stream, conn_shared, conn, id));
        if spawned.is_err() {
            shared.conns.lock().expect("conns poisoned").remove(&id);
            shared.conn_count.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

// ---------------------------------------------------------------------------
// Connection threads
// ---------------------------------------------------------------------------

fn serve_conn(stream: TcpStream, shared: Arc<Shared>, conn: Arc<ConnShared>, id: u64) {
    let _ = stream.set_nodelay(true);
    let poll = shared.cfg.read_timeout.min(POLL_TICK).max(Duration::from_millis(1));
    let _ = stream.set_read_timeout(Some(poll));

    let mut writer = None;
    if read_preamble(&stream, shared.cfg.read_timeout).is_ok() {
        if let Ok(wstream) = stream.try_clone() {
            let wconn = conn.clone();
            writer = std::thread::Builder::new()
                .name(format!("mali-conn-w{id}"))
                .spawn(move || writer_loop(wstream, wconn))
                .ok();
        }
        if writer.is_some() {
            // errors end the connection; per-request failures were
            // already answered in-band
            let _ = reader_loop(&stream, &shared, &conn);
        }
    }

    // teardown: tell the writer, let it flush whatever completions are
    // still owed (requests already admitted keep their envelopes until
    // the workers finish), then unregister
    {
        let mut st = conn.out.lock().expect("outbound poisoned");
        st.reader_gone = true;
        conn.cv.notify_all();
    }
    if let Some(w) = writer {
        let _ = w.join();
    }
    let _ = stream.shutdown(Shutdown::Both);
    shared.conns.lock().expect("conns poisoned").remove(&id);
    shared.conn_count.fetch_sub(1, Ordering::SeqCst);
}

/// Read + validate the 8-byte preamble, resumable across poll ticks,
/// bounded by `deadline_in`.
fn read_preamble(stream: &TcpStream, deadline_in: Duration) -> Result<()> {
    let deadline = Instant::now() + deadline_in;
    let mut buf = [0u8; frame::PREAMBLE_LEN];
    let mut have = 0usize;
    let mut r = stream;
    while have < buf.len() {
        match r.read(&mut buf[have..]) {
            Ok(0) => bail!("peer closed during preamble"),
            Ok(n) => have += n,
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut
                    || e.kind() == ErrorKind::Interrupted =>
            {
                if Instant::now() >= deadline {
                    bail!("preamble timeout");
                }
            }
            Err(e) => return Err(e).context("preamble read"),
        }
    }
    frame::check_preamble(&buf)
}

fn reader_loop(stream: &TcpStream, shared: &Arc<Shared>, conn: &Arc<ConnShared>) -> Result<()> {
    let mut sessions: BTreeMap<u64, Arc<RequestClass>> = BTreeMap::new();
    let result = pump_frames(stream, shared, conn, &mut sessions);
    // however the connection ended (clean GOODBYE, peer death, protocol
    // violation), release every session it opened: the warm per-session
    // solver state must not outlive its only client.  In-flight steps
    // keep the session entry alive (Arc) until the worker finishes, then
    // everything drops.
    for sid in sessions.keys() {
        shared.bridge.close_session(*sid);
    }
    result
}

fn pump_frames(
    stream: &TcpStream,
    shared: &Arc<Shared>,
    conn: &Arc<ConnShared>,
    sessions: &mut BTreeMap<u64, Arc<RequestClass>>,
) -> Result<()> {
    let cfg = &shared.cfg;
    let mut fr = FrameReader::new(cfg.max_frame);
    let mut classes: Vec<Option<ConnClass>> = Vec::new();
    let sink: Arc<dyn CompletionSink> = Arc::new(ConnSink {
        conn: conn.clone(),
        shared: shared.clone(),
    });
    let mut last_progress = Instant::now();
    let mut prev_buffered = 0usize;
    let mut r = stream;
    loop {
        match fr.poll(&mut r) {
            Ok(ReadOutcome::Frame) => {
                last_progress = Instant::now();
                prev_buffered = 0;
                handle_frame(
                    fr.frame_type(),
                    fr.body(),
                    shared,
                    conn,
                    &mut classes,
                    sessions,
                    &sink,
                )?;
                fr.reset();
            }
            Ok(ReadOutcome::Idle) => {
                let b = fr.buffered();
                if b != prev_buffered {
                    prev_buffered = b;
                    last_progress = Instant::now();
                } else if b > 0 && last_progress.elapsed() >= cfg.read_timeout {
                    // mid-frame stall: the peer started a frame and went
                    // quiet — a wedged or malicious client, not an idle one
                    bail!("mid-frame read stall ({b} B buffered)");
                }
            }
            Ok(ReadOutcome::Closed) => return Ok(()),
            Err(e) => return Err(e).context("frame read"),
        }
    }
}

fn handle_frame(
    ftype: u8,
    body: &[u8],
    shared: &Arc<Shared>,
    conn: &Arc<ConnShared>,
    classes: &mut Vec<Option<ConnClass>>,
    sessions: &mut BTreeMap<u64, Arc<RequestClass>>,
    sink: &Arc<dyn CompletionSink>,
) -> Result<()> {
    match ftype {
        frame::T_SUBMIT => handle_submit(body, shared, conn, classes, sink),
        frame::T_OPEN_CLASS => handle_open_class(body, shared, conn, classes),
        frame::T_SESSION_OPEN => handle_session_open(body, shared, conn, sessions),
        frame::T_SESSION_STEP => handle_session_step(body, shared, conn, sessions, sink),
        frame::T_SESSION_CLOSE => handle_session_close(body, shared, conn, sessions),
        frame::T_HEALTH => {
            let mut c = frame::Cursor::new(body);
            let probe_id = c.u64()?;
            c.done()?;
            let h = health_snapshot(shared, probe_id);
            enqueue_ctl(shared, conn, OutMsg::Health(h))
        }
        frame::T_GOODBYE => {
            frame::Cursor::new(body).done()?;
            enqueue_ctl(shared, conn, OutMsg::GoodbyeOk)
        }
        frame::T_SHUTDOWN => {
            frame::Cursor::new(body).done()?;
            // flip into drain mode; the embedding process polls
            // shutdown_requested() and performs the actual drain + exit
            shared.draining.store(true, Ordering::SeqCst);
            shared.shutdown_req.store(true, Ordering::SeqCst);
            enqueue_ctl(shared, conn, OutMsg::GoodbyeOk)
        }
        other => bail!("unknown frame type 0x{other:02x}"),
    }
}

fn handle_open_class(
    body: &[u8],
    shared: &Arc<Shared>,
    conn: &Arc<ConnShared>,
    classes: &mut Vec<Option<ConnClass>>,
) -> Result<()> {
    // a malformed body is a protocol violation (kills the connection);
    // a *semantically* bad class is answered in-band with CLASS_ERR
    let oc = frame::parse_open_class(body)?;
    let class_id = oc.class_id;
    let refuse = |msg: String| OutMsg::ClassErr { class_id, msg };
    if class_id as usize >= shared.cfg.max_classes {
        let m = format!("class id {class_id} ≥ per-connection cap {}", shared.cfg.max_classes);
        return enqueue_ctl(shared, conn, refuse(m));
    }
    let grid = match ObsGrid::new(oc.grid) {
        Ok(g) => g,
        Err(e) => return enqueue_ctl(shared, conn, refuse(format!("bad obs grid: {e:#}"))),
    };
    let class = match RequestClass::new(
        &oc.model, &oc.solver, oc.n_z, oc.t0, oc.t1, oc.mode, grid,
    ) {
        Ok(c) => Arc::new(c),
        Err(e) => return enqueue_ctl(shared, conn, refuse(format!("bad class: {e:#}"))),
    };
    match shared.bridge.open_class(&class) {
        Ok(model_raw) => {
            if classes.len() <= class_id as usize {
                classes.resize_with(class_id as usize + 1, || None);
            }
            classes[class_id as usize] = Some(ConnClass { class, model_raw });
            enqueue_ctl(
                shared,
                conn,
                OutMsg::ClassOk {
                    class_id,
                    model_id: model_raw,
                },
            )
        }
        Err(msg) => enqueue_ctl(shared, conn, refuse(msg)),
    }
}

/// The per-request hot path: pooled envelope, zero allocations once
/// warm.  Refusals (drain, in-flight cap, quota, queue shed) answer
/// with RETRY; malformed-but-parseable requests answer with REQ_ERR;
/// only undecodable input kills the connection.
fn handle_submit(
    body: &[u8],
    shared: &Arc<Shared>,
    conn: &Arc<ConnShared>,
    classes: &mut [Option<ConnClass>],
    sink: &Arc<dyn CompletionSink>,
) -> Result<()> {
    let cfg = &shared.cfg;
    let mut c = frame::Cursor::new(body);
    let req_id = c.u64()?;
    let class_id = c.u32()? as usize;
    let Some(Some(cc)) = classes.get(class_id) else {
        let msg = format!("SUBMIT names unopened class id {class_id}");
        return enqueue_ctl(shared, conn, OutMsg::ReqErr { req_id, msg });
    };
    let n_z = cc.class.n_z;
    if c.remaining() != n_z * 4 {
        let msg = format!(
            "SUBMIT payload is {} B, class {class_id} (n_z = {n_z}) needs {}",
            c.remaining(),
            n_z * 4
        );
        return enqueue_ctl(shared, conn, OutMsg::ReqErr { req_id, msg });
    }
    // admission gates, cheapest first (no envelope touched on refusal)
    if shared.draining.load(Ordering::SeqCst) {
        return send_retry(shared, conn, req_id, true);
    }
    if conn.inflight.load(Ordering::SeqCst) >= cfg.max_inflight {
        return send_retry(shared, conn, req_id, false);
    }
    let model_slot = shared.model_inflight.get(cc.model_raw as usize);
    if cfg.model_quota > 0 {
        if let Some(slot) = model_slot {
            if slot.load(Ordering::SeqCst) >= cfg.model_quota {
                return send_retry(shared, conn, req_id, false);
            }
        }
    }
    // pooled envelope: pop (or allocate during warmup), retarget to this
    // class — `ensure` reuses capacity, so a warmed pool serves mixed
    // classes without allocating
    let mut env = {
        let mut pool = conn.pool.lock().expect("pool poisoned");
        pool.pop()
            .unwrap_or_else(|| Pending::new(cc.class.clone(), Vec::new()))
    };
    if !Arc::ptr_eq(&env.class, &cc.class) {
        env.class = cc.class.clone();
    }
    ensure(&mut env.z0, n_z);
    ensure(&mut env.z_final, n_z);
    ensure(&mut env.obs, cc.class.grid.len() * n_z);
    c.f32s_into(&mut env.z0)?;
    env.rearm(req_id);
    env.model_raw = cc.model_raw;
    env.set_sink(sink.clone());
    // count the request in flight *before* submitting: the completion
    // (which decrements) can land on another thread immediately
    conn.inflight.fetch_add(1, Ordering::SeqCst);
    shared.inflight.fetch_add(1, Ordering::SeqCst);
    if let Some(slot) = model_slot {
        slot.fetch_add(1, Ordering::SeqCst);
    }
    match shared.bridge.submit(env) {
        Ok(()) => {
            shared.admitted.fetch_add(1, Ordering::SeqCst);
            Ok(())
        }
        Err((e, mut env)) => {
            conn.inflight.fetch_sub(1, Ordering::SeqCst);
            shared.inflight.fetch_sub(1, Ordering::SeqCst);
            if let Some(slot) = shared.model_inflight.get(env.model_raw as usize) {
                slot.fetch_sub(1, Ordering::SeqCst);
            }
            // break the envelope→sink→pool cycle before pooling
            env.delivery = Delivery::None;
            conn.pool.lock().expect("pool poisoned").push(env);
            match e {
                SubmitError::Overloaded { .. } => send_retry(shared, conn, req_id, false),
                SubmitError::Closed => send_retry(shared, conn, req_id, true),
                SubmitError::BadRequest(msg) => {
                    enqueue_ctl(shared, conn, OutMsg::ReqErr { req_id, msg })
                }
            }
        }
    }
}

/// SESSION_OPEN: validate through the bridge (model + solver exist,
/// width matches, version pinned), record the session as owned by this
/// connection, ack with the server-assigned id.  Semantic refusals are
/// in-band SESSION_ERR; only a malformed body kills the connection.
fn handle_session_open(
    body: &[u8],
    shared: &Arc<Shared>,
    conn: &Arc<ConnShared>,
    sessions: &mut BTreeMap<u64, Arc<RequestClass>>,
) -> Result<()> {
    let so = frame::parse_session_open(body)?;
    let token = so.token;
    if shared.draining.load(Ordering::SeqCst) {
        let msg = "server is draining".to_string();
        return enqueue_ctl(shared, conn, OutMsg::SessionErr { token, msg });
    }
    if sessions.len() >= shared.cfg.max_sessions {
        let msg = format!(
            "per-connection session cap {} reached",
            shared.cfg.max_sessions
        );
        return enqueue_ctl(shared, conn, OutMsg::SessionErr { token, msg });
    }
    match shared
        .bridge
        .open_session(&so.model, &so.solver, so.n_z, so.t0, &so.mode, &so.z0)
    {
        Ok((sid, class)) => {
            sessions.insert(sid, class);
            enqueue_ctl(shared, conn, OutMsg::SessionOk { token, sid })
        }
        Err(msg) => enqueue_ctl(shared, conn, OutMsg::SessionErr { token, msg }),
    }
}

/// SESSION_STEP: the streaming hot path.  Same pooled-envelope
/// discipline as SUBMIT — the event times are decoded straight into the
/// envelope's pooled `times` buffer, so a warmed session stream performs
/// no allocation between the socket and the solver.  A sid this
/// connection did not open is refused (sessions are connection-scoped
/// capabilities, not guessable global handles).
fn handle_session_step(
    body: &[u8],
    shared: &Arc<Shared>,
    conn: &Arc<ConnShared>,
    sessions: &mut BTreeMap<u64, Arc<RequestClass>>,
    sink: &Arc<dyn CompletionSink>,
) -> Result<()> {
    let cfg = &shared.cfg;
    let (req_id, sid, k, mut c) = frame::parse_session_step_header(body)?;
    let Some(class) = sessions.get(&sid) else {
        let msg = format!("SESSION_STEP names session {sid} not opened on this connection");
        return enqueue_ctl(shared, conn, OutMsg::ReqErr { req_id, msg });
    };
    if shared.draining.load(Ordering::SeqCst) {
        return send_retry(shared, conn, req_id, true);
    }
    if conn.inflight.load(Ordering::SeqCst) >= cfg.max_inflight {
        return send_retry(shared, conn, req_id, false);
    }
    let mut env = {
        let mut pool = conn.pool.lock().expect("pool poisoned");
        pool.pop()
            .unwrap_or_else(|| Pending::new(class.clone(), Vec::new()))
    };
    if !Arc::ptr_eq(&env.class, class) {
        env.class = class.clone();
    }
    env.rearm(req_id);
    env.session_id = sid;
    // sentinel outside the model table: session steps are admission-
    // bounded by their one-step-in-flight rule, not the per-model quota,
    // and the completion-side decrement skips the same way
    env.model_raw = u32::MAX;
    ensure_f64(&mut env.times, k);
    c.f64s_into(&mut env.times)?;
    c.done()?;
    env.set_sink(sink.clone());
    conn.inflight.fetch_add(1, Ordering::SeqCst);
    shared.inflight.fetch_add(1, Ordering::SeqCst);
    match shared.bridge.submit(env) {
        Ok(()) => {
            shared.admitted.fetch_add(1, Ordering::SeqCst);
            Ok(())
        }
        Err((e, mut env)) => {
            conn.inflight.fetch_sub(1, Ordering::SeqCst);
            shared.inflight.fetch_sub(1, Ordering::SeqCst);
            env.delivery = Delivery::None;
            conn.pool.lock().expect("pool poisoned").push(env);
            match e {
                SubmitError::Overloaded { .. } => send_retry(shared, conn, req_id, false),
                SubmitError::Closed => send_retry(shared, conn, req_id, true),
                // includes the busy refusal (a step already in flight on
                // this session): a protocol misuse, not an overload — it
                // must not read as shed
                SubmitError::BadRequest(msg) => {
                    enqueue_ctl(shared, conn, OutMsg::ReqErr { req_id, msg })
                }
            }
        }
    }
}

/// SESSION_CLOSE: idempotent at the server; scoped to sessions this
/// connection opened.  Acked with SESSION_OK (token 0 — closes carry no
/// open token).
fn handle_session_close(
    body: &[u8],
    shared: &Arc<Shared>,
    conn: &Arc<ConnShared>,
    sessions: &mut BTreeMap<u64, Arc<RequestClass>>,
) -> Result<()> {
    let sid = frame::parse_session_close(body)?;
    if sessions.remove(&sid).is_some() {
        shared.bridge.close_session(sid);
        enqueue_ctl(shared, conn, OutMsg::SessionOk { token: 0, sid })
    } else {
        let msg = format!("session {sid} is not open on this connection");
        enqueue_ctl(shared, conn, OutMsg::SessionErr { token: 0, msg })
    }
}

fn send_retry(
    shared: &Arc<Shared>,
    conn: &Arc<ConnShared>,
    req_id: u64,
    draining: bool,
) -> Result<()> {
    shared.retries_sent.fetch_add(1, Ordering::SeqCst);
    let hint_us = shared.cfg.backoff_hint.as_micros().min(u32::MAX as u128) as u32;
    enqueue_ctl(
        shared,
        conn,
        OutMsg::Retry {
            req_id,
            hint_us,
            draining,
        },
    )
}

/// Queue a control frame for the writer.  A client that stops reading
/// while hammering us would grow this queue without bound — beyond the
/// backlog cap the connection is killed instead.
fn enqueue_ctl(shared: &Arc<Shared>, conn: &Arc<ConnShared>, msg: OutMsg) -> Result<()> {
    let cap = shared.cfg.max_inflight + CONTROL_BACKLOG;
    let mut st = conn.out.lock().expect("outbound poisoned");
    if st.msgs.len() >= cap {
        bail!("outbound backlog overflow ({cap} frames queued; client is not reading)");
    }
    st.msgs.push_back(msg);
    conn.cv.notify_all();
    Ok(())
}

fn writer_loop(stream: TcpStream, conn: Arc<ConnShared>) {
    let mut wbuf: Vec<u8> = Vec::new();
    let mut recycle: Vec<Pending> = Vec::new();
    let mut dead = false;
    loop {
        {
            let mut st = conn.out.lock().expect("outbound poisoned");
            loop {
                if !st.msgs.is_empty() {
                    break;
                }
                if st.reader_gone && conn.inflight.load(Ordering::SeqCst) == 0 {
                    return;
                }
                st = conn.cv.wait(st).expect("outbound poisoned");
            }
            st.writing = true;
            wbuf.clear();
            while let Some(m) = st.msgs.pop_front() {
                encode_msg(&mut wbuf, m, &mut recycle);
            }
        }
        // one coalesced write for everything that was queued
        if !dead && !wbuf.is_empty() && (&stream).write_all(&wbuf).is_err() {
            dead = true;
            // kick the reader out of its poll loop too
            let _ = stream.shutdown(Shutdown::Both);
        }
        if !recycle.is_empty() {
            let mut pool = conn.pool.lock().expect("pool poisoned");
            pool.append(&mut recycle);
        }
        let mut st = conn.out.lock().expect("outbound poisoned");
        st.writing = false;
        conn.cv.notify_all();
    }
}

fn encode_msg(wbuf: &mut Vec<u8>, msg: OutMsg, recycle: &mut Vec<Pending>) {
    match msg {
        OutMsg::Done(Completion::Ok(mut p)) => {
            frame::response(wbuf, &p);
            p.delivery = Delivery::None;
            recycle.push(p);
        }
        OutMsg::Done(Completion::Failed(mut p, msg)) => {
            frame::req_err(wbuf, p.req_id, &msg);
            p.delivery = Delivery::None;
            recycle.push(p);
        }
        OutMsg::ClassOk { class_id, model_id } => frame::class_ok(wbuf, class_id, model_id),
        OutMsg::ClassErr { class_id, msg } => frame::class_err(wbuf, class_id, &msg),
        OutMsg::Retry {
            req_id,
            hint_us,
            draining,
        } => frame::retry(wbuf, req_id, hint_us, draining),
        OutMsg::ReqErr { req_id, msg } => frame::req_err(wbuf, req_id, &msg),
        OutMsg::SessionOk { token, sid } => frame::session_ok(wbuf, token, sid),
        OutMsg::SessionErr { token, msg } => frame::session_err(wbuf, token, &msg),
        OutMsg::Health(h) => frame::health_ok(wbuf, &h),
        OutMsg::GoodbyeOk => frame::goodbye_ok(wbuf),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A scripted bridge: no serve core behind it, every submit is
    /// refused as Closed.
    struct RefusingBridge;

    impl Bridge for RefusingBridge {
        fn open_class(&self, _class: &Arc<RequestClass>) -> std::result::Result<u32, String> {
            Err("no models here".into())
        }
        fn submit(&self, pending: Pending) -> std::result::Result<(), (SubmitError, Pending)> {
            Err((SubmitError::Closed, pending))
        }
        fn model_count(&self) -> usize {
            0
        }
        fn queue_depth(&self) -> usize {
            3
        }
        fn queue_capacity(&self) -> usize {
            7
        }
        fn shed_count(&self) -> u64 {
            11
        }
    }

    #[test]
    fn health_and_class_err_over_loopback() {
        let front = TcpFront::bind(
            "127.0.0.1:0",
            Arc::new(RefusingBridge),
            TransportConfig::default(),
        )
        .unwrap();
        let addr = front.local_addr();
        let mut cl = super::super::client::TcpClient::connect(addr).unwrap();
        let h = cl.health(5).unwrap();
        assert_eq!(h.probe_id, 5);
        assert_eq!(h.queue_depth, 3);
        assert_eq!(h.queue_capacity, 7);
        assert_eq!(h.shed_total, 11);
        assert_eq!(h.admitted, 0);
        assert_eq!(h.sessions, 0);
        // nothing admitted, 11 shed → the whole observed traffic was shed
        assert_eq!(h.shed_rate, 1.0);
        assert!(h.ready);

        let class = Arc::new(
            RequestClass::new(
                "ghost",
                "alf",
                2,
                0.0,
                1.0,
                crate::solvers::integrate::StepMode::Fixed { h: 0.1 },
                ObsGrid::none(),
            )
            .unwrap(),
        );
        let err = cl.open_class(0, &class).unwrap_err();
        assert!(err.to_string().contains("no models here"), "{err}");

        let out = front.shutdown(Duration::from_secs(5));
        assert!(out.flushed, "nothing in flight, drain must flush");
    }

    #[test]
    fn bad_preamble_gets_disconnected() {
        let front = TcpFront::bind(
            "127.0.0.1:0",
            Arc::new(RefusingBridge),
            TransportConfig {
                read_timeout: Duration::from_millis(200),
                ..TransportConfig::default()
            },
        )
        .unwrap();
        let mut s = TcpStream::connect(front.local_addr()).unwrap();
        s.write_all(b"HTTP/1.1 GET / pls").unwrap();
        let mut buf = [0u8; 16];
        // server hangs up without writing anything
        let n = s.read(&mut buf).unwrap_or(0);
        assert_eq!(n, 0, "bad preamble must be met with a close, got {n} bytes");
        drop(front);
    }
}
