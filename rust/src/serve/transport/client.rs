//! Client side of the transport: a small, dependency-free TCP client
//! speaking the frame grammar in [`frame`](super::frame), plus the
//! [`Backoff`] helper that turns RETRY frames into capped exponential
//! backoff with jitter.
//!
//! The client is deliberately synchronous and single-threaded: one
//! socket, one [`FrameReader`].  Pipelining is still fully available —
//! [`TcpClient::submit`] is fire-and-forget, so a caller can keep many
//! requests in flight and correlate completions by `req_id` as
//! [`TcpClient::next_event`] yields them (responses arrive in
//! *completion* order, not submission order).  Response payloads decode
//! into a caller-owned [`ResponseFrame`] whose buffers are reused, so a
//! warmed request/response loop allocates nothing on either side of the
//! socket.

use super::frame::{self, FrameReader, HealthFrame, ReadOutcome, ResponseFrame};
use crate::serve::RequestClass;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// One decoded server→client frame, as surfaced by
/// [`TcpClient::next_event`].
#[derive(Debug, Clone, PartialEq)]
pub enum ClientEvent {
    /// A completed request; the payload was decoded into the
    /// `ResponseFrame` passed to [`TcpClient::next_event`].
    Response,
    /// Explicit backpressure: re-submit after backing off (unless the
    /// server is draining, in which case go elsewhere).
    Retry {
        req_id: u64,
        /// Server-suggested minimum wait.
        backoff: Duration,
        draining: bool,
    },
    /// The request was rejected or failed while being served.
    ReqErr { req_id: u64, msg: String },
    /// A session was opened (`token` echoes the SESSION_OPEN) or closed
    /// (`token` is 0 — close acks carry no open token).
    SessionOk { token: u64, sid: u64 },
    /// A SESSION_OPEN or SESSION_CLOSE was refused.
    SessionErr { token: u64, msg: String },
    /// Health/readiness report (answer to a HEALTH probe).
    Health(HealthFrame),
    /// Acknowledgement of GOODBYE or SHUTDOWN.
    GoodbyeOk,
}

/// A blocking client connection.  See the module docs for the
/// pipelining model.
pub struct TcpClient {
    stream: TcpStream,
    fr: FrameReader,
    wbuf: Vec<u8>,
}

impl TcpClient {
    /// Connect and send the protocol preamble.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<TcpClient> {
        let stream = TcpStream::connect(addr).context("transport connect")?;
        let _ = stream.set_nodelay(true);
        let mut wbuf = Vec::with_capacity(256);
        frame::write_preamble(&mut wbuf);
        (&stream).write_all(&wbuf).context("send preamble")?;
        Ok(TcpClient {
            stream,
            fr: FrameReader::new(1 << 24),
            wbuf,
        })
    }

    /// Open a request class under `class_id` and return the server's
    /// interned model id.  Handshake-time only: there must be no
    /// submits outstanding on this connection.
    pub fn open_class(&mut self, class_id: u32, class: &RequestClass) -> Result<u32> {
        self.wbuf.clear();
        frame::open_class(&mut self.wbuf, class_id, class);
        (&self.stream).write_all(&self.wbuf).context("send OPEN_CLASS")?;
        loop {
            match self.next_frame()? {
                frame::T_CLASS_OK => {
                    let mut c = frame::Cursor::new(self.fr.body());
                    let got_id = c.u32()?;
                    let model_id = c.u32()?;
                    c.done()?;
                    self.fr.reset();
                    if got_id != class_id {
                        bail!("CLASS_OK for class {got_id}, expected {class_id}");
                    }
                    return Ok(model_id);
                }
                frame::T_CLASS_ERR => {
                    let mut c = frame::Cursor::new(self.fr.body());
                    let _id = c.u32()?;
                    let msg = c.str16()?.to_string();
                    self.fr.reset();
                    bail!("server refused class {class_id}: {msg}");
                }
                t => bail!("unexpected frame 0x{t:02x} while opening a class"),
            }
        }
    }

    /// Fire-and-forget submit of `z0` under an opened class.  Many may
    /// be in flight at once; correlate completions by `req_id`.
    pub fn submit(&mut self, req_id: u64, class_id: u32, z0: &[f32]) -> Result<()> {
        self.wbuf.clear();
        frame::submit(&mut self.wbuf, req_id, class_id, z0);
        (&self.stream).write_all(&self.wbuf).context("send SUBMIT")
    }

    /// Open a streaming session seeded at `(t0, z0)` and block for the
    /// server-assigned session id.  Handshake-style: call with no
    /// submits outstanding on this connection (any other frame arriving
    /// first is an error).
    pub fn open_session(
        &mut self,
        token: u64,
        model: &str,
        solver: &str,
        t0: f64,
        mode: &crate::solvers::integrate::StepMode,
        z0: &[f32],
    ) -> Result<u64> {
        self.wbuf.clear();
        frame::session_open(&mut self.wbuf, token, model, solver, t0, mode, z0);
        (&self.stream).write_all(&self.wbuf).context("send SESSION_OPEN")?;
        let mut scratch = ResponseFrame::default();
        match self.next_event(&mut scratch)? {
            ClientEvent::SessionOk { token: t, sid } if t == token => Ok(sid),
            ClientEvent::SessionErr { token: t, msg } if t == token => {
                bail!("server refused session open: {msg}")
            }
            other => bail!("unexpected frame {other:?} while opening a session"),
        }
    }

    /// Fire-and-forget incremental step: integrate session `sid` through
    /// the (strictly advancing) event `times`.  At most one step may be
    /// in flight per session; the response's `obs` holds the state at
    /// each event time and `z_final` the state at the last.
    pub fn session_step(&mut self, req_id: u64, sid: u64, times: &[f64]) -> Result<()> {
        self.wbuf.clear();
        frame::session_step(&mut self.wbuf, req_id, sid, times);
        (&self.stream).write_all(&self.wbuf).context("send SESSION_STEP")
    }

    /// Close a session and block for the ack.  Call with no steps
    /// outstanding on the session.
    pub fn close_session(&mut self, sid: u64) -> Result<()> {
        self.wbuf.clear();
        frame::session_close(&mut self.wbuf, sid);
        (&self.stream).write_all(&self.wbuf).context("send SESSION_CLOSE")?;
        let mut scratch = ResponseFrame::default();
        match self.next_event(&mut scratch)? {
            ClientEvent::SessionOk { token: 0, sid: s } if s == sid => Ok(()),
            ClientEvent::SessionErr { token: 0, msg } => {
                bail!("server refused session close: {msg}")
            }
            other => bail!("unexpected frame {other:?} while closing session {sid}"),
        }
    }

    /// Block until the next server frame and decode it.  RESPONSE
    /// payloads land in `resp` (buffers reused; zero-alloc once warm).
    pub fn next_event(&mut self, resp: &mut ResponseFrame) -> Result<ClientEvent> {
        let t = self.next_frame()?;
        let ev = decode_event(t, self.fr.body(), resp)?;
        self.fr.reset();
        Ok(ev)
    }

    /// Like [`TcpClient::next_event`] but gives up after `dur`,
    /// returning `Ok(None)`.  Partial frames survive the timeout — the
    /// next call resumes mid-frame.
    pub fn next_event_timeout(
        &mut self,
        dur: Duration,
        resp: &mut ResponseFrame,
    ) -> Result<Option<ClientEvent>> {
        let deadline = Instant::now() + dur;
        let tick = dur.min(Duration::from_millis(50)).max(Duration::from_millis(1));
        self.stream
            .set_read_timeout(Some(tick))
            .context("set read timeout")?;
        let out = loop {
            match self.fr.poll(&mut (&self.stream)) {
                Ok(ReadOutcome::Frame) => {
                    let ev = decode_event(self.fr.frame_type(), self.fr.body(), resp);
                    self.fr.reset();
                    break ev.map(Some);
                }
                Ok(ReadOutcome::Idle) => {
                    if Instant::now() >= deadline {
                        break Ok(None);
                    }
                }
                Ok(ReadOutcome::Closed) => break Err(anyhow::anyhow!("server closed connection")),
                Err(e) => break Err(e).context("frame read"),
            }
        };
        self.stream
            .set_read_timeout(None)
            .context("clear read timeout")?;
        out
    }

    /// Probe server health.  Call with no submits outstanding (any
    /// other frame arriving first is an error).
    pub fn health(&mut self, probe_id: u64) -> Result<HealthFrame> {
        self.wbuf.clear();
        frame::health(&mut self.wbuf, probe_id);
        (&self.stream).write_all(&self.wbuf).context("send HEALTH")?;
        let mut scratch = ResponseFrame::default();
        match self.next_event(&mut scratch)? {
            ClientEvent::Health(h) => Ok(h),
            other => bail!("expected HEALTH_OK, got {other:?}"),
        }
    }

    /// Polite hangup: send GOODBYE and wait for the ack.
    pub fn goodbye(&mut self) -> Result<()> {
        self.wbuf.clear();
        frame::goodbye(&mut self.wbuf);
        (&self.stream).write_all(&self.wbuf).context("send GOODBYE")?;
        let mut scratch = ResponseFrame::default();
        match self.next_event(&mut scratch)? {
            ClientEvent::GoodbyeOk => Ok(()),
            other => bail!("expected GOODBYE_OK, got {other:?}"),
        }
    }

    /// Ask the server process to drain and exit (the `serve-tcp` CLI
    /// honors this).  Waits for the ack.
    pub fn shutdown_server(&mut self) -> Result<()> {
        self.wbuf.clear();
        frame::shutdown(&mut self.wbuf);
        (&self.stream).write_all(&self.wbuf).context("send SHUTDOWN")?;
        let mut scratch = ResponseFrame::default();
        match self.next_event(&mut scratch)? {
            ClientEvent::GoodbyeOk => Ok(()),
            other => bail!("expected GOODBYE_OK, got {other:?}"),
        }
    }

    /// Submit and wait for the response, honoring RETRY backpressure
    /// with `backoff`.  Requires **no other outstanding requests** on
    /// this connection (every event is interpreted against `req_id`).
    /// Returns the number of submit attempts (1 = first try landed).
    pub fn submit_with_retry(
        &mut self,
        req_id: u64,
        class_id: u32,
        z0: &[f32],
        resp: &mut ResponseFrame,
        backoff: &mut Backoff,
    ) -> Result<u32> {
        let mut attempts = 0u32;
        loop {
            self.submit(req_id, class_id, z0)?;
            attempts += 1;
            match self.next_event(resp)? {
                ClientEvent::Response => {
                    if resp.req_id != req_id {
                        bail!("response for req {} while waiting on {req_id}", resp.req_id);
                    }
                    return Ok(attempts);
                }
                ClientEvent::Retry {
                    req_id: rid,
                    backoff: hint,
                    draining,
                } => {
                    if rid != req_id {
                        bail!("RETRY for req {rid} while waiting on {req_id}");
                    }
                    if draining {
                        bail!("server is draining; request {req_id} refused");
                    }
                    std::thread::sleep(backoff.next_delay(hint));
                }
                ClientEvent::ReqErr { req_id: rid, msg } => {
                    bail!("request {rid} failed: {msg}");
                }
                other => bail!("unexpected frame {other:?} while waiting on {req_id}"),
            }
        }
    }

    /// Block until a full frame is buffered; returns its type.
    fn next_frame(&mut self) -> Result<u8> {
        loop {
            match self.fr.poll(&mut (&self.stream)).context("frame read")? {
                ReadOutcome::Frame => return Ok(self.fr.frame_type()),
                ReadOutcome::Idle => continue,
                ReadOutcome::Closed => bail!("server closed connection"),
            }
        }
    }
}

fn decode_event(ftype: u8, body: &[u8], resp: &mut ResponseFrame) -> Result<ClientEvent> {
    match ftype {
        frame::T_RESPONSE => {
            frame::parse_response_into(body, resp)?;
            Ok(ClientEvent::Response)
        }
        frame::T_RETRY => {
            let mut c = frame::Cursor::new(body);
            let req_id = c.u64()?;
            let hint_us = c.u32()?;
            let draining = c.u8()? != 0;
            c.done()?;
            Ok(ClientEvent::Retry {
                req_id,
                backoff: Duration::from_micros(hint_us as u64),
                draining,
            })
        }
        frame::T_REQ_ERR => {
            let mut c = frame::Cursor::new(body);
            let req_id = c.u64()?;
            let msg = c.str16()?.to_string();
            c.done()?;
            Ok(ClientEvent::ReqErr { req_id, msg })
        }
        frame::T_SESSION_OK => {
            let (token, sid) = frame::parse_session_ok(body)?;
            Ok(ClientEvent::SessionOk { token, sid })
        }
        frame::T_SESSION_ERR => {
            let (token, msg) = frame::parse_session_err(body)?;
            Ok(ClientEvent::SessionErr { token, msg })
        }
        frame::T_HEALTH_OK => Ok(ClientEvent::Health(frame::parse_health_ok(body)?)),
        frame::T_GOODBYE_OK => {
            frame::Cursor::new(body).done()?;
            Ok(ClientEvent::GoodbyeOk)
        }
        other => bail!("unexpected server frame type 0x{other:02x}"),
    }
}

/// Capped exponential backoff with jitter, seeded deterministically.
/// The delay for attempt `n` is
/// `max(server_hint, jitter * min(cap, base * 2^n))` with jitter drawn
/// uniformly from `[0.5, 1.0]` — jitter de-synchronizes a thundering
/// herd of retrying clients, while the server hint stays a hard floor.
#[derive(Debug, Clone)]
pub struct Backoff {
    base: Duration,
    cap: Duration,
    attempt: u32,
    rng: Rng,
}

impl Backoff {
    pub fn new(base: Duration, cap: Duration, seed: u64) -> Backoff {
        Backoff {
            base,
            cap,
            attempt: 0,
            rng: Rng::new(seed),
        }
    }

    /// Attempts recorded since construction or [`Backoff::reset`].
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    /// Start a fresh retry sequence (e.g. for the next request).
    pub fn reset(&mut self) {
        self.attempt = 0;
    }

    /// The delay to sleep before the next attempt; advances the
    /// attempt counter.
    pub fn next_delay(&mut self, server_hint: Duration) -> Duration {
        let shift = self.attempt.min(20);
        self.attempt = self.attempt.saturating_add(1);
        let exp = self
            .base
            .saturating_mul(1u32 << shift)
            .min(self.cap)
            .max(Duration::from_micros(1));
        let jittered = exp.mul_f64(0.5 + 0.5 * self.rng.uniform());
        jittered.max(server_hint)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_to_cap_and_honors_hint() {
        let base = Duration::from_millis(1);
        let cap = Duration::from_millis(64);
        let mut b = Backoff::new(base, cap, 7);
        let mut prev_ceiling = Duration::ZERO;
        // run well past attempt 32: the exponent must saturate instead
        // of overflowing the `1u32 << shift` (a u32 shift by ≥ 32 would
        // panic in debug and wrap in release)
        for n in 0..40u32 {
            let d = b.next_delay(Duration::ZERO);
            // ceiling for attempt n is min(cap, base * 2^n); jitter keeps
            // the draw within [ceiling/2, ceiling]
            let ceiling = base.saturating_mul(1u32 << n.min(20)).min(cap);
            assert!(d <= ceiling, "attempt {n}: {d:?} > {ceiling:?}");
            assert!(d >= ceiling / 2, "attempt {n}: {d:?} < {:?}", ceiling / 2);
            assert!(ceiling >= prev_ceiling, "ceiling must be monotone");
            prev_ceiling = ceiling;
            if n >= 6 {
                // base·2^6 = 64 ms ≥ cap: every later draw saturates at it
                assert_eq!(ceiling, cap, "attempt {n} must be capped");
            }
        }
        assert_eq!(b.attempts(), 40);

        // the server hint is a hard floor even early in the sequence and
        // deep into a saturated one
        let hint = Duration::from_millis(500);
        assert_eq!(b.next_delay(hint), hint, "hint floors a saturated sequence");
        b.reset();
        assert_eq!(b.attempts(), 0);
        assert_eq!(b.next_delay(hint), hint);
    }

    #[test]
    fn backoff_is_deterministic_per_seed() {
        let mk = || Backoff::new(Duration::from_millis(2), Duration::from_secs(1), 42);
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..8 {
            assert_eq!(a.next_delay(Duration::ZERO), b.next_delay(Duration::ZERO));
        }
        let mut a2 = mk();
        let mut c = Backoff::new(Duration::from_millis(2), Duration::from_secs(1), 43);
        let seq_a: Vec<_> = (0..8).map(|_| a2.next_delay(Duration::ZERO)).collect();
        let seq_c: Vec<_> = (0..8).map(|_| c.next_delay(Duration::ZERO)).collect();
        assert_ne!(seq_a, seq_c, "different seeds must jitter differently");
    }
}
