//! The serving worker: one per thread, owning every buffer the serve
//! loop needs so that after warm-up a batch is admitted, integrated and
//! scattered back to its requests with **zero** heap allocations
//! (`tests/alloc_serve.rs` pins this with the counting global
//! allocator).
//!
//! Warm state per worker:
//!
//! * a [`BatchWorkspace`] — solver stage scratch, ping-pong batch
//!   states, *and* the per-sample controller vectors of
//!   [`integrate_batch_obs_stats_ws`];
//! * a recycled `[B, N_z]` assembly buffer + init [`BatchState`] filled
//!   in place by [`Solver::init_batch_into`];
//! * a recycled per-sample stats vector;
//! * lazily constructed solver instances, cached by name.
//!
//! Responses are written into the requests' **preallocated** buffers
//! ([`Pending::z_final`] / [`Pending::obs`]), so the per-request
//! envelope cost (one `Vec` each at submit time) stays on the submit
//! path and off the serve loop.
//!
//! With `shard_count > 1` (`MALI_SHARDS`, [`ServeWorker::with_shards`],
//! or `ServerConfig::shards`) the worker splits each micro-batch into
//! contiguous row-range shards integrated concurrently on a persistent
//! [`WorkerPool`] — bitwise-identical results (DESIGN §10,
//! `tests/shard_equivalence.rs`), still zero steady-state allocations
//! (per-shard workspaces in [`BatchShards`] warm once).

use super::batcher::{fill_next_batch, BatcherCfg};
use super::metrics::ServeMetrics;
use super::queue::BoundedQueue;
use super::session::{SessionEntry, SessionTable};
use super::{Completion, Delivery, ModelRegistry, Pending, RequestClass, ServeResponse};
use crate::solvers::batch::{BatchSpec, BatchState};
use crate::solvers::dynamics::ScopedDynamics;
use crate::solvers::integrate::{
    integrate_batch_obs_stats_sharded, integrate_batch_obs_stats_ws, integrate_obs_resume_ws,
    BatchShards, BatchStepObserver, ErrorNorm, IntStats, State, StepObserver,
};
use crate::solvers::workspace::{ensure, BatchWorkspace};
use crate::solvers::{by_name as solver_by_name, Solver};
use crate::tensor::Tensor;
use crate::util::pool::{self, DisjointRowsMut, WorkerPool};
use anyhow::{anyhow, ensure as ensure_that, Result};
use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

/// Streams each sample's observation states straight into its request's
/// `[K, n_z]` response buffer as the batched loop lands (bitwise) on the
/// grid times.
struct ObsCapture<'a> {
    batch: &'a mut [Pending],
    n_z: usize,
}

impl BatchStepObserver for ObsCapture<'_> {
    fn on_observation(&mut self, sample: usize, k: usize, _t: f64, z: &[f32], _v: Option<&[f32]>) {
        let dst = &mut self.batch[sample].obs[k * self.n_z..(k + 1) * self.n_z];
        dst.copy_from_slice(z);
    }
}

/// Single-trajectory twin of [`ObsCapture`] for the session step path:
/// streams each event's state into the step envelope's `[K, n_z]`
/// response buffer as the resumable loop lands on the event times.
struct SessionObsCapture<'a> {
    obs: &'a mut [f32],
    n_z: usize,
}

impl StepObserver for SessionObsCapture<'_> {
    fn on_observation(&mut self, k: usize, _t: f64, state: &State) {
        self.obs[k * self.n_z..(k + 1) * self.n_z].copy_from_slice(&state.z);
    }
}

/// Clears a session's one-step-in-flight flag on scope exit — including
/// the unwind path, so a panicking solve cannot wedge the session busy.
struct BusyClear<'a>(&'a SessionEntry);

impl Drop for BusyClear<'_> {
    fn drop(&mut self) {
        self.0.busy.store(false, Ordering::Release);
    }
}

/// Per-thread serving state (see the module docs).  Drive it through
/// [`worker_loop`] (the threaded server) or call
/// [`ServeWorker::process`] directly with a homogeneous batch (tests,
/// benches, embedding).
pub struct ServeWorker {
    registry: Arc<ModelRegistry>,
    /// Session table for streaming steps (`Pending::session_id != 0`);
    /// absent on direct-drive workers that never see session envelopes.
    sessions: Option<Arc<SessionTable>>,
    solvers: BTreeMap<String, Box<dyn Solver + Send + Sync>>,
    ws: BatchWorkspace,
    init: BatchState,
    z0_flat: Vec<f32>,
    per: Vec<IntStats>,
    metrics: ServeMetrics,
    /// Intra-batch shard count (1 = unsharded fast path, byte-for-byte
    /// the pre-sharding serve loop).
    n_shards: usize,
    shards: BatchShards,
    /// Persistent shard workers (`n_shards - 1`, capped by
    /// `MALI_THREADS`; the serve thread itself runs the first shard).
    /// Spawned once at construction — `thread::spawn` allocates, so it
    /// must never happen inside `process`.
    shard_pool: Option<WorkerPool>,
}

/// Intra-batch shard count for new workers: `MALI_SHARDS`, default 1
/// (read once per worker at construction — `env::var` allocates, so the
/// serve loop must not consult it per batch).
pub fn shards_from_env() -> usize {
    std::env::var("MALI_SHARDS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .map(|n| n.max(1))
        .unwrap_or(1)
}

impl ServeWorker {
    /// A fresh worker over `registry`; every buffer grows on first use.
    /// Shard count comes from [`shards_from_env`] (`MALI_SHARDS`).
    pub fn new(registry: Arc<ModelRegistry>) -> ServeWorker {
        ServeWorker::with_shards(registry, shards_from_env())
    }

    /// A fresh worker that splits every micro-batch into `shards`
    /// row-range shards (clamped to at least 1).  Results are bitwise
    /// independent of the shard count (`tests/shard_equivalence.rs`);
    /// sharding is purely a latency/throughput knob.
    pub fn with_shards(registry: Arc<ModelRegistry>, shards: usize) -> ServeWorker {
        let n_shards = shards.max(1);
        let shard_pool = if n_shards > 1 {
            let threads = (n_shards - 1).min(pool::num_threads().saturating_sub(1));
            Some(WorkerPool::new(threads))
        } else {
            None
        };
        ServeWorker {
            registry,
            sessions: None,
            solvers: BTreeMap::new(),
            ws: BatchWorkspace::new(),
            init: BatchState {
                z: Tensor {
                    data: Vec::new(),
                    shape: vec![0, 0],
                },
                v: None,
            },
            z0_flat: Vec::new(),
            per: Vec::new(),
            metrics: ServeMetrics::new(),
            n_shards,
            shards: BatchShards::new(n_shards),
            shard_pool,
        }
    }

    /// The worker's intra-batch shard count.
    pub fn shard_count(&self) -> usize {
        self.n_shards
    }

    /// Attach the server's session table so this worker can serve
    /// session step envelopes.
    pub fn attach_sessions(&mut self, sessions: Arc<SessionTable>) {
        self.sessions = Some(sessions);
    }

    /// Serving counters accumulated so far.
    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    /// Consume the worker, yielding its metrics (the thread-exit path).
    pub fn into_metrics(self) -> ServeMetrics {
        self.metrics
    }

    /// Record the queue depth observed at batch formation.
    pub fn note_queue_depth(&mut self, depth: usize) {
        self.metrics.max_queue_depth = self.metrics.max_queue_depth.max(depth);
    }

    /// Serve one homogeneous micro-batch: assemble the `[B, N_z]` state,
    /// integrate through the batched fast path, scatter results into
    /// each request's buffers, record metrics, and deliver responses to
    /// any attached slots.
    ///
    /// **Fault isolation**: if the batched solve errors and the batch
    /// has more than one row, every row is re-served **solo** — a
    /// poisoned request (say, a step-size search that cannot converge)
    /// fails alone and its coalesced neighbors still get their exact
    /// solo results, preserving the "coalescing is a pure scheduling
    /// change" contract on the error path too.  The original batch
    /// error is still returned so direct drivers see that the fast path
    /// failed; per-request outcomes are what the slots/buffers say.
    pub fn process(&mut self, batch: &mut [Pending]) -> Result<()> {
        if batch.is_empty() {
            return Ok(());
        }
        if batch[0].session_id != 0 {
            return self.serve_session(batch);
        }
        let t_start = Instant::now();
        self.metrics.note_activity(t_start);
        let class = batch[0].class.clone();
        if batch.iter().any(|p| p.class.key() != class.key()) {
            let e = anyhow!(
                "micro-batch mixes incompatible request classes (batcher contract violated)"
            );
            self.fail_rows(batch, &e);
            return Err(e);
        }
        match self.run_batch(&class, batch) {
            Ok(f_evals) => {
                self.deliver_rows(batch, t_start, f_evals);
                Ok(())
            }
            Err(e) if batch.len() > 1 => {
                for i in 0..batch.len() {
                    let row = &mut batch[i..i + 1];
                    // service time is this row's own solo solve; the
                    // failed batch attempt and earlier retries count as
                    // queue wait (time before the solve that served you)
                    let row_start = Instant::now();
                    match self.run_batch(&class, row) {
                        Ok(f_evals) => self.deliver_rows(row, row_start, f_evals),
                        Err(row_err) => self.fail_rows(row, &row_err),
                    }
                }
                Err(e)
            }
            Err(e) => {
                self.fail_rows(batch, &e);
                Err(e)
            }
        }
    }

    /// Serve one session step envelope: look up the warm session, run
    /// the resumable integrator from the carried `(t, z, v)` through the
    /// envelope's event times, and deliver exactly like a one-shot row.
    /// Session steps are served solo — the batcher never coalesces them
    /// ([`Pending::session_id`] is a coalescing barrier) because two
    /// steps of one session are sequentially dependent.
    ///
    /// Failures (unknown/closed session, bad event times, a diverging
    /// solve) are delivered in-band on the row; an integration error
    /// additionally **poisons** the session — its carried state may sit
    /// at a non-event point, so every later step is refused until the
    /// client closes and reopens.
    fn serve_session(&mut self, batch: &mut [Pending]) -> Result<()> {
        let t_start = Instant::now();
        self.metrics.note_activity(t_start);
        if batch.len() != 1 {
            let e = anyhow!("session steps are served solo (batcher contract violated)");
            self.fail_rows(batch, &e);
            return Err(e);
        }
        let sid = batch[0].session_id;
        let entry = match self.sessions.as_ref().and_then(|t| t.entry(sid)) {
            Some(entry) => entry,
            None => {
                let e = anyhow!("session {sid} is unknown or already closed");
                self.fail_rows(batch, &e);
                return Err(e);
            }
        };
        // cleared on every exit path, including unwind: a panicking solve
        // must not wedge the session busy forever
        let _busy = BusyClear(&entry);
        match Self::run_session_step(&entry, &mut batch[0]) {
            Ok(f_evals) => {
                self.metrics.session_steps += 1;
                self.deliver_rows(batch, t_start, f_evals);
                Ok(())
            }
            Err(e) => {
                self.fail_rows(batch, &e);
                Err(e)
            }
        }
    }

    /// The session-step core: under the session's own lock, advance the
    /// carried state through `p.times` with the session's pinned model
    /// version, warm solver and workspace.  Observation rows stream into
    /// `p.obs`; the final state lands in `p.z_final`.  Returns this
    /// step's exact `f`-evaluation count (scoped counters — other
    /// workers sharing the model never bleed in).
    fn run_session_step(entry: &SessionEntry, p: &mut Pending) -> Result<u64> {
        let mut guard = entry
            .core
            .lock()
            .map_err(|_| anyhow!("session {} core poisoned by a panic", p.session_id))?;
        let core = &mut *guard;
        ensure_that!(
            !core.poisoned,
            "session {} was poisoned by an earlier failed step; close and reopen it",
            p.session_id
        );
        ensure_that!(
            !p.times.is_empty(),
            "session {} step carries no event times",
            p.session_id
        );
        let n_z = core.class.n_z;
        let k = p.times.len();
        // response buffers are sized by the transport/submit path;
        // re-shape defensively for direct-drive envelopes
        ensure(&mut p.z_final, n_z);
        ensure(&mut p.obs, k * n_z);
        // per-step scoped counter window (exact f_evals under sharing);
        // the inner counters still accrue for registry-wide accounting
        let scoped = ScopedDynamics::new(core.model.dynamics());
        let mut cap = SessionObsCapture {
            obs: &mut p.obs,
            n_z,
        };
        let stats = match integrate_obs_resume_ws(
            core.solver.as_ref(),
            &scoped,
            &mut core.resume,
            &p.times,
            &core.class.mode,
            &ErrorNorm::Full,
            &mut cap,
            &mut core.ws,
        ) {
            Ok(stats) => stats,
            Err(e) => {
                core.poisoned = true;
                return Err(e);
            }
        };
        p.z_final.copy_from_slice(core.resume.z());
        p.n_accepted = stats.n_accepted;
        p.n_trials = stats.n_trials;
        core.stats.n_accepted += stats.n_accepted;
        core.stats.n_trials += stats.n_trials;
        core.stats.f_evals += stats.f_evals;
        core.steps += 1;
        Ok(stats.f_evals)
    }

    /// Record metrics for a successfully solved batch (or solo retry)
    /// and deliver each row's response.  Sink-routed envelopes are moved
    /// out whole (a no-allocation husk swap keeps the batch slice valid)
    /// so the transport can write + recycle them; slot-routed rows copy
    /// into a [`ServeResponse`]; direct-drive rows just keep their
    /// filled buffers.
    fn deliver_rows(&mut self, batch: &mut [Pending], t_start: Instant, f_evals: u64) {
        let service_s = t_start.elapsed().as_secs_f64();
        self.metrics.batches += 1;
        self.metrics.batch_rows += batch.len() as u64;
        self.metrics.f_evals += f_evals;
        for p in batch.iter_mut() {
            let queue_wait_s = t_start.saturating_duration_since(p.enqueued).as_secs_f64();
            p.queue_wait_s = queue_wait_s;
            p.service_s = service_s;
            self.metrics.requests += 1;
            self.metrics.steps += p.n_accepted as u64;
            self.metrics.trials += p.n_trials as u64;
            self.metrics.queue_wait.record(queue_wait_s);
            self.metrics.service.record(service_s);
            self.metrics.total.record(queue_wait_s + service_s);
            match std::mem::take(&mut p.delivery) {
                Delivery::None => {}
                Delivery::Slot(slot) => {
                    slot.fulfill(Ok(ServeResponse {
                        z_final: std::mem::take(&mut p.z_final),
                        obs: std::mem::take(&mut p.obs),
                        n_accepted: p.n_accepted,
                        n_trials: p.n_trials,
                        queue_wait_s,
                        service_s,
                    }));
                }
                Delivery::Sink(sink) => {
                    let class = p.class.clone();
                    let env = std::mem::replace(p, Pending::husk(class));
                    sink.complete(Completion::Ok(env));
                }
            }
        }
        self.metrics.note_activity(Instant::now());
    }

    /// Fail every row of `batch` with `e`'s message.
    fn fail_rows(&mut self, batch: &mut [Pending], e: &anyhow::Error) {
        self.metrics.failed += batch.len() as u64;
        let msg = format!("serve batch failed: {e:#}");
        for p in batch.iter_mut() {
            match std::mem::take(&mut p.delivery) {
                Delivery::None => {}
                Delivery::Slot(slot) => slot.fulfill(Err(msg.clone())),
                Delivery::Sink(sink) => {
                    let class = p.class.clone();
                    let env = std::mem::replace(p, Pending::husk(class));
                    sink.complete(Completion::Failed(env, msg.clone()));
                }
            }
        }
    }

    /// The allocation-free core: batch assembly → `init_batch_into` →
    /// `integrate_batch_obs_stats_ws` (or its sharded twin when
    /// `shard_count > 1` — bitwise the same results) → per-row scatter.
    /// Returns the batch's `f`-evaluation count.
    fn run_batch(&mut self, class: &RequestClass, batch: &mut [Pending]) -> Result<u64> {
        // interned lookup: one tag compare after the class's first batch
        // on this registry (ModelRegistry::resolve_cached) — the serve
        // loop never hashes the model string.  The snapshot pins the
        // model *version* for the whole batch: a hot_swap landing
        // mid-solve changes what future batches see, never this one.
        let model = self
            .registry
            .resolve_cached(class)
            .and_then(|id| self.registry.snapshot(id))
            .ok_or_else(|| {
                anyhow!(
                    "unknown model '{}' (registered: {:?})",
                    class.model,
                    self.registry.names()
                )
            })?;
        // direct drivers bypass Server::submit, so re-check the shape
        // contract here (cheap scalar compares; an error, not a panic)
        ensure_that!(
            !model.is_device_batched(),
            "model '{}' is device-batched (fixed [B, n_z] baked into its executable) \
             and cannot be dynamically micro-batched",
            class.model
        );
        ensure_that!(
            model.dim() == class.n_z,
            "model '{}' has state width {}, request class expects n_z = {}",
            class.model,
            model.dim(),
            class.n_z
        );
        // per-batch scoped counter window: two workers sharing one
        // dynamics no longer interleave their deltas — this batch's
        // f_evals are counted on a worker-local scope, while the inner
        // counters still accrue for registry-wide accounting
        let dynamics = ScopedDynamics::new(model.dynamics());
        let dynamics = &dynamics;
        if !self.solvers.contains_key(&class.solver) {
            // cold path: first batch of this solver name on this worker
            let s = solver_by_name(&class.solver)?;
            self.solvers.insert(class.solver.clone(), s);
        }
        let solver = self.solvers.get(&class.solver).expect("just inserted");
        let nb = batch.len();
        let n_z = class.n_z;
        let spec = BatchSpec::new(nb, n_z);
        let k = class.grid.len();
        ensure(&mut self.z0_flat, spec.flat_len());
        for (b, p) in batch.iter_mut().enumerate() {
            ensure_that!(
                p.z0.len() == n_z,
                "request row {b}: z0 has {} elements, class expects {n_z}",
                p.z0.len()
            );
            ensure_that!(
                p.z0.iter().all(|v| v.is_finite()),
                "request row {b}: z0 contains non-finite components"
            );
            spec.row_mut(&mut self.z0_flat, b).copy_from_slice(&p.z0);
            // response buffers are sized at submit time; re-shape
            // defensively for recycled direct-drive envelopes
            ensure(&mut p.z_final, n_z);
            ensure(&mut p.obs, k * n_z);
        }
        // the scope spans init + integrate, so the batch's f_evals
        // includes ALF's v₀ = f(z₀) evaluations
        solver.init_batch_into(dynamics, class.t0, &self.z0_flat, &spec, &mut self.init, &mut self.ws);
        if self.n_shards > 1 && nb > 1 {
            // Sharded path: the batch's rows are integrated as contiguous
            // sub-batches, concurrently on the shard pool.  Each shard
            // streams its observations straight into its own rows'
            // response buffers via a shard-local ObsCapture.
            let caps = DisjointRowsMut::new(&mut *batch);
            let make_obs = |_shard: usize, rows: std::ops::Range<usize>| ObsCapture {
                // SAFETY: the sharded driver builds one observer per
                // shard, the shards' global row ranges are pairwise
                // disjoint, each shard index is dispatched exactly once,
                // and the driver joins before returning — so no two live
                // borrows overlap and none outlives `batch`.
                batch: unsafe { caps.range(rows.start, rows.end) },
                n_z,
            };
            integrate_batch_obs_stats_sharded(
                solver.as_ref(),
                dynamics,
                class.t0,
                class.t1,
                &self.init,
                &class.mode,
                &ErrorNorm::Full,
                &class.grid,
                make_obs,
                &mut self.per,
                &mut self.shards,
                &mut self.ws,
                self.shard_pool.as_ref(),
            )?;
        } else {
            let mut cap = ObsCapture {
                batch: &mut *batch,
                n_z,
            };
            integrate_batch_obs_stats_ws(
                solver.as_ref(),
                dynamics,
                class.t0,
                class.t1,
                &self.init,
                &class.mode,
                &ErrorNorm::Full,
                &class.grid,
                &mut cap,
                &mut self.per,
                &mut self.ws,
            )?;
        }
        let f_evals = dynamics.counters().f_evals.get();
        let out = self.ws.output();
        for (b, p) in batch.iter_mut().enumerate() {
            out.copy_row_into(b, &mut p.z_final, None);
            p.n_accepted = self.per[b].n_accepted;
            p.n_trials = self.per[b].n_trials;
        }
        Ok(f_evals)
    }
}

/// The thread body of one serving worker: form micro-batches until the
/// queue closes, serve each through a [`ServeWorker`], and return the
/// accumulated metrics.  The batch vector is reused across iterations,
/// so a warmed loop forms and serves batches without allocating.
///
/// A panic inside a solve (a bug in a registered dynamics, say) is
/// caught here: every still-unfulfilled response slot of the batch gets
/// an explicit error — one poisoned request must not strand its own
/// waiters, let alone take the worker (and every later waiter) with it.
pub fn worker_loop(
    queue: &BoundedQueue<Pending>,
    registry: &Arc<ModelRegistry>,
    sessions: &Arc<SessionTable>,
    cfg: &BatcherCfg,
    shards: usize,
) -> ServeMetrics {
    let mut worker = ServeWorker::with_shards(registry.clone(), shards);
    worker.attach_sessions(sessions.clone());
    let mut batch: Vec<Pending> = Vec::new();
    while fill_next_batch(queue, cfg, &mut batch) {
        worker.note_queue_depth(queue.len() + batch.len());
        // non-panic errors were already delivered to the response slots
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = worker.process(&mut batch);
        }));
        if outcome.is_err() {
            for p in batch.iter_mut() {
                if matches!(p.delivery, Delivery::None) {
                    continue;
                }
                let class = p.class.clone();
                let env = std::mem::replace(p, Pending::husk(class));
                env.fail("serve worker panicked while integrating this batch");
                worker.metrics.failed += 1;
            }
        }
        batch.clear();
    }
    worker.into_metrics()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::RequestClass;
    use crate::solvers::dynamics::LinearToy;
    use crate::solvers::integrate::{ObsGrid, StepMode};

    fn registry(n_z: usize) -> Arc<ModelRegistry> {
        let mut reg = ModelRegistry::new();
        reg.register("toy", Box::new(LinearToy::new(-0.4, n_z)));
        Arc::new(reg)
    }

    #[test]
    fn worker_serves_a_direct_batch() {
        let reg = registry(2);
        let class = Arc::new(
            RequestClass::new("toy", "alf", 2, 0.0, 1.0, StepMode::Fixed { h: 0.1 }, ObsGrid::none())
                .unwrap(),
        );
        let mut w = ServeWorker::new(reg);
        let mut batch = vec![
            Pending::new(class.clone(), vec![1.0, -0.5]),
            Pending::new(class.clone(), vec![0.25, 2.0]),
        ];
        w.process(&mut batch).unwrap();
        for p in &batch {
            assert_eq!(p.n_accepted, 10);
            assert_eq!(p.n_trials, 10);
            // contracting dynamics: |z| shrinks
            assert!(p.z_final[0].abs() < p.z0[0].abs().max(1e-6));
        }
        assert_eq!(w.metrics().requests, 2);
        assert_eq!(w.metrics().batches, 1);
        assert_eq!(w.metrics().steps, 20);
        assert!(w.metrics().f_evals > 0);
    }

    #[test]
    fn worker_rejects_mixed_classes_and_unknown_models() {
        let reg = registry(1);
        let a = Arc::new(
            RequestClass::new("toy", "alf", 1, 0.0, 1.0, StepMode::Fixed { h: 0.1 }, ObsGrid::none())
                .unwrap(),
        );
        let b = Arc::new(
            RequestClass::new("toy", "alf", 1, 0.0, 1.0, StepMode::Fixed { h: 0.2 }, ObsGrid::none())
                .unwrap(),
        );
        let mut w = ServeWorker::new(reg.clone());
        let mut mixed = vec![
            Pending::new(a.clone(), vec![1.0]),
            Pending::new(b, vec![1.0]),
        ];
        assert!(w.process(&mut mixed).is_err());
        assert_eq!(w.metrics().failed, 2);

        let ghost = Arc::new(
            RequestClass::new("ghost", "alf", 1, 0.0, 1.0, StepMode::Fixed { h: 0.1 }, ObsGrid::none())
                .unwrap(),
        );
        let mut batch = vec![Pending::new(ghost, vec![1.0])];
        assert!(w.process(&mut batch).is_err());
        // a class whose width disagrees with the registered model is an
        // error, not a panic inside the solve
        let wide = Arc::new(
            RequestClass::new("toy", "alf", 3, 0.0, 1.0, StepMode::Fixed { h: 0.1 }, ObsGrid::none())
                .unwrap(),
        );
        let mut batch = vec![Pending::new(wide, vec![1.0, 2.0, 3.0])];
        let err = w.process(&mut batch).unwrap_err();
        assert!(err.to_string().contains("state width"), "{err}");
        // a good batch still works afterwards (worker state intact)
        let mut ok = vec![Pending::new(a, vec![1.0])];
        w.process(&mut ok).unwrap();
        assert_eq!(w.metrics().requests, 1);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let mut w = ServeWorker::new(registry(1));
        w.process(&mut []).unwrap();
        assert_eq!(w.metrics().batches, 0);
    }

    /// A failing batch is retried row by row, so every request gets its
    /// own verdict (here: euler + adaptive is a per-solve error, so all
    /// rows fail — each through its own solo retry, none stranded).
    #[test]
    fn batch_error_is_isolated_per_row() {
        let reg = registry(1);
        let class = Arc::new(
            RequestClass::new(
                "toy",
                "euler",
                1,
                0.0,
                1.0,
                StepMode::adaptive(1e-4, 1e-6),
                ObsGrid::none(),
            )
            .unwrap(),
        );
        let mut w = ServeWorker::new(reg);
        let mut batch = vec![
            Pending::new(class.clone(), vec![1.0]),
            Pending::new(class.clone(), vec![2.0]),
        ];
        assert!(w.process(&mut batch).is_err());
        assert_eq!(w.metrics().failed, 2, "each row failed individually");
        assert_eq!(w.metrics().requests, 0);
        // non-finite rows are rejected by the worker too (direct drive
        // bypasses Server::submit's gate)
        let fixed = Arc::new(
            RequestClass::new("toy", "alf", 1, 0.0, 1.0, StepMode::Fixed { h: 0.1 }, ObsGrid::none())
                .unwrap(),
        );
        let mut batch = vec![Pending::new(fixed, vec![f32::NAN])];
        let err = w.process(&mut batch).unwrap_err();
        assert!(err.to_string().contains("non-finite"), "{err}");
    }
}
