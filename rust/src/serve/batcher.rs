//! Dynamic micro-batching: coalesce queued requests with the same
//! [`CompatKey`](crate::serve::CompatKey) into one batch.
//!
//! The policy (ADR-002):
//!
//! 1. **Head-of-line seeding** — the next batch starts with the FIFO
//!    head, so no class can be starved by a busier one.
//! 2. **Selective drain** — requests compatible with the head are pulled
//!    from anywhere in the queue (incompatible ones keep their FIFO
//!    positions for the next round).
//! 3. **Bounded patience** — the batch closes at `max_batch` rows or
//!    when `max_wait` expires, whichever first.  `max_wait = 0` still
//!    sweeps everything *already* queued — coalescing then costs zero
//!    added latency and only helps under backlog.
//!
//! The filler reuses the caller's `Vec` so a warmed serve loop forms
//! batches without allocating.

use super::queue::BoundedQueue;
use super::Pending;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Micro-batching knobs (one per worker; cheap to clone).
#[derive(Debug, Clone)]
pub struct BatcherCfg {
    /// Largest batch to form (≥ 1; 1 disables coalescing — the "solo"
    /// baseline of E12).
    pub max_batch: usize,
    /// How long to hold a forming batch open for stragglers.
    pub max_wait: Duration,
}

/// Fill `out` with the next micro-batch: the queue head plus up to
/// `max_batch − 1` key-compatible followers, waiting at most `max_wait`
/// after the head is taken.  Blocks while the queue is empty; returns
/// `false` when the queue is closed and drained (shutdown).
pub fn fill_next_batch(
    queue: &BoundedQueue<Pending>,
    cfg: &BatcherCfg,
    out: &mut Vec<Pending>,
) -> bool {
    out.clear();
    let Some(head) = queue.pop_wait() else {
        return false;
    };
    // session steps (`session_id != 0`) never coalesce: two steps of one
    // session share the class Arc but are sequentially dependent, so each
    // is served solo (the head barrier below plus this early return)
    let head_is_session = head.session_id != 0;
    let class = head.class.clone();
    out.push(head);
    if cfg.max_batch <= 1 || head_is_session {
        return true;
    }
    let deadline = Instant::now() + cfg.max_wait;
    loop {
        // generation BEFORE the scan: a push racing in after the sweep
        // bumps it, so the wait below returns immediately (no lost
        // wakeup, no burned patience)
        // Arc identity first (the documented build-once-share-the-Arc
        // pattern makes the common case one pointer compare under the
        // producers' lock); the key compare covers separately built but
        // identical classes.  Session steps are barred from joining any
        // batch (and from seeding one — see the head check above).
        let compatible = |p: &Pending| {
            p.session_id == 0
                && (Arc::ptr_eq(&p.class, &class) || p.class.key() == class.key())
        };
        let gen = queue.push_generation();
        queue.pop_matching_into(&compatible, cfg.max_batch - out.len(), out);
        if out.len() >= cfg.max_batch {
            return true;
        }
        if !queue.wait_newer_until(gen, deadline) {
            // patience exhausted (or closing): one final sweep for
            // anything that raced in, then run what we have
            queue.pop_matching_into(&compatible, cfg.max_batch - out.len(), out);
            return true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::RequestClass;
    use crate::solvers::integrate::{ObsGrid, StepMode};
    use std::sync::Arc;

    fn class(h: f64) -> Arc<RequestClass> {
        Arc::new(
            RequestClass::new("toy", "alf", 1, 0.0, 1.0, StepMode::Fixed { h }, ObsGrid::none())
                .unwrap(),
        )
    }

    fn req(class: &Arc<RequestClass>, z: f32) -> Pending {
        Pending::new(class.clone(), vec![z])
    }

    #[test]
    fn coalesces_only_compatible_requests() {
        let a = class(0.1);
        let b = class(0.2);
        let q = BoundedQueue::new(16);
        // interleaved classes: a, b, a, a, b
        for (c, z) in [(&a, 1.0), (&b, 2.0), (&a, 3.0), (&a, 4.0), (&b, 5.0)] {
            q.try_push(req(c, z)).unwrap();
        }
        let cfg = BatcherCfg {
            max_batch: 8,
            max_wait: Duration::ZERO,
        };
        let mut batch = Vec::new();
        assert!(fill_next_batch(&q, &cfg, &mut batch));
        let zs: Vec<f32> = batch.iter().map(|p| p.z0[0]).collect();
        assert_eq!(zs, vec![1.0, 3.0, 4.0], "all class-a rows, FIFO order");
        assert!(batch.iter().all(|p| p.class.key() == a.key()));
        // the b rows are untouched and come out next, in order
        assert!(fill_next_batch(&q, &cfg, &mut batch));
        let zs: Vec<f32> = batch.iter().map(|p| p.z0[0]).collect();
        assert_eq!(zs, vec![2.0, 5.0]);
        assert!(q.is_empty());
    }

    #[test]
    fn respects_max_batch() {
        let a = class(0.1);
        let q = BoundedQueue::new(16);
        for z in 0..5 {
            q.try_push(req(&a, z as f32)).unwrap();
        }
        // max_wait far beyond any plausible CI scheduling hiccup: the
        // loose elapsed bound below fails only if the filler actually
        // waited out the deadline instead of returning on a full batch
        let cfg = BatcherCfg {
            max_batch: 2,
            max_wait: Duration::from_secs(10),
        };
        let mut batch = Vec::new();
        let t0 = Instant::now();
        assert!(fill_next_batch(&q, &cfg, &mut batch));
        assert_eq!(batch.len(), 2);
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "full batch must return without waiting out max_wait"
        );
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn max_batch_one_is_solo_mode() {
        let a = class(0.1);
        let q = BoundedQueue::new(16);
        q.try_push(req(&a, 1.0)).unwrap();
        q.try_push(req(&a, 2.0)).unwrap();
        let cfg = BatcherCfg {
            max_batch: 1,
            max_wait: Duration::from_millis(50),
        };
        let mut batch = Vec::new();
        assert!(fill_next_batch(&q, &cfg, &mut batch));
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].z0[0], 1.0);
    }

    #[test]
    fn waits_for_stragglers_within_patience() {
        let a = class(0.1);
        let q = Arc::new(BoundedQueue::new(16));
        q.try_push(req(&a, 1.0)).unwrap();
        let q2 = q.clone();
        let a2 = a.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            q2.try_push(req(&a2, 2.0)).unwrap();
        });
        let cfg = BatcherCfg {
            max_batch: 2,
            max_wait: Duration::from_millis(200),
        };
        let mut batch = Vec::new();
        assert!(fill_next_batch(&q, &cfg, &mut batch));
        t.join().unwrap();
        assert_eq!(batch.len(), 2, "straggler joined the forming batch");
    }

    #[test]
    fn shutdown_stops_the_filler() {
        let q: BoundedQueue<Pending> = BoundedQueue::new(4);
        q.close();
        let cfg = BatcherCfg {
            max_batch: 4,
            max_wait: Duration::ZERO,
        };
        let mut batch = Vec::new();
        assert!(!fill_next_batch(&q, &cfg, &mut batch));
        assert!(batch.is_empty());
    }
}
