//! Bounded in-process MPSC request queue with explicit shedding.
//!
//! The serving front door: producers (`Server::submit`) push with
//! [`BoundedQueue::try_push`], which **fails fast** when the queue is at
//! capacity instead of blocking or growing — overload surfaces to the
//! caller as a shed error while the queue's memory stays bounded at
//! `capacity` requests (the backpressure/shed policy of ADR-002).
//! Consumers (the micro-batcher loop) block on [`BoundedQueue::pop_wait`]
//! and selectively drain coalescible entries with
//! [`BoundedQueue::pop_matching_into`].
//!
//! Built on `std::sync::{Mutex, Condvar}` — no async runtime (tokio is
//! not vendored offline, and the consumers are a handful of worker
//! threads whose work items are multi-millisecond ODE solves, so parked
//! OS threads cost nothing that matters here; see ADR-002).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Why a push was rejected; the rejected item is handed back so the
/// caller can retry or fail its request.
#[derive(Debug)]
pub enum PushError<T> {
    /// The queue held `capacity` items — the request is shed (counted in
    /// [`BoundedQueue::shed_count`]).
    Full(T),
    /// [`BoundedQueue::close`] was called; no new work is admitted.
    Closed(T),
}

struct Inner<T> {
    items: VecDeque<T>,
    /// Empty between calls; [`BoundedQueue::pop_matching_into`] swaps it
    /// in as the compaction target so the O(n) selective drain reuses
    /// warm capacity instead of allocating under the lock.
    spare: VecDeque<T>,
    /// Monotone push counter — the generation token that makes the
    /// batcher's scan-then-wait race-free (a push between a scan and the
    /// wait bumps it, so the wait returns immediately instead of losing
    /// the wakeup until the deadline).
    pushes: u64,
    closed: bool,
}

/// A bounded multi-producer queue for serve requests (see module docs).
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    /// Signalled on every push and on close.
    changed: Condvar,
    capacity: usize,
    shed: AtomicU64,
}

impl<T> BoundedQueue<T> {
    /// A queue admitting at most `capacity` (> 0) buffered items.
    pub fn new(capacity: usize) -> BoundedQueue<T> {
        assert!(capacity > 0, "queue capacity must be positive");
        BoundedQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::with_capacity(capacity),
                spare: VecDeque::with_capacity(capacity),
                pushes: 0,
                closed: false,
            }),
            changed: Condvar::new(),
            capacity,
            shed: AtomicU64::new(0),
        }
    }

    /// Non-blocking push: sheds (with a count) when the queue is full,
    /// rejects when it is closed.
    pub fn try_push(&self, item: T) -> Result<(), PushError<T>> {
        let mut g = self.inner.lock().expect("queue poisoned");
        if g.closed {
            return Err(PushError::Closed(item));
        }
        if g.items.len() >= self.capacity {
            self.shed.fetch_add(1, Ordering::Relaxed);
            return Err(PushError::Full(item));
        }
        g.items.push_back(item);
        g.pushes += 1;
        drop(g);
        self.changed.notify_all();
        Ok(())
    }

    /// Block until an item is available (FIFO head) or the queue is
    /// closed *and* drained; `None` means shutdown.
    pub fn pop_wait(&self) -> Option<T> {
        let mut g = self.inner.lock().expect("queue poisoned");
        loop {
            if let Some(item) = g.items.pop_front() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.changed.wait(g).expect("queue poisoned");
        }
    }

    /// Non-blocking pop of the FIFO head.
    pub fn try_pop(&self) -> Option<T> {
        self.inner.lock().expect("queue poisoned").items.pop_front()
    }

    /// Remove up to `max` items matching `pred` — from anywhere in the
    /// queue, preserving the relative order of both the taken and the
    /// remaining items — and append them to `out`.  Returns how many were
    /// taken.  This is the coalescing primitive: the batcher drains
    /// requests compatible with the batch head past any incompatible ones
    /// parked in between (which keep their FIFO positions).
    ///
    /// One ordered O(n) compaction pass (repeated `VecDeque::remove`
    /// would be O(n²) element moves under the lock every producer
    /// needs); the non-matches land in the pooled `spare` deque, so the
    /// steady state allocates nothing.
    pub fn pop_matching_into(
        &self,
        mut pred: impl FnMut(&T) -> bool,
        max: usize,
        out: &mut Vec<T>,
    ) -> usize {
        if max == 0 {
            return 0;
        }
        let mut g = self.inner.lock().expect("queue poisoned");
        if g.items.is_empty() {
            return 0;
        }
        let mut src = std::mem::take(&mut g.items);
        let mut dst = std::mem::take(&mut g.spare);
        debug_assert!(dst.is_empty());
        let mut taken = 0;
        for item in src.drain(..) {
            if taken < max && pred(&item) {
                out.push(item);
                taken += 1;
            } else {
                dst.push_back(item);
            }
        }
        g.spare = src; // drained empty; keeps its capacity warm
        g.items = dst;
        taken
    }

    /// Current push-generation token; grab it **before** scanning the
    /// queue, then hand it to [`BoundedQueue::wait_newer_until`].
    pub fn push_generation(&self) -> u64 {
        self.inner.lock().expect("queue poisoned").pushes
    }

    /// Block until a push newer than generation `gen` lands, or
    /// `deadline` passes, or the queue closes (the latter two return
    /// `false` — the batcher's "stop waiting for more coalescible work"
    /// signal).  Because the check is against the push counter under the
    /// lock, a push that raced in between the caller's scan and this
    /// wait is seen immediately — no wakeup can be lost to the
    /// scan/wait window.
    pub fn wait_newer_until(&self, gen: u64, deadline: Instant) -> bool {
        let mut g = self.inner.lock().expect("queue poisoned");
        loop {
            if g.pushes != gen {
                return true;
            }
            if g.closed {
                return false;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            g = self
                .changed
                .wait_timeout(g, deadline - now)
                .expect("queue poisoned")
                .0;
        }
    }

    /// Stop admitting work; blocked consumers drain the remainder and
    /// then see `None`.
    pub fn close(&self) {
        self.inner.lock().expect("queue poisoned").closed = true;
        self.changed.notify_all();
    }

    /// Current depth (racy by nature; for metrics/backpressure probes).
    pub fn len(&self) -> usize {
        self.inner.lock().expect("queue poisoned").items.len()
    }

    /// `true` when no items are buffered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The fixed capacity bound.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// How many pushes have been shed for capacity so far.
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn fifo_and_capacity_shed() {
        let q: BoundedQueue<u32> = BoundedQueue::new(3);
        assert_eq!(q.capacity(), 3);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        q.try_push(3).unwrap();
        // bounded: the 4th is shed with the item handed back
        match q.try_push(4) {
            Err(PushError::Full(4)) => {}
            other => panic!("expected Full(4), got {other:?}"),
        }
        assert_eq!(q.shed_count(), 1);
        assert_eq!(q.len(), 3);
        assert_eq!(q.try_pop(), Some(1));
        assert_eq!(q.pop_wait(), Some(2));
        q.try_push(5).unwrap();
        assert_eq!(q.pop_wait(), Some(3));
        assert_eq!(q.pop_wait(), Some(5));
        assert!(q.is_empty());
    }

    #[test]
    fn pop_matching_preserves_order_of_rest() {
        let q: BoundedQueue<u32> = BoundedQueue::new(16);
        for x in [1, 10, 2, 11, 3, 12, 4] {
            q.try_push(x).unwrap();
        }
        let mut out = Vec::new();
        // take at most 2 of the small ones
        let n = q.pop_matching_into(|&x| x < 10, 2, &mut out);
        assert_eq!(n, 2);
        assert_eq!(out, vec![1, 2]);
        // the rest drain in their original relative order
        let mut rest = Vec::new();
        while let Some(x) = q.try_pop() {
            rest.push(x);
        }
        assert_eq!(rest, vec![10, 11, 3, 12, 4]);
    }

    #[test]
    fn close_rejects_pushes_and_drains() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        q.try_push(7).unwrap();
        q.close();
        match q.try_push(8) {
            Err(PushError::Closed(8)) => {}
            other => panic!("expected Closed(8), got {other:?}"),
        }
        // buffered work still drains, then shutdown is signalled
        assert_eq!(q.pop_wait(), Some(7));
        assert_eq!(q.pop_wait(), None);
        let gen = q.push_generation();
        assert!(!q.wait_newer_until(gen, Instant::now() + Duration::from_millis(5)));
    }

    #[test]
    fn pop_wait_blocks_until_push() {
        let q = std::sync::Arc::new(BoundedQueue::<u32>::new(2));
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.pop_wait());
        std::thread::sleep(Duration::from_millis(10));
        q.try_push(42).unwrap();
        assert_eq!(t.join().unwrap(), Some(42));
    }

    #[test]
    fn wait_newer_times_out_without_pushes() {
        let q: BoundedQueue<u32> = BoundedQueue::new(2);
        let gen = q.push_generation();
        let t0 = Instant::now();
        assert!(!q.wait_newer_until(gen, t0 + Duration::from_millis(10)));
        assert!(t0.elapsed() >= Duration::from_millis(5));
    }

    /// The scan-then-wait race: a push landing after the generation was
    /// read (but before the wait) is seen immediately — the wait must
    /// not sleep on an already-stale generation.
    #[test]
    fn wait_newer_sees_races_immediately() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        let gen = q.push_generation();
        q.try_push(1).unwrap(); // the "raced-in" push
        let t0 = Instant::now();
        assert!(q.wait_newer_until(gen, t0 + Duration::from_millis(200)));
        assert!(
            t0.elapsed() < Duration::from_millis(100),
            "stale generation must return without sleeping out the deadline"
        );
    }
}
