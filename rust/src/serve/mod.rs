//! Online inference: a dynamic micro-batching server over the batch-first
//! solver stack.
//!
//! The offline stack integrates mini-batches it is handed; serving
//! inverts the control flow — single-trajectory requests (`z₀`, a span,
//! an optional observation grid, a model name) arrive one at a time and
//! must come back as low-latency responses.  This layer closes that gap
//! with four pieces (DESIGN.md §10, ADR-002):
//!
//! * [`queue::BoundedQueue`] — the bounded MPSC front door: submissions
//!   past capacity are **shed** with an explicit error instead of
//!   buffered, so server memory stays bounded under overload;
//! * [`batcher`] — dynamic micro-batching: the next batch starts at the
//!   FIFO head and coalesces queued requests with the **same
//!   compatibility key** ([`CompatKey`]: model + solver + step mode +
//!   span + observation grid, floats compared by bit pattern) up to
//!   `max_batch` rows or until `max_wait` expires;
//! * [`worker::ServeWorker`] — one per thread, owning a warm
//!   [`BatchWorkspace`](crate::solvers::workspace::BatchWorkspace): the
//!   coalesced rows run through the per-sample-adaptive
//!   [`integrate_batch_obs_stats_ws`](crate::solvers::integrate::integrate_batch_obs_stats_ws)
//!   fast path, which is **decision-identical per row to a solo solve**
//!   — so a coalesced response is bitwise the same trajectory the
//!   request would have gotten alone (`tests/serve.rs` pins this), and a
//!   warmed serve loop performs **zero** heap allocations
//!   (`tests/alloc_serve.rs`);
//! * [`metrics::ServeMetrics`] — per-request queue-wait / service / total
//!   latency histograms plus batch-occupancy and throughput counters,
//!   emitted as the `util::bench`-style JSON that `mali serve-bench`
//!   (experiment E12) reports;
//! * [`transport`] — the network front door (DESIGN.md §11, ADR-006): a
//!   pure-std TCP listener speaking a length-prefixed binary protocol,
//!   bridged onto [`Server::submit_pooled`] through the transport-agnostic
//!   [`transport::Bridge`] trait so the workers never learn about
//!   sockets.  Request envelopes are pooled per connection and responses
//!   travel back through [`CompletionSink`], keeping the warmed
//!   read → submit → respond loop at zero heap allocations
//!   (`tests/alloc_serve.rs`);
//! * [`session`] — streaming online inference (DESIGN.md §12): long-lived
//!   sessions hold warm per-session solver state
//!   ([`ResumeState`](crate::solvers::integrate::ResumeState)) and
//!   integrate **incrementally** to each new irregular event, bitwise
//!   identical to a one-shot solve over the concatenated grid
//!   (`tests/session.rs`); the registry is **versioned** —
//!   [`ModelRegistry::hot_swap`] publishes copy-on-write θ snapshots
//!   without draining, while in-flight batches and open sessions keep the
//!   version they pinned at dispatch.
//!
//! # Example
//!
//! ```
//! use mali_ode::serve::{ModelRegistry, RequestClass, Server, ServerConfig};
//! use mali_ode::solvers::dynamics::LinearToy;
//! use mali_ode::solvers::integrate::{ObsGrid, StepMode};
//! use std::sync::Arc;
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut registry = ModelRegistry::new();
//! registry.register("toy", Box::new(LinearToy::new(-0.4, 2)));
//! let server = Server::start(Arc::new(registry), ServerConfig::default());
//!
//! // One compatibility class, shared by every request that may coalesce.
//! let class = Arc::new(RequestClass::new(
//!     "toy",
//!     "alf",
//!     2,
//!     0.0,
//!     1.0,
//!     StepMode::Fixed { h: 0.1 },
//!     ObsGrid::none(),
//! )?);
//!
//! let a = server.submit(&class, &[1.0, -0.5]).expect("admitted");
//! let b = server.submit(&class, &[0.3, 2.0]).expect("admitted");
//! let ra = a.wait()?;
//! let rb = b.wait()?;
//! assert_eq!(ra.z_final.len(), 2);
//! assert_eq!(rb.n_accepted, 10); // 1.0 / 0.1 fixed steps
//!
//! let metrics = server.shutdown();
//! assert_eq!(metrics.requests, 2);
//! # Ok(())
//! # }
//! ```

pub mod batcher;
pub mod metrics;
pub mod queue;
pub mod session;
pub mod transport;
pub mod worker;

pub use batcher::BatcherCfg;
pub use metrics::{LatencyHistogram, ServeMetrics};
pub use queue::{BoundedQueue, PushError};
pub use session::{SessionEntry, SessionTable};
pub use worker::ServeWorker;

use crate::solvers::dynamics::Dynamics;
use crate::solvers::integrate::{ObsGrid, StepMode};
use anyhow::{ensure, Result};
use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Compatibility classes
// ---------------------------------------------------------------------------

/// [`StepMode`] reduced to a hashable key (f64 parameters by bit
/// pattern): two requests may share a batch only when every controller
/// decision they would make alone is the same, which requires *exactly*
/// equal mode parameters.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum ModeKey {
    /// `StepMode::Fixed` with `h.to_bits()`.
    Fixed { h: u64 },
    /// `StepMode::Adaptive` with every tolerance/bound as bits.
    Adaptive {
        rtol: u64,
        atol: u64,
        h_init: u64,
        h_min: u64,
        h_max: u64,
    },
}

impl ModeKey {
    fn of(mode: &StepMode) -> ModeKey {
        match *mode {
            StepMode::Fixed { h } => ModeKey::Fixed { h: h.to_bits() },
            StepMode::Adaptive {
                rtol,
                atol,
                h_init,
                h_min,
                h_max,
            } => ModeKey::Adaptive {
                rtol: rtol.to_bits(),
                atol: atol.to_bits(),
                h_init: h_init.to_bits(),
                h_min: h_min.to_bits(),
                h_max: h_max.to_bits(),
            },
        }
    }
}

/// The coalescing gate: requests micro-batch together **iff** their keys
/// are equal.  Everything that feeds a controller decision or the
/// dynamics is in here — model, solver, state width, span endpoints,
/// step-mode parameters and the observation grid (floats by bit
/// pattern) — which is exactly the precondition under which the batched
/// loop is decision-identical to solo solves, making coalescing a pure
/// latency/throughput optimization with bitwise-unchanged results.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CompatKey {
    model: String,
    solver: String,
    n_z: usize,
    t0: u64,
    t1: u64,
    mode: ModeKey,
    grid: Vec<u64>,
}

/// A validated, immutable description of one coalescible request class.
/// Build once (wrapped in an [`Arc`]) and share it across every request
/// of that shape — submissions then cost no per-request validation or
/// grid copies.
#[derive(Debug)]
pub struct RequestClass {
    /// Registry name of the dynamics to integrate.
    pub model: String,
    /// Solver name (`solvers::by_name`).
    pub solver: String,
    /// Per-request state width `N_z`.
    pub n_z: usize,
    /// Span start.
    pub t0: f64,
    /// Span end.
    pub t1: f64,
    /// Step-size policy (shared verbatim by every coalesced row).
    pub mode: StepMode,
    /// Observation times whose states are returned per request
    /// (empty = endpoint only).
    pub grid: ObsGrid,
    key: CompatKey,
    /// Memoized `(registry tag, model id)` from the first successful
    /// [`ModelRegistry::resolve_cached`] — per-request model lookup then
    /// costs one tag compare instead of a string hash/walk.
    resolved: OnceLock<(u64, u32)>,
}

impl RequestClass {
    /// Validate and freeze a request class.  Rejects unknown solvers,
    /// non-finite spans, degenerate mode parameters and grids outside
    /// the open-closed span `(t0, t1]` — so per-request submission and
    /// the serve loop itself never re-validate.
    pub fn new(
        model: &str,
        solver: &str,
        n_z: usize,
        t0: f64,
        t1: f64,
        mode: StepMode,
        grid: ObsGrid,
    ) -> Result<RequestClass> {
        ensure!(n_z > 0, "request class needs n_z > 0");
        ensure!(
            t0.is_finite() && t1.is_finite(),
            "request span must be finite: {t0} → {t1}"
        );
        // constructing the solver validates the name; serving workers
        // build their own instances lazily
        let _ = crate::solvers::by_name(solver)?;
        match mode {
            StepMode::Fixed { h } => {
                ensure!(h.is_finite() && h > 0.0, "fixed step size must be positive, got {h}");
            }
            StepMode::Adaptive {
                rtol,
                atol,
                h_init,
                h_min,
                h_max,
            } => {
                ensure!(
                    rtol.is_finite() && rtol > 0.0 && atol.is_finite() && atol >= 0.0,
                    "adaptive tolerances must be positive/non-negative: rtol={rtol}, atol={atol}"
                );
                ensure!(
                    h_init.is_finite()
                        && h_min.is_finite()
                        && h_max.is_finite()
                        && h_init > 0.0
                        && h_min > 0.0
                        && h_max >= h_min,
                    "adaptive step bounds must be finite with 0 < h_min ≤ h_max, h_init > 0"
                );
            }
        }
        if !grid.is_empty() {
            ensure!(
                t0 != t1,
                "zero-span request class cannot reach observation times"
            );
            grid.validate_for(t0, t1)?;
        }
        let key = CompatKey {
            model: model.to_string(),
            solver: solver.to_string(),
            n_z,
            t0: t0.to_bits(),
            t1: t1.to_bits(),
            mode: ModeKey::of(&mode),
            grid: grid.times().iter().map(|t| t.to_bits()).collect(),
        };
        Ok(RequestClass {
            model: model.to_string(),
            solver: solver.to_string(),
            n_z,
            t0,
            t1,
            mode,
            grid,
            key,
            resolved: OnceLock::new(),
        })
    }

    /// The coalescing key (precomputed at construction).
    pub fn key(&self) -> &CompatKey {
        &self.key
    }
}

// ---------------------------------------------------------------------------
// Requests in flight
// ---------------------------------------------------------------------------

/// The result of one served request.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeResponse {
    /// State at `t1`, length `n_z`.
    pub z_final: Vec<f32>,
    /// `[K, n_z]` row-major states at the class's observation times
    /// (empty when the grid is empty).
    pub obs: Vec<f32>,
    /// Accepted solver steps of this trajectory.
    pub n_accepted: usize,
    /// Controller trials (accepted + rejected) of this trajectory.
    pub n_trials: usize,
    /// Seconds spent queued before batch formation.
    pub queue_wait_s: f64,
    /// Seconds of batched solve + response scatter (shared by the batch).
    pub service_s: f64,
}

/// One-shot rendezvous between a worker and a waiting client.
#[derive(Debug, Default)]
pub(crate) struct ResponseSlot {
    state: Mutex<Option<Result<ServeResponse, String>>>,
    cv: Condvar,
}

impl ResponseSlot {
    pub(crate) fn fulfill(&self, r: Result<ServeResponse, String>) {
        *self.state.lock().expect("slot poisoned") = Some(r);
        self.cv.notify_all();
    }
}

/// Client-side handle returned by [`Server::submit`]; block on
/// [`ResponseHandle::wait`] for the response.
#[derive(Debug)]
pub struct ResponseHandle(Arc<ResponseSlot>);

impl ResponseHandle {
    /// Block until the worker delivers this request's response (or its
    /// error).
    pub fn wait(self) -> Result<ServeResponse> {
        let mut g = self.0.state.lock().expect("slot poisoned");
        loop {
            if let Some(r) = g.take() {
                return r.map_err(|e| anyhow::anyhow!(e));
            }
            g = self.0.cv.wait(g).expect("slot poisoned");
        }
    }

    /// Non-blocking probe; `Some` exactly once, when the response has
    /// landed.
    pub fn try_wait(&self) -> Option<Result<ServeResponse>> {
        self.0
            .state
            .lock()
            .expect("slot poisoned")
            .take()
            .map(|r| r.map_err(|e| anyhow::anyhow!(e)))
    }

    /// Bounded wait: block up to `dur` for the response, `None` on
    /// timeout (the handle stays valid — call again or fall back to
    /// [`ResponseHandle::wait`]).  This is the building block bounded
    /// callers (the TCP transport's drain path among them) use instead
    /// of spinning on [`ResponseHandle::try_wait`].
    pub fn wait_timeout(&self, dur: Duration) -> Option<Result<ServeResponse>> {
        let deadline = Instant::now() + dur;
        let mut g = self.0.state.lock().expect("slot poisoned");
        loop {
            if let Some(r) = g.take() {
                return Some(r.map_err(|e| anyhow::anyhow!(e)));
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            g = self
                .0
                .cv
                .wait_timeout(g, deadline - now)
                .expect("slot poisoned")
                .0;
        }
    }
}

/// A completed request on its way back to a transport: either the
/// envelope with its output buffers filled, or the envelope plus the
/// reason serving it failed.  Both variants return the [`Pending`] so
/// its buffers can be recycled into a connection pool.
pub enum Completion {
    /// Served: `z_final` / `obs` / step counters / timings are filled.
    Ok(Pending),
    /// Failed (solver error, panic isolation, shutdown): the buffers
    /// are unspecified but reusable after [`Pending::reset`].
    Failed(Pending, String),
}

impl fmt::Debug for Completion {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Completion::Ok(p) => write!(f, "Completion::Ok(req_id={})", p.req_id),
            Completion::Failed(p, e) => {
                write!(f, "Completion::Failed(req_id={}, {e:?})", p.req_id)
            }
        }
    }
}

/// Where a finished request goes when nobody is blocked on a
/// [`ResponseHandle`]: transports implement this (one sink per
/// connection) and get the whole envelope back — buffers included — so
/// the response write and the envelope recycling both happen without
/// allocation.  Must be cheap and non-blocking-ish: workers call it
/// inline from the serve loop.
pub trait CompletionSink: Send + Sync {
    /// Deliver one finished envelope (called from a worker thread).
    fn complete(&self, done: Completion);
}

/// How a finished [`Pending`] is delivered.
#[derive(Default)]
pub enum Delivery {
    /// Direct drive: the caller holds the envelope slice and reads the
    /// output buffers itself (tests, benches).
    #[default]
    None,
    /// In-process rendezvous ([`Server::submit`]): the worker copies the
    /// outputs into a [`ServeResponse`] and fulfills the slot.
    Slot(Arc<ResponseSlot>),
    /// Transport delivery ([`Server::submit_pooled`]): the worker moves
    /// the envelope itself into the sink.
    Sink(Arc<dyn CompletionSink>),
}

impl fmt::Debug for Delivery {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Delivery::None => "None",
            Delivery::Slot(_) => "Slot(..)",
            Delivery::Sink(_) => "Sink(..)",
        })
    }
}

/// A queued request: the class handle, the initial state, preallocated
/// response buffers (the worker writes results in place, so the serve
/// loop itself allocates nothing) and delivery bookkeeping.
#[derive(Debug)]
pub struct Pending {
    /// Shared class (step config + grid + key).
    pub class: Arc<RequestClass>,
    /// Initial state row, length `n_z`.
    pub z0: Vec<f32>,
    /// Output: state at `t1` (length `n_z`).
    pub z_final: Vec<f32>,
    /// Output: `[K, n_z]` observation states.
    pub obs: Vec<f32>,
    /// Output: accepted steps of this row.
    pub n_accepted: usize,
    /// Output: controller trials of this row.
    pub n_trials: usize,
    /// Caller correlation id (the transport's pipelining key; echoed
    /// back verbatim, unused by in-process delivery).
    pub req_id: u64,
    /// `0` for one-shot requests; a [`session::SessionTable`] id for an
    /// incremental session step.  A non-zero id is a batcher coalescing
    /// barrier — session steps always run solo (they are sequentially
    /// dependent on the session's carried state).
    pub session_id: u64,
    /// Event times of a session step (empty for one-shot requests): the
    /// advance integrates to each and snapshots the state there, into
    /// `obs` rows `[k, n_z]`; `z_final` receives the state at the last
    /// event.  Pooled like the other buffers.
    pub times: Vec<f64>,
    /// Raw [`ModelId`] for transport quota bookkeeping (set at submit by
    /// the connection; meaningless for in-process submissions).
    pub(crate) model_raw: u32,
    /// Output: seconds spent queued before batch formation.
    pub queue_wait_s: f64,
    /// Output: seconds of batched solve + scatter (shared by the batch).
    pub service_s: f64,
    /// Submission timestamp (queue-wait accounting).
    pub enqueued: Instant,
    /// Response routing; [`Delivery::None`] when the caller drives a
    /// worker synchronously (tests, benches) and reads the buffers
    /// directly.
    pub(crate) delivery: Delivery,
}

impl Pending {
    /// A request with freshly sized response buffers and no delivery
    /// route (direct-drive shape; [`Server::submit`] attaches a slot,
    /// transports attach a sink via [`Pending::set_sink`]).
    pub fn new(class: Arc<RequestClass>, z0: Vec<f32>) -> Pending {
        let n_z = class.n_z;
        let k = class.grid.len();
        Pending {
            z0,
            z_final: vec![0.0; n_z],
            obs: vec![0.0; k * n_z],
            n_accepted: 0,
            n_trials: 0,
            req_id: 0,
            session_id: 0,
            times: Vec::new(),
            model_raw: 0,
            queue_wait_s: 0.0,
            service_s: 0.0,
            enqueued: Instant::now(),
            delivery: Delivery::None,
            class,
        }
    }

    /// Route this envelope's completion through `sink` (transport
    /// delivery; see [`CompletionSink`]).  An `Arc` clone is refcount
    /// traffic only — attaching a sink allocates nothing.
    pub fn set_sink(&mut self, sink: Arc<dyn CompletionSink>) {
        self.delivery = Delivery::Sink(sink);
    }

    /// Re-arm a recycled request with a new initial state — buffers,
    /// class, id and delivery are kept, so direct-drive loops (and their
    /// allocation accounting) reuse one set of envelopes.
    pub fn reset(&mut self, z0: &[f32]) {
        self.z0.copy_from_slice(z0);
        self.rearm(self.req_id);
    }

    /// Re-arm counters/timing for reuse under a new correlation id; the
    /// transport decodes the next frame's `z0` directly into the kept
    /// buffer, so unlike [`Pending::reset`] no state copy happens here.
    /// Session routing is cleared (the session path re-stamps it after
    /// re-arming) so a pooled envelope can alternate between one-shot and
    /// session traffic.
    pub fn rearm(&mut self, req_id: u64) {
        self.req_id = req_id;
        self.n_accepted = 0;
        self.n_trials = 0;
        self.session_id = 0;
        self.times.clear();
        self.queue_wait_s = 0.0;
        self.service_s = 0.0;
        self.enqueued = Instant::now();
    }

    /// A no-allocation placeholder (empty buffers, cheap class clone)
    /// that workers swap into a batch slot to move the real envelope out
    /// of `&mut [Pending]` for sink delivery.
    pub(crate) fn husk(class: Arc<RequestClass>) -> Pending {
        Pending {
            z0: Vec::new(),
            z_final: Vec::new(),
            obs: Vec::new(),
            n_accepted: 0,
            n_trials: 0,
            req_id: 0,
            session_id: 0,
            times: Vec::new(),
            model_raw: 0,
            queue_wait_s: 0.0,
            service_s: 0.0,
            enqueued: Instant::now(),
            delivery: Delivery::None,
            class,
        }
    }

    /// Route a failure to whoever is waiting on this envelope (no-op
    /// for direct drive — the caller sees the error elsewhere).
    pub(crate) fn fail(mut self, msg: &str) {
        match std::mem::take(&mut self.delivery) {
            Delivery::None => {}
            Delivery::Slot(slot) => slot.fulfill(Err(msg.to_string())),
            Delivery::Sink(sink) => sink.complete(Completion::Failed(self, msg.to_string())),
        }
    }
}

// ---------------------------------------------------------------------------
// Model registry
// ---------------------------------------------------------------------------

/// A registry-issued dense model id: the per-request lookup key after a
/// name has been interned once ([`ModelRegistry::resolve`]).  Ids are
/// stable for the registry's lifetime — re-registering a name keeps its
/// id — so transports intern at handshake and never hash a model string
/// on the request path again.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ModelId(pub(crate) u32);

impl ModelId {
    /// The raw dense index (wire representation in the TCP protocol).
    pub fn raw(self) -> u32 {
        self.0
    }
}

/// Registry identity tags: each registry instance gets a unique tag so
/// a [`ModelId`] (or a [`RequestClass`]'s memoized resolution) can never
/// be replayed against a different registry that happens to reuse the
/// same address.
static REGISTRY_TAG: AtomicU64 = AtomicU64::new(1);

/// One immutable published version of a model: the dynamics plus a
/// monotone version number.  Workers pin a version per batch
/// ([`ModelRegistry::snapshot`]) and sessions pin one at open — an
/// `Arc<ModelVersion>` held across a solve is the **version-pinning
/// rule**: [`ModelRegistry::hot_swap`] can publish new parameters at any
/// time without changing the θ an already-dispatched batch sees.
pub struct ModelVersion {
    /// Monotone per-slot version (1 for the initially registered model).
    version: u64,
    dynamics: Box<dyn Dynamics + Send + Sync>,
}

impl ModelVersion {
    /// The monotone version number of this snapshot.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// The pinned dynamics.
    pub fn dynamics(&self) -> &(dyn Dynamics + Send + Sync) {
        self.dynamics.as_ref()
    }
}

impl std::ops::Deref for ModelVersion {
    type Target = dyn Dynamics + Send + Sync;

    fn deref(&self) -> &Self::Target {
        self.dynamics.as_ref()
    }
}

impl fmt::Debug for ModelVersion {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ModelVersion")
            .field("version", &self.version)
            .finish_non_exhaustive()
    }
}

/// One registered name: the current published version plus the retired
/// versions still pinned by in-flight work.
struct ModelSlot {
    name: String,
    /// The version new pins get; swapped wholesale by
    /// [`ModelRegistry::hot_swap`] (copy-on-write, never in place).
    current: Mutex<Arc<ModelVersion>>,
    /// Retired versions still referenced by in-flight batches/sessions —
    /// kept so [`ModelRegistry::total_f_evals`] stays exact and monotone
    /// while old-θ work drains.  Pruned inside `hot_swap` once the last
    /// pin drops, so growth is bounded by concurrently in-flight work.
    retired: Mutex<Vec<Arc<ModelVersion>>>,
    /// `f`-eval counts of fully-drained retired versions, folded in at
    /// prune time.
    retired_f: AtomicU64,
}

/// Name → dynamics table the workers serve from.  Names are interned:
/// [`ModelRegistry::resolve`] turns a name into a dense [`ModelId`] once
/// (handshake / class construction) and [`ModelRegistry::snapshot`] is
/// then an index + `Arc` clone — no per-request string hashing.
///
/// The registry is **versioned**: each name holds a current
/// [`ModelVersion`] behind copy-on-write.  Serving pins a version per
/// batch (and per session); [`ModelRegistry::hot_swap`] clones the
/// current dynamics ([`Dynamics::clone_box`]), installs new parameters
/// on the clone and publishes it as `version + 1` — in-flight work keeps
/// the version it pinned, so parameter updates never block or corrupt
/// inference traffic (ADR-007).
pub struct ModelRegistry {
    /// Dense id → slot; ids are indices, never reused.
    models: Vec<ModelSlot>,
    /// Name → dense id (interning map; touched at registration and
    /// handshake only).
    index: BTreeMap<String, u32>,
    /// Unique instance tag (see [`REGISTRY_TAG`]).
    tag: u64,
}

impl Default for ModelRegistry {
    fn default() -> ModelRegistry {
        ModelRegistry {
            models: Vec::new(),
            index: BTreeMap::new(),
            tag: REGISTRY_TAG.fetch_add(1, Ordering::Relaxed),
        }
    }
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Register `dynamics` under `name`.  Replacing an existing name
    /// keeps its [`ModelId`] (ids are stable) and bumps the slot's
    /// version; a new name gets the next dense id at version 1.
    pub fn register(&mut self, name: &str, dynamics: Box<dyn Dynamics + Send + Sync>) {
        match self.index.get(name) {
            Some(&id) => {
                let slot = &mut self.models[id as usize];
                let current = slot.current.get_mut().expect("registry poisoned");
                let version = current.version + 1;
                let old = std::mem::replace(current, Arc::new(ModelVersion { version, dynamics }));
                Self::retire(slot, old);
            }
            None => {
                let id = u32::try_from(self.models.len()).expect("registry overflow");
                self.models.push(ModelSlot {
                    name: name.to_string(),
                    current: Mutex::new(Arc::new(ModelVersion { version: 1, dynamics })),
                    retired: Mutex::new(Vec::new()),
                    retired_f: AtomicU64::new(0),
                });
                self.index.insert(name.to_string(), id);
            }
        }
    }

    /// Park a replaced version on the slot's retired list and prune every
    /// retired version whose last pin has dropped (folding its counters
    /// into the slot base, keeping [`ModelRegistry::total_f_evals`]
    /// exact and monotone).
    fn retire(slot: &ModelSlot, old: Arc<ModelVersion>) {
        let mut retired = slot.retired.lock().expect("registry poisoned");
        retired.push(old);
        retired.retain(|r| {
            if Arc::strong_count(r) == 1 {
                slot.retired_f
                    .fetch_add(r.dynamics.counters().f_evals.get(), Ordering::Relaxed);
                false
            } else {
                true
            }
        });
    }

    /// Publish new parameters for `name` without draining: clone the
    /// current version ([`Dynamics::clone_box`]), install `params` on the
    /// clone, and swap it in as the next version.  In-flight batches and
    /// open sessions keep the version they pinned — the swap changes only
    /// what *future* pins see.  Returns the new version number.
    ///
    /// Fails for unknown names, models without a host-side clone
    /// (`clone_box() == None`), and parameter-length mismatches.
    pub fn hot_swap(&self, name: &str, params: &[f32]) -> Result<u64> {
        let Some(id) = self.resolve(name) else {
            anyhow::bail!("unknown model '{name}' (registered: {:?})", self.names());
        };
        let slot = &self.models[id.0 as usize];
        let mut current = slot.current.lock().expect("registry poisoned");
        ensure!(
            params.len() == current.dynamics.param_dim(),
            "hot_swap('{name}'): got {} parameters, model has param_dim {}",
            params.len(),
            current.dynamics.param_dim()
        );
        let Some(mut fresh) = current.dynamics.clone_box() else {
            anyhow::bail!(
                "model '{name}' is not hot-swappable (no host-side clone); \
                 re-register it instead"
            );
        };
        fresh.set_params(params);
        let version = current.version + 1;
        let old = std::mem::replace(
            &mut *current,
            Arc::new(ModelVersion {
                version,
                dynamics: fresh,
            }),
        );
        Self::retire(slot, old);
        Ok(version)
    }

    /// Intern a model name: the one string lookup, done at handshake or
    /// class-construction time.  Everything after uses the returned id.
    pub fn resolve(&self, name: &str) -> Option<ModelId> {
        self.index.get(name).copied().map(ModelId)
    }

    /// Look up the current version by name (one-shot convenience; request
    /// paths should [`ModelRegistry::resolve`] once and use
    /// [`ModelRegistry::snapshot`]).
    pub fn get(&self, name: &str) -> Option<Arc<ModelVersion>> {
        self.resolve(name).and_then(|id| self.snapshot(id))
    }

    /// Pin the current version of a model: a bounds-checked `Vec` index
    /// plus an `Arc` clone, the per-batch fast path.  The returned
    /// snapshot's θ never changes — see [`ModelRegistry::hot_swap`].
    /// `None` only for an id minted by a *different* registry (larger
    /// than this one's table).
    pub fn snapshot(&self, id: ModelId) -> Option<Arc<ModelVersion>> {
        self.models
            .get(id.0 as usize)
            .map(|slot| slot.current.lock().expect("registry poisoned").clone())
    }

    /// The current version number of a model.
    pub fn version_of(&self, id: ModelId) -> Option<u64> {
        self.models
            .get(id.0 as usize)
            .map(|slot| slot.current.lock().expect("registry poisoned").version)
    }

    /// The name an id was interned from.
    pub fn name_of(&self, id: ModelId) -> Option<&str> {
        self.models.get(id.0 as usize).map(|s| s.name.as_str())
    }

    /// Resolve `class.model` against this registry, memoizing the id on
    /// the class.  First call per (class, registry) walks the name
    /// index; every later call is one tag compare.  A class resolved
    /// against a different registry falls back to the string lookup
    /// (correct, just not memoized) — the memo is written once, tagged
    /// with this registry's unique [`REGISTRY_TAG`] identity.
    pub fn resolve_cached(&self, class: &RequestClass) -> Option<ModelId> {
        if let Some(&(tag, id)) = class.resolved.get() {
            if tag == self.tag {
                return Some(ModelId(id));
            }
            return self.resolve(&class.model);
        }
        let id = self.resolve(&class.model)?;
        let _ = class.resolved.set((self.tag, id.0));
        Some(id)
    }

    /// Registered model names, sorted.
    pub fn names(&self) -> Vec<&str> {
        self.index.keys().map(String::as_str).collect()
    }

    /// Number of registered models (== the id space: ids are `0..len`).
    pub fn len(&self) -> usize {
        self.models.len()
    }

    /// True when nothing is registered.
    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// Sum of the `f`-evaluation counters across every registered model
    /// (per-sample units), **including** retired versions — folded bases
    /// for drained versions, live counters for versions still pinned by
    /// in-flight work — so the total stays exact and monotone across
    /// [`ModelRegistry::hot_swap`].  A snapshot pair around a serving
    /// window gives the exact evaluation count even when several workers
    /// hit the same model concurrently.
    pub fn total_f_evals(&self) -> u64 {
        self.models
            .iter()
            .map(|slot| {
                let mut sum = slot.retired_f.load(Ordering::Relaxed);
                sum += slot
                    .current
                    .lock()
                    .expect("registry poisoned")
                    .dynamics
                    .counters()
                    .f_evals
                    .get();
                for r in slot.retired.lock().expect("registry poisoned").iter() {
                    sum += r.dynamics.counters().f_evals.get();
                }
                sum
            })
            .sum()
    }
}

// ---------------------------------------------------------------------------
// The server
// ---------------------------------------------------------------------------

/// Why [`Server::submit`] refused a request.
#[derive(Debug)]
pub enum SubmitError {
    /// The request queue held `capacity` entries — the request was shed.
    /// Back off and retry, or fail upstream; the server's memory stays
    /// bounded either way.
    Overloaded {
        /// The queue bound that was hit.
        capacity: usize,
    },
    /// The server is shutting down.
    Closed,
    /// The request is malformed (wrong `z0` width, unknown model).
    BadRequest(String),
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Overloaded { capacity } => {
                write!(f, "request shed: queue at capacity ({capacity})")
            }
            SubmitError::Closed => write!(f, "server is shutting down"),
            SubmitError::BadRequest(msg) => write!(f, "bad request: {msg}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Knobs of one [`Server`].
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bounded queue depth; submissions beyond it are shed.
    pub queue_capacity: usize,
    /// Largest micro-batch a worker executes.
    pub max_batch: usize,
    /// How long a forming batch waits for more coalescible requests
    /// after the head arrives.  `0` coalesces only what is already
    /// queued (no added latency); larger values trade head latency for
    /// occupancy.
    pub max_wait: Duration,
    /// Worker threads.  `0` starts a paused server (nothing drains —
    /// the overload/saturation tests and external drivers use this).
    pub workers: usize,
    /// Intra-batch shards per worker: each micro-batch is split into
    /// this many contiguous row ranges integrated concurrently (bitwise
    /// the same results — `tests/shard_equivalence.rs`).  `0` defers to
    /// `MALI_SHARDS` (default 1, i.e. unsharded).
    pub shards: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            queue_capacity: 1024,
            max_batch: 32,
            max_wait: Duration::from_micros(500),
            workers: crate::util::pool::num_threads().min(4),
            shards: 0,
        }
    }
}

/// The online inference server: a bounded queue feeding worker threads
/// that micro-batch compatible requests through warm batch workspaces.
/// See the module docs for the architecture and a usage example.
pub struct Server {
    queue: Arc<BoundedQueue<Pending>>,
    registry: Arc<ModelRegistry>,
    sessions: Arc<SessionTable>,
    workers: Vec<JoinHandle<ServeMetrics>>,
    cfg: ServerConfig,
    /// Registry-wide `f`-eval counter total at startup; shutdown reports
    /// the exact serving-window delta against it.
    f_evals_at_start: u64,
}

impl Server {
    /// Spawn `cfg.workers` serving threads over `registry` and return
    /// the handle requests are submitted through.
    pub fn start(registry: Arc<ModelRegistry>, cfg: ServerConfig) -> Server {
        let queue = Arc::new(BoundedQueue::new(cfg.queue_capacity));
        let sessions = Arc::new(SessionTable::new());
        let bcfg = BatcherCfg {
            max_batch: cfg.max_batch.max(1),
            max_wait: cfg.max_wait,
        };
        let shards = if cfg.shards == 0 {
            worker::shards_from_env()
        } else {
            cfg.shards
        };
        let workers = (0..cfg.workers)
            .map(|i| {
                let queue = queue.clone();
                let registry = registry.clone();
                let sessions = sessions.clone();
                let bcfg = bcfg.clone();
                std::thread::Builder::new()
                    .name(format!("serve-worker-{i}"))
                    .spawn(move || worker::worker_loop(&queue, &registry, &sessions, &bcfg, shards))
                    .expect("spawn serve worker")
            })
            .collect();
        let f_evals_at_start = registry.total_f_evals();
        Server {
            queue,
            registry,
            sessions,
            workers,
            cfg,
            f_evals_at_start,
        }
    }

    /// Submit one request.  Fails fast — [`SubmitError::Overloaded`] is
    /// the backpressure signal — and otherwise returns a handle the
    /// caller blocks on.
    pub fn submit(
        &self,
        class: &Arc<RequestClass>,
        z0: &[f32],
    ) -> Result<ResponseHandle, SubmitError> {
        let slot = Arc::new(ResponseSlot::default());
        let mut pending = Pending::new(class.clone(), z0.to_vec());
        pending.delivery = Delivery::Slot(slot.clone());
        match self.submit_pooled(pending) {
            Ok(()) => Ok(ResponseHandle(slot)),
            Err((e, _)) => Err(e),
        }
    }

    /// Submit a caller-owned (pooled) envelope: the transport fast path.
    /// Validation and admission are identical to [`Server::submit`], but
    /// nothing is allocated on the admit path and a refused envelope
    /// comes back with the error so its buffers return to the pool
    /// (error *messages* allocate — refusal is not the steady state).
    /// Delivery follows `pending.delivery`; the queue-wait clock is
    /// restamped here.
    pub fn submit_pooled(&self, mut pending: Pending) -> Result<(), (SubmitError, Pending)> {
        if pending.session_id != 0 {
            return self.submit_session_pooled(pending);
        }
        let class = &pending.class;
        if pending.z0.len() != class.n_z {
            let e = SubmitError::BadRequest(format!(
                "z0 has {} elements, class expects n_z = {}",
                pending.z0.len(),
                class.n_z
            ));
            return Err((e, pending));
        }
        // a NaN/Inf row would not error — it would crawl (NaN error
        // norms reject down to h_min, then accept ~(span/h_min) steps),
        // stalling every innocently coalesced neighbor; reject it here
        if pending.z0.iter().any(|v| !v.is_finite()) {
            let e = SubmitError::BadRequest("z0 contains non-finite components".to_string());
            return Err((e, pending));
        }
        // interned lookup: one tag compare once the class has been
        // resolved against this registry (no string hashing per request)
        let Some(model) = self
            .registry
            .resolve_cached(class)
            .and_then(|id| self.registry.snapshot(id))
        else {
            let e = SubmitError::BadRequest(format!(
                "unknown model '{}' (registered: {:?})",
                class.model,
                self.registry.names()
            ));
            return Err((e, pending));
        };
        // reject width/shape mismatches here, as a clean BadRequest,
        // instead of letting them blow up inside a worker's solve
        if model.is_device_batched() {
            let e = SubmitError::BadRequest(format!(
                "model '{}' is device-batched (a fixed [B, n_z] is baked into its \
                 executable) and cannot be dynamically micro-batched",
                class.model
            ));
            return Err((e, pending));
        }
        if model.dim() != class.n_z {
            let e = SubmitError::BadRequest(format!(
                "model '{}' has state width {}, request class expects n_z = {}",
                class.model,
                model.dim(),
                class.n_z
            ));
            return Err((e, pending));
        }
        pending.enqueued = Instant::now();
        match self.queue.try_push(pending) {
            Ok(()) => Ok(()),
            Err(PushError::Full(p)) => Err((
                SubmitError::Overloaded {
                    capacity: self.queue.capacity(),
                },
                p,
            )),
            Err(PushError::Closed(p)) => Err((SubmitError::Closed, p)),
        }
    }

    /// Admission for a session step envelope (`session_id != 0`): the
    /// session must be live and idle.  z0 is ignored — the worker
    /// integrates from the session's carried state — so the one-shot z0
    /// shape checks do not apply; `times` carries the event grid instead.
    fn submit_session_pooled(&self, mut pending: Pending) -> Result<(), (SubmitError, Pending)> {
        let Some(entry) = self.sessions.entry(pending.session_id) else {
            let e = SubmitError::BadRequest(format!(
                "unknown session id {}",
                pending.session_id
            ));
            return Err((e, pending));
        };
        if pending.times.is_empty() {
            let e = SubmitError::BadRequest("session step carries no event times".to_string());
            return Err((e, pending));
        }
        if pending.times.iter().any(|t| !t.is_finite()) {
            let e = SubmitError::BadRequest(
                "session step times contain non-finite values".to_string(),
            );
            return Err((e, pending));
        }
        // One outstanding step per session: steps are sequentially
        // dependent, so a concurrent second step is a protocol error —
        // refused as BadRequest, not Overloaded, to keep shed accounting
        // exact (nothing was admitted then dropped).
        if entry
            .busy
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            let e = SubmitError::BadRequest(format!(
                "session {} already has a step in flight",
                pending.session_id
            ));
            return Err((e, pending));
        }
        pending.enqueued = Instant::now();
        match self.queue.try_push(pending) {
            Ok(()) => Ok(()),
            Err(PushError::Full(p)) => {
                entry.busy.store(false, Ordering::Release);
                Err((
                    SubmitError::Overloaded {
                        capacity: self.queue.capacity(),
                    },
                    p,
                ))
            }
            Err(PushError::Closed(p)) => {
                entry.busy.store(false, Ordering::Release);
                Err((SubmitError::Closed, p))
            }
        }
    }

    /// Open a streaming session (see [`session`]): pins the current
    /// version of `model` and seeds the carried state at `(t0, z0)`.
    pub fn open_session(
        &self,
        model: &str,
        solver: &str,
        n_z: usize,
        t0: f64,
        mode: StepMode,
        z0: &[f32],
    ) -> Result<u64, SubmitError> {
        self.sessions
            .open(&self.registry, model, solver, n_z, t0, mode, z0)
    }

    /// Advance a session through `times` (strictly monotone event times;
    /// the first may coincide with the session's current barrier).  The
    /// response carries one observation row per event plus the final
    /// state, exactly as a one-shot request with that grid would.
    pub fn session_step(&self, sid: u64, times: &[f64]) -> Result<ResponseHandle, SubmitError> {
        let Some(class) = self.sessions.class_of(sid) else {
            return Err(SubmitError::BadRequest(format!("unknown session id {sid}")));
        };
        let slot = Arc::new(ResponseSlot::default());
        let mut pending = Pending::new(class, Vec::new());
        pending.session_id = sid;
        pending.times.extend_from_slice(times);
        pending.delivery = Delivery::Slot(slot.clone());
        match self.submit_pooled(pending) {
            Ok(()) => Ok(ResponseHandle(slot)),
            Err((e, _)) => Err(e),
        }
    }

    /// Close a session (idempotent).  A step already in flight completes
    /// normally — the worker holds its own reference — after which the
    /// warm state and the pinned model version drop.
    pub fn close_session(&self, sid: u64) -> bool {
        self.sessions.close(sid)
    }

    /// Live session count.
    pub fn session_count(&self) -> usize {
        self.sessions.len()
    }

    /// The shared session table (transports hold this to open/close
    /// sessions on behalf of connections).
    pub fn sessions(&self) -> &Arc<SessionTable> {
        &self.sessions
    }

    /// The model registry this server serves from (transports intern
    /// names against it at handshake).
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Current queue depth (racy; a load-generator backpressure probe).
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Requests shed at the queue so far.
    pub fn shed_count(&self) -> u64 {
        self.queue.shed_count()
    }

    /// The configuration this server was started with.
    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// Stop admitting work, let the workers drain the queue, and return
    /// the merged serving metrics (shed count folded in).  Requests
    /// still queued on a paused (`workers: 0`) server are failed with an
    /// explicit shutdown error so no waiter blocks forever.
    pub fn shutdown(self) -> ServeMetrics {
        self.queue.close();
        let mut metrics = ServeMetrics::new();
        for h in self.workers {
            match h.join() {
                Ok(m) => metrics.merge(&m),
                Err(_) => metrics.failed += 1,
            }
        }
        // only reachable with workers == 0 (workers drain before exit)
        while let Some(p) = self.queue.try_pop() {
            p.fail("server shut down before the request was served");
            metrics.failed += 1;
        }
        // Per-worker f_evals are counter deltas around each batch, which
        // interleave when workers share a model; replace the merged sum
        // with the exact registry-wide serving-window delta.
        metrics.f_evals = self
            .registry
            .total_f_evals()
            .saturating_sub(self.f_evals_at_start);
        // sheds never reach a worker; fold in the queue's counter
        metrics.shed = self.queue.shed_count();
        metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_class(mode: StepMode, grid: ObsGrid) -> RequestClass {
        RequestClass::new("toy", "alf", 3, 0.0, 1.0, mode, grid).unwrap()
    }

    #[test]
    fn class_validation_rejects_nonsense() {
        assert!(RequestClass::new("m", "alf", 0, 0.0, 1.0, StepMode::Fixed { h: 0.1 }, ObsGrid::none()).is_err(), "n_z = 0");
        assert!(RequestClass::new("m", "nope", 2, 0.0, 1.0, StepMode::Fixed { h: 0.1 }, ObsGrid::none()).is_err(), "unknown solver");
        assert!(RequestClass::new("m", "alf", 2, 0.0, 1.0, StepMode::Fixed { h: 0.0 }, ObsGrid::none()).is_err(), "h = 0");
        assert!(RequestClass::new("m", "alf", 2, 0.0, f64::NAN, StepMode::Fixed { h: 0.1 }, ObsGrid::none()).is_err(), "NaN span");
        let g = ObsGrid::new(vec![2.0]).unwrap();
        assert!(RequestClass::new("m", "alf", 2, 0.0, 1.0, StepMode::Fixed { h: 0.1 }, g).is_err(), "obs beyond t1");
        assert!(RequestClass::new("m", "alf", 2, 0.0, 1.0, StepMode::adaptive(-1.0, 1e-6), ObsGrid::none()).is_err(), "rtol < 0");
        let inf_bounds = StepMode::Adaptive {
            rtol: 1e-4,
            atol: 1e-6,
            h_init: f64::INFINITY,
            h_min: 1e-6,
            h_max: f64::INFINITY,
        };
        assert!(RequestClass::new("m", "alf", 2, 0.0, 1.0, inf_bounds, ObsGrid::none()).is_err(), "infinite step bounds");
    }

    #[test]
    fn compat_keys_gate_on_every_parameter() {
        let base = toy_class(StepMode::Fixed { h: 0.1 }, ObsGrid::none());
        let same = toy_class(StepMode::Fixed { h: 0.1 }, ObsGrid::none());
        assert_eq!(base.key(), same.key());
        let other_h = toy_class(StepMode::Fixed { h: 0.05 }, ObsGrid::none());
        assert_ne!(base.key(), other_h.key());
        let other_mode = toy_class(StepMode::adaptive(1e-4, 1e-6), ObsGrid::none());
        assert_ne!(base.key(), other_mode.key());
        let with_grid = toy_class(
            StepMode::Fixed { h: 0.1 },
            ObsGrid::new(vec![0.5, 1.0]).unwrap(),
        );
        assert_ne!(base.key(), with_grid.key());
        let other_solver =
            RequestClass::new("toy", "dopri5", 3, 0.0, 1.0, StepMode::Fixed { h: 0.1 }, ObsGrid::none())
                .unwrap();
        assert_ne!(base.key(), other_solver.key());
        let other_span =
            RequestClass::new("toy", "alf", 3, 0.0, 2.0, StepMode::Fixed { h: 0.1 }, ObsGrid::none())
                .unwrap();
        assert_ne!(base.key(), other_span.key());
    }

    #[test]
    fn pending_buffers_sized_from_class() {
        let class = Arc::new(toy_class(
            StepMode::Fixed { h: 0.1 },
            ObsGrid::new(vec![0.5, 1.0]).unwrap(),
        ));
        let p = Pending::new(class, vec![1.0, 2.0, 3.0]);
        assert_eq!(p.z_final.len(), 3);
        assert_eq!(p.obs.len(), 2 * 3);
        assert!(matches!(p.delivery, Delivery::None));
    }

    #[test]
    fn registry_lookup() {
        use crate::solvers::dynamics::LinearToy;
        let mut reg = ModelRegistry::new();
        reg.register("toy", Box::new(LinearToy::new(-0.3, 3)));
        assert!(reg.get("toy").is_some());
        assert!(reg.get("absent").is_none());
        assert_eq!(reg.names(), vec!["toy"]);
        assert_eq!(reg.get("toy").unwrap().dim(), 3);
    }

    #[test]
    fn registry_interning_ids_are_stable() {
        use crate::solvers::dynamics::LinearToy;
        let mut reg = ModelRegistry::new();
        reg.register("a", Box::new(LinearToy::new(-0.3, 3)));
        reg.register("b", Box::new(LinearToy::new(-0.3, 4)));
        let ida = reg.resolve("a").unwrap();
        let idb = reg.resolve("b").unwrap();
        assert_ne!(ida, idb);
        assert!(reg.resolve("absent").is_none());
        assert_eq!(reg.snapshot(ida).unwrap().dim(), 3);
        assert_eq!(reg.name_of(idb), Some("b"));
        // replacing a name keeps its id and bumps the version; ids from
        // elsewhere miss cleanly
        assert_eq!(reg.version_of(ida), Some(1));
        reg.register("a", Box::new(LinearToy::new(-0.3, 7)));
        assert_eq!(reg.resolve("a").unwrap(), ida);
        assert_eq!(reg.snapshot(ida).unwrap().dim(), 7);
        assert_eq!(reg.version_of(ida), Some(2));
        assert!(reg.snapshot(ModelId(99)).is_none());
        assert_eq!(reg.names(), vec!["a", "b"]);
    }

    #[test]
    fn hot_swap_pins_inflight_snapshots_and_bumps_version() {
        use crate::solvers::dynamics::MlpDynamics;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(7);
        let mut reg = ModelRegistry::new();
        reg.register("mlp", Box::new(MlpDynamics::new(3, 4, &mut rng)));
        let id = reg.resolve("mlp").unwrap();
        let pinned = reg.snapshot(id).unwrap();
        assert_eq!(pinned.version(), 1);
        let theta_before = pinned.params().to_vec();

        // publish new parameters while `pinned` is still held
        let new_theta = vec![0.125_f32; pinned.param_dim()];
        let v = reg.hot_swap("mlp", &new_theta).expect("swap succeeds");
        assert_eq!(v, 2);
        assert_eq!(reg.version_of(id), Some(2));

        // the in-flight snapshot still sees the θ it started with...
        assert_eq!(pinned.params(), &theta_before[..], "pinned θ unchanged by hot_swap");
        // ...while new lookups see the published version
        let fresh = reg.snapshot(id).unwrap();
        assert_eq!(fresh.version(), 2);
        assert_eq!(fresh.params(), &new_theta[..]);

        // bad swaps are refused cleanly
        assert!(reg.hot_swap("absent", &new_theta).is_err(), "unknown name");
        assert!(reg.hot_swap("mlp", &new_theta[1..]).is_err(), "wrong width");
    }

    #[test]
    fn total_f_evals_is_monotone_across_swaps() {
        use crate::solvers::dynamics::MlpDynamics;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(3);
        let mut reg = ModelRegistry::new();
        reg.register("mlp", Box::new(MlpDynamics::new(2, 3, &mut rng)));
        let id = reg.resolve("mlp").unwrap();
        let v1 = reg.snapshot(id).unwrap();
        let _ = v1.f(0.0, &[1.0, -1.0]);
        let _ = v1.f(0.0, &[1.0, -1.0]);
        assert_eq!(reg.total_f_evals(), 2);

        let theta = v1.params().to_vec();
        reg.hot_swap("mlp", &theta).unwrap();
        // the retired version is still referenced; its counters still count
        assert_eq!(reg.total_f_evals(), 2);
        let _ = v1.f(0.0, &[1.0, -1.0]);
        assert_eq!(reg.total_f_evals(), 3);
        drop(v1);
        // dropping the last reference folds the retired counters in
        reg.hot_swap("mlp", &theta).unwrap();
        let fresh = reg.snapshot(id).unwrap();
        let _ = fresh.f(0.0, &[1.0, -1.0]);
        assert_eq!(reg.total_f_evals(), 4, "counters survive retirement");
    }

    #[test]
    fn resolve_cached_memoizes_per_registry() {
        use crate::solvers::dynamics::LinearToy;
        let mut reg1 = ModelRegistry::new();
        reg1.register("toy", Box::new(LinearToy::new(-0.3, 3)));
        let mut reg2 = ModelRegistry::new();
        reg2.register("other", Box::new(LinearToy::new(-0.3, 3)));
        reg2.register("toy", Box::new(LinearToy::new(-0.3, 3)));
        let class = toy_class(StepMode::Fixed { h: 0.1 }, ObsGrid::none());
        let id1 = reg1.resolve_cached(&class).unwrap();
        assert_eq!(id1, reg1.resolve("toy").unwrap());
        // memo hit returns the same id
        assert_eq!(reg1.resolve_cached(&class).unwrap(), id1);
        // a different registry must not be served the memoized id
        let id2 = reg2.resolve_cached(&class).unwrap();
        assert_eq!(id2, reg2.resolve("toy").unwrap());
        assert_ne!(id1.raw(), id2.raw(), "ids differ across registries here");
        // and the original registry still resolves correctly after
        assert_eq!(reg1.resolve_cached(&class).unwrap(), id1);
    }

    #[test]
    fn wait_timeout_times_out_then_delivers() {
        let slot = Arc::new(ResponseSlot::default());
        let handle = ResponseHandle(slot.clone());
        assert!(handle.wait_timeout(Duration::from_millis(5)).is_none());
        slot.fulfill(Err("boom".into()));
        let got = handle.wait_timeout(Duration::from_secs(5));
        assert!(got.expect("fulfilled").is_err());
        // exactly-once: the slot is drained now
        assert!(handle.try_wait().is_none());
    }

    #[test]
    fn submit_pooled_returns_envelope_on_refusal() {
        use crate::solvers::dynamics::LinearToy;
        let mut reg = ModelRegistry::new();
        reg.register("toy", Box::new(LinearToy::new(-0.3, 3)));
        let server = Server::start(
            Arc::new(reg),
            ServerConfig {
                queue_capacity: 1,
                workers: 0,
                ..ServerConfig::default()
            },
        );
        let class = Arc::new(toy_class(StepMode::Fixed { h: 0.1 }, ObsGrid::none()));
        let p = Pending::new(class.clone(), vec![1.0, 2.0, 3.0]);
        server.submit_pooled(p).expect("admitted");
        let mut p2 = Pending::new(class.clone(), vec![4.0, 5.0, 6.0]);
        p2.req_id = 42;
        match server.submit_pooled(p2) {
            Err((SubmitError::Overloaded { capacity: 1 }, back)) => {
                assert_eq!(back.req_id, 42, "refused envelope comes back intact");
                assert_eq!(back.z0, vec![4.0, 5.0, 6.0]);
            }
            other => panic!("expected Overloaded with envelope, got {other:?}"),
        }
        server.shutdown();
    }

    #[test]
    fn paused_server_sheds_and_fails_pending_on_shutdown() {
        use crate::solvers::dynamics::LinearToy;
        let mut reg = ModelRegistry::new();
        reg.register("toy", Box::new(LinearToy::new(-0.3, 3)));
        let server = Server::start(
            Arc::new(reg),
            ServerConfig {
                queue_capacity: 2,
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                workers: 0,
                shards: 0,
            },
        );
        let class = Arc::new(toy_class(StepMode::Fixed { h: 0.1 }, ObsGrid::none()));
        let h1 = server.submit(&class, &[1.0, 2.0, 3.0]).unwrap();
        let _h2 = server.submit(&class, &[1.0, 2.0, 3.0]).unwrap();
        match server.submit(&class, &[1.0, 2.0, 3.0]) {
            Err(SubmitError::Overloaded { capacity: 2 }) => {}
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert_eq!(server.queue_depth(), 2, "memory bounded at capacity");
        assert_eq!(server.shed_count(), 1);
        // wrong-width, non-finite and unknown-model requests are
        // rejected before queueing
        assert!(matches!(
            server.submit(&class, &[1.0]),
            Err(SubmitError::BadRequest(_))
        ));
        assert!(matches!(
            server.submit(&class, &[1.0, f32::INFINITY, 3.0]),
            Err(SubmitError::BadRequest(_))
        ));
        let bad = Arc::new(
            RequestClass::new("absent", "alf", 3, 0.0, 1.0, StepMode::Fixed { h: 0.1 }, ObsGrid::none())
                .unwrap(),
        );
        assert!(matches!(
            server.submit(&bad, &[1.0, 2.0, 3.0]),
            Err(SubmitError::BadRequest(_))
        ));
        let metrics = server.shutdown();
        assert_eq!(metrics.failed, 2, "queued requests failed loudly");
        assert!(h1.wait().is_err(), "waiter unblocked with shutdown error");
    }
}
