//! Serving telemetry: fixed-footprint latency histograms and per-worker
//! counters, merged at shutdown and emitted as `util::bench`-style JSON.
//!
//! Everything here is allocation-free on the record path (bucket
//! increments into inline arrays, scalar accumulators) so the serve
//! loop's zero-allocation contract extends to its own bookkeeping; the
//! JSON materializes only when [`ServeMetrics::to_json`] is called at
//! report time.

use crate::util::json::Json;
use std::time::Instant;

/// Number of geometric latency buckets: `BUCKET_FLOOR_S · RATIO^i`.
const N_BUCKETS: usize = 96;
/// Lowest bucket boundary: 1 µs.
const BUCKET_FLOOR_S: f64 = 1e-6;
/// Geometric bucket growth; 96 buckets × 1.25 cover 1 µs … ~4700 s.
const RATIO: f64 = 1.25;

/// A fixed-size log-spaced latency histogram (an HDR-histogram-lite):
/// recording is two adds and a compare — no allocation, ~25% relative
/// quantile resolution, exact count/mean/min/max.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: [u64; N_BUCKETS],
    count: u64,
    sum_s: f64,
    min_s: f64,
    max_s: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            counts: [0; N_BUCKETS],
            count: 0,
            sum_s: 0.0,
            min_s: f64::INFINITY,
            max_s: 0.0,
        }
    }

    fn bucket(seconds: f64) -> usize {
        if seconds <= BUCKET_FLOOR_S {
            return 0;
        }
        let i = ((seconds / BUCKET_FLOOR_S).ln() / RATIO.ln()) as usize;
        i.min(N_BUCKETS - 1)
    }

    /// Record one latency sample (seconds).
    pub fn record(&mut self, seconds: f64) {
        let s = seconds.max(0.0);
        self.counts[Self::bucket(s)] += 1;
        self.count += 1;
        self.sum_s += s;
        self.min_s = self.min_s.min(s);
        self.max_s = self.max_s.max(s);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_s / self.count as f64
        }
    }

    /// Approximate quantile `q ∈ [0, 1]`: the geometric midpoint of the
    /// bucket holding the `⌈q·count⌉`-th sample, clamped to the observed
    /// min/max so degenerate histograms stay sane.
    pub fn quantile_s(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                let lo = BUCKET_FLOOR_S * RATIO.powi(i as i32);
                let mid = if i == 0 { lo } else { lo * RATIO.sqrt() };
                return mid.clamp(self.min_s, self.max_s);
            }
        }
        self.max_s
    }

    /// Fold another histogram's samples into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum_s += other.sum_s;
        self.min_s = self.min_s.min(other.min_s);
        self.max_s = self.max_s.max(other.max_s);
    }

    /// `{"p50_ms": …, "p99_ms": …, "mean_ms": …, "max_ms": …, "count": …}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("p50_ms", Json::Num(self.quantile_s(0.50) * 1e3)),
            ("p99_ms", Json::Num(self.quantile_s(0.99) * 1e3)),
            ("mean_ms", Json::Num(self.mean_s() * 1e3)),
            ("max_ms", Json::Num(self.max_s * 1e3)),
            ("count", Json::Num(self.count as f64)),
        ])
    }
}

/// One worker's serving counters + latency breakdown.  Each worker owns
/// its instance (no cross-thread sharing on the hot path); the server
/// merges them at shutdown.
///
/// Latency decomposition per request: `total = queue_wait + service`,
/// where `queue_wait` spans submit → batch formation and `service` spans
/// the batched solve + response scatter (shared by every request in the
/// batch).
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    /// Requests completed (responses delivered).
    pub requests: u64,
    /// Micro-batches executed.
    pub batches: u64,
    /// Sum of executed batch sizes (mean = `batch_occupancy()`).
    pub batch_rows: u64,
    /// Largest queue depth observed at batch formation.
    pub max_queue_depth: usize,
    /// Accepted solver steps across all requests.
    pub steps: u64,
    /// Controller trials across all requests.
    pub trials: u64,
    /// Dynamics `f` evaluations (per-sample units).  Worker-local values
    /// are exact: each batch (and each session step) counts on a
    /// worker-local [`ScopedDynamics`] window, so concurrent workers
    /// sharing one model never bleed into each other's counts.
    /// `Server::shutdown` still overwrites the merged value with the
    /// registry-wide serving-window delta
    /// ([`ModelRegistry::total_f_evals`]) — the two agree, but the
    /// registry delta also covers work outside any worker (paranoia, not
    /// correction).
    ///
    /// [`ScopedDynamics`]: crate::solvers::dynamics::ScopedDynamics
    /// [`ModelRegistry::total_f_evals`]: crate::serve::ModelRegistry::total_f_evals
    pub f_evals: u64,
    /// Session steps served (each is one solo "batch"; also counted in
    /// `requests`/`batches`/`batch_rows`).
    pub session_steps: u64,
    /// Requests failed (integration error surfaced to the caller).
    pub failed: u64,
    /// Submissions shed at the bounded queue.  Workers cannot observe
    /// sheds (the request never reaches them), so worker-local values
    /// stay 0; `Server::shutdown` folds in the queue's counter.
    pub shed: u64,
    /// Time spent queued, per request.
    pub queue_wait: LatencyHistogram,
    /// Batched-solve + scatter time, per request.
    pub service: LatencyHistogram,
    /// End-to-end (submit → response) time, per request.
    pub total: LatencyHistogram,
    /// First/last activity timestamps bracketing the serving window.
    pub started: Option<Instant>,
    pub finished: Option<Instant>,
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics::default()
    }

    /// Mark the serving window edges (idempotent for `started`).
    pub fn note_activity(&mut self, now: Instant) {
        if self.started.is_none() {
            self.started = Some(now);
        }
        self.finished = Some(now);
    }

    /// Mean executed batch size.
    pub fn batch_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_rows as f64 / self.batches as f64
        }
    }

    /// Wall-clock seconds between the first and last served batch.
    pub fn elapsed_s(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) => (b - a).as_secs_f64(),
            _ => 0.0,
        }
    }

    /// Fold another worker's metrics into this one.
    pub fn merge(&mut self, other: &ServeMetrics) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.batch_rows += other.batch_rows;
        self.max_queue_depth = self.max_queue_depth.max(other.max_queue_depth);
        self.steps += other.steps;
        self.trials += other.trials;
        self.f_evals += other.f_evals;
        self.session_steps += other.session_steps;
        self.failed += other.failed;
        self.shed += other.shed;
        self.queue_wait.merge(&other.queue_wait);
        self.service.merge(&other.service);
        self.total.merge(&other.total);
        self.started = match (self.started, other.started) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        self.finished = match (self.finished, other.finished) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, b) => a.or(b),
        };
    }

    /// The serving metrics schema (DESIGN.md §10) as ordered JSON — the
    /// same diffable-report convention as `BENCH_hotpath.json`.
    pub fn to_json(&self) -> Json {
        let el = self.elapsed_s();
        let rate = |n: u64| {
            if el > 0.0 {
                n as f64 / el
            } else {
                0.0
            }
        };
        Json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("batch_occupancy", Json::Num(self.batch_occupancy())),
            ("max_queue_depth", Json::Num(self.max_queue_depth as f64)),
            ("steps", Json::Num(self.steps as f64)),
            ("trials", Json::Num(self.trials as f64)),
            ("f_evals", Json::Num(self.f_evals as f64)),
            ("session_steps", Json::Num(self.session_steps as f64)),
            ("elapsed_s", Json::Num(el)),
            ("requests_per_sec", Json::Num(rate(self.requests))),
            ("steps_per_sec", Json::Num(rate(self.steps))),
            (
                "latency",
                Json::obj(vec![
                    ("queue_wait", self.queue_wait.to_json()),
                    ("service", self.service.to_json()),
                    ("total", self.total.to_json()),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_bracket_samples() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(i as f64 * 1e-4); // 0.1 ms … 100 ms
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_s(0.50);
        let p99 = h.quantile_s(0.99);
        // ~25% bucket resolution: generous envelopes
        assert!((0.03..0.08).contains(&p50), "p50 {p50}");
        assert!((0.07..0.13).contains(&p99), "p99 {p99}");
        assert!(p50 <= p99);
        assert!((h.mean_s() - 0.05005).abs() < 0.01);
    }

    #[test]
    fn histogram_merge_equals_combined() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut all = LatencyHistogram::new();
        for i in 0..100 {
            let s = 1e-5 * (1 + i % 17) as f64;
            if i % 2 == 0 {
                a.record(s);
            } else {
                b.record(s);
            }
            all.record(s);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.quantile_s(0.5), all.quantile_s(0.5));
        assert_eq!(a.quantile_s(0.99), all.quantile_s(0.99));
    }

    #[test]
    fn metrics_merge_and_json() {
        let mut m = ServeMetrics::new();
        let t = Instant::now();
        m.note_activity(t);
        m.requests = 4;
        m.batches = 1;
        m.batch_rows = 4;
        m.steps = 40;
        m.total.record(0.001);
        let mut other = ServeMetrics::new();
        other.requests = 2;
        other.batches = 2;
        other.batch_rows = 2;
        other.max_queue_depth = 7;
        other.note_activity(t + std::time::Duration::from_millis(50));
        m.merge(&other);
        assert_eq!(m.requests, 6);
        assert_eq!(m.batches, 3);
        assert_eq!(m.max_queue_depth, 7);
        assert!(m.elapsed_s() >= 0.05);
        assert_eq!(m.batch_occupancy(), 2.0);
        let j = m.to_json();
        assert_eq!(j.get("requests").as_f64(), Some(6.0));
        assert!(j.get("latency").get("total").get("count").as_f64() == Some(1.0));
        // the schema round-trips through the writer/parser
        let back = Json::parse(&j.dump()).unwrap();
        assert_eq!(back.get("batches").as_f64(), Some(3.0));
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_s(0.5), 0.0);
        assert_eq!(h.mean_s(), 0.0);
        assert_eq!(h.count(), 0);
    }

    /// Power-of-two nanosecond durations probe the bucket-index float
    /// math at awkward points: 2^10 ns sits just above the 1 µs floor,
    /// and the 2^k ladder spans sub-floor (1 ns) through the top clamp.
    /// Whatever bucket the log math picks, the index must be monotone
    /// and in range, and the exact accumulators must stay exact.
    #[test]
    fn bucket_edges_at_power_of_two_nanoseconds() {
        assert_eq!(LatencyHistogram::bucket(1e-6), 0, "exact floor boundary");
        assert_eq!(LatencyHistogram::bucket(1024e-9), 0, "2^10 ns lands in the first bucket");
        assert_eq!(LatencyHistogram::bucket(0.0), 0);
        assert_eq!(LatencyHistogram::bucket(1e9), N_BUCKETS - 1, "clamped at the top");
        let mut h = LatencyHistogram::new();
        let mut prev = 0usize;
        for k in 0..=32u32 {
            let s = (1u64 << k) as f64 * 1e-9;
            let b = LatencyHistogram::bucket(s);
            assert!(b >= prev, "bucket index not monotone at 2^{k} ns");
            assert!(b < N_BUCKETS);
            prev = b;
            h.record(s);
        }
        assert_eq!(h.count(), 33);
        // sum of 2^0 … 2^32 ns is (2^33 − 1) ns
        let mean = ((1u64 << 33) - 1) as f64 * 1e-9 / 33.0;
        assert!((h.mean_s() / mean - 1.0).abs() < 1e-12, "mean {} != {mean}", h.mean_s());
        // quantiles stay inside the observed envelope and are monotone in q
        let (lo, hi) = (1e-9, (1u64 << 32) as f64 * 1e-9);
        let mut last = 0.0;
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
            let v = h.quantile_s(q);
            assert!((lo..=hi).contains(&v), "q={q} -> {v} escapes [min, max]");
            assert!(v >= last, "quantile not monotone at q={q}");
            last = v;
        }
    }

    /// Zero-duration samples (and negative inputs, which clamp to zero)
    /// land in the first bucket and keep every exact accumulator exact;
    /// an all-zero histogram reports 0 at every quantile because the
    /// bucket midpoint is clamped to the observed min/max.
    #[test]
    fn zero_duration_samples_collapse_to_zero() {
        let mut h = LatencyHistogram::new();
        for _ in 0..5 {
            h.record(0.0);
        }
        h.record(-3.0); // clamped, not a negative sum
        assert_eq!(h.count(), 6);
        assert_eq!(h.mean_s(), 0.0);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile_s(q), 0.0, "all-zero histogram at q={q}");
        }
        // one real sample: quantiles stay inside [0, max] and the top
        // quantile clamps to the exact observed max
        h.record(2e-3);
        let p50 = h.quantile_s(0.5);
        assert!((0.0..=2e-3).contains(&p50));
        assert_eq!(h.quantile_s(1.0), 2e-3);
    }

    /// Empty histograms answer 0 for every quantile; a single-sample
    /// histogram pins every quantile to exactly that sample (min = max,
    /// so the bucket-midpoint approximation clamps away entirely).
    #[test]
    fn quantile_on_empty_vs_single_sample() {
        let empty = LatencyHistogram::new();
        for q in [0.0, 0.5, 1.0] {
            assert_eq!(empty.quantile_s(q), 0.0);
        }
        let mut one = LatencyHistogram::new();
        one.record(3.7e-4);
        assert_eq!(one.count(), 1);
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert_eq!(one.quantile_s(q), 3.7e-4, "single sample must pin q={q}");
        }
        assert_eq!(one.mean_s(), 3.7e-4);
    }
}
