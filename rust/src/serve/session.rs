//! Long-lived streaming sessions: warm per-session solver state in the
//! worker pool (DESIGN.md §12).
//!
//! A session pins a model version and a solver instance at open time and
//! carries a [`ResumeState`] — the last accepted `(t, z, v)` plus the
//! step-size controller's memory — between requests.  Each `SESSION_STEP`
//! advances the trajectory **incrementally** to the new irregular event
//! times via
//! [`integrate_obs_resume_ws`](crate::solvers::integrate::integrate_obs_resume_ws),
//! instead of re-solving `[t0, t_now]` per request; `tests/session.rs`
//! pins that the incremental path is bitwise-identical to the one-shot
//! solve over the concatenated grid.
//!
//! Concurrency model:
//!
//! * the table maps `session id → Arc<SessionEntry>`; openers and closers
//!   take the table lock, steppers clone the `Arc` out and never hold it;
//! * a session admits **one step in flight at a time** (`busy` CAS at
//!   submit, cleared by the worker after delivery) — steps of one session
//!   are sequentially dependent by construction, so a second concurrent
//!   step is a protocol error, not a queueing problem;
//! * session steps never coalesce with anything in the batcher
//!   (`Pending::session_id != 0` is a coalescing barrier): two steps of
//!   one session share the class `Arc` and would otherwise be batched
//!   together, breaking the sequential dependency;
//! * closing a session (explicitly, or when its connection dies) removes
//!   it from the table; a worker mid-step keeps its own `Arc` alive until
//!   delivery, after which the warm state drops.  The pinned model
//!   version drops with it, letting
//!   [`ModelRegistry::hot_swap`](super::ModelRegistry::hot_swap) fold the
//!   retired version's counters.

use super::{ModelRegistry, ModelVersion, RequestClass, SubmitError};
use crate::solvers::integrate::{IntStats, ObsGrid, ResumeState, StepMode};
use crate::solvers::workspace::SolverWorkspace;
use crate::solvers::Solver;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Everything one session's worker-side step needs, behind one lock:
/// the resumable integration state, the session's own warm solver +
/// workspace, and the θ snapshot pinned at open.
pub struct SessionCore {
    /// Synthetic request class the session's step envelopes ride (model /
    /// solver / n_z / mode are real; the span is a placeholder — session
    /// steps carry their own event times and never coalesce).
    pub(crate) class: Arc<RequestClass>,
    /// The model version pinned at `SESSION_OPEN`: every step of this
    /// session sees the same θ, whatever `hot_swap` publishes meanwhile —
    /// the one-shot-equivalence guarantee needs a single θ.
    pub(crate) model: Arc<ModelVersion>,
    /// The session's own solver instance (warm, never shared).
    pub(crate) solver: Box<dyn Solver + Send + Sync>,
    /// Carried integration state (see [`ResumeState`]).
    pub(crate) resume: ResumeState,
    /// Warm per-session workspace: after the first step, an incremental
    /// advance allocates nothing (`tests/alloc_serve.rs`).
    pub(crate) ws: SolverWorkspace,
    /// Cumulative integration stats across every step so far.
    pub(crate) stats: IntStats,
    /// Steps served.
    pub(crate) steps: u64,
    /// Set when a step failed mid-advance: the carried state may sit at a
    /// non-barrier point, so every later step is refused.
    pub(crate) poisoned: bool,
}

/// One live session: the lockable core plus the single-step-in-flight
/// admission flag.
pub struct SessionEntry {
    pub(crate) core: Mutex<SessionCore>,
    /// One outstanding step per session: set by CAS at submit, cleared by
    /// the worker after delivery (or by a failed enqueue).
    pub(crate) busy: AtomicBool,
}

impl SessionEntry {
    /// The model version this session pinned at open.
    pub fn pinned_version(&self) -> u64 {
        self.core.lock().expect("session poisoned").model.version()
    }

    /// Current barrier time of the carried trajectory.
    pub fn t(&self) -> f64 {
        self.core.lock().expect("session poisoned").resume.t()
    }
}

/// The shared session table: one per server, shared by every worker and
/// the transport layer.
#[derive(Default)]
pub struct SessionTable {
    slots: Mutex<BTreeMap<u64, Arc<SessionEntry>>>,
    /// Session ids are minted here; `0` is reserved as "no session"
    /// ([`Pending::session_id`](super::Pending::session_id)).
    next_id: AtomicU64,
}

impl SessionTable {
    pub fn new() -> SessionTable {
        SessionTable::default()
    }

    /// Open a session: validate the shape against `registry`, pin the
    /// current model version, build the session's solver, and insert the
    /// warm state.  Returns the new session id (> 0).
    pub fn open(
        &self,
        registry: &ModelRegistry,
        model: &str,
        solver: &str,
        n_z: usize,
        t0: f64,
        mode: StepMode,
        z0: &[f32],
    ) -> Result<u64, SubmitError> {
        if z0.len() != n_z {
            return Err(SubmitError::BadRequest(format!(
                "z0 has {} elements, session expects n_z = {n_z}",
                z0.len()
            )));
        }
        if z0.iter().any(|v| !v.is_finite()) {
            return Err(SubmitError::BadRequest(
                "z0 contains non-finite components".to_string(),
            ));
        }
        if !t0.is_finite() {
            return Err(SubmitError::BadRequest(format!(
                "session t0 = {t0} is not finite"
            )));
        }
        // The synthetic class validates solver name + mode parameters and
        // gives the session's step envelopes a real class to ride through
        // the queue/batcher machinery.  The span is a placeholder: steps
        // carry their own event times.
        let class = RequestClass::new(model, solver, n_z, t0, t0 + 1.0, mode, ObsGrid::none())
            .map_err(|e| SubmitError::BadRequest(e.to_string()))?;
        let Some(snapshot) = registry.resolve(model).and_then(|id| registry.snapshot(id)) else {
            return Err(SubmitError::BadRequest(format!(
                "unknown model '{model}' (registered: {:?})",
                registry.names()
            )));
        };
        if snapshot.dynamics().is_device_batched() {
            return Err(SubmitError::BadRequest(format!(
                "model '{model}' is device-batched and cannot hold per-session host state"
            )));
        }
        if snapshot.dynamics().dim() != n_z {
            return Err(SubmitError::BadRequest(format!(
                "model '{model}' has state width {}, session expects n_z = {n_z}",
                snapshot.dynamics().dim()
            )));
        }
        let solver = crate::solvers::by_name(solver)
            .map_err(|e| SubmitError::BadRequest(e.to_string()))?;
        let core = SessionCore {
            class: Arc::new(class),
            model: snapshot,
            solver,
            resume: ResumeState::new(t0, z0.to_vec()),
            ws: SolverWorkspace::new(),
            stats: IntStats::default(),
            steps: 0,
            poisoned: false,
        };
        let sid = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        self.slots
            .lock()
            .expect("session table poisoned")
            .insert(sid, Arc::new(SessionEntry {
                core: Mutex::new(core),
                busy: AtomicBool::new(false),
            }));
        Ok(sid)
    }

    /// Look up a live session (an `Arc` clone; the table lock is not
    /// held across the step).
    pub fn entry(&self, sid: u64) -> Option<Arc<SessionEntry>> {
        self.slots
            .lock()
            .expect("session table poisoned")
            .get(&sid)
            .cloned()
    }

    /// The synthetic request class of a live session — transports retarget
    /// pooled step envelopes onto it.
    pub fn class_of(&self, sid: u64) -> Option<Arc<RequestClass>> {
        self.entry(sid)
            .map(|e| e.core.lock().expect("session poisoned").class.clone())
    }

    /// Close a session: remove it from the table (its warm state drops
    /// when the last worker reference does).  Returns whether it existed.
    /// Idempotent — double closes and closes of unknown ids are no-ops.
    pub fn close(&self, sid: u64) -> bool {
        self.slots
            .lock()
            .expect("session table poisoned")
            .remove(&sid)
            .is_some()
    }

    /// Live session count.
    pub fn len(&self) -> usize {
        self.slots.lock().expect("session table poisoned").len()
    }

    /// True when no session is live.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}
