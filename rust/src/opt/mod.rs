//! First-order optimizers and LR schedules — Rust owns all training state
//! (parameters never exist on the Python side).
//!
//! The paper's recipes: SGD+momentum with step-decay for image recognition,
//! Adamax with exponential decay for latent-ODE, Adam for FFJORD/CDE.

use crate::tensor::axpy;

/// Optimizer over one flat parameter vector.
pub trait Optimizer {
    fn step(&mut self, params: &mut [f32], grad: &[f32]);
    fn set_lr(&mut self, lr: f64);
    fn lr(&self) -> f64;
    fn name(&self) -> &'static str;
}

/// SGD with classical momentum and optional weight decay.
pub struct Sgd {
    lr: f64,
    pub momentum: f64,
    pub weight_decay: f64,
    velocity: Vec<f32>,
}

impl Sgd {
    pub fn new(lr: f64, momentum: f64, weight_decay: f64, n: usize) -> Self {
        Sgd {
            lr,
            momentum,
            weight_decay,
            velocity: vec![0.0; n],
        }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(params.len(), grad.len());
        let (lr, mu, wd) = (self.lr as f32, self.momentum as f32, self.weight_decay as f32);
        for i in 0..params.len() {
            let g = grad[i] + wd * params[i];
            self.velocity[i] = mu * self.velocity[i] + g;
            params[i] -= lr * self.velocity[i];
        }
    }

    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }

    fn lr(&self) -> f64 {
        self.lr
    }

    fn name(&self) -> &'static str {
        "sgd"
    }
}

/// Adam (Kingma & Ba) with bias correction.
pub struct Adam {
    lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(lr: f64, n: usize) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
        }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(params.len(), grad.len());
        self.t += 1;
        let (b1, b2) = (self.beta1, self.beta2);
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let step = self.lr * bc2.sqrt() / bc1;
        let wd = self.weight_decay as f32;
        for i in 0..params.len() {
            let g = grad[i] + wd * params[i];
            self.m[i] = (b1 as f32) * self.m[i] + (1.0 - b1 as f32) * g;
            self.v[i] = (b2 as f32) * self.v[i] + (1.0 - b2 as f32) * g * g;
            params[i] -= (step as f32) * self.m[i] / (self.v[i].sqrt() + self.eps as f32);
        }
    }

    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }

    fn lr(&self) -> f64 {
        self.lr
    }

    fn name(&self) -> &'static str {
        "adam"
    }
}

/// Adamax (the ∞-norm variant of Adam) — the latent-ODE recipe.
pub struct Adamax {
    lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f32>,
    u: Vec<f32>,
    t: u64,
}

impl Adamax {
    pub fn new(lr: f64, n: usize) -> Self {
        Adamax {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; n],
            u: vec![0.0; n],
            t: 0,
        }
    }
}

impl Optimizer for Adamax {
    fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let step = (self.lr / bc1) as f32;
        let (b1, b2) = (self.beta1 as f32, self.beta2 as f32);
        for i in 0..params.len() {
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * grad[i];
            self.u[i] = (b2 * self.u[i]).max(grad[i].abs());
            params[i] -= step * self.m[i] / (self.u[i] + self.eps as f32);
        }
    }

    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }

    fn lr(&self) -> f64 {
        self.lr
    }

    fn name(&self) -> &'static str {
        "adamax"
    }
}

pub fn by_name(name: &str, lr: f64, n: usize) -> anyhow::Result<Box<dyn Optimizer>> {
    Ok(match name {
        "sgd" => Box::new(Sgd::new(lr, 0.9, 0.0, n)),
        "adam" => Box::new(Adam::new(lr, n)),
        "adamax" => Box::new(Adamax::new(lr, n)),
        other => anyhow::bail!("unknown optimizer '{other}'"),
    })
}

/// Learning-rate schedules.
#[derive(Debug, Clone)]
pub enum Schedule {
    Constant,
    /// Multiply by `factor` at each epoch in `milestones` (the paper's
    /// step-decay at epochs 30/60 with factor 0.1).
    StepDecay { milestones: Vec<usize>, factor: f64 },
    /// Multiply by `gamma` every epoch (latent-ODE's 0.999).
    Exponential { gamma: f64 },
}

impl Schedule {
    pub fn lr_at(&self, base_lr: f64, epoch: usize) -> f64 {
        match self {
            Schedule::Constant => base_lr,
            Schedule::StepDecay { milestones, factor } => {
                let k = milestones.iter().filter(|&&m| epoch >= m).count();
                base_lr * factor.powi(k as i32)
            }
            Schedule::Exponential { gamma } => base_lr * gamma.powi(epoch as i32),
        }
    }
}

/// Global-norm gradient clipping; returns the pre-clip norm.
pub fn clip_grad_norm(grad: &mut [f32], max_norm: f64) -> f64 {
    let norm = crate::tensor::nrm2(grad);
    if norm > max_norm && norm > 0.0 {
        let scale = (max_norm / norm) as f32;
        for g in grad.iter_mut() {
            *g *= scale;
        }
    }
    norm
}

/// Polyak averaging helper (EMA of parameters) used by generative evals.
pub struct Ema {
    pub decay: f64,
    pub shadow: Vec<f32>,
    initialized: bool,
}

impl Ema {
    pub fn new(decay: f64, n: usize) -> Self {
        Ema {
            decay,
            shadow: vec![0.0; n],
            initialized: false,
        }
    }

    pub fn update(&mut self, params: &[f32]) {
        if !self.initialized {
            self.shadow.copy_from_slice(params);
            self.initialized = true;
            return;
        }
        let d = self.decay as f32;
        for (s, &p) in self.shadow.iter_mut().zip(params) {
            *s = d * *s + (1.0 - d) * p;
        }
    }
}

/// Convenience: accumulate `g` into `acc` (gradient accumulation across
/// micro-batches).
pub fn accumulate(acc: &mut [f32], g: &[f32]) {
    axpy(1.0, g, acc);
}

#[cfg(test)]
mod tests {
    use super::*;

    /// All optimizers should descend a convex quadratic f(x) = ||x||².
    #[test]
    fn optimizers_descend_quadratic() {
        for name in ["sgd", "adam", "adamax"] {
            let mut p = vec![1.0f32, -2.0, 3.0];
            let mut opt = by_name(name, 0.05, p.len()).unwrap();
            for _ in 0..300 {
                let g: Vec<f32> = p.iter().map(|&x| 2.0 * x).collect();
                opt.step(&mut p, &g);
            }
            let norm = crate::tensor::nrm2(&p);
            assert!(norm < 0.05, "{name}: ‖p‖ = {norm}");
        }
    }

    #[test]
    fn step_decay_schedule() {
        let s = Schedule::StepDecay {
            milestones: vec![30, 60],
            factor: 0.1,
        };
        assert_eq!(s.lr_at(0.1, 0), 0.1);
        assert!((s.lr_at(0.1, 30) - 0.01).abs() < 1e-12);
        assert!((s.lr_at(0.1, 75) - 0.001).abs() < 1e-12);
    }

    #[test]
    fn exponential_schedule() {
        let s = Schedule::Exponential { gamma: 0.999 };
        let lr = s.lr_at(0.01, 100);
        assert!((lr - 0.01 * 0.999f64.powi(100)).abs() < 1e-12);
    }

    #[test]
    fn clipping_caps_norm() {
        let mut g = vec![3.0f32, 4.0];
        let pre = clip_grad_norm(&mut g, 1.0);
        assert!((pre - 5.0).abs() < 1e-6);
        assert!((crate::tensor::nrm2(&g) - 1.0).abs() < 1e-6);
        // below the cap: untouched
        let mut g2 = vec![0.3f32, 0.4];
        clip_grad_norm(&mut g2, 1.0);
        assert_eq!(g2, vec![0.3, 0.4]);
    }

    #[test]
    fn ema_tracks_params() {
        let mut ema = Ema::new(0.9, 2);
        ema.update(&[1.0, 1.0]);
        assert_eq!(ema.shadow, vec![1.0, 1.0]);
        ema.update(&[0.0, 2.0]);
        assert!((ema.shadow[0] - 0.9).abs() < 1e-6);
        assert!((ema.shadow[1] - 1.1).abs() < 1e-6);
    }
}
