//! Experiment configuration system.
//!
//! Experiments are described by JSON files under `configs/` (serde/toml are
//! not vendored offline).  A [`Config`] is the parsed file plus CLI
//! `key=value` overrides with dotted-path addressing, e.g.
//! `mali run fig5 --set train.lr=0.05 --set solver.rtol=1e-1`.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct Config {
    root: Json,
    /// Name the config was loaded as (for run logs).
    pub name: String,
}

impl Config {
    pub fn from_json(name: &str, root: Json) -> Config {
        Config {
            root,
            name: name.to_string(),
        }
    }

    pub fn load(path: &Path) -> Result<Config> {
        let root = Json::parse_file(path)
            .map_err(|e| anyhow!("config {}: {e}", path.display()))?;
        let name = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("config")
            .to_string();
        Ok(Config { root, name })
    }

    pub fn empty(name: &str) -> Config {
        Config {
            root: Json::Obj(BTreeMap::new()),
            name: name.to_string(),
        }
    }

    /// Apply a dotted-path override, parsing the value as JSON when possible
    /// and falling back to a string.
    pub fn set(&mut self, dotted: &str, raw: &str) -> Result<()> {
        let value = Json::parse(raw).unwrap_or_else(|_| Json::Str(raw.to_string()));
        let parts: Vec<&str> = dotted.split('.').collect();
        if parts.is_empty() || parts.iter().any(|p| p.is_empty()) {
            bail!("bad config path '{dotted}'");
        }
        let mut node = &mut self.root;
        for (i, part) in parts.iter().enumerate() {
            if !matches!(node, Json::Obj(_)) {
                *node = Json::Obj(BTreeMap::new());
            }
            let Json::Obj(map) = node else { unreachable!() };
            if i == parts.len() - 1 {
                map.insert(part.to_string(), value);
                return Ok(());
            }
            node = map
                .entry(part.to_string())
                .or_insert_with(|| Json::Obj(BTreeMap::new()));
        }
        unreachable!()
    }

    fn lookup(&self, dotted: &str) -> &Json {
        let mut node = &self.root;
        for part in dotted.split('.') {
            node = node.get(part);
        }
        node
    }

    pub fn has(&self, dotted: &str) -> bool {
        !self.lookup(dotted).is_null()
    }

    // Typed getters with defaults ------------------------------------------

    pub fn f64(&self, dotted: &str, default: f64) -> f64 {
        self.lookup(dotted).as_f64().unwrap_or(default)
    }

    pub fn usize(&self, dotted: &str, default: usize) -> usize {
        self.lookup(dotted).as_usize().unwrap_or(default)
    }

    pub fn u64(&self, dotted: &str, default: u64) -> u64 {
        self.lookup(dotted)
            .as_f64()
            .map(|v| v as u64)
            .unwrap_or(default)
    }

    pub fn bool(&self, dotted: &str, default: bool) -> bool {
        self.lookup(dotted).as_bool().unwrap_or(default)
    }

    pub fn str(&self, dotted: &str, default: &str) -> String {
        self.lookup(dotted)
            .as_str()
            .unwrap_or(default)
            .to_string()
    }

    /// Required string (errors if missing).
    pub fn str_req(&self, dotted: &str) -> Result<String> {
        self.lookup(dotted)
            .as_str()
            .map(str::to_string)
            .with_context(|| format!("config '{}' missing required key '{dotted}'", self.name))
    }

    pub fn f64_list(&self, dotted: &str, default: &[f64]) -> Vec<f64> {
        match self.lookup(dotted).as_arr() {
            Some(items) => items.iter().filter_map(Json::as_f64).collect(),
            None => default.to_vec(),
        }
    }

    pub fn str_list(&self, dotted: &str, default: &[&str]) -> Vec<String> {
        match self.lookup(dotted).as_arr() {
            Some(items) => items
                .iter()
                .filter_map(Json::as_str)
                .map(str::to_string)
                .collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    pub fn dump(&self) -> String {
        self.root.pretty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Config {
        let root = Json::parse(
            r#"{"train": {"lr": 0.1, "epochs": 30}, "solver": {"name": "alf", "rtol": 0.1},
                "seeds": [1, 2, 3], "methods": ["mali", "aca"]}"#,
        )
        .unwrap();
        Config::from_json("sample", root)
    }

    #[test]
    fn typed_getters() {
        let c = sample();
        assert_eq!(c.f64("train.lr", 0.0), 0.1);
        assert_eq!(c.usize("train.epochs", 0), 30);
        assert_eq!(c.str("solver.name", "x"), "alf");
        assert_eq!(c.f64("missing.key", 7.5), 7.5);
        assert_eq!(c.f64_list("seeds", &[]), vec![1.0, 2.0, 3.0]);
        assert_eq!(c.str_list("methods", &[]), vec!["mali", "aca"]);
        assert!(c.has("solver.rtol"));
        assert!(!c.has("solver.atol"));
    }

    #[test]
    fn overrides_create_paths() {
        let mut c = sample();
        c.set("train.lr", "0.01").unwrap();
        assert_eq!(c.f64("train.lr", 0.0), 0.01);
        c.set("new.nested.flag", "true").unwrap();
        assert!(c.bool("new.nested.flag", false));
        c.set("solver.name", "dopri5").unwrap();
        assert_eq!(c.str("solver.name", ""), "dopri5");
        // non-JSON values become strings
        c.set("run.tag", "hello-world").unwrap();
        assert_eq!(c.str("run.tag", ""), "hello-world");
    }

    #[test]
    fn required_key_errors() {
        let c = sample();
        assert!(c.str_req("solver.name").is_ok());
        assert!(c.str_req("absent").is_err());
    }
}
