//! # mali-ode
//!
//! A production-grade reproduction of **MALI: A memory efficient and reverse
//! accurate integrator for Neural ODEs** (Zhuang et al., ICLR 2021) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L1 (Pallas)** — fused asynchronous-leapfrog (ALF) step / inverse /
//!   dynamics kernels, authored in `python/compile/kernels/` and validated
//!   against pure-`jnp` oracles.
//! * **L2 (JAX)** — per-model compute graphs (ψ, ψ⁻¹, ψ-vjp, augmented
//!   adjoint dynamics, stems/heads, discrete baselines) AOT-lowered once to
//!   HLO text by `make artifacts`.
//! * **L3 (this crate)** — the paper's algorithmic contribution: adaptive
//!   integration (Algo. 1), the four gradient-estimation protocols
//!   (naive / adjoint / ACA / **MALI**, Algo. 4), training, datasets,
//!   physics simulation, benchmarks.  Python never runs at request time.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index
//! mapping every paper table/figure to a bench target.

// `std::simd` is nightly-only; the opt-in `simd` feature gates the explicit
// SIMD chunk bodies in `tensor` (ADR-004).  Default builds stay on stable.
#![cfg_attr(feature = "simd", feature(portable_simd))]

pub mod cli;
pub mod config;
pub mod tensor;
pub mod util;

pub mod dynamics_native;
pub mod runtime;
pub mod solvers;
pub mod grad;
pub mod serve;

pub mod data;
pub mod models;
pub mod opt;
pub mod sim;
pub mod spline;
pub mod train;

pub mod coordinator;
